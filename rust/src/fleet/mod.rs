//! `qft::fleet` — the model-lifecycle layer behind the serving registry.
//!
//! The paper's offline/online split freezes a deployment grid once and
//! serves it forever; this module makes the *frozen* part replaceable
//! while the engine is live, so a re-finetuned or requantized grid can be
//! swapped in without dropping a request.  A registry slot is no longer
//! one `PreparedNet` but a [`Slot`]: an append-only list of frozen
//! [`Version`]s plus one atomic *route word* deciding which version(s)
//! the next micro-batch runs on.
//!
//! Lifecycle of a version (the README carries the same diagram):
//!
//! ```text
//!            install()            promote()/set_ab()
//!  .qftw ──► installed ─────────► serving (primary or A/B arm)
//!                ▲                    │ rollback()/promote(other)
//!                │                    ▼
//!                └──── idle ◄──── draining (in-flight batches only)
//! ```
//!
//! Concurrency model (std-only, no locks on the request path):
//!
//! * **Versions** live in a fixed-capacity slab of `OnceLock<Arc<Version>>`
//!   cells.  `install` reserves an index with a `fetch_add` on the length
//!   and publishes through the `OnceLock` (release), so readers that learn
//!   the index through the route word (acquire) always observe a fully
//!   initialized version — the epoch-pointer idiom over plain std atomics.
//! * **Routing** is one `AtomicU64` packing `(primary idx, secondary idx,
//!   weight)` — see [`Slot::set_ab`].  `promote` / `rollback` are a single
//!   store/swap of that word: atomic, instant, and invisible to workers
//!   mid-batch.  Each worker clones the routed `Arc<Version>` *once per
//!   batch*, so an in-flight batch finishes on the version it started on;
//!   a demoted version is retired (dropped) when its last in-flight
//!   reference drains — [`Slot::in_flight`] watches exactly that refcount.
//! * **A/B splits** pick the secondary arm by deficit-weighted routing
//!   ([`Slot::select`]): arm B serves the next batch iff its request share
//!   would otherwise fall below the configured weight, so arm counts
//!   converge to the weight without randomness (reply bits never depend on
//!   routing — each arm is a frozen net; the fleet tests pin convergence).
//!
//! Per-version observability rides the existing [`crate::obs`] registry:
//! version 1 keeps the slot's wire key (`"arch/backend"`) so single-version
//! serving is unchanged, and every later version gets a distinct
//! `"arch/backend@vN"` label with its own stage histograms — A/B arms are
//! therefore separable in every exposition format for free.
//!
//! [`Fleet`] is the collection the engine holds: one [`Slot`] per wire key,
//! loaded by [`Fleet::load`] (weight resolution order documented there).
//! With [`FleetOptions::shadow_every`] set, every v1 is wrapped in a
//! [`crate::backend::CalibBackend`] so live traffic feeds per-value range
//! capture, and [`Slot::install_requantized`] turns a capture into the next
//! installed version — the `repro requantize` loop.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::backend::{self, BackendKind, CalibBackend, CalibRanges, PreparedNet};
use crate::coordinator::{state, weights_io};
use crate::data::{Dataset, Split};
use crate::nn::{ArchSpec, ParamMap};
use crate::obs::{Counter, StageMetrics};
use crate::quant::deploy::Mode;
use crate::runtime::manifest::Manifest;

/// Versions a slot can hold over its lifetime (the slab is fixed so
/// publication needs no reallocation under readers).
pub const MAX_VERSIONS: usize = 32;

/// Weight basis points: the A/B weight is `0..=10_000` of traffic to the
/// secondary arm.
pub const WEIGHT_SCALE: u32 = 10_000;

/// One frozen deployment grid inside a [`Slot`], plus its lifecycle
/// counters.  Immutable once installed — all mutability lives in the
/// slot's route word.
pub struct Version {
    /// 1-based id within the slot (`fleet load` order).
    pub id: u32,
    /// Obs label: the slot key for v1, `"{slot}@v{id}"` afterwards.
    pub key: String,
    /// Grid this version runs under (arms of an A/B split may differ).
    pub kind: BackendKind,
    pub model: Box<dyn PreparedNet>,
    /// Parameter/trainable map the model was prepared from (kept so the
    /// shadow-calibration and requantize paths can rebuild constants).
    pub params: ParamMap,
    /// Where the weights came from (export / teacher / he-init / retune).
    pub source: String,
    /// Per-version stage histograms, registered under [`Version::key`].
    pub stage: Arc<StageMetrics>,
    /// Requests routed to this version (the A/B convergence measure).
    pub requests: Counter,
    /// Micro-batches executed on this version.
    pub batches: Counter,
    /// Replies this version could not deliver (dropped receivers).
    pub errors: Counter,
}

/// What a version is currently doing, derived — not stored — from the
/// route word and the live refcount.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Routed as the primary arm.
    Primary,
    /// Routed as the secondary arm at `weight_bp` basis points.
    Secondary { weight_bp: u32 },
    /// Not routed, but in-flight batches still hold it.
    Draining,
    /// Not routed, fully drained (installed-but-idle or retired).
    Idle,
}

/// One status row per version (the `fleet` CLI table).
pub struct VersionStatus {
    pub id: u32,
    pub key: String,
    pub kind: BackendKind,
    pub source: String,
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub in_flight: usize,
    pub role: Role,
}

// Route word layout: bits 0..16 primary index, 16..32 secondary index
// (NO_ARM = none), 32..48 weight in basis points to the secondary.
const NO_ARM: u64 = 0xFFFF;

fn pack(primary: usize, secondary: Option<(usize, u32)>) -> u64 {
    let (s, w) = match secondary {
        Some((idx, w_bp)) => (idx as u64, w_bp as u64),
        None => (NO_ARM, 0),
    };
    primary as u64 | (s << 16) | (w << 32)
}

fn unpack(word: u64) -> (usize, Option<(usize, u32)>) {
    let primary = (word & 0xFFFF) as usize;
    let s = (word >> 16) & 0xFFFF;
    if s == NO_ARM {
        (primary, None)
    } else {
        (primary, Some((s as usize, (word >> 32) as u32)))
    }
}

/// A versioned registry slot: every model a wire key has ever loaded, plus
/// the atomic route word deciding what the next batch runs on.  Shared
/// freely across workers and admin threads — all methods take `&self`.
pub struct Slot {
    /// `"arch/backend-key"`, the wire name clients resolve.
    pub key: String,
    /// The arch every version of this slot deploys (new versions are
    /// prepared against it, and payload compatibility is enforced on
    /// install).
    pub arch: ArchSpec,
    versions: Box<[OnceLock<Arc<Version>>]>,
    len: AtomicUsize,
    route: AtomicU64,
    prev_route: AtomicU64,
    /// Route-word changes (promote / set_ab / rollback).
    pub route_changes: Counter,
    /// Shadow-capture accumulator, present when the slot was loaded with
    /// [`FleetOptions::shadow_every`] > 0 (set once, at load).
    calib: OnceLock<Arc<CalibRanges>>,
}

impl Slot {
    /// A slot serving its first version.
    pub fn new(
        key: String,
        arch: ArchSpec,
        kind: BackendKind,
        model: Box<dyn PreparedNet>,
        params: ParamMap,
        source: String,
    ) -> Arc<Slot> {
        let slot = Arc::new(Slot {
            key,
            arch,
            versions: (0..MAX_VERSIONS).map(|_| OnceLock::new()).collect(),
            len: AtomicUsize::new(0),
            route: AtomicU64::new(pack(0, None)),
            prev_route: AtomicU64::new(pack(0, None)),
            route_changes: Counter::new(),
            calib: OnceLock::new(),
        });
        slot.install(kind, model, params, source)
            .expect("an empty slot accepts its first version");
        slot
    }

    fn make_key(&self, id: u32) -> String {
        if id == 1 {
            self.key.clone()
        } else {
            format!("{}@v{id}", self.key)
        }
    }

    /// Install a prepared model as the next version (NOT routed — promote
    /// or A/B it in explicitly).  Returns the new 1-based version id.
    /// Fails if the model's payload contract differs from the slot's, or
    /// the slab is full.
    pub fn install(
        &self,
        kind: BackendKind,
        model: Box<dyn PreparedNet>,
        params: ParamMap,
        source: String,
    ) -> Result<u32> {
        if let Some(first) = self.versions[0].get() {
            // arms must be interchangeable on the wire: same payload, same
            // logit width
            if model.image_len() != first.model.image_len()
                || model.num_classes() != first.model.num_classes()
            {
                bail!(
                    "slot {}: new version has payload {}x{} (expected {}x{})",
                    self.key,
                    model.image_len(),
                    model.num_classes(),
                    first.model.image_len(),
                    first.model.num_classes()
                );
            }
        }
        // reserve an index; the OnceLock publish (release) below is what
        // makes the version visible to routed readers
        let idx = self.len.fetch_add(1, Ordering::AcqRel);
        if idx >= MAX_VERSIONS {
            self.len.fetch_sub(1, Ordering::AcqRel);
            bail!("slot {}: version slab full ({MAX_VERSIONS} versions)", self.key);
        }
        let id = (idx + 1) as u32;
        let key = self.make_key(id);
        let stage = crate::obs::stage_metrics(&key);
        let v = Arc::new(Version {
            id,
            key,
            kind,
            model,
            params,
            source,
            stage,
            requests: Counter::new(),
            batches: Counter::new(),
            errors: Counter::new(),
        });
        self.versions[idx]
            .set(v)
            .unwrap_or_else(|_| unreachable!("index {idx} reserved uniquely"));
        Ok(id)
    }

    /// Number of installed (or installing) versions.
    pub fn version_count(&self) -> usize {
        self.len.load(Ordering::Acquire).min(MAX_VERSIONS)
    }

    /// A version by 1-based id, if installed.
    pub fn version(&self, id: u32) -> Option<Arc<Version>> {
        let idx = (id as usize).checked_sub(1)?;
        if idx >= self.version_count() {
            return None;
        }
        self.versions[idx].get().cloned()
    }

    /// Every installed version, in install order.
    pub fn versions(&self) -> Vec<Arc<Version>> {
        (0..self.version_count())
            .filter_map(|i| self.versions[i].get().cloned())
            .collect()
    }

    fn routed(&self, idx: usize) -> Arc<Version> {
        self.versions[idx]
            .get()
            .expect("route words only ever point at installed versions")
            .clone()
    }

    fn checked(&self, id: u32, what: &str) -> Result<usize> {
        match self.version(id) {
            Some(_) => Ok(id as usize - 1),
            None => bail!(
                "slot {}: cannot {what} version {id} ({} installed)",
                self.key,
                self.version_count()
            ),
        }
    }

    /// Atomically make version `id` the sole serving version.  In-flight
    /// batches finish on whatever they started on; the displaced route is
    /// remembered for [`Slot::rollback`].
    pub fn promote(&self, id: u32) -> Result<()> {
        let idx = self.checked(id, "promote")?;
        let old = self.route.swap(pack(idx, None), Ordering::AcqRel);
        self.prev_route.store(old, Ordering::Release);
        self.route_changes.add(1);
        crate::obs::route_changes().add(1);
        Ok(())
    }

    /// Atomically split traffic: primary `a`, secondary `b` at `weight_bp`
    /// basis points (`0..=10_000`) of requests.
    pub fn set_ab(&self, a: u32, b: u32, weight_bp: u32) -> Result<()> {
        let ai = self.checked(a, "route")?;
        let bi = self.checked(b, "route")?;
        if a == b {
            bail!("slot {}: A/B arms must differ (both v{a})", self.key);
        }
        if weight_bp > WEIGHT_SCALE {
            bail!("slot {}: weight {weight_bp} out of range 0..={WEIGHT_SCALE}", self.key);
        }
        let old = self.route.swap(pack(ai, Some((bi, weight_bp))), Ordering::AcqRel);
        self.prev_route.store(old, Ordering::Release);
        self.route_changes.add(1);
        crate::obs::route_changes().add(1);
        Ok(())
    }

    /// Instantly restore the route displaced by the last promote/set_ab
    /// (swapping again rolls forward — the two words exchange).
    pub fn rollback(&self) {
        let prev = self.prev_route.load(Ordering::Acquire);
        let old = self.route.swap(prev, Ordering::AcqRel);
        self.prev_route.store(old, Ordering::Release);
        self.route_changes.add(1);
        crate::obs::route_changes().add(1);
    }

    /// The current route: primary version plus the optional secondary arm
    /// and its weight.
    pub fn route(&self) -> (Arc<Version>, Option<(Arc<Version>, u32)>) {
        let (pi, sec) = unpack(self.route.load(Ordering::Acquire));
        (self.routed(pi), sec.map(|(si, w)| (self.routed(si), w)))
    }

    /// The primary serving version (what single-version callers execute).
    pub fn primary(&self) -> Arc<Version> {
        self.route().0
    }

    /// Route one micro-batch of `n` requests: returns the version it must
    /// run on and charges `n` to that arm's request counter.  One atomic
    /// load on the single-version fast path; under an A/B split the
    /// secondary serves iff its share would otherwise drop below the
    /// configured weight (deficit-weighted, so arm counts converge to the
    /// weight deterministically).
    pub fn select(&self, n: usize) -> Arc<Version> {
        let (pi, sec) = unpack(self.route.load(Ordering::Acquire));
        let chosen = match sec {
            None => self.routed(pi),
            Some((si, w_bp)) => {
                let a = self.routed(pi);
                let b = self.routed(si);
                let (ra, rb, n64) = (a.requests.get(), b.requests.get(), n as u64);
                if (rb + n64) * WEIGHT_SCALE as u64 <= (ra + rb + n64) * w_bp as u64 {
                    b
                } else {
                    a
                }
            }
        };
        chosen.requests.add(n as u64);
        chosen
    }

    /// Payload contract shared by every version of this slot.
    pub fn image_len(&self) -> usize {
        self.versions[0].get().expect("slots hold >= 1 version").model.image_len()
    }

    /// In-flight references to version `id`: worker-held `Arc` clones, i.e.
    /// batches currently executing on it (approximate — status readers
    /// holding the version count too).  A demoted version is retired when
    /// this drains to zero.
    pub fn in_flight(&self, id: u32) -> usize {
        match self.version(id) {
            // the slab itself holds one reference, `version` a second
            Some(v) => Arc::strong_count(&v).saturating_sub(2),
            None => 0,
        }
    }

    /// One status row per installed version (role derived from the route
    /// word + live refcounts).
    pub fn status(&self) -> Vec<VersionStatus> {
        let (pi, sec) = unpack(self.route.load(Ordering::Acquire));
        self.versions()
            .into_iter()
            .map(|v| {
                let idx = v.id as usize - 1;
                // this scope holds `v` and the `versions()` vec cloned it:
                // subtract slab + this copy
                let in_flight = Arc::strong_count(&v).saturating_sub(2);
                let role = if idx == pi {
                    Role::Primary
                } else if sec.map(|(si, _)| si == idx).unwrap_or(false) {
                    Role::Secondary { weight_bp: sec.unwrap().1 }
                } else if in_flight > 0 {
                    Role::Draining
                } else {
                    Role::Idle
                };
                VersionStatus {
                    id: v.id,
                    key: v.key.clone(),
                    kind: v.kind,
                    source: v.source.clone(),
                    requests: v.requests.get(),
                    batches: v.batches.get(),
                    errors: v.errors.get(),
                    in_flight,
                    role,
                }
            })
            .collect()
    }

    /// Human-readable status table (the `repro fleet` report).
    pub fn status_table(&self) -> String {
        use std::fmt::Write as _;
        let mut o = String::new();
        let _ = writeln!(o, "slot {} ({} versions):", self.key, self.version_count());
        let _ = writeln!(
            o,
            "  {:<4} {:<8} {:<22} {:>10} {:>8} {:>7} {:>9}  source",
            "ver", "backend", "role", "requests", "batches", "errors", "in-flight"
        );
        for s in self.status() {
            let role = match s.role {
                Role::Primary => "primary".to_string(),
                Role::Secondary { weight_bp } => {
                    format!("secondary ({:.1}%)", weight_bp as f64 / 100.0)
                }
                Role::Draining => "draining".to_string(),
                Role::Idle => "idle".to_string(),
            };
            let _ = writeln!(
                o,
                "  v{:<3} {:<8} {:<22} {:>10} {:>8} {:>7} {:>9}  {}",
                s.id,
                s.kind.key(),
                role,
                s.requests,
                s.batches,
                s.errors,
                s.in_flight,
                s.source
            );
        }
        o
    }

    /// Attach the shadow-capture accumulator (load-time, once; later calls
    /// are ignored so the handle serving workers see never changes).
    pub fn attach_calib(&self, ranges: Arc<CalibRanges>) {
        let _ = self.calib.set(ranges);
    }

    /// The shadow-capture accumulator, when the slot serves through a
    /// [`CalibBackend`].
    pub fn calib(&self) -> Option<Arc<CalibRanges>> {
        self.calib.get().cloned()
    }

    /// Rebuild the primary's deployment constants from *observed* activation
    /// absmax (a [`CalibRanges::absmax`] capture) and install the result as
    /// the next version — NOT routed; promote it explicitly.  This is the
    /// requantize loop: the same PTQ init as offline load, fed live ranges.
    pub fn install_requantized(
        &self,
        absmax: &HashMap<usize, Vec<f32>>,
        source: String,
    ) -> Result<u32> {
        let primary = self.primary();
        let Some(mode) = primary.kind.mode() else {
            bail!(
                "slot {}: backend {} has no quantized grid to requantize",
                self.key,
                primary.kind.key()
            );
        };
        if absmax.is_empty() {
            bail!("slot {}: no captured ranges to requantize from", self.key);
        }
        let tm =
            crate::quant::deploy::requantize_trainables(&self.arch, &primary.params, absmax, mode);
        let model = backend::prepare(primary.kind, &self.arch, &tm);
        self.install(primary.kind, model, tm, source)
    }
}

/// Options for [`Fleet::load_with`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetOptions {
    /// When > 0, wrap every slot's first version in a
    /// [`CalibBackend`] mirroring one micro-batch in `shadow_every` into a
    /// shadow FP forward for range capture (0 = no shadow, no overhead).
    pub shadow_every: u32,
}

/// The collection of versioned [`Slot`]s one engine serves — the lifecycle
/// successor of the old frozen registry.  The collection itself is
/// immutable after load (slot ids are wire-stable); all lifecycle
/// mutability (install / promote / A/B / rollback) lives *inside* the
/// slots, so `Arc<Fleet>` is shared freely between workers and admin
/// threads.
#[derive(Default)]
pub struct Fleet {
    slots: Vec<Arc<Slot>>,
    by_key: HashMap<String, usize>,
}

impl Fleet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a slot; returns its id (what requests carry on the wire).
    pub fn insert(&mut self, slot: Arc<Slot>) -> Result<usize> {
        if self.by_key.contains_key(&slot.key) {
            bail!("model {} requested twice", slot.key);
        }
        let id = self.slots.len();
        self.by_key.insert(slot.key.clone(), id);
        self.slots.push(slot);
        Ok(id)
    }

    /// Slot by id, if it exists (the request path's non-panicking lookup).
    pub fn slot(&self, id: usize) -> Option<&Arc<Slot>> {
        self.slots.get(id)
    }

    /// Slot id for a `"arch/backend-key"` wire key.
    pub fn resolve(&self, key: &str) -> Option<usize> {
        self.by_key.get(key).copied()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.slots.iter().map(|s| s.key.as_str())
    }

    /// Status tables for every slot, concatenated.
    pub fn status_table(&self) -> String {
        self.slots.iter().map(|s| s.status_table()).collect()
    }

    /// [`Fleet::load_with`] with default options (no shadow capture).
    pub fn load(dir: &Path, specs: &[(String, BackendKind)]) -> Result<Arc<Fleet>> {
        Self::load_with(dir, specs, FleetOptions::default())
    }

    /// Load `(arch name, backend)` pairs from an artifacts dir into a
    /// shareable fleet, one slot per pair, each serving its v1.  Arch specs
    /// come from the AOT manifest when present; the name `"synthetic"` (or
    /// any name when no manifest exists) falls back to
    /// [`crate::serve::synthetic_arch`] so serving runs artifact-free.
    /// Weight resolution per slot is [`resolve_weights`].
    pub fn load_with(
        dir: &Path,
        specs: &[(String, BackendKind)],
        opts: FleetOptions,
    ) -> Result<Arc<Fleet>> {
        anyhow::ensure!(!specs.is_empty(), "fleet: no models requested");
        let manifest = Manifest::load(dir.join("manifest.json")).ok();
        let mut fleet = Fleet::new();
        for (name, kind) in specs {
            let arch: ArchSpec = match &manifest {
                Some(m) => match m.archs.get(name) {
                    Some(a) => a.clone(),
                    None if name == "synthetic" => crate::serve::synthetic_arch(),
                    None => bail!(
                        "unknown arch {name}; manifest has {:?} (plus the built-in \"synthetic\")",
                        m.archs.keys().collect::<Vec<_>>()
                    ),
                },
                None => {
                    eprintln!(
                        "fleet: no manifest under {dir:?}; using the built-in \
                         synthetic arch for {name:?}"
                    );
                    // keep the wire key the caller asked for, even though the
                    // graph underneath is the synthetic one
                    let mut a = crate::serve::synthetic_arch();
                    a.name = name.clone();
                    a
                }
            };
            let key = format!("{}/{}", arch.name, kind.key());
            let (params, source) = resolve_weights(dir, &arch, *kind)?;
            let mut model = backend::prepare(*kind, &arch, &params);
            let mut calib = None;
            if opts.shadow_every > 0 {
                let (wrapped, ranges) =
                    CalibBackend::wrap(model, &arch, &params, opts.shadow_every);
                model = wrapped;
                calib = Some(ranges);
            }
            eprintln!("fleet: {key} <- {source}");
            let slot = Slot::new(key, arch, *kind, model, params, source);
            if let Some(ranges) = calib {
                slot.attach_calib(ranges);
            }
            fleet.insert(slot)?;
        }
        Ok(Arc::new(fleet))
    }
}

/// Resolve weights for one arch × backend (shared by [`Fleet::load_with`]
/// and [`install_version`]).  Resolution order:
///
/// 1. `{artifacts}/weights/{arch}.{mode}.qftw` — the trainable set exported
///    by `repro qft` (the real deployment artifact; `lw-i8` shares the `lw`
///    export — same DoF, different engine);
/// 2. `{artifacts}/weights/{arch}.qftw` — the cached FP teacher, pushed
///    through the offline PTQ init (naive-max calibration on the synthetic
///    calib split + MMSE weight scales);
/// 3. He-init weights through the same PTQ init — accuracy is meaningless
///    but every serving code path still runs (smoke/bench mode).
///
/// The `fp` backend consumes raw FP parameters, so it resolves the teacher
/// file (2) directly, else he-init, with no PTQ init.
pub fn resolve_weights(
    dir: &Path,
    arch: &ArchSpec,
    kind: BackendKind,
) -> Result<(ParamMap, String)> {
    let teacher = dir.join("weights").join(format!("{}.qftw", arch.name));
    match kind.mode() {
        // quantized grids consume the mode's trainable set
        Some(mode) => {
            let export = dir.join("weights").join(format!("{}.{}.qftw", arch.name, mode.key()));
            if export.is_file() {
                Ok((weights_io::load(&export)?, format!("qft export {export:?}")))
            } else {
                let (params, source) = if teacher.is_file() {
                    (
                        weights_io::load(&teacher)?,
                        format!("fp teacher {teacher:?} + offline PTQ init"),
                    )
                } else {
                    (
                        state::he_init_params(arch, 0),
                        "he-init + offline PTQ init (untrained: smoke/bench only)".to_string(),
                    )
                };
                let ds = Dataset::new(0);
                let batches: Vec<_> = (0..4)
                    .map(|i| ds.batch(Split::Calib, (i * arch.batch) as u64, arch.batch).0)
                    .collect();
                let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
                let winit = match mode {
                    Mode::Lw => state::WeightScaleInit::Uniform,
                    Mode::Dch => state::WeightScaleInit::DoublyChannelwise,
                };
                Ok((state::init_trainables(arch, &params, &absmax, mode, winit, None), source))
            }
        }
        // the fp grid runs raw FP parameters — no PTQ init
        None => {
            if teacher.is_file() {
                Ok((weights_io::load(&teacher)?, format!("fp teacher {teacher:?}")))
            } else {
                Ok((
                    state::he_init_params(arch, 0),
                    "he-init (untrained: smoke/bench only)".to_string(),
                ))
            }
        }
    }
}

/// Resolve weights for `kind` against `slot.arch` and install the prepared
/// result as the slot's next version (the `fleet load` admin verb; also how
/// the CLI installs an A/B arm on another backend).  Returns the new
/// version id — not routed until promoted or A/B'd.
pub fn install_version(slot: &Slot, dir: &Path, kind: BackendKind) -> Result<u32> {
    let (params, source) = resolve_weights(dir, &slot.arch, kind)?;
    let model = backend::prepare(kind, &slot.arch, &params);
    slot.install(kind, model, params, source)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::deploy::Mode;

    fn slot() -> Arc<Slot> {
        let (arch, tm) = crate::serve::synthetic_trainables(Mode::Lw, 7);
        let kind = BackendKind::Int(Mode::Lw);
        let model = crate::backend::prepare(kind, &arch, &tm);
        Slot::new("synthetic/lw".into(), arch, kind, model, tm, "test".into())
    }

    fn install_v2(s: &Slot) -> u32 {
        let kind = BackendKind::Int(Mode::Lw);
        let model = crate::backend::prepare(kind, &s.arch, &s.primary().params);
        s.install(kind, model, s.primary().params.clone(), "test v2".into()).unwrap()
    }

    #[test]
    fn install_does_not_reroute_until_promote() {
        let s = slot();
        assert_eq!(s.primary().id, 1);
        let v2 = install_v2(&s);
        assert_eq!(v2, 2);
        assert_eq!(s.primary().id, 1, "install must not change the route");
        s.promote(v2).unwrap();
        assert_eq!(s.primary().id, 2);
        s.rollback();
        assert_eq!(s.primary().id, 1);
        s.rollback(); // roll forward again: the two words exchange
        assert_eq!(s.primary().id, 2);
    }

    #[test]
    fn version_keys_label_per_version_obs() {
        let s = slot();
        install_v2(&s);
        assert_eq!(s.version(1).unwrap().key, "synthetic/lw");
        assert_eq!(s.version(2).unwrap().key, "synthetic/lw@v2");
    }

    #[test]
    fn bad_route_targets_error() {
        let s = slot();
        assert!(s.promote(2).is_err());
        assert!(s.promote(0).is_err());
        let v2 = install_v2(&s);
        assert!(s.set_ab(1, v2, WEIGHT_SCALE + 1).is_err());
        assert!(s.set_ab(v2, v2, 100).is_err());
        s.set_ab(1, v2, 2_500).unwrap();
        let (a, b) = s.route();
        assert_eq!(a.id, 1);
        assert_eq!(b.unwrap().0.id, 2);
    }

    #[test]
    fn deficit_select_converges_to_weight() {
        let s = slot();
        let v2 = install_v2(&s);
        s.set_ab(1, v2, 2_500).unwrap();
        for _ in 0..400 {
            s.select(1);
        }
        let rb = s.version(2).unwrap().requests.get();
        assert_eq!(rb, 100, "25% of 400 single-request batches");
        // weight 0 / 10000 are the degenerate arms
        s.set_ab(1, v2, 0).unwrap();
        let before = s.version(2).unwrap().requests.get();
        for _ in 0..32 {
            s.select(3);
        }
        assert_eq!(s.version(2).unwrap().requests.get(), before);
    }

    #[test]
    fn incompatible_payloads_are_rejected() {
        let s = slot();
        let mut arch2 = s.arch.clone();
        arch2.input_hw = 8; // different payload contract
        let params = crate::coordinator::state::he_init_params(&arch2, 0);
        let model = crate::backend::prepare(BackendKind::Fp, &arch2, &params);
        let err = s.install(BackendKind::Fp, model, params, "bad".into()).unwrap_err();
        assert!(err.to_string().contains("payload"), "{err}");
    }

    #[test]
    fn draining_role_tracks_refcount() {
        let s = slot();
        let v2 = install_v2(&s);
        let held = s.version(1).unwrap(); // simulate an in-flight batch
        s.promote(v2).unwrap();
        let st = s.status();
        assert_eq!(st[0].role, Role::Draining);
        assert_eq!(st[1].role, Role::Primary);
        drop(held);
        assert_eq!(s.status()[0].role, Role::Idle);
    }

    #[test]
    fn synthetic_fallback_loads_both_modes() {
        let dir = std::env::temp_dir().join("qft_fleet_test_nonexistent");
        let fleet = Fleet::load(
            &dir,
            &[
                ("synthetic".to_string(), BackendKind::Int(Mode::Lw)),
                ("synthetic".to_string(), BackendKind::Int(Mode::Dch)),
            ],
        )
        .unwrap();
        assert_eq!(fleet.len(), 2);
        assert_eq!(fleet.resolve("synthetic/lw"), Some(0));
        assert_eq!(fleet.resolve("synthetic/dch"), Some(1));
        assert_eq!(fleet.slot(0).unwrap().image_len(), 16 * 16 * 3);
        assert!(fleet.slot(0).unwrap().calib().is_none(), "no shadow by default");
        assert!(fleet.slot(2).is_none());
    }

    #[test]
    fn every_backend_kind_loads_artifact_free() {
        let dir = std::env::temp_dir().join("qft_fleet_test_nonexistent");
        let specs: Vec<(String, BackendKind)> =
            BackendKind::ALL.iter().map(|k| ("synthetic".to_string(), *k)).collect();
        let fleet = Fleet::load(&dir, &specs).unwrap();
        assert_eq!(fleet.len(), BackendKind::ALL.len());
        for kind in BackendKind::ALL {
            let id = fleet.resolve(&format!("synthetic/{}", kind.key())).unwrap();
            let slot = fleet.slot(id).unwrap();
            assert_eq!(slot.primary().kind, kind);
            assert_eq!(slot.image_len(), 16 * 16 * 3);
        }
    }

    #[test]
    fn shadowed_load_captures_and_requantizes() {
        let dir = std::env::temp_dir().join("qft_fleet_test_nonexistent");
        let fleet = Fleet::load_with(
            &dir,
            &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
            FleetOptions { shadow_every: 1 },
        )
        .unwrap();
        let slot = fleet.slot(0).unwrap();
        let ranges = slot.calib().expect("shadow_every attaches a recorder");
        // nothing captured yet: requantize must refuse
        assert!(slot.install_requantized(&ranges.absmax(), "premature".into()).is_err());
        // push a batch through v1 so the shadow records
        let x = crate::data::Dataset::new(1).batch(Split::Val, 0, 4).0;
        let v1 = slot.primary();
        let pool = crate::par::Pool::new(1);
        v1.model.forward_batch(&x, &mut crate::backend::Scratch::new(), &pool);
        assert!(!ranges.is_empty());
        let v2 = slot.install_requantized(&ranges.absmax(), "requantized".into()).unwrap();
        assert_eq!(v2, 2);
        assert_eq!(slot.primary().id, 1, "install must not reroute");
        slot.promote(v2).unwrap();
        let p = slot.primary();
        assert_eq!(p.id, 2);
        // the requantized grid serves the same payload contract
        let y = p.model.forward_batch(&x, &mut crate::backend::Scratch::new(), &pool);
        assert_eq!(y.shape, vec![4, slot.arch.num_classes]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn requantize_refuses_grids_without_a_mode() {
        let dir = std::env::temp_dir().join("qft_fleet_test_nonexistent");
        let fleet = Fleet::load(&dir, &[("synthetic".to_string(), BackendKind::Fp)]).unwrap();
        let slot = fleet.slot(0).unwrap();
        let absmax: HashMap<usize, Vec<f32>> = [(0, vec![1.0])].into();
        let err = slot.install_requantized(&absmax, "x".into()).unwrap_err();
        assert!(err.to_string().contains("no quantized grid"), "{err}");
    }

    #[test]
    fn install_version_adds_another_backend_arm() {
        let dir = std::env::temp_dir().join("qft_fleet_test_nonexistent");
        let fleet =
            Fleet::load(&dir, &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))]).unwrap();
        let slot = fleet.slot(0).unwrap();
        let v2 = install_version(slot, &dir, BackendKind::Int8).unwrap();
        assert_eq!(slot.version(v2).unwrap().kind, BackendKind::Int8);
        slot.set_ab(1, v2, 5_000).unwrap();
        let (a, b) = slot.route();
        assert_eq!(a.kind, BackendKind::Int(Mode::Lw));
        assert_eq!(b.unwrap().0.kind, BackendKind::Int8);
    }
}
