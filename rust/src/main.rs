//! `repro` — the QFT leader CLI (spec-table arg parsing via [`qft::cli`];
//! the image's cargo cache has no clap/tokio — see Cargo.toml).
//!
//! All compute flows through AOT-compiled HLO artifacts (run `make
//! artifacts` once); this binary owns process lifecycle, the pipeline, and
//! metrics.  The serving commands (`serve`, `bench-serve`) run the pure-rust
//! integer deployment path and need no PJRT runtime at all.  Examples:
//!
//! ```text
//! repro pretrain --arch resnet_tiny
//! repro qft --arch mobilenet_tiny --mode lw --cle
//! repro table1 --archs resnet_tiny,mobilenet_tiny --fast
//! repro serve --arch resnet_tiny --mode lw --workers 4
//! repro bench-serve --workers 4 --concurrency 16
//! ```

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use qft::backend::BackendKind;
use qft::cli::{self, Args};
use qft::coordinator::{eval, experiments, metrics, pretrain, qft as qft_stage};
use qft::fleet::{install_version, Fleet, FleetOptions, Slot};
use qft::obs::{Exposition, Format};
use qft::quant::deploy::Mode;
use qft::runtime::Runtime;
use qft::serve::{run_closed_loop, Engine, ServeConfig};

// The USAGE text, the flag surface, and the per-command applicability
// rules all live in the qft::cli spec table — this file only wires the
// parsed Args into the command implementations.

/// Execution grid for the serving / backend-eval commands: `--backend` wins
/// when given; the legacy `--mode lw|dch` flag maps to the integer grids
/// ([`BackendKind::Int`]), which is exactly what those commands ran before
/// the backend seam existed.  Giving both is a conflict (no silent
/// precedence).
fn parse_backend(args: &Args) -> Result<BackendKind> {
    match (args.kv.get("backend"), args.kv.get("mode")) {
        (Some(_), Some(_)) => bail!("--backend and --mode are mutually exclusive"),
        (Some(b), None) => BackendKind::from_key(b),
        (None, mode) => {
            Ok(BackendKind::Int(Mode::from_key(mode.map(String::as_str).unwrap_or("lw"))?))
        }
    }
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let mut artifacts = "artifacts".to_string();
    if argv.first().map(|a| a == "--artifacts").unwrap_or(false) {
        artifacts = argv.get(1).cloned().unwrap_or_default();
        argv.drain(0..2);
    }
    let Some(cmd) = argv.first().cloned() else {
        print!("{}", cli::help());
        return Ok(());
    };
    if !cli::COMMANDS.contains(&cmd.as_str()) {
        bail!("unknown command {cmd:?}\n{}", cli::USAGE);
    }
    let rest = &argv[1..];
    let args = Args::parse(rest)?;
    cli::check(&cmd, &args)?;

    // size the process-wide kernel pool before anything touches it (the
    // pool is built lazily on first use and its width is then fixed)
    if let Some(t) = args.kv.get("threads") {
        let t: usize = t.parse()?;
        if !qft::par::configure_global(t) {
            bail!("--threads {t}: the kernel pool already runs at a different width");
        }
    }

    // observability knobs are process-global and must be set before any
    // backend is prepared (prepare registers the per-layer slots)
    qft::obs::set_enabled(!args.flag("no-obs"));
    if let Some(n) = args.kv.get("obs-sample") {
        qft::obs::set_sample_every(n.parse()?);
    }

    match cmd.as_str() {
        // the serving / backend-eval commands run the pure-rust execution
        // backends and must work without PJRT/artifacts
        "serve" => cmd_serve(&artifacts, &args),
        "bench-serve" => cmd_bench_serve(&artifacts, &args),
        "net-bench" => cmd_net_bench(&artifacts, &args),
        "eval" => cmd_eval(&artifacts, &args),
        "stats" => cmd_stats(&args),
        "requantize" => cmd_requantize(&artifacts, &args),
        _ => {
            let rt = Runtime::load(&artifacts)?;
            eprintln!("platform: {}", rt.platform());
            run_pipeline_cmd(&rt, &cmd, &args)
        }
    }
}

/// One atomic `--stats-json` flush: write the snapshot next to the target
/// and rename over it, so a concurrent `repro stats` reader never parses a
/// torn file.
fn write_stats_json(path: &str) -> Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, qft::obs::render_json())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Background `--stats-json` flusher: rewrites the snapshot every ~2s while
/// the engine runs, plus one final flush when stopped, so the file is fresh
/// both for live scraping and after shutdown.
struct StatsFlush {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

fn spawn_stats_flush(path: String) -> StatsFlush {
    let stop = Arc::new(AtomicBool::new(false));
    let thread_stop = stop.clone();
    let handle = std::thread::spawn(move || loop {
        // sleep in 100ms slices so a stop request flushes promptly
        for _ in 0..20 {
            if thread_stop.load(Ordering::Relaxed) {
                break;
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        if let Err(e) = write_stats_json(&path) {
            eprintln!("stats-json: cannot write {path:?}: {e}");
        }
        if thread_stop.load(Ordering::Relaxed) {
            return;
        }
    });
    StatsFlush { stop, handle }
}

impl StatsFlush {
    /// Stop the flusher after one final write (blocks until it lands).
    fn finish(self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.handle.join();
    }
}

/// Graceful-shutdown stats dump shared by serve and bench-serve: stop the
/// periodic flusher (final write included) and print the human table.
fn obs_shutdown_dump(flush: Option<StatsFlush>) {
    if let Some(f) = flush {
        f.finish();
    }
    if qft::obs::enabled() {
        print!("\n{}", qft::obs::snapshot().render(Format::Table));
    }
}

fn serve_cfg(args: &Args) -> Result<ServeConfig> {
    Ok(ServeConfig {
        workers: args.usize("workers", 2)?,
        max_batch: args.usize("max-batch", 8)?,
        max_wait: Duration::from_micros(args.usize("max-wait-us", 200)? as u64),
        queue_cap: args.usize("queue-cap", 256)?,
        adaptive: !args.flag("no-adaptive"),
    })
}

/// Install a bit-identical twin of the slot's primary (same params, same
/// backend, freshly prepared) and atomically promote it — the hot-swap
/// demo/check behind `--swap-after`: replies must not change across it.
fn hot_swap_twin(slot: &Slot) -> Result<u32> {
    let p = slot.primary();
    let model = qft::backend::prepare(p.kind, &slot.arch, &p.params);
    let v = slot.install(p.kind, model, p.params.clone(), format!("hot-swap twin of v{}", p.id))?;
    slot.promote(v)?;
    Ok(v)
}

fn cmd_serve(artifacts: &str, args: &Args) -> Result<()> {
    if !args.kv.contains_key("listen") {
        for k in ["serve-secs", "max-conns"] {
            if args.kv.contains_key(k) {
                bail!("--{k} only applies with --listen");
            }
        }
    }
    let arch = args.get("arch", "synthetic");
    let kind = parse_backend(args)?;
    let requests = args.usize("requests", 512)?;
    let cfg = serve_cfg(args)?;
    let shadow_every = args.usize("shadow-every", 0)? as u32;
    let swap_after = args.usize("swap-after", 0)?;

    eprintln!("serve: kernel dispatch {}", qft::kernel::kernel_dispatch());
    let fleet = Fleet::load_with(
        Path::new(artifacts),
        &[(arch.clone(), kind)],
        FleetOptions { shadow_every },
    )?;
    let slot_id = 0;
    let slot = fleet.slot(slot_id).expect("fleet just loaded slot 0").clone();
    // optional second arm on another backend (e.g. lw vs lw-i8)
    if let Some(bk) = args.kv.get("backend-b") {
        let kind_b = BackendKind::from_key(bk)?;
        let weight_bp = args.usize("ab-bp", 5_000)? as u32;
        let vb = install_version(&slot, Path::new(artifacts), kind_b)?;
        slot.set_ab(1, vb, weight_bp)?;
        eprintln!(
            "serve: A/B split {:.1}% of traffic to {} (v{vb})",
            weight_bp as f64 / 100.0,
            kind_b.key()
        );
    } else if args.kv.contains_key("ab-bp") {
        bail!("--ab-bp requires --backend-b");
    }
    let engine = Engine::start(fleet.clone(), &cfg);
    let flush = args.kv.get("stats-json").cloned().map(spawn_stats_flush);
    if let Some(listen) = args.kv.get("listen") {
        // wire mode: traffic arrives over TCP, not from the smoke client
        for k in ["requests", "swap-after"] {
            if args.kv.contains_key(k) {
                bail!("--{k} drives the in-process smoke client; with --listen traffic \
                       comes over the wire");
            }
        }
        let net_cfg = qft::net::NetConfig {
            addr: listen.clone(),
            max_conns: args.usize("max-conns", 256)?,
            ..Default::default()
        };
        let server = qft::net::NetServer::start(engine, &net_cfg)?;
        let secs = args.usize("serve-secs", 0)?;
        println!(
            "serving {arch}/{} on {} (binary QFN1 + HTTP /infer /healthz /metrics)",
            kind.key(),
            server.local_addr()
        );
        if secs == 0 {
            eprintln!("serve: no --serve-secs given; serving until killed");
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        std::thread::sleep(Duration::from_secs(secs as u64));
        let rep = server.shutdown(Duration::from_secs(5));
        println!("serve {arch}/{}: {}", kind.key(), rep.drain.report);
        if rep.drain.dropped > 0 {
            println!(
                "drain: {} queued requests answered with Shutdown at the deadline",
                rep.drain.dropped
            );
        }
        print!("{}", slot.status_table());
        if let Some(ranges) = slot.calib() {
            print!("{}", ranges.table());
        }
        obs_shutdown_dump(flush);
        return Ok(());
    }
    let client = engine.client();
    let ds = qft::data::Dataset::new(0);
    let mut correct = 0usize;
    for i in 0..requests {
        let (img, label) = ds.sample(qft::data::Split::Val, i as u64);
        let rep = client.infer(slot_id, img)?;
        if rep.top1 == label {
            correct += 1;
        }
        if swap_after != 0 && i + 1 == swap_after {
            let v = hot_swap_twin(&slot)?;
            eprintln!("serve: hot-swapped to v{v} after {} replies", i + 1);
        }
    }
    let report = engine.shutdown();
    println!("serve {arch}/{}: {report}", kind.key());
    println!(
        "top-1 over {requests} served requests: {:.1}%",
        correct as f32 / requests.max(1) as f32 * 100.0
    );
    print!("{}", slot.status_table());
    if let Some(ranges) = slot.calib() {
        print!("{}", ranges.table());
    }
    obs_shutdown_dump(flush);
    Ok(())
}

/// `repro requantize` — close the calibration loop end-to-end: phase 1
/// serves the offline-initialized grid while the shadow backend captures
/// live activation ranges; the deployment constants are then rebuilt from
/// exactly those ranges ([`Slot::install_requantized`]) and hot-swapped in;
/// phase 2 serves the requantized grid.  Accuracy is reported per phase.
fn cmd_requantize(artifacts: &str, args: &Args) -> Result<()> {
    let arch = args.get("arch", "synthetic");
    let kind = parse_backend(args)?;
    anyhow::ensure!(
        kind.mode().is_some(),
        "--backend {} has no quantized grid to requantize (pick lw / dch / lw-i8)",
        kind.key()
    );
    let requests = args.usize("requests", 512)?;
    let shadow_every = args.usize("shadow-every", 4)? as u32;
    anyhow::ensure!(shadow_every > 0, "--shadow-every 0 captures nothing");
    let cfg = serve_cfg(args)?;

    let fleet = Fleet::load_with(
        Path::new(artifacts),
        &[(arch.clone(), kind)],
        FleetOptions { shadow_every },
    )?;
    let slot = fleet.slot(0).expect("fleet just loaded slot 0").clone();
    let ranges = slot.calib().expect("shadow-every > 0 attaches a recorder");
    // pooled mode: no local serving — the ranges come from live replicas
    if let Some(addrs) = args.kv.get("pool") {
        let list: Vec<&str> = addrs.split(',').filter(|a| !a.is_empty()).collect();
        anyhow::ensure!(!list.is_empty(), "--pool needs at least one ADDR");
        let merged = qft::cluster::pull_merged(&list, Duration::from_secs(5))?;
        let Some(delta) = merged.calib.get(&slot.key) else {
            bail!(
                "no replica in {addrs:?} captured ranges for slot {:?} \
                 (serve them with --shadow-every)",
                slot.key
            );
        };
        ranges.merge_ranges(&delta.ranges_map());
        anyhow::ensure!(!ranges.is_empty(), "pooled ranges are empty");
        ranges.shadow_batches.add(delta.shadow_batches.value());
        ranges.shadow_images.add(delta.shadow_images.value());
        let n = merged.replicas().len();
        let v2 = slot.install_requantized(
            &ranges.absmax(),
            format!("requantized from {n} replicas' pooled shadow ranges"),
        )?;
        slot.promote(v2)?;
        println!("requantize {arch}/{}: promoted v{v2} from {n} pooled replicas", kind.key());
        print!("{}", ranges.table());
        print!("{}", slot.status_table());
        return Ok(());
    }
    let engine = Engine::start(fleet.clone(), &cfg);
    let flush = args.kv.get("stats-json").cloned().map(spawn_stats_flush);
    let client = engine.client();
    let ds = qft::data::Dataset::new(0);
    let mut correct = [0usize; 2];
    for phase in 0..2 {
        for i in 0..requests {
            let (img, label) = ds.sample(qft::data::Split::Val, i as u64);
            let rep = client.infer(0, img)?;
            if rep.top1 == label {
                correct[phase] += 1;
            }
        }
        if phase == 0 {
            let v2 = slot.install_requantized(
                &ranges.absmax(),
                format!("requantized from {} shadow batches", ranges.shadow_batches.get()),
            )?;
            slot.promote(v2)?;
            eprintln!("requantize: promoted v{v2} (constants rebuilt from captured ranges)");
        }
    }
    let report = engine.shutdown();
    println!("requantize {arch}/{}: {report}", kind.key());
    let pct = |c: usize| c as f32 / requests.max(1) as f32 * 100.0;
    println!(
        "top-1 over {requests} requests: phase 1 (offline init) {:.1}% | phase 2 (requantized) {:.1}%",
        pct(correct[0]),
        pct(correct[1])
    );
    print!("{}", ranges.table());
    print!("{}", slot.status_table());
    obs_shutdown_dump(flush);
    Ok(())
}

fn cmd_bench_serve(artifacts: &str, args: &Args) -> Result<()> {
    let arch = args.get("arch", "synthetic");
    let kind = parse_backend(args)?;
    let concurrency = args.usize("concurrency", 16)?;
    let requests = args.usize("requests", 2048)?;
    let cfg = serve_cfg(args)?;
    let per_client = requests.div_ceil(concurrency.max(1));

    eprintln!("bench-serve: kernel dispatch {}", qft::kernel::kernel_dispatch());
    let fleet = Fleet::load(Path::new(artifacts), &[(arch.clone(), kind)])?;
    // warm-up pass so first-touch buffer growth doesn't skew the measurement
    let _ = run_closed_loop(&fleet, &cfg, concurrency.max(1), 4, 0);
    // drop the warm-up's obs samples so the flushed stats cover the
    // measured run only
    qft::obs::reset();
    let flush = args.kv.get("stats-json").cloned().map(spawn_stats_flush);
    let report = run_closed_loop(&fleet, &cfg, concurrency.max(1), per_client, 0);
    println!(
        "bench-serve {arch}/{} workers={} max-batch={} concurrency={}:",
        kind.key(),
        cfg.workers,
        cfg.max_batch,
        concurrency
    );
    println!("  {report}");
    for (lo, hi, n) in report.batch_hist.rows() {
        println!("  batch size {lo:>4}..{hi:<4} x{n}");
    }
    for (lo, hi, n) in report.depth_hist.rows() {
        println!("  queue depth {lo:>4}..{hi:<4} x{n}");
    }
    obs_shutdown_dump(flush);
    Ok(())
}

/// `repro net-bench` — self-hosted open-loop wire bench: start a fresh
/// engine + TCP front-end on an ephemeral loopback port, drive it with the
/// [`qft::net::open_loop`] Poisson harness, and print
/// latency-under-load.  The same harness (swept) backs `make bench-net`.
fn cmd_net_bench(artifacts: &str, args: &Args) -> Result<()> {
    let arch = args.get("arch", "synthetic");
    let kind = parse_backend(args)?;
    let cfg = serve_cfg(args)?;
    let connections = args.usize("connections", 4)?;
    let rate = args.f32("rate", 200.0)? as f64;
    let secs = args.usize("secs", 3)?;
    anyhow::ensure!(rate > 0.0, "--rate must be positive");
    anyhow::ensure!(secs > 0, "--secs must be positive");

    eprintln!("net-bench: kernel dispatch {}", qft::kernel::kernel_dispatch());
    let fleet = Fleet::load(Path::new(artifacts), &[(arch.clone(), kind)])?;
    let slot = fleet.slot(0).expect("fleet just loaded slot 0");
    let slot_key = slot.key.clone();
    let image_len = slot.image_len();
    let engine = Engine::start(fleet.clone(), &cfg);
    let net_cfg = qft::net::NetConfig {
        max_conns: args.usize("max-conns", 256)?,
        ..Default::default()
    };
    let server = qft::net::NetServer::start(engine, &net_cfg)?;
    let load_cfg = qft::net::LoadConfig {
        addr: server.local_addr(),
        slot_key: slot_key.clone(),
        image_len,
        connections,
        rate_rps: rate,
        duration: Duration::from_secs(secs as u64),
        seed: 7,
    };
    let report = qft::net::open_loop(&load_cfg)?;
    println!(
        "net-bench {slot_key} workers={} connections={connections} offered={rate:.0}/s:",
        cfg.workers
    );
    println!("{report}");
    let rep = server.shutdown(Duration::from_secs(5));
    println!(
        "drain: {} dropped{}",
        rep.drain.dropped,
        if rep.drain.timed_out { " (deadline hit)" } else { "" }
    );
    obs_shutdown_dump(None);
    Ok(())
}

/// `repro stats` — render a `--stats-json` flush file (any
/// [`qft::obs::render_json`] document) without touching the engine, or —
/// with `--pull ADDR,..` — act as the cluster aggregator: pull a live CRDT
/// stats delta from every listed replica over QFN1 and render the merged
/// view (repeated pulls never double count).
fn cmd_stats(args: &Args) -> Result<()> {
    let fmt = if args.flag("prom") { Format::Prometheus } else { Format::Table };
    if let Some(addrs) = args.kv.get("pull") {
        anyhow::ensure!(
            !args.kv.contains_key("stats-json"),
            "--pull reads live replicas and --stats-json reads a flush file; pick one"
        );
        let list: Vec<&str> = addrs.split(',').filter(|a| !a.is_empty()).collect();
        anyhow::ensure!(!list.is_empty(), "--pull needs at least one ADDR");
        let merged = qft::cluster::pull_merged(&list, Duration::from_secs(5))?;
        print!("{}", merged.render(fmt));
        return Ok(());
    }
    let path = args.get("stats-json", "OBS_stats.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!("cannot read {path:?} (run serve/bench-serve with --stats-json): {e}")
    })?;
    let snap = qft::obs::Snapshot::from_json(&text)?;
    print!("{}", snap.render(fmt));
    Ok(())
}

/// Offline top-1 under any execution backend — the same weight resolution
/// the serve fleet uses and literally the same forward code the serving
/// workers run, so this is the number the server would produce.
fn cmd_eval(artifacts: &str, args: &Args) -> Result<()> {
    let arch = args.get("arch", "synthetic");
    let kind = parse_backend(args)?;
    let images = args.usize("images", 512)?;
    eprintln!("eval: kernel dispatch {}", qft::kernel::kernel_dispatch());
    let fleet = Fleet::load(Path::new(artifacts), &[(arch.clone(), kind)])?;
    let version = fleet.slot(0).expect("fleet just loaded slot 0").primary();
    let batch = 8;
    // whole batches only — report the count actually scored, not the ask
    let scored = eval::eval_image_count(batch, images);
    anyhow::ensure!(scored > 0, "--images {images} evaluates nothing");
    let t0 = std::time::Instant::now();
    let acc = eval::eval_prepared(version.model.as_ref(), batch, images, 0);
    let dt = t0.elapsed();
    println!(
        "eval {}: top-1 {:.1}% over {scored} val images in {:.2}s ({:.0} img/s, pool {})",
        version.key,
        acc * 100.0,
        dt.as_secs_f64(),
        scored as f64 / dt.as_secs_f64().max(1e-9),
        qft::par::global().threads(),
    );
    obs_shutdown_dump(None);
    Ok(())
}

fn run_pipeline_cmd(rt: &Runtime, cmd: &str, args: &Args) -> Result<()> {
    let fast = args.flag("fast");
    match cmd {
        "pretrain" => {
            let arch = args.req("arch")?;
            let steps: usize = args.get("steps", "6000").parse()?;
            let base_lr = args.f32("lr", 1.5e-3)?;
            let cfg = pretrain::PretrainConfig { steps, base_lr, ..Default::default() };
            let span = metrics::Span::start(rt, "pretrain");
            let r = pretrain::pretrain(rt, &arch, &cfg)?;
            let arch_spec = rt.manifest.arch(&arch)?;
            qft::coordinator::weights_io::save(
                rt.dir().join("weights").join(format!("{arch}.qftw")),
                &arch_spec.params,
                &r.params,
            )?;
            let acc = eval::eval_fp(rt, &arch, &r.params, experiments::EVAL_IMAGES, 0)?;
            println!("{}", span.finish());
            println!(
                "{arch}: loss {:.3} -> {:.3}, fp top-1 {:.1}%",
                r.losses.first().unwrap_or(&f32::NAN),
                r.losses.last().unwrap_or(&f32::NAN),
                acc * 100.0
            );
        }
        "eval-fp" => {
            let arch = args.req("arch")?;
            let t = experiments::teacher_ctx(rt, &arch)?;
            println!("{arch}: fp top-1 {:.1}%", t.fp_acc * 100.0);
        }
        "qft" => {
            let arch = args.req("arch")?;
            let mode = Mode::from_key(&args.get("mode", "lw"))?;
            let t = experiments::teacher_ctx(rt, &arch)?;
            let mut cfg = if fast {
                qft_stage::QftConfig::fast(mode)
            } else {
                qft_stage::QftConfig::standard(mode)
            };
            cfg.cle_init = args.flag("cle");
            cfg.train_scales = !args.flag("frozen-scales");
            cfg.base_lr = args.f32("lr", cfg.base_lr)?;
            cfg.ce_mix = args.f32("ce-mix", 0.0)?;
            let span = metrics::Span::start(rt, "qft");
            let r = qft_stage::run_qft(rt, &arch, &t.params, &cfg)?;
            let report = span.finish();
            let acc_init = eval::eval_q(rt, &arch, &r.init, mode, experiments::EVAL_IMAGES, 0)?;
            let acc = eval::eval_q(rt, &arch, &r.trainables, mode, experiments::EVAL_IMAGES, 0)?;
            // export the deployment trainable set for `repro serve`
            let arch_spec = rt.manifest.arch(&arch)?;
            let export = rt
                .dir()
                .join("weights")
                .join(format!("{arch}.{}.qftw", cfg.mode.key()));
            qft::coordinator::weights_io::save(
                &export,
                arch_spec.trainable_specs(cfg.mode.key()),
                &r.trainables,
            )?;
            eprintln!("exported deployment trainables -> {export:?}");
            println!("{report}");
            println!(
                "{arch} [{}]: fp {:.1}% | init {:.1}% (degr {:.1}) | QFT {:.1}% (degr {:.1}) | kd-loss {:.4} -> {:.4}",
                cfg.mode.key(),
                t.fp_acc * 100.0,
                acc_init * 100.0,
                (t.fp_acc - acc_init) * 100.0,
                acc * 100.0,
                (t.fp_acc - acc) * 100.0,
                r.losses.first().unwrap_or(&f32::NAN),
                r.losses.last().unwrap_or(&f32::NAN),
            );
        }
        "table1" => {
            let archs = args.get(
                "archs",
                "resnet_tiny,mobilenet_tiny,regnet_tiny,mnasnet_tiny,resnet_wide,regnet_wide",
            );
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::table1(rt, &names, fast)?;
            experiments::print_rows("Table 1: QFT vs PTQ baselines", &rows);
        }
        "table2" => {
            let archs = args.get("archs", "resnet_tiny,mobilenet_tiny,regnet_tiny");
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::table2(rt, &names)?;
            experiments::print_rows("Table 2: accuracy without QFT", &rows);
        }
        "fig3" => {
            let arch = args.get("arch", "mobilenet_tiny");
            let rows = experiments::fig3(rt, &arch)?;
            println!("\n=== Fig. 3: kernel MMSE error vs granularity ({arch}) ===");
            println!("{:<10} {:>10} {:>12} {:>10}", "layer", "layerwise", "channelwise", "dCh");
            for r in rows {
                println!(
                    "{:<10} {:>10.4} {:>12.4} {:>10.4}",
                    r.layer, r.e_layerwise, r.e_channelwise, r.e_dch
                );
            }
        }
        "fig5" => {
            let arch = args.get("arch", "regnet_tiny");
            let sizes = [64u64, 128, 256, 512, 1024];
            let rows = experiments::fig5(rt, &arch, &sizes, fast)?;
            experiments::print_rows("Fig. 5: dataset size ablation", &rows);
        }
        "fig6" => {
            let arch = args.get("arch", "mobilenet_tiny");
            let mixes = [0.0, 0.1, 0.3, 0.5, 1.0];
            let rows = experiments::fig6(rt, &arch, &mixes, fast)?;
            experiments::print_rows("Fig. 6: CE mixing ablation", &rows);
        }
        "fig7" => {
            let arch = args.get("arch", "regnet_tiny");
            let lrs = [1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2];
            let rows = experiments::fig7(rt, &arch, &lrs, fast)?;
            experiments::print_rows("Fig. 7: base LR sweep", &rows);
        }
        "fig8" => {
            let archs = args.get("archs", "resnet_tiny,mobilenet_tiny");
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::fig8(rt, &names, fast)?;
            experiments::print_rows("Fig. 8: CLE init x trained scales (lw)", &rows);
        }
        "fig9" => {
            let archs = args.get("archs", "resnet_tiny,mobilenet_tiny");
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::fig9(rt, &names, fast)?;
            experiments::print_rows("Fig. 9: dch frozen vs trained L/R scales", &rows);
        }
        "fig12" => {
            let arch = args.get("arch", "regnet_tiny");
            let rows = experiments::fig12(rt, &arch, fast)?;
            println!("\n=== Fig. 12: kernel error by scale optimization ({arch}) ===");
            println!(
                "{:<10} {:>10} {:>8} {:>8} {:>12}",
                "layer", "layerwise", "CLE", "QFT", "channelwise"
            );
            for r in rows {
                println!(
                    "{:<10} {:>10.4} {:>8.4} {:>8.4} {:>12.4}",
                    r.layer, r.e_layerwise, r.e_cle, r.e_qft, r.e_channelwise
                );
            }
        }
        other => bail!("unknown command {other:?}\n{}", cli::USAGE),
    }
    Ok(())
}
