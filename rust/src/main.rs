//! `repro` — the QFT leader CLI (hand-rolled arg parsing; the image's cargo
//! cache has no clap/tokio — see Cargo.toml).
//!
//! All compute flows through AOT-compiled HLO artifacts (run `make
//! artifacts` once); this binary owns process lifecycle, the pipeline, and
//! metrics.  Examples:
//!
//! ```text
//! repro pretrain --arch resnet_tiny
//! repro qft --arch mobilenet_tiny --mode lw --cle
//! repro table1 --archs resnet_tiny,mobilenet_tiny --fast
//! repro fig5 --arch regnet_tiny
//! ```

use std::collections::HashMap;

use anyhow::{bail, Result};

use qft::coordinator::{eval, experiments, metrics, pretrain, qft as qft_stage};
use qft::quant::deploy::Mode;
use qft::runtime::Runtime;

const USAGE: &str = "\
repro — QFT post-training quantization pipeline

USAGE: repro [--artifacts DIR] <command> [options]

COMMANDS:
  pretrain  --arch A [--steps N]          pretrain + cache the FP teacher
  eval-fp   --arch A                      evaluate the cached FP teacher
  qft       --arch A [--mode lw|dch] [--cle] [--frozen-scales]
            [--lr F] [--ce-mix F] [--fast]   run the full QFT pipeline
  table1    [--archs A,B,..] [--fast]     Table 1: QFT vs PTQ baselines
  table2    [--archs A,B,..]              Table 2: accuracy without QFT
  fig3      [--arch A]                    kernel error vs granularity
  fig5      [--arch A] [--fast]           dataset-size ablation
  fig6      [--arch A] [--fast]           CE-mixing ablation
  fig7      [--arch A] [--fast]           base-LR sweep
  fig8      [--archs A,B] [--fast]        CLE-init x trained-scales 2x2
  fig9      [--archs A,B] [--fast]        dch frozen vs trained L/R scales
  fig12     [--arch A] [--fast]           per-layer kernel error lw/CLE/QFT/chw
";

/// flags: `--key value` pairs plus boolean `--flag`s.
struct Args {
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?}\n{USAGE}");
            };
            if bool_flags.contains(&name) {
                flags.push(name.to_string());
                i += 1;
            } else {
                let Some(v) = argv.get(i + 1) else {
                    bail!("--{name} requires a value");
                };
                kv.insert(name.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(Args { kv, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn req(&self, key: &str) -> Result<String> {
        self.kv
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("missing required --{key}"))
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    fn f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.kv.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

fn parse_mode(s: &str) -> Result<Mode> {
    match s {
        "lw" => Ok(Mode::Lw),
        "dch" => Ok(Mode::Dch),
        other => bail!("unknown mode {other} (use lw|dch)"),
    }
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let mut artifacts = "artifacts".to_string();
    if argv.first().map(|a| a == "--artifacts").unwrap_or(false) {
        artifacts = argv.get(1).cloned().unwrap_or_default();
        argv.drain(0..2);
    }
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    let args = Args::parse(rest, &["cle", "frozen-scales", "fast"])?;
    let fast = args.flag("fast");

    let rt = Runtime::load(&artifacts)?;
    eprintln!("platform: {}", rt.platform());

    match cmd.as_str() {
        "pretrain" => {
            let arch = args.req("arch")?;
            let steps: usize = args.get("steps", "6000").parse()?;
            let base_lr = args.f32("lr", 1.5e-3)?;
            let cfg = pretrain::PretrainConfig { steps, base_lr, ..Default::default() };
            let span = metrics::Span::start(&rt, "pretrain");
            let r = pretrain::pretrain(&rt, &arch, &cfg)?;
            let arch_spec = rt.manifest.arch(&arch)?;
            qft::coordinator::weights_io::save(
                rt.dir().join("weights").join(format!("{arch}.qftw")),
                &arch_spec.params,
                &r.params,
            )?;
            let acc = eval::eval_fp(&rt, &arch, &r.params, experiments::EVAL_IMAGES, 0)?;
            println!("{}", span.finish());
            println!(
                "{arch}: loss {:.3} -> {:.3}, fp top-1 {:.1}%",
                r.losses.first().unwrap_or(&f32::NAN),
                r.losses.last().unwrap_or(&f32::NAN),
                acc * 100.0
            );
        }
        "eval-fp" => {
            let arch = args.req("arch")?;
            let t = experiments::teacher_ctx(&rt, &arch)?;
            println!("{arch}: fp top-1 {:.1}%", t.fp_acc * 100.0);
        }
        "qft" => {
            let arch = args.req("arch")?;
            let mode = parse_mode(&args.get("mode", "lw"))?;
            let t = experiments::teacher_ctx(&rt, &arch)?;
            let mut cfg = if fast {
                qft_stage::QftConfig::fast(mode)
            } else {
                qft_stage::QftConfig::standard(mode)
            };
            cfg.cle_init = args.flag("cle");
            cfg.train_scales = !args.flag("frozen-scales");
            cfg.base_lr = args.f32("lr", cfg.base_lr)?;
            cfg.ce_mix = args.f32("ce-mix", 0.0)?;
            let span = metrics::Span::start(&rt, "qft");
            let r = qft_stage::run_qft(&rt, &arch, &t.params, &cfg)?;
            let report = span.finish();
            let acc_init = eval::eval_q(&rt, &arch, &r.init, mode, experiments::EVAL_IMAGES, 0)?;
            let acc = eval::eval_q(&rt, &arch, &r.trainables, mode, experiments::EVAL_IMAGES, 0)?;
            println!("{report}");
            println!(
                "{arch} [{}]: fp {:.1}% | init {:.1}% (degr {:.1}) | QFT {:.1}% (degr {:.1}) | kd-loss {:.4} -> {:.4}",
                cfg.mode.key(),
                t.fp_acc * 100.0,
                acc_init * 100.0,
                (t.fp_acc - acc_init) * 100.0,
                acc * 100.0,
                (t.fp_acc - acc) * 100.0,
                r.losses.first().unwrap_or(&f32::NAN),
                r.losses.last().unwrap_or(&f32::NAN),
            );
        }
        "table1" => {
            let archs = args.get(
                "archs",
                "resnet_tiny,mobilenet_tiny,regnet_tiny,mnasnet_tiny,resnet_wide,regnet_wide",
            );
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::table1(&rt, &names, fast)?;
            experiments::print_rows("Table 1: QFT vs PTQ baselines", &rows);
        }
        "table2" => {
            let archs = args.get("archs", "resnet_tiny,mobilenet_tiny,regnet_tiny");
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::table2(&rt, &names)?;
            experiments::print_rows("Table 2: accuracy without QFT", &rows);
        }
        "fig3" => {
            let arch = args.get("arch", "mobilenet_tiny");
            let rows = experiments::fig3(&rt, &arch)?;
            println!("\n=== Fig. 3: kernel MMSE error vs granularity ({arch}) ===");
            println!("{:<10} {:>10} {:>12} {:>10}", "layer", "layerwise", "channelwise", "dCh");
            for r in rows {
                println!(
                    "{:<10} {:>10.4} {:>12.4} {:>10.4}",
                    r.layer, r.e_layerwise, r.e_channelwise, r.e_dch
                );
            }
        }
        "fig5" => {
            let arch = args.get("arch", "regnet_tiny");
            let sizes = [64u64, 128, 256, 512, 1024];
            let rows = experiments::fig5(&rt, &arch, &sizes, fast)?;
            experiments::print_rows("Fig. 5: dataset size ablation", &rows);
        }
        "fig6" => {
            let arch = args.get("arch", "mobilenet_tiny");
            let mixes = [0.0, 0.1, 0.3, 0.5, 1.0];
            let rows = experiments::fig6(&rt, &arch, &mixes, fast)?;
            experiments::print_rows("Fig. 6: CE mixing ablation", &rows);
        }
        "fig7" => {
            let arch = args.get("arch", "regnet_tiny");
            let lrs = [1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2];
            let rows = experiments::fig7(&rt, &arch, &lrs, fast)?;
            experiments::print_rows("Fig. 7: base LR sweep", &rows);
        }
        "fig8" => {
            let archs = args.get("archs", "resnet_tiny,mobilenet_tiny");
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::fig8(&rt, &names, fast)?;
            experiments::print_rows("Fig. 8: CLE init x trained scales (lw)", &rows);
        }
        "fig9" => {
            let archs = args.get("archs", "resnet_tiny,mobilenet_tiny");
            let names: Vec<&str> = archs.split(',').collect();
            let rows = experiments::fig9(&rt, &names, fast)?;
            experiments::print_rows("Fig. 9: dch frozen vs trained L/R scales", &rows);
        }
        "fig12" => {
            let arch = args.get("arch", "regnet_tiny");
            let rows = experiments::fig12(&rt, &arch, fast)?;
            println!("\n=== Fig. 12: kernel error by scale optimization ({arch}) ===");
            println!(
                "{:<10} {:>10} {:>8} {:>8} {:>12}",
                "layer", "layerwise", "CLE", "QFT", "channelwise"
            );
            for r in rows {
                println!(
                    "{:<10} {:>10.4} {:>8.4} {:>8.4} {:>12.4}",
                    r.layer, r.e_layerwise, r.e_cle, r.e_qft, r.e_channelwise
                );
            }
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
    Ok(())
}
