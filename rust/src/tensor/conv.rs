//! NHWC 2-D convolution via im2col (SAME padding), with grouped / depthwise
//! support — mirrors `jax.lax.conv_general_dilated(NHWC, HWIO)` as used by L2
//! so the rust deployment simulator reproduces the AOT graphs bit-for-shape.
//!
//! Two entry points over one implementation: [`conv2d`] (allocating, for
//! one-off heuristics) and [`conv2d_into`] (writes into caller-owned buffers
//! via [`ConvScratch`], for the serving / batched-eval hot path).  Both run
//! the same loops in the same order, so results are bit-identical.

use super::{matmul_slices, Tensor};

/// SAME-padding output size for stride s.
fn out_dim(i: usize, s: usize) -> usize {
    i.div_ceil(s)
}

/// Reusable im2col / grouped-conv buffers.  After the first call at a given
/// geometry every buffer is right-sized and later calls allocate nothing.
#[derive(Default)]
pub struct ConvScratch {
    /// im2col patch matrix.
    cols: Vec<f32>,
    /// per-group weight slice (grouped convs only).
    wg: Vec<f32>,
    /// per-group output block (grouped convs only).
    gout: Vec<f32>,
}

impl ConvScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// im2col patch matrix: x[b,h,w,cin] -> [b*oh*ow, k*k*cg] for one group
/// slice along the channel axis (`c0..c0+cg`), written into `cols`.
fn im2col_into(
    x: &Tensor,
    k: usize,
    stride: usize,
    c0: usize,
    cg: usize,
    cols: &mut Vec<f32>,
) -> (usize, usize) {
    let (b, h, w, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (out_dim(h, stride), out_dim(w, stride));
    // SAME padding offsets (matches XLA for odd k)
    let pad_top = ((oh - 1) * stride + k).saturating_sub(h) / 2;
    let pad_left = ((ow - 1) * stride + k).saturating_sub(w) / 2;
    cols.clear();
    cols.resize(b * oh * ow * k * k * cg, 0.0);
    let mut idx = 0;
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad_left as isize;
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let base =
                                ((bi * h + iy as usize) * w + ix as usize) * cin + c0;
                            cols[idx..idx + cg].copy_from_slice(&x.data[base..base + cg]);
                        }
                        idx += cg;
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// NHWC conv, SAME padding.  `w` is HWIO `[k,k,cin/groups,cout]`, `bias` is
/// `[cout]`.  `groups == cin == cout` gives a depthwise conv.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, groups: usize) -> Tensor {
    let mut scratch = ConvScratch::new();
    let mut out = Tensor { shape: vec![0], data: Vec::new() };
    conv2d_into(x, w, bias, stride, groups, &mut scratch, &mut out);
    out
}

/// [`conv2d`] writing into `out` and borrowing all intermediate buffers from
/// `scratch` — zero allocation on the hot path once buffers are warm.
pub fn conv2d_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
) {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (b, cin) = (x.shape[0], x.shape[3]);
    let k = w.shape[0];
    let (wcin, cout) = (w.shape[2], w.shape[3]);
    assert_eq!(wcin, cin / groups, "HWIO in-channels vs groups");
    assert_eq!(cout % groups, 0);
    assert_eq!(bias.len(), cout);
    let cg_in = cin / groups;
    let cg_out = cout / groups;
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));

    if groups == 1 {
        im2col_into(x, k, stride, 0, cin, &mut scratch.cols);
        // weight [k,k,cin,cout] is already [k*k*cin, cout] row-major
        matmul_slices(&scratch.cols, b * oh * ow, k * k * cin, &w.data, cout, &mut out.data);
    } else {
        out.data.clear();
        out.data.resize(b * oh * ow * cout, 0.0);
        for g in 0..groups {
            im2col_into(x, k, stride, g * cg_in, cg_in, &mut scratch.cols);
            // group weight slice: [k,k,cg_in,cout] -> columns [g*cg_out..]
            scratch.wg.clear();
            scratch.wg.resize(k * k * cg_in * cg_out, 0.0);
            for r in 0..k * k * cg_in {
                let src = r * cout + g * cg_out;
                scratch.wg[r * cg_out..(r + 1) * cg_out]
                    .copy_from_slice(&w.data[src..src + cg_out]);
            }
            matmul_slices(
                &scratch.cols,
                b * oh * ow,
                k * k * cg_in,
                &scratch.wg,
                cg_out,
                &mut scratch.gout,
            );
            for (row, chunk) in scratch.gout.chunks(cg_out).enumerate() {
                let dst = row * cout + g * cg_out;
                out.data[dst..dst + cg_out].copy_from_slice(chunk);
            }
        }
    }
    for chunk in out.data.chunks_mut(cout) {
        for (o, &bv) in chunk.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    out.shape = vec![b, oh, ow, cout];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        let x = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        // 1x1 identity kernel [1,1,2,2]
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn bias_is_added() {
        let x = Tensor::zeros(&[1, 2, 2, 1]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 1.0]);
        let y = conv2d(&x, &w, &[1.5, -2.0], 1, 1);
        assert_eq!(y.shape, vec![1, 2, 2, 2]);
        assert_eq!(&y.data[0..2], &[1.5, -2.0]);
    }

    #[test]
    fn stride2_same_padding_shape() {
        let x = Tensor::zeros(&[2, 5, 5, 3]);
        let w = Tensor::zeros(&[3, 3, 3, 4]);
        let y = conv2d(&x, &w, &[0.0; 4], 2, 1);
        assert_eq!(y.shape, vec![2, 3, 3, 4]);
    }

    #[test]
    fn sum_kernel_3x3_interior() {
        // all-ones 3x3 kernel on all-ones input: interior pixels see 9
        let x = Tensor::full(&[1, 4, 4, 1], 1.0);
        let w = Tensor::full(&[3, 3, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 1, 1);
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // interior (1,1): full 3x3 window
        assert_eq!(y.data[(1 * 4 + 1) as usize], 9.0);
        // corner (0,0): 2x2 window under SAME padding
        assert_eq!(y.data[0], 4.0);
    }

    #[test]
    fn depthwise_independent_channels() {
        // 2-channel depthwise 1x1: channel i scaled by (i+1)
        let x = Tensor::new(vec![1, 1, 1, 2], vec![3.0, 5.0]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 2.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 2);
        assert_eq!(y.data, vec![3.0, 10.0]);
    }

    #[test]
    fn grouped_conv_matches_blockdiag() {
        // groups=2 over 4 channels == block-diagonal full conv
        let x = Tensor::new(vec![1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        // grouped weight [1,1,2,4]: group0 maps ch0..2 -> out0..2, group1 -> out2..4
        let wg = Tensor::new(
            vec![1, 1, 2, 4],
            vec![
                1.0, 0.0, 5.0, 0.0, // in0: out0 += 1*in0 (g0), out2 += 5*in2 (g1)
                0.0, 1.0, 0.0, 5.0,
            ],
        );
        let y = conv2d(&x, &wg, &[0.0; 4], 1, 2);
        assert_eq!(y.data, vec![1.0, 2.0, 15.0, 20.0]);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_geometries() {
        // one ConvScratch driven through different shapes must keep matching
        // the allocating path exactly (stale-buffer regression guard)
        let mk = |shape: &[usize], seed: u64| {
            let mut rng = crate::data::Rng::new(seed);
            let n = shape.iter().product::<usize>();
            Tensor::new(shape.to_vec(), (0..n).map(|_| rng.normal()).collect())
        };
        let mut scratch = ConvScratch::new();
        let mut out = Tensor { shape: vec![0], data: Vec::new() };
        let cases: &[(&[usize], &[usize], usize, usize)] = &[
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1),
            (&[1, 5, 5, 4], &[3, 3, 4, 8], 2, 1),
            (&[2, 4, 4, 4], &[3, 3, 1, 4], 1, 4),
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1), // revisit first geometry
        ];
        for (i, (xs, ws, stride, groups)) in cases.iter().enumerate() {
            let x = mk(xs, 10 + i as u64);
            let w = mk(ws, 20 + i as u64);
            let bias: Vec<f32> = (0..ws[3]).map(|j| j as f32 * 0.1).collect();
            conv2d_into(&x, &w, &bias, *stride, *groups, &mut scratch, &mut out);
            let want = conv2d(&x, &w, &bias, *stride, *groups);
            assert_eq!(out.shape, want.shape, "case {i}");
            assert_eq!(out.data, want.data, "case {i}");
        }
    }
}
