//! NHWC 2-D convolution via im2col (SAME padding), with grouped / depthwise
//! support — mirrors `jax.lax.conv_general_dilated(NHWC, HWIO)` as used by L2
//! so the rust deployment simulator reproduces the AOT graphs bit-for-shape.
//!
//! All entry points lower to one im2col + [`crate::kernel::gemm`] pipeline
//! over the panel-packed weight layout [`PackedConvW`]:
//!
//! * [`conv2d`] — allocating, for one-off heuristics; borrows a
//!   thread-local [`ConvScratch`] so even the "one-off" path reuses its
//!   im2col / pack buffers across calls (the nn heuristics hit it in a
//!   loop).
//! * [`conv2d_into`] / [`conv2d_into_par`] — write into caller-owned
//!   buffers via [`ConvScratch`], packing the weight tensor into the
//!   scratch per call (amortized over the `b*oh*ow` GEMM rows).
//! * [`conv2d_packed_into`] / [`conv2d_packed_into_par`] — the serving /
//!   deployment hot path: weights were packed ONCE (per group) at
//!   [`crate::quant::deploy::DeployedModel::prepare`] time and stream
//!   K-major through the register-blocked kernel on every call.
//!
//! The `_par` variants split the `b*oh*ow` output-row dimension into
//! [`crate::kernel::MR`]-aligned chunks across a [`crate::par::Pool`];
//! im2col and the per-group GEMMs run per disjoint row block.  All variants
//! run the same kernel in the same per-element order — including its
//! [`crate::kernel::KC`] reduction blocking, which reloads accumulators
//! from the output between K-blocks and is therefore order-preserving —
//! so results are bit-identical (see the [`crate::kernel`] contract).  The
//! `lw-i8` backend mirrors this row-chunked structure over its own i8
//! im2col (`crate::backend::Int8Backend`).

use super::{size_for_write, Tensor};
use crate::kernel::{self, PackedW};

/// SAME-padding output size for stride s (shared with the i8 deployment
/// backend, which must agree on geometry with the f32 paths exactly).
pub(crate) fn out_dim(i: usize, s: usize) -> usize {
    i.div_ceil(s)
}

/// A conv weight tensor (HWIO `[k, k, cin/groups, cout]`) panel-packed per
/// group: group `g` is columns `g*cg_out .. (g+1)*cg_out` of the row-major
/// `[k*k*cin_g, cout]` matrix, packed into its own [`PackedW`] so the
/// grouped GEMMs need no dense per-group weight copy at all.  Narrow
/// groups (depthwise: `cg_out == 1`) still pad their panel to full width
/// but run the kernel's narrow-lane path, so the padding costs memory, not
/// multiplies.
#[derive(Clone, Debug, Default)]
pub struct PackedConvW {
    k: usize,
    cin_g: usize,
    cout: usize,
    groups: usize,
    packs: Vec<PackedW>,
}

impl PackedConvW {
    /// Pack an HWIO weight tensor for `groups` groups.
    pub fn pack(w: &Tensor, groups: usize) -> Self {
        let mut pw = Self::default();
        pw.pack_into(w, groups);
        pw
    }

    /// (Re)pack, reusing the per-group buffers — the per-call conv paths
    /// drive one of these through every layer of a forward pass.
    pub fn pack_into(&mut self, w: &Tensor, groups: usize) {
        assert_eq!(w.rank(), 4, "HWIO weight must be rank 4");
        assert!(groups >= 1);
        let k = w.shape[0];
        assert_eq!(w.shape[1], k, "square kernels only");
        let (cin_g, cout) = (w.shape[2], w.shape[3]);
        assert_eq!(cout % groups, 0);
        let cg_out = cout / groups;
        self.k = k;
        self.cin_g = cin_g;
        self.cout = cout;
        self.groups = groups;
        self.packs.truncate(groups);
        self.packs.resize_with(groups, PackedW::default);
        let rows = k * k * cin_g;
        for (g, p) in self.packs.iter_mut().enumerate() {
            p.pack_cols(&w.data, rows, cout, g * cg_out, cg_out);
        }
    }

    /// Kernel spatial size.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Total output channels.
    pub fn cout(&self) -> usize {
        self.cout
    }

    /// Group count (`groups == cin == cout` is depthwise).
    pub fn groups(&self) -> usize {
        self.groups
    }

    /// In-channels per group.
    pub fn cin_g(&self) -> usize {
        self.cin_g
    }

    /// Group `g`'s packed weight slice.
    pub fn group(&self, g: usize) -> &PackedW {
        &self.packs[g]
    }
}

/// Reusable im2col / grouped-conv / weight-pack buffers.  After the first
/// call at a given geometry every buffer is right-sized and later calls
/// allocate nothing.
#[derive(Default)]
pub struct ConvScratch {
    /// im2col patch matrix.
    cols: Vec<f32>,
    /// per-group output block (grouped convs only).
    gout: Vec<f32>,
    /// per-call weight packing for the Tensor-weight entry points.
    wpack: PackedConvW,
    /// per-chunk child scratches for [`conv2d_into_par`].
    par: Vec<ConvScratch>,
}

impl ConvScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// im2col patch matrix for a contiguous block of output rows: x[b,h,w,cin]
/// -> [rows.len(), k*k*cg] for one group slice along the channel axis
/// (`c0..c0+cg`), written into `cols`.  `rows` indexes the flattened
/// `(bi, oy, ox)` output-position space, so disjoint row ranges touch
/// disjoint patch rows — the parallel conv path hands each pool chunk its
/// own range and its own `cols` buffer.
///
/// SAME padding follows the XLA/TF rule for every kernel size:
/// `total = (o-1)*stride + k - i`, `pad_before = total / 2` rounded DOWN,
/// so for even `k` (odd total) the extra pad row/column lands on the
/// bottom/right (verified against hand-computed references in the even-k
/// tests below).
fn im2col_rows_into(
    x: &Tensor,
    k: usize,
    stride: usize,
    c0: usize,
    cg: usize,
    rows: std::ops::Range<usize>,
    cols: &mut Vec<f32>,
) {
    im2col_rows_generic(
        &x.data, x.shape[1], x.shape[2], x.shape[3], k, stride, c0, cg, rows, 0.0, cols,
    );
}

/// Element-type-generic im2col core behind [`im2col_rows_into`] and the i8
/// deployment backend's code-tensor im2col: ONE copy of the SAME-padding /
/// patch-index arithmetic, so the f32 and integer grids cannot drift
/// geometrically.  `fill` is the padding value — `0.0` for FP tensors, the
/// negated zero-point for offset i8 codes (so padded positions decode to
/// activation code 0).
#[allow(clippy::too_many_arguments)]
pub(crate) fn im2col_rows_generic<T: Copy>(
    data: &[T],
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    stride: usize,
    c0: usize,
    cg: usize,
    rows: std::ops::Range<usize>,
    fill: T,
    cols: &mut Vec<T>,
) {
    let (oh, ow) = (out_dim(h, stride), out_dim(w, stride));
    let pad_top = ((oh - 1) * stride + k).saturating_sub(h) / 2;
    let pad_left = ((ow - 1) * stride + k).saturating_sub(w) / 2;
    cols.clear();
    cols.resize((rows.end - rows.start) * k * k * cg, fill);
    let mut idx = 0;
    for row in rows {
        let bi = row / (oh * ow);
        let oy = (row / ow) % oh;
        let ox = row % ow;
        for ky in 0..k {
            let iy = (oy * stride + ky) as isize - pad_top as isize;
            for kx in 0..k {
                let ix = (ox * stride + kx) as isize - pad_left as isize;
                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                    let base = ((bi * h + iy as usize) * w + ix as usize) * cin + c0;
                    cols[idx..idx + cg].copy_from_slice(&data[base..base + cg]);
                }
                idx += cg;
            }
        }
    }
}

/// Whole-tensor im2col: every output row of every image in one call.
fn im2col_into(x: &Tensor, k: usize, stride: usize, c0: usize, cg: usize, cols: &mut Vec<f32>) {
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));
    im2col_rows_into(x, k, stride, c0, cg, 0..x.shape[0] * oh * ow, cols);
}

thread_local! {
    /// Per-thread scratch behind the allocating [`conv2d`] wrapper, so the
    /// one-off path stops reallocating im2col buffers per call (the nn
    /// heuristic loops hit it once per layer per image batch).
    static CONV_SCRATCH: std::cell::RefCell<ConvScratch> =
        std::cell::RefCell::new(ConvScratch::new());
}

/// NHWC conv, SAME padding.  `w` is HWIO `[k,k,cin/groups,cout]`, `bias` is
/// `[cout]`.  `groups == cin == cout` gives a depthwise conv.  Allocates
/// only the output tensor; intermediates come from a thread-local
/// [`ConvScratch`] (re-entrant calls fall back to a fresh scratch).
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, groups: usize) -> Tensor {
    conv2d_obs(x, w, bias, stride, groups, None)
}

/// [`conv2d`] with optional per-layer phase timing (`pack` / `im2col` /
/// `gemm` accumulate into `obs` when a sampled pass passes one down).
pub fn conv2d_obs(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    obs: Option<&crate::obs::LayerObs>,
) -> Tensor {
    let mut out = Tensor::default();
    CONV_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut scratch) => {
            conv2d_into_obs(x, w, bias, stride, groups, &mut scratch, &mut out, obs)
        }
        Err(_) => {
            conv2d_into_obs(x, w, bias, stride, groups, &mut ConvScratch::new(), &mut out, obs)
        }
    });
    out
}

/// [`conv2d`] writing into `out` and borrowing all intermediate buffers from
/// `scratch` — zero allocation on the hot path once buffers are warm.  The
/// weight tensor is packed into the scratch's [`PackedConvW`] each call;
/// long-lived weights should be packed once and run through
/// [`conv2d_packed_into`] instead.
pub fn conv2d_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
) {
    conv2d_into_obs(x, w, bias, stride, groups, scratch, out, None);
}

/// [`conv2d_into`] with optional phase timing: the per-call weight packing
/// is attributed to the `pack` phase, the core to `im2col` / `gemm`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into_obs(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    obs: Option<&crate::obs::LayerObs>,
) {
    let mut wp = std::mem::take(&mut scratch.wpack);
    let t0 = crate::obs::layer::start(obs);
    wp.pack_into(w, groups);
    crate::obs::layer::lap(obs, crate::obs::Phase::Pack, t0);
    conv2d_packed_into_obs(x, &wp, bias, stride, scratch, out, obs);
    scratch.wpack = wp;
}

/// The serial conv core over pre-packed weights: im2col per group, one
/// write-mode GEMM per group, scatter (grouped) plus bias.
pub fn conv2d_packed_into(
    x: &Tensor,
    pw: &PackedConvW,
    bias: &[f32],
    stride: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
) {
    conv2d_packed_into_obs(x, pw, bias, stride, scratch, out, None);
}

/// [`conv2d_packed_into`] with optional `im2col` / `gemm` phase timing.
/// The grouped scatter and the bias add stay untimed — they land in the
/// layer's wall-clock total only.
pub fn conv2d_packed_into_obs(
    x: &Tensor,
    pw: &PackedConvW,
    bias: &[f32],
    stride: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    obs: Option<&crate::obs::LayerObs>,
) {
    use crate::obs::{layer, Phase};
    assert_eq!(x.rank(), 4);
    let (b, cin) = (x.shape[0], x.shape[3]);
    let (k, cout, groups) = (pw.k, pw.cout, pw.groups);
    assert_eq!(pw.cin_g * groups, cin, "HWIO in-channels vs groups");
    assert_eq!(bias.len(), cout);
    let cg_in = pw.cin_g;
    let cg_out = cout / groups;
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));
    let rows = b * oh * ow;
    size_for_write(&mut out.data, rows * cout);

    if groups == 1 {
        let t0 = layer::start(obs);
        im2col_into(x, k, stride, 0, cin, &mut scratch.cols);
        let t1 = layer::lap(obs, Phase::Im2col, t0);
        // weight [k,k,cin,cout] is already [k*k*cin, cout] row-major
        kernel::gemm(&scratch.cols, rows, pw.group(0), &mut out.data);
        layer::lap(obs, Phase::Gemm, t1);
    } else {
        for g in 0..groups {
            let t0 = layer::start(obs);
            im2col_into(x, k, stride, g * cg_in, cg_in, &mut scratch.cols);
            let t1 = layer::lap(obs, Phase::Im2col, t0);
            size_for_write(&mut scratch.gout, rows * cg_out);
            kernel::gemm(&scratch.cols, rows, pw.group(g), &mut scratch.gout);
            layer::lap(obs, Phase::Gemm, t1);
            for (row, chunk) in scratch.gout.chunks(cg_out).enumerate() {
                let dst = row * cout + g * cg_out;
                out.data[dst..dst + cg_out].copy_from_slice(chunk);
            }
        }
    }
    for chunk in out.data.chunks_mut(cout) {
        for (o, &bv) in chunk.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    out.shape = vec![b, oh, ow, cout];
}

/// Minimum output rows per parallel conv chunk (`b*oh*ow` granularity).
const MIN_PAR_CONV_ROWS: usize = 64;

/// [`conv2d_into`] with the `b*oh*ow` output-row dimension split across
/// `pool` (weights packed into the scratch first, once, on the submitting
/// thread).  See [`conv2d_packed_into_par`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into_par(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    pool: &crate::par::Pool,
) {
    conv2d_into_par_obs(x, w, bias, stride, groups, scratch, out, pool, None);
}

/// [`conv2d_into_par`] with optional phase timing (packing → `pack`, then
/// the parallel core's per-chunk `im2col` / `gemm` laps — CPU time summed
/// across pool threads, so phase sums can exceed the layer's wall total).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into_par_obs(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    pool: &crate::par::Pool,
    obs: Option<&crate::obs::LayerObs>,
) {
    let mut wp = std::mem::take(&mut scratch.wpack);
    let t0 = crate::obs::layer::start(obs);
    wp.pack_into(w, groups);
    crate::obs::layer::lap(obs, crate::obs::Phase::Pack, t0);
    conv2d_packed_into_par_obs(x, &wp, bias, stride, scratch, out, pool, obs);
    scratch.wpack = wp;
}

/// [`conv2d_packed_into`] with the `b*oh*ow` output-row dimension split
/// into [`crate::kernel::MR`]-aligned chunks across `pool`: each chunk runs
/// im2col and the (per-group) GEMMs for its own disjoint row block into its
/// own child [`ConvScratch`], writing a disjoint slice of `out`; all chunks
/// read the same packed panels.  Per-element accumulation order is
/// identical to the serial path, so results are bit-identical at any thread
/// count.  Falls back to the serial core when the pool is serial or the
/// output is too small to split.
pub fn conv2d_packed_into_par(
    x: &Tensor,
    pw: &PackedConvW,
    bias: &[f32],
    stride: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    pool: &crate::par::Pool,
) {
    conv2d_packed_into_par_obs(x, pw, bias, stride, scratch, out, pool, None);
}

/// [`conv2d_packed_into_par`] with optional phase timing: every chunk laps
/// its own `im2col` / `gemm` into the shared [`crate::obs::LayerObs`]
/// atomics, so the recorded nanoseconds are CPU time summed across pool
/// threads (they can exceed the layer's wall-clock total — that gap IS the
/// parallel speedup).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_packed_into_par_obs(
    x: &Tensor,
    pw: &PackedConvW,
    bias: &[f32],
    stride: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    pool: &crate::par::Pool,
    obs: Option<&crate::obs::LayerObs>,
) {
    use crate::obs::{layer, Phase};
    assert_eq!(x.rank(), 4);
    let (b, cin) = (x.shape[0], x.shape[3]);
    let (k, cout, groups) = (pw.k, pw.cout, pw.groups);
    assert_eq!(pw.cin_g * groups, cin, "HWIO in-channels vs groups");
    assert_eq!(bias.len(), cout);
    let cg_in = pw.cin_g;
    let cg_out = cout / groups;
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));
    let rows = b * oh * ow;
    let ranges =
        crate::par::chunk_ranges_aligned(rows, pool.threads(), MIN_PAR_CONV_ROWS, kernel::MR);
    if pool.threads() <= 1 || ranges.len() <= 1 {
        conv2d_packed_into_obs(x, pw, bias, stride, scratch, out, obs);
        return;
    }
    size_for_write(&mut out.data, rows * cout);
    let nch = ranges.len();
    if scratch.par.len() < nch {
        scratch.par.resize_with(nch, ConvScratch::default);
    }
    let mut tasks: Vec<crate::par::ScopedTask<'_>> = Vec::with_capacity(nch);
    let mut rest: &mut [f32] = &mut out.data;
    for (child, r) in scratch.par.iter_mut().take(nch).zip(ranges) {
        let nrows = r.end - r.start;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(nrows * cout);
        rest = tail;
        tasks.push(Box::new(move || {
            if groups == 1 {
                let t0 = layer::start(obs);
                im2col_rows_into(x, k, stride, 0, cin, r.clone(), &mut child.cols);
                let t1 = layer::lap(obs, Phase::Im2col, t0);
                kernel::gemm(&child.cols, nrows, pw.group(0), head);
                layer::lap(obs, Phase::Gemm, t1);
            } else {
                for g in 0..groups {
                    let t0 = layer::start(obs);
                    im2col_rows_into(x, k, stride, g * cg_in, cg_in, r.clone(), &mut child.cols);
                    let t1 = layer::lap(obs, Phase::Im2col, t0);
                    size_for_write(&mut child.gout, nrows * cg_out);
                    kernel::gemm(&child.cols, nrows, pw.group(g), &mut child.gout);
                    layer::lap(obs, Phase::Gemm, t1);
                    for (row, chunk) in child.gout.chunks(cg_out).enumerate() {
                        let dst = row * cout + g * cg_out;
                        head[dst..dst + cg_out].copy_from_slice(chunk);
                    }
                }
            }
            for chunk in head.chunks_mut(cout) {
                for (o, &bv) in chunk.iter_mut().zip(bias) {
                    *o += bv;
                }
            }
        }));
    }
    pool.scope(tasks);
    out.shape = vec![b, oh, ow, cout];
}

/// Allocating convenience wrapper over [`conv2d_into_par`].
pub fn conv2d_par(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    pool: &crate::par::Pool,
) -> Tensor {
    let mut scratch = ConvScratch::new();
    let mut out = Tensor { shape: vec![0], data: Vec::new() };
    conv2d_into_par(x, w, bias, stride, groups, &mut scratch, &mut out, pool);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        let x = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        // 1x1 identity kernel [1,1,2,2]
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn bias_is_added() {
        let x = Tensor::zeros(&[1, 2, 2, 1]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 1.0]);
        let y = conv2d(&x, &w, &[1.5, -2.0], 1, 1);
        assert_eq!(y.shape, vec![1, 2, 2, 2]);
        assert_eq!(&y.data[0..2], &[1.5, -2.0]);
    }

    #[test]
    fn stride2_same_padding_shape() {
        let x = Tensor::zeros(&[2, 5, 5, 3]);
        let w = Tensor::zeros(&[3, 3, 3, 4]);
        let y = conv2d(&x, &w, &[0.0; 4], 2, 1);
        assert_eq!(y.shape, vec![2, 3, 3, 4]);
    }

    #[test]
    fn sum_kernel_3x3_interior() {
        // all-ones 3x3 kernel on all-ones input: interior pixels see 9
        let x = Tensor::full(&[1, 4, 4, 1], 1.0);
        let w = Tensor::full(&[3, 3, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 1, 1);
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // interior (1,1): full 3x3 window
        assert_eq!(y.data[(1 * 4 + 1) as usize], 9.0);
        // corner (0,0): 2x2 window under SAME padding
        assert_eq!(y.data[0], 4.0);
    }

    #[test]
    fn even_kernel_same_padding_lands_bottom_right() {
        // 2x2 sum kernel on a 2x2 input, SAME: total pad is 1 per axis and
        // the XLA/TF rule puts it entirely on the bottom/right
        // (pad_before = floor(total/2) = 0).  Hand-computed reference:
        //   out(0,0) = 1+2+3+4      (full window)
        //   out(0,1) = 2+4          (right column padded)
        //   out(1,0) = 3+4          (bottom row padded)
        //   out(1,1) = 4
        // A top/left mis-pad would give out(0,0) = 1 instead of 10.
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::full(&[2, 2, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 1, 1);
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![10.0, 6.0, 7.0, 4.0]);
    }

    #[test]
    fn even_kernel_stride2_same_padding_reference() {
        // 5x1 column through a 2x2 sum kernel at stride 2: o = ceil(5/2) = 3,
        // total pad = (3-1)*2 + 2 - 5 = 1, all bottom.  Windows over rows:
        // {0,1}, {2,3}, {4,pad} -> sums 3, 7, 5.
        let x = Tensor::new(vec![1, 5, 1, 1], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let w = Tensor::full(&[2, 2, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 2, 1);
        assert_eq!(y.shape, vec![1, 3, 1, 1]);
        assert_eq!(y.data, vec![3.0, 7.0, 5.0]);
    }

    #[test]
    fn depthwise_independent_channels() {
        // 2-channel depthwise 1x1: channel i scaled by (i+1)
        let x = Tensor::new(vec![1, 1, 1, 2], vec![3.0, 5.0]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 2.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 2);
        assert_eq!(y.data, vec![3.0, 10.0]);
    }

    #[test]
    fn grouped_conv_matches_blockdiag() {
        // groups=2 over 4 channels == block-diagonal full conv
        let x = Tensor::new(vec![1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        // grouped weight [1,1,2,4]: group0 maps ch0..2 -> out0..2, group1 -> out2..4
        let wg = Tensor::new(
            vec![1, 1, 2, 4],
            vec![
                1.0, 0.0, 5.0, 0.0, // in0: out0 += 1*in0 (g0), out2 += 5*in2 (g1)
                0.0, 1.0, 0.0, 5.0,
            ],
        );
        let y = conv2d(&x, &wg, &[0.0; 4], 1, 2);
        assert_eq!(y.data, vec![1.0, 2.0, 15.0, 20.0]);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_geometries() {
        // one ConvScratch driven through different shapes must keep matching
        // the allocating path exactly (stale-buffer regression guard)
        let mk = |shape: &[usize], seed: u64| {
            let mut rng = crate::data::Rng::new(seed);
            let n = shape.iter().product::<usize>();
            Tensor::new(shape.to_vec(), (0..n).map(|_| rng.normal()).collect())
        };
        let mut scratch = ConvScratch::new();
        let mut out = Tensor { shape: vec![0], data: Vec::new() };
        let cases: &[(&[usize], &[usize], usize, usize)] = &[
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1),
            (&[1, 5, 5, 4], &[3, 3, 4, 8], 2, 1),
            (&[2, 4, 4, 4], &[3, 3, 1, 4], 1, 4),
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1), // revisit first geometry
        ];
        for (i, (xs, ws, stride, groups)) in cases.iter().enumerate() {
            let x = mk(xs, 10 + i as u64);
            let w = mk(ws, 20 + i as u64);
            let bias: Vec<f32> = (0..ws[3]).map(|j| j as f32 * 0.1).collect();
            conv2d_into(&x, &w, &bias, *stride, *groups, &mut scratch, &mut out);
            let want = conv2d(&x, &w, &bias, *stride, *groups);
            assert_eq!(out.shape, want.shape, "case {i}");
            assert_eq!(out.data, want.data, "case {i}");
        }
    }

    #[test]
    fn prepacked_path_matches_per_call_packing() {
        let mk = |shape: &[usize], seed: u64| {
            let mut rng = crate::data::Rng::new(seed);
            let n = shape.iter().product::<usize>();
            Tensor::new(shape.to_vec(), (0..n).map(|_| rng.normal()).collect())
        };
        let cases: &[(&[usize], &[usize], usize, usize)] = &[
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1),
            (&[2, 4, 4, 4], &[3, 3, 1, 4], 1, 4),
            (&[1, 5, 5, 6], &[3, 3, 3, 8], 2, 2),
        ];
        for (i, (xs, ws, stride, groups)) in cases.iter().enumerate() {
            let x = mk(xs, 30 + i as u64);
            let w = mk(ws, 40 + i as u64);
            let bias: Vec<f32> = (0..ws[3]).map(|j| j as f32 * 0.05 - 0.1).collect();
            let want = conv2d(&x, &w, &bias, *stride, *groups);
            let pw = PackedConvW::pack(&w, *groups);
            let mut out = Tensor::default();
            conv2d_packed_into(&x, &pw, &bias, *stride, &mut ConvScratch::new(), &mut out);
            assert_eq!(want.shape, out.shape, "case {i}");
            assert_eq!(want.data, out.data, "case {i}");
        }
    }
}
