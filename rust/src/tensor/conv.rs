//! NHWC 2-D convolution via im2col (SAME padding), with grouped / depthwise
//! support — mirrors `jax.lax.conv_general_dilated(NHWC, HWIO)` as used by L2
//! so the rust deployment simulator reproduces the AOT graphs bit-for-shape.
//!
//! Three entry points over one implementation: [`conv2d`] (allocating, for
//! one-off heuristics), [`conv2d_into`] (writes into caller-owned buffers
//! via [`ConvScratch`], for the serving / batched-eval hot path), and
//! [`conv2d_into_par`] (splits the output-row dimension across a
//! [`crate::par::Pool`]; im2col and the per-group GEMMs run per disjoint
//! row block).  All run the same inner loops in the same per-element order,
//! so results are bit-identical.

use super::{matmul_rows, matmul_slices, Tensor};

/// SAME-padding output size for stride s.
fn out_dim(i: usize, s: usize) -> usize {
    i.div_ceil(s)
}

/// Reusable im2col / grouped-conv buffers.  After the first call at a given
/// geometry every buffer is right-sized and later calls allocate nothing.
#[derive(Default)]
pub struct ConvScratch {
    /// im2col patch matrix.
    cols: Vec<f32>,
    /// per-group weight slice(s): one slice (serial path) or all groups
    /// packed back-to-back (parallel path, read-only across chunks).
    wg: Vec<f32>,
    /// per-group output block (grouped convs only).
    gout: Vec<f32>,
    /// per-chunk child scratches for [`conv2d_into_par`].
    par: Vec<ConvScratch>,
}

impl ConvScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// im2col patch matrix for a contiguous block of output rows: x[b,h,w,cin]
/// -> [rows.len(), k*k*cg] for one group slice along the channel axis
/// (`c0..c0+cg`), written into `cols`.  `rows` indexes the flattened
/// `(bi, oy, ox)` output-position space, so disjoint row ranges touch
/// disjoint patch rows — the parallel conv path hands each pool chunk its
/// own range and its own `cols` buffer.
///
/// SAME padding follows the XLA/TF rule for every kernel size:
/// `total = (o-1)*stride + k - i`, `pad_before = total / 2` rounded DOWN,
/// so for even `k` (odd total) the extra pad row/column lands on the
/// bottom/right (verified against hand-computed references in the even-k
/// tests below).
fn im2col_rows_into(
    x: &Tensor,
    k: usize,
    stride: usize,
    c0: usize,
    cg: usize,
    rows: std::ops::Range<usize>,
    cols: &mut Vec<f32>,
) {
    let (h, w, cin) = (x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (out_dim(h, stride), out_dim(w, stride));
    let pad_top = ((oh - 1) * stride + k).saturating_sub(h) / 2;
    let pad_left = ((ow - 1) * stride + k).saturating_sub(w) / 2;
    cols.clear();
    cols.resize((rows.end - rows.start) * k * k * cg, 0.0);
    let mut idx = 0;
    for row in rows {
        let bi = row / (oh * ow);
        let oy = (row / ow) % oh;
        let ox = row % ow;
        for ky in 0..k {
            let iy = (oy * stride + ky) as isize - pad_top as isize;
            for kx in 0..k {
                let ix = (ox * stride + kx) as isize - pad_left as isize;
                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                    let base = ((bi * h + iy as usize) * w + ix as usize) * cin + c0;
                    cols[idx..idx + cg].copy_from_slice(&x.data[base..base + cg]);
                }
                idx += cg;
            }
        }
    }
}

/// Whole-tensor im2col: every output row of every image in one call.
fn im2col_into(x: &Tensor, k: usize, stride: usize, c0: usize, cg: usize, cols: &mut Vec<f32>) {
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));
    im2col_rows_into(x, k, stride, c0, cg, 0..x.shape[0] * oh * ow, cols);
}

/// Copy group `g`'s weight slice (columns `g*cg_out..(g+1)*cg_out` of the
/// row-major `[kk_cg_in, cout]` HWIO matrix) into `dst` as a dense
/// `[kk_cg_in, cg_out]` block.  The serial and parallel grouped paths both
/// call this, so the slicing can never diverge between them.
fn pack_group_weights(
    w: &Tensor,
    g: usize,
    kk_cg_in: usize,
    cg_out: usize,
    cout: usize,
    dst: &mut [f32],
) {
    for r in 0..kk_cg_in {
        let src = r * cout + g * cg_out;
        dst[r * cg_out..(r + 1) * cg_out].copy_from_slice(&w.data[src..src + cg_out]);
    }
}

/// NHWC conv, SAME padding.  `w` is HWIO `[k,k,cin/groups,cout]`, `bias` is
/// `[cout]`.  `groups == cin == cout` gives a depthwise conv.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, groups: usize) -> Tensor {
    let mut scratch = ConvScratch::new();
    let mut out = Tensor { shape: vec![0], data: Vec::new() };
    conv2d_into(x, w, bias, stride, groups, &mut scratch, &mut out);
    out
}

/// [`conv2d`] writing into `out` and borrowing all intermediate buffers from
/// `scratch` — zero allocation on the hot path once buffers are warm.
pub fn conv2d_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
) {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (b, cin) = (x.shape[0], x.shape[3]);
    let k = w.shape[0];
    let (wcin, cout) = (w.shape[2], w.shape[3]);
    assert_eq!(wcin, cin / groups, "HWIO in-channels vs groups");
    assert_eq!(cout % groups, 0);
    assert_eq!(bias.len(), cout);
    let cg_in = cin / groups;
    let cg_out = cout / groups;
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));

    if groups == 1 {
        im2col_into(x, k, stride, 0, cin, &mut scratch.cols);
        // weight [k,k,cin,cout] is already [k*k*cin, cout] row-major
        matmul_slices(&scratch.cols, b * oh * ow, k * k * cin, &w.data, cout, &mut out.data);
    } else {
        out.data.clear();
        out.data.resize(b * oh * ow * cout, 0.0);
        for g in 0..groups {
            im2col_into(x, k, stride, g * cg_in, cg_in, &mut scratch.cols);
            scratch.wg.clear();
            scratch.wg.resize(k * k * cg_in * cg_out, 0.0);
            pack_group_weights(w, g, k * k * cg_in, cg_out, cout, &mut scratch.wg);
            matmul_slices(
                &scratch.cols,
                b * oh * ow,
                k * k * cg_in,
                &scratch.wg,
                cg_out,
                &mut scratch.gout,
            );
            for (row, chunk) in scratch.gout.chunks(cg_out).enumerate() {
                let dst = row * cout + g * cg_out;
                out.data[dst..dst + cg_out].copy_from_slice(chunk);
            }
        }
    }
    for chunk in out.data.chunks_mut(cout) {
        for (o, &bv) in chunk.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    out.shape = vec![b, oh, ow, cout];
}

/// Minimum output rows per parallel conv chunk (`b*oh*ow` granularity).
const MIN_PAR_CONV_ROWS: usize = 64;

/// [`conv2d_into`] with the `b*oh*ow` output-row dimension split across
/// `pool`: each chunk runs im2col and the (per-group) GEMMs for its own
/// disjoint row block into its own child [`ConvScratch`], writing a
/// disjoint slice of `out`.  Per-element accumulation order is identical to
/// the serial path, so results are bit-identical at any thread count.
/// Falls back to [`conv2d_into`] when the pool is serial or the output is
/// too small to split.
pub fn conv2d_into_par(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    scratch: &mut ConvScratch,
    out: &mut Tensor,
    pool: &crate::par::Pool,
) {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (b, cin) = (x.shape[0], x.shape[3]);
    let k = w.shape[0];
    let (wcin, cout) = (w.shape[2], w.shape[3]);
    assert_eq!(wcin, cin / groups, "HWIO in-channels vs groups");
    assert_eq!(cout % groups, 0);
    assert_eq!(bias.len(), cout);
    let cg_in = cin / groups;
    let cg_out = cout / groups;
    let (oh, ow) = (out_dim(x.shape[1], stride), out_dim(x.shape[2], stride));
    let rows = b * oh * ow;
    let ranges = crate::par::chunk_ranges(rows, pool.threads(), MIN_PAR_CONV_ROWS);
    if pool.threads() <= 1 || ranges.len() <= 1 {
        conv2d_into(x, w, bias, stride, groups, scratch, out);
        return;
    }
    out.data.clear();
    out.data.resize(rows * cout, 0.0);
    let nch = ranges.len();
    let ConvScratch { wg, par, .. } = scratch;
    if par.len() < nch {
        par.resize_with(nch, ConvScratch::default);
    }
    // grouped path: pack every group's weight slice once up front; chunks
    // only ever read it
    let wg_len = k * k * cg_in * cg_out;
    if groups > 1 {
        wg.clear();
        wg.resize(groups * wg_len, 0.0);
        for g in 0..groups {
            let dst = &mut wg[g * wg_len..(g + 1) * wg_len];
            pack_group_weights(w, g, k * k * cg_in, cg_out, cout, dst);
        }
    }
    let wg_all: &[f32] = wg;
    let mut tasks: Vec<crate::par::ScopedTask<'_>> = Vec::with_capacity(nch);
    let mut rest: &mut [f32] = &mut out.data;
    for (child, r) in par.iter_mut().take(nch).zip(ranges) {
        let nrows = r.end - r.start;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(nrows * cout);
        rest = tail;
        tasks.push(Box::new(move || {
            if groups == 1 {
                im2col_rows_into(x, k, stride, 0, cin, r.clone(), &mut child.cols);
                matmul_rows(&child.cols, k * k * cin, &w.data, cout, head);
            } else {
                for g in 0..groups {
                    im2col_rows_into(x, k, stride, g * cg_in, cg_in, r.clone(), &mut child.cols);
                    matmul_slices(
                        &child.cols,
                        nrows,
                        k * k * cg_in,
                        &wg_all[g * wg_len..(g + 1) * wg_len],
                        cg_out,
                        &mut child.gout,
                    );
                    for (row, chunk) in child.gout.chunks(cg_out).enumerate() {
                        let dst = row * cout + g * cg_out;
                        head[dst..dst + cg_out].copy_from_slice(chunk);
                    }
                }
            }
            for chunk in head.chunks_mut(cout) {
                for (o, &bv) in chunk.iter_mut().zip(bias) {
                    *o += bv;
                }
            }
        }));
    }
    pool.scope(tasks);
    out.shape = vec![b, oh, ow, cout];
}

/// Allocating convenience wrapper over [`conv2d_into_par`].
pub fn conv2d_par(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    groups: usize,
    pool: &crate::par::Pool,
) -> Tensor {
    let mut scratch = ConvScratch::new();
    let mut out = Tensor { shape: vec![0], data: Vec::new() };
    conv2d_into_par(x, w, bias, stride, groups, &mut scratch, &mut out, pool);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        let x = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        // 1x1 identity kernel [1,1,2,2]
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn bias_is_added() {
        let x = Tensor::zeros(&[1, 2, 2, 1]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 1.0]);
        let y = conv2d(&x, &w, &[1.5, -2.0], 1, 1);
        assert_eq!(y.shape, vec![1, 2, 2, 2]);
        assert_eq!(&y.data[0..2], &[1.5, -2.0]);
    }

    #[test]
    fn stride2_same_padding_shape() {
        let x = Tensor::zeros(&[2, 5, 5, 3]);
        let w = Tensor::zeros(&[3, 3, 3, 4]);
        let y = conv2d(&x, &w, &[0.0; 4], 2, 1);
        assert_eq!(y.shape, vec![2, 3, 3, 4]);
    }

    #[test]
    fn sum_kernel_3x3_interior() {
        // all-ones 3x3 kernel on all-ones input: interior pixels see 9
        let x = Tensor::full(&[1, 4, 4, 1], 1.0);
        let w = Tensor::full(&[3, 3, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 1, 1);
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // interior (1,1): full 3x3 window
        assert_eq!(y.data[(1 * 4 + 1) as usize], 9.0);
        // corner (0,0): 2x2 window under SAME padding
        assert_eq!(y.data[0], 4.0);
    }

    #[test]
    fn even_kernel_same_padding_lands_bottom_right() {
        // 2x2 sum kernel on a 2x2 input, SAME: total pad is 1 per axis and
        // the XLA/TF rule puts it entirely on the bottom/right
        // (pad_before = floor(total/2) = 0).  Hand-computed reference:
        //   out(0,0) = 1+2+3+4      (full window)
        //   out(0,1) = 2+4          (right column padded)
        //   out(1,0) = 3+4          (bottom row padded)
        //   out(1,1) = 4
        // A top/left mis-pad would give out(0,0) = 1 instead of 10.
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::full(&[2, 2, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 1, 1);
        assert_eq!(y.shape, vec![1, 2, 2, 1]);
        assert_eq!(y.data, vec![10.0, 6.0, 7.0, 4.0]);
    }

    #[test]
    fn even_kernel_stride2_same_padding_reference() {
        // 5x1 column through a 2x2 sum kernel at stride 2: o = ceil(5/2) = 3,
        // total pad = (3-1)*2 + 2 - 5 = 1, all bottom.  Windows over rows:
        // {0,1}, {2,3}, {4,pad} -> sums 3, 7, 5.
        let x = Tensor::new(vec![1, 5, 1, 1], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let w = Tensor::full(&[2, 2, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 2, 1);
        assert_eq!(y.shape, vec![1, 3, 1, 1]);
        assert_eq!(y.data, vec![3.0, 7.0, 5.0]);
    }

    #[test]
    fn depthwise_independent_channels() {
        // 2-channel depthwise 1x1: channel i scaled by (i+1)
        let x = Tensor::new(vec![1, 1, 1, 2], vec![3.0, 5.0]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 2.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 2);
        assert_eq!(y.data, vec![3.0, 10.0]);
    }

    #[test]
    fn grouped_conv_matches_blockdiag() {
        // groups=2 over 4 channels == block-diagonal full conv
        let x = Tensor::new(vec![1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        // grouped weight [1,1,2,4]: group0 maps ch0..2 -> out0..2, group1 -> out2..4
        let wg = Tensor::new(
            vec![1, 1, 2, 4],
            vec![
                1.0, 0.0, 5.0, 0.0, // in0: out0 += 1*in0 (g0), out2 += 5*in2 (g1)
                0.0, 1.0, 0.0, 5.0,
            ],
        );
        let y = conv2d(&x, &wg, &[0.0; 4], 1, 2);
        assert_eq!(y.data, vec![1.0, 2.0, 15.0, 20.0]);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_geometries() {
        // one ConvScratch driven through different shapes must keep matching
        // the allocating path exactly (stale-buffer regression guard)
        let mk = |shape: &[usize], seed: u64| {
            let mut rng = crate::data::Rng::new(seed);
            let n = shape.iter().product::<usize>();
            Tensor::new(shape.to_vec(), (0..n).map(|_| rng.normal()).collect())
        };
        let mut scratch = ConvScratch::new();
        let mut out = Tensor { shape: vec![0], data: Vec::new() };
        let cases: &[(&[usize], &[usize], usize, usize)] = &[
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1),
            (&[1, 5, 5, 4], &[3, 3, 4, 8], 2, 1),
            (&[2, 4, 4, 4], &[3, 3, 1, 4], 1, 4),
            (&[2, 6, 6, 4], &[3, 3, 4, 8], 1, 1), // revisit first geometry
        ];
        for (i, (xs, ws, stride, groups)) in cases.iter().enumerate() {
            let x = mk(xs, 10 + i as u64);
            let w = mk(ws, 20 + i as u64);
            let bias: Vec<f32> = (0..ws[3]).map(|j| j as f32 * 0.1).collect();
            conv2d_into(&x, &w, &bias, *stride, *groups, &mut scratch, &mut out);
            let want = conv2d(&x, &w, &bias, *stride, *groups);
            assert_eq!(out.shape, want.shape, "case {i}");
            assert_eq!(out.data, want.data, "case {i}");
        }
    }
}
