//! NHWC 2-D convolution via im2col (SAME padding), with grouped / depthwise
//! support — mirrors `jax.lax.conv_general_dilated(NHWC, HWIO)` as used by L2
//! so the rust deployment simulator reproduces the AOT graphs bit-for-shape.

use super::Tensor;

/// SAME-padding output size for stride s.
fn out_dim(i: usize, s: usize) -> usize {
    i.div_ceil(s)
}

/// im2col patch matrix: x[b,h,w,cin] -> [b*oh*ow, k*k*cin_group] for one group
/// slice along the channel axis. `c0..c0+cg` selects the group's channels.
fn im2col(
    x: &Tensor,
    k: usize,
    stride: usize,
    c0: usize,
    cg: usize,
) -> (Tensor, usize, usize) {
    let (b, h, w, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (out_dim(h, stride), out_dim(w, stride));
    // SAME padding offsets (matches XLA for odd k)
    let pad_top = ((oh - 1) * stride + k).saturating_sub(h) / 2;
    let pad_left = ((ow - 1) * stride + k).saturating_sub(w) / 2;
    let mut cols = vec![0.0f32; b * oh * ow * k * k * cg];
    let mut idx = 0;
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad_top as isize;
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad_left as isize;
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let base =
                                ((bi * h + iy as usize) * w + ix as usize) * cin + c0;
                            cols[idx..idx + cg].copy_from_slice(&x.data[base..base + cg]);
                        }
                        idx += cg;
                    }
                }
            }
        }
    }
    (Tensor::new(vec![b * oh * ow, k * k * cg], cols), oh, ow)
}

/// NHWC conv, SAME padding.  `w` is HWIO `[k,k,cin/groups,cout]`, `bias` is
/// `[cout]`.  `groups == cin == cout` gives a depthwise conv.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, groups: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (b, cin) = (x.shape[0], x.shape[3]);
    let k = w.shape[0];
    let (wcin, cout) = (w.shape[2], w.shape[3]);
    assert_eq!(wcin, cin / groups, "HWIO in-channels vs groups");
    assert_eq!(cout % groups, 0);
    assert_eq!(bias.len(), cout);
    let cg_in = cin / groups;
    let cg_out = cout / groups;

    let (oh, ow);
    let mut out;
    if groups == 1 {
        let (cols, oh_, ow_) = im2col(x, k, stride, 0, cin);
        oh = oh_;
        ow = ow_;
        // weight [k,k,cin,cout] is already [k*k*cin, cout] row-major
        let wmat = Tensor::new(vec![k * k * cin, cout], w.data.clone());
        out = cols.matmul(&wmat).data;
    } else {
        oh = out_dim(x.shape[1], stride);
        ow = out_dim(x.shape[2], stride);
        out = vec![0.0f32; b * oh * ow * cout];
        for g in 0..groups {
            let (cols, _, _) = im2col(x, k, stride, g * cg_in, cg_in);
            // group weight slice: [k,k,cg_in,cout] -> columns [g*cg_out..]
            let mut wg = vec![0.0f32; k * k * cg_in * cg_out];
            for r in 0..k * k * cg_in {
                let src = r * cout + g * cg_out;
                wg[r * cg_out..(r + 1) * cg_out]
                    .copy_from_slice(&w.data[src..src + cg_out]);
            }
            let wmat = Tensor::new(vec![k * k * cg_in, cg_out], wg);
            let og = cols.matmul(&wmat);
            for (row, chunk) in og.data.chunks(cg_out).enumerate() {
                let dst = row * cout + g * cg_out;
                out[dst..dst + cg_out].copy_from_slice(chunk);
            }
        }
    }
    for chunk in out.chunks_mut(cout) {
        for (o, &bv) in chunk.iter_mut().zip(bias) {
            *o += bv;
        }
    }
    Tensor::new(vec![b, oh, ow, cout], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv() {
        let x = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        // 1x1 identity kernel [1,1,2,2]
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn bias_is_added() {
        let x = Tensor::zeros(&[1, 2, 2, 1]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 1.0]);
        let y = conv2d(&x, &w, &[1.5, -2.0], 1, 1);
        assert_eq!(y.shape, vec![1, 2, 2, 2]);
        assert_eq!(&y.data[0..2], &[1.5, -2.0]);
    }

    #[test]
    fn stride2_same_padding_shape() {
        let x = Tensor::zeros(&[2, 5, 5, 3]);
        let w = Tensor::zeros(&[3, 3, 3, 4]);
        let y = conv2d(&x, &w, &[0.0; 4], 2, 1);
        assert_eq!(y.shape, vec![2, 3, 3, 4]);
    }

    #[test]
    fn sum_kernel_3x3_interior() {
        // all-ones 3x3 kernel on all-ones input: interior pixels see 9
        let x = Tensor::full(&[1, 4, 4, 1], 1.0);
        let w = Tensor::full(&[3, 3, 1, 1], 1.0);
        let y = conv2d(&x, &w, &[0.0], 1, 1);
        assert_eq!(y.shape, vec![1, 4, 4, 1]);
        // interior (1,1): full 3x3 window
        assert_eq!(y.data[(1 * 4 + 1) as usize], 9.0);
        // corner (0,0): 2x2 window under SAME padding
        assert_eq!(y.data[0], 4.0);
    }

    #[test]
    fn depthwise_independent_channels() {
        // 2-channel depthwise 1x1: channel i scaled by (i+1)
        let x = Tensor::new(vec![1, 1, 1, 2], vec![3.0, 5.0]);
        let w = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 2.0]);
        let y = conv2d(&x, &w, &[0.0, 0.0], 1, 2);
        assert_eq!(y.data, vec![3.0, 10.0]);
    }

    #[test]
    fn grouped_conv_matches_blockdiag() {
        // groups=2 over 4 channels == block-diagonal full conv
        let x = Tensor::new(vec![1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        // grouped weight [1,1,2,4]: group0 maps ch0..2 -> out0..2, group1 -> out2..4
        let wg = Tensor::new(
            vec![1, 1, 2, 4],
            vec![
                1.0, 0.0, 5.0, 0.0, // in0: out0 += 1*in0 (g0), out2 += 5*in2 (g1)
                0.0, 1.0, 0.0, 5.0,
            ],
        );
        let y = conv2d(&x, &wg, &[0.0; 4], 1, 2);
        assert_eq!(y.data, vec![1.0, 2.0, 15.0, 20.0]);
    }
}
