//! Minimal dense f32 tensor substrate (S1 in DESIGN.md).
//!
//! Row-major `Vec<f32>` + shape; exactly the operations the coordinator and
//! the pure-rust deployment simulator need: elementwise ops, NHWC conv via
//! im2col ([`conv`]), matmul, reductions.  Small on purpose — the heavy math
//! runs in AOT-compiled XLA; this substrate exists for heuristics (PPQ, APQ,
//! CLE, bias correction), analysis figures, and the integer cross-check.
//! Every matmul here lowers to the [`crate::kernel`] packed register-blocked
//! GEMM (bit-identical to its scalar reference — see that module's
//! contract).

pub mod conv;

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Default for Tensor {
    /// The empty-buffer idiom used by scratch holders: shape `[0]`, no data.
    fn default() -> Self {
        Tensor { shape: vec![0], data: Vec::new() }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, "{:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![1], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Self, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape);
        Self {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a - b)
    }

    pub fn relu(&self) -> Self {
        self.map(|x| x.max(0.0))
    }

    pub fn relu6(&self) -> Self {
        self.map(|x| x.clamp(0.0, 6.0))
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum()
    }

    pub fn norm(&self) -> f32 {
        self.sq_norm().sqrt()
    }

    /// argmax over the last axis, one result per leading-row.
    pub fn argmax_lastdim(&self) -> Vec<usize> {
        let n = *self.shape.last().expect("rank >= 1");
        self.data
            .chunks(n)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// x[m,k] @ w[k,n] -> [m,n]
    pub fn matmul(&self, w: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(w.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (w.shape[0], w.shape[1]);
        assert_eq!(k, k2);
        let mut out = Vec::new();
        matmul_slices(&self.data, m, k, &w.data, n, &mut out);
        Tensor::new(vec![m, n], out)
    }

    /// NHWC global average pool: [b,h,w,c] -> [b,c]
    pub fn global_avg_pool(&self) -> Tensor {
        assert_eq!(self.rank(), 4);
        let (b, h, w, c) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let inv = 1.0 / (h * w) as f32;
        let mut out = vec![0.0f32; b * c];
        for bi in 0..b {
            for p in 0..h * w {
                let base = (bi * h * w + p) * c;
                for ci in 0..c {
                    out[bi * c + ci] += self.data[base + ci];
                }
            }
        }
        for v in &mut out {
            *v *= inv;
        }
        Tensor::new(vec![b, c], out)
    }

    /// Per-last-axis-channel max(|.|): [.., c] -> [c]
    pub fn abs_max_per_channel(&self) -> Vec<f32> {
        let c = *self.shape.last().unwrap();
        let mut out = vec![0.0f32; c];
        for chunk in self.data.chunks(c) {
            for (o, &x) in out.iter_mut().zip(chunk) {
                *o = o.max(x.abs());
            }
        }
        out
    }
}

/// Resize `buf` to exactly `len` elements without zero-filling a buffer
/// that is already the right size — the write-mode kernels
/// ([`crate::kernel::gemm`], [`crate::kernel::gemm_i8`]) overwrite every
/// element, so the historical clear-then-zero pass is needed only when the
/// length actually changes.  ONE copy of the warm-buffer rule, shared by
/// the matmul entry points here, the conv paths in [`conv`], and the i8
/// deployment backend's i32 accumulators.
pub(crate) fn size_for_write<T: Copy + Default>(buf: &mut Vec<T>, len: usize) {
    if buf.len() != len {
        buf.clear();
        buf.resize(len, T::default());
    }
}

/// x[m,k] @ w[k,n] written into `out` (resized to fit, so a right-sized
/// buffer is reused without reallocation or zero-fill).  `w` is packed into
/// this thread's [`crate::kernel::PackedW`] scratch and run through the
/// register-blocked [`crate::kernel::gemm`]; results are bit-identical to
/// the scalar [`crate::kernel::gemm_ref`] loop (see the kernel module docs
/// for the contract).  [`Tensor::matmul`] and the scratch-based conv path
/// both call it, which is what makes the buffer-reusing deployment forward
/// bit-exactly equal to the allocating one.
pub fn matmul_slices(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    size_for_write(out, m * n);
    crate::kernel::with_pack_scratch(|pw| {
        pw.pack_cols(w, k, n, 0, n);
        crate::kernel::gemm(x, m, pw, out);
    });
}

/// [`matmul_slices`] against weights already packed by the caller (the
/// deployment path packs once at prepare time and reuses forever).
pub fn matmul_packed_slices(x: &[f32], m: usize, pw: &crate::kernel::PackedW, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), m * pw.k());
    size_for_write(out, m * pw.n());
    crate::kernel::gemm(x, m, pw, out);
}

/// Minimum output rows per parallel GEMM chunk: below this the scope
/// submit/latch overhead outweighs the row work, so the call stays serial.
const MIN_PAR_ROWS: usize = 32;

/// [`matmul_slices`] with the `m` (output-row) dimension split into
/// contiguous cache-sized blocks across `pool`.  `w` is packed once on the
/// submitting thread; each chunk owns a disjoint [`crate::kernel::MR`]-
/// aligned slice of `out` and runs the identical [`crate::kernel::gemm`]
/// kernel, so the result is bit-identical to the serial call at any thread
/// count.
pub fn matmul_slices_par(
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    out: &mut Vec<f32>,
    pool: &crate::par::Pool,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    size_for_write(out, m * n);
    crate::kernel::with_pack_scratch(|pw| {
        pw.pack_cols(w, k, n, 0, n);
        matmul_packed_rows_par(x, m, pw, out, pool);
    });
}

/// The parallel core shared by [`matmul_slices_par`] and the prepacked
/// deployment callers (the f32 fc head and the `lw-i8` backend's fc path):
/// split `m` into MR-aligned chunks, each running the write-mode kernel
/// over its disjoint output rows.
pub fn matmul_packed_rows_par(
    x: &[f32],
    m: usize,
    pw: &crate::kernel::PackedW,
    out: &mut [f32],
    pool: &crate::par::Pool,
) {
    let (k, n) = (pw.k(), pw.n());
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    let ranges =
        crate::par::chunk_ranges_aligned(m, pool.threads(), MIN_PAR_ROWS, crate::kernel::MR);
    if pool.threads() <= 1 || ranges.len() <= 1 {
        crate::kernel::gemm(x, m, pw, out);
        return;
    }
    let mut tasks: Vec<crate::par::ScopedTask<'_>> = Vec::with_capacity(ranges.len());
    let mut rest: &mut [f32] = out;
    for r in ranges {
        let rows = r.end - r.start;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
        rest = tail;
        let xr = &x[r.start * k..r.end * k];
        tasks.push(Box::new(move || crate::kernel::gemm(xr, rows, pw, head)));
    }
    pool.scope(tasks);
}

/// Numerically stable softmax over the last axis.
pub fn softmax_lastdim(t: &Tensor) -> Tensor {
    let n = *t.shape.last().unwrap();
    let mut out = t.data.clone();
    for row in out.chunks_mut(n) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
    Tensor::new(t.shape.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(x.matmul(&w).data, vec![1.0, 2.0, 3.0, 4.0]);
        let w2 = Tensor::new(vec![2, 3], vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        assert_eq!(x.matmul(&w2).data, vec![3.0, 3.0, 3.0, 7.0, 7.0, 7.0]);
    }

    #[test]
    fn gap_matches_mean() {
        let t = Tensor::new(vec![1, 2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let g = t.global_avg_pool();
        assert_eq!(g.shape, vec![1, 2]);
        assert_eq!(g.data, vec![4.0, 5.0]);
    }

    #[test]
    fn abs_max_per_channel_works() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -5.0, -3.0, 2.0]);
        assert_eq!(t.abs_max_per_channel(), vec![3.0, 5.0]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.2, 0.7, 0.1, 0.3]);
        assert_eq!(t.argmax_lastdim(), vec![1, 0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let s = softmax_lastdim(&t);
        for row in s.data.chunks(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
