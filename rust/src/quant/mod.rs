//! The paper's quantization mathematics, natively in rust (S4–S9).
//!
//! * [`ppq`] — Progressive Projection Quantization (Alg. 1, adopted from
//!   [14]): scalar-scale MMSE by orthogonality-principle iteration.
//! * [`apq`] — Alternating Projection Quantization (Alg. 2, the paper's
//!   novel extension): doubly-channelwise (left ⊗ right co-vector) MMSE.
//! * [`mmse`] — MMSE at all granularities (Eq. 5): layerwise, channelwise,
//!   doubly-channelwise, plus fake-quant utilities.
//! * [`dof`] — the scale-tensor DoF algebra: Eq. 2 and its inversion
//!   (Eqs. 3–4), outer-product grids.
//! * [`cle`] — 4b-adapted cross-layer equalization (App. D, Eqs. 19/21):
//!   MMSE-ratio geometric mean, β-weighted heterogeneous pairs, fan-out.
//! * [`bias`] — empirical bias correction [29] and quantized-bias residue
//!   absorption (Eq. 7 / App. A).
//! * [`deploy`] — the integer deployment simulator: fully-integer online
//!   graph cross-checked against the fake-quant simulation (deployability
//!   rigor per App. A).
//! * [`baselines`] — trainable-set builders for every Table-1/2 comparator:
//!   naive-max, MMSE round-to-nearest, +CLE, +bias-correction.

pub mod apq;
pub mod baselines;
pub mod bias;
pub mod cle;
pub mod deploy;
pub mod dof;
pub mod mmse;
pub mod ppq;

/// clip(round(x/s)) — the integer code.
#[inline]
pub fn qcode(x: f32, s: f32, qmin: f32, qmax: f32) -> f32 {
    (x / s).round().clamp(qmin, qmax)
}

/// s * clip(round(x/s)) — fake-quantization of one element.
#[inline]
pub fn fq(x: f32, s: f32, qmin: f32, qmax: f32) -> f32 {
    qcode(x, s, qmin, qmax) * s
}
