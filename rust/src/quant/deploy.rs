//! Integer deployment simulator (S9) and the fake-quant twin (App. A rigor).
//!
//! Two forward paths over the same trainable set:
//!
//! * [`forward_fakequant`] — the FP32-represented simulation, a rust mirror
//!   of the L2 `qft.student_forward` graph (used for parity tests against
//!   the AOT `q_eval` executable and for the analysis figures).
//! * [`DeployedModel`] — the deployed online pipeline.  In `lw` mode it is
//!   fully integer: u8/i8 codes, integer accumulation, quantized bias at
//!   accumulator scale (Eq. 8), multiplicative recode by F̂ (Eq. 11),
//!   integer activation.  In `dch` mode (W4A32) weights ship as 4b codes on
//!   the doubly-channelwise grid and accumulation stays FP32, so the path
//!   is bit-identical to the fake-quant twin.  The gap between lw-integer
//!   and fake-quant is the bias/threshold rounding the paper folds under
//!   "additional lossy elements".
//!
//! The deployment split mirrors the paper's offline/online subgraphs:
//! [`DeployedModel::prepare`] runs the *offline* subgraph once (kernel
//! co-vectors via Eqs. 2-4, integer weight/bias codes, recode factors,
//! integer relu6 thresholds) and freezes everything; the *online*
//! [`DeployedModel::forward_batch`] then never touches [`kernel_covectors`]
//! or the trainable map, and borrows every intermediate buffer from a
//! caller-owned [`DeployScratch`] so steady-state serving allocates nothing
//! on the hot path.  Batched and single-image execution share one
//! implementation and are bit-exactly equal per image.

use std::collections::HashMap;

use crate::kernel::PackedW;
use crate::nn::{apply_act_inplace, ArchSpec, OpKind, ParamMap};
use crate::par::Pool;
use crate::obs::{layer, NetObs, Phase};
use crate::tensor::conv::{
    conv2d_obs, conv2d_packed_into_obs, conv2d_packed_into_par_obs, ConvScratch, PackedConvW,
};
use crate::tensor::Tensor;
use crate::WEIGHT_QMAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// W4A8, layerwise (scalar) rescale factors; DoF {W, b, S_a, F}.
    Lw,
    /// W4A32, channelwise rescale: doubly-channelwise kernels; DoF
    /// {W, b, S_wL, S_wR}.
    Dch,
}

impl Mode {
    pub fn key(self) -> &'static str {
        match self {
            Mode::Lw => "lw",
            Mode::Dch => "dch",
        }
    }

    /// Fallible inverse of [`Mode::key`].  Exact-match only: `.qftw`
    /// filenames and wire keys are generated from `key()`, so case or
    /// whitespace drift (`"LW"`, `"lw "`) is a caller bug we want surfaced,
    /// not silently accepted.
    pub fn from_key(s: &str) -> anyhow::Result<Mode> {
        match s {
            "lw" => Ok(Mode::Lw),
            "dch" => Ok(Mode::Dch),
            other => anyhow::bail!("unknown mode {other:?} (expected \"lw\" or \"dch\")"),
        }
    }
}

const EPS: f32 = 1e-12;

pub(crate) fn pos(v: f32) -> f32 {
    v.abs() + EPS
}

/// Offline subgraph (Eq. 2 / Eqs. 3-4): kernel scale co-vectors for a conv.
/// Returns (s_l, s_r); depthwise convs get s_l = None (single channel axis).
pub fn kernel_covectors(
    _arch: &ArchSpec,
    tm: &ParamMap,
    mode: Mode,
    op: &crate::nn::OpSpec,
) -> (Option<Vec<f32>>, Vec<f32>) {
    match mode {
        Mode::Lw => {
            let su: Vec<f32> = tm.get(&format!("sv:{}", op.inp)).data.iter().map(|&v| pos(v)).collect();
            let sv: Vec<f32> = tm.get(&format!("sv:{}", op.out)).data.iter().map(|&v| pos(v)).collect();
            let f = pos(tm.get(&format!("f:{}", op.name)).data[0]);
            if op.groups == 1 {
                let s_l = su.iter().map(|&s| 1.0 / s).collect();
                let s_r = sv.iter().map(|&s| s * f).collect();
                (Some(s_l), s_r)
            } else {
                let s_r = sv.iter().zip(&su).map(|(&v, &u)| v * f / u).collect();
                (None, s_r)
            }
        }
        Mode::Dch => {
            let s_r: Vec<f32> = tm
                .get(&format!("swr:{}", op.name))
                .data
                .iter()
                .map(|&v| pos(v))
                .collect();
            if op.groups == 1 {
                let s_l = tm
                    .get(&format!("swl:{}", op.name))
                    .data
                    .iter()
                    .map(|&v| pos(v))
                    .collect();
                (Some(s_l), s_r)
            } else {
                (None, s_r)
            }
        }
    }
}

fn fq_kernel(w: &Tensor, s_l: &Option<Vec<f32>>, s_r: &[f32]) -> Tensor {
    match s_l {
        Some(l) => super::mmse::fq_outer(w, l, s_r, WEIGHT_QMAX),
        None => super::mmse::fq_per_out_channel(w, s_r, WEIGHT_QMAX),
    }
}

pub(crate) fn act_range(arch: &ArchSpec, v: usize) -> (f32, f32) {
    if arch.signed_of(v) {
        (-crate::ACT_SIGNED_QMAX, crate::ACT_SIGNED_QMAX)
    } else {
        (0.0, crate::ACT_UNSIGNED_QMAX)
    }
}

pub(crate) fn sv_of(tm: &ParamMap, v: usize) -> Vec<f32> {
    tm.get(&format!("sv:{v}")).data.iter().map(|&x| pos(x)).collect()
}

/// Fake-quant student forward: rust mirror of the L2 online subgraph.
pub fn forward_fakequant(
    arch: &ArchSpec,
    tm: &ParamMap,
    mode: Mode,
    x: &Tensor,
) -> (Tensor, Tensor) {
    forward_fakequant_obs(arch, tm, mode, x, None)
}

/// [`forward_fakequant`] with optional per-layer timing: on a sampled pass
/// each conv op laps kernel co-vector derivation + fake-quant kernel build
/// into `pack`, the conv into `im2col` / `gemm`, and the output fake-quant
/// re-encode (`lw`) into `recode`; the fc matmul is all `gemm`.
pub fn forward_fakequant_obs(
    arch: &ArchSpec,
    tm: &ParamMap,
    mode: Mode,
    x: &Tensor,
    obs: Option<&NetObs>,
) -> (Tensor, Tensor) {
    let mut vals: std::collections::HashMap<usize, Tensor> = Default::default();
    let x0 = match mode {
        Mode::Lw => {
            let (qmin, qmax) = act_range(arch, 0);
            super::mmse::fq_act(x, &sv_of(tm, 0), qmin, qmax)
        }
        Mode::Dch => x.clone(),
    };
    vals.insert(0, x0);
    let mut logits = None;
    let mut feat = None;
    for (i, op) in arch.ops.iter().enumerate() {
        let lobs = obs.and_then(|o| o.layer(i));
        match op.kind() {
            OpKind::Conv => {
                let w = tm.get(&format!("w:{}", op.name));
                let b = tm.get(&format!("b:{}", op.name));
                let t0 = layer::start(lobs);
                let (s_l, s_r) = kernel_covectors(arch, tm, mode, op);
                let wq = fq_kernel(w, &s_l, &s_r);
                layer::lap(lobs, Phase::Pack, t0);
                let mut a = conv2d_obs(&vals[&op.inp], &wq, &b.data, op.stride, op.groups, lobs);
                apply_act_inplace(&mut a, &op.act);
                if mode == Mode::Lw {
                    let (qmin, qmax) = act_range(arch, op.out);
                    let tr = layer::start(lobs);
                    a = super::mmse::fq_act(&a, &sv_of(tm, op.out), qmin, qmax);
                    layer::lap(lobs, Phase::Recode, tr);
                }
                layer::finish(lobs, t0);
                vals.insert(op.out, a);
            }
            OpKind::Add => {
                let mut a = vals[&op.a].add(&vals[&op.b]);
                apply_act_inplace(&mut a, &op.act);
                if mode == Mode::Lw {
                    let (qmin, qmax) = act_range(arch, op.out);
                    a = super::mmse::fq_act(&a, &sv_of(tm, op.out), qmin, qmax);
                }
                vals.insert(op.out, a);
            }
            OpKind::Gap => {
                feat = Some(vals[&op.inp].clone());
                vals.insert(op.out, vals[&op.inp].global_avg_pool());
            }
            OpKind::Fc => {
                let w = tm.get(&format!("w:{}", op.name));
                let b = tm.get(&format!("b:{}", op.name));
                let t0 = layer::start(lobs);
                let mut y = vals[&op.inp].matmul(w);
                layer::lap(lobs, Phase::Gemm, t0);
                for row in y.data.chunks_mut(b.data.len()) {
                    for (v, &bv) in row.iter_mut().zip(&b.data) {
                        *v += bv;
                    }
                }
                layer::finish(lobs, t0);
                logits = Some(y.clone());
                vals.insert(op.out, y);
            }
        }
    }
    (logits.unwrap(), feat.unwrap())
}

// ------------------------------------------------------------------ deployed

/// Whether every i8 weight code fits the two's-complement nibble range a
/// [`crate::kernel::PackedW4`] panel stores (`[-8, 7]`).  The lw grids clamp
/// to `±`[`WEIGHT_QMAX`]` = ±7`, so this always holds for them; the probe is
/// what lets [`crate::backend::Int8Backend`] fall back per conv if a wider
/// codebook ever reaches it.
pub(crate) fn codes_fit_w4(codes: &[i8]) -> bool {
    codes.iter().all(|&c| (-8..=7).contains(&c))
}

/// Integer weight codes on the Eq. 2 grid (outer-product or per-out-channel).
pub(crate) fn kernel_codes(w: &Tensor, s_l: &Option<Vec<f32>>, s_r: &[f32]) -> Tensor {
    match s_l {
        Some(l) => {
            let (cin, cout) = (w.shape[2], w.shape[3]);
            let data = w
                .data
                .iter()
                .enumerate()
                .map(|(idx, &x)| {
                    let j = idx % cout;
                    let i = (idx / cout) % cin;
                    (x / (l[i] * s_r[j])).round().clamp(-WEIGHT_QMAX, WEIGHT_QMAX)
                })
                .collect();
            Tensor::new(w.shape.clone(), data)
        }
        None => {
            let cout = w.shape[3];
            let data = w
                .data
                .iter()
                .enumerate()
                .map(|(idx, &x)| (x / s_r[idx % cout]).round().clamp(-WEIGHT_QMAX, WEIGHT_QMAX))
                .collect();
            Tensor::new(w.shape.clone(), data)
        }
    }
}

pub(crate) fn act_scalar(act: &str, v: f32) -> f32 {
    match act {
        "relu" => v.max(0.0),
        "relu6" => v.clamp(0.0, 6.0),
        _ => v,
    }
}

/// One conv lowered to frozen deployment constants.  `lw`: `packed` holds
/// integer codes, `bias` the integer bias at accumulator scale, plus the
/// recode factor and integer relu6 thresholds.  `dch`: `packed` holds the
/// dequantized 4b weights and everything runs at FP32 accumulator precision.
/// Either way the kernel is stored panel-packed ([`PackedConvW`], one
/// [`PackedW`] per group) so the online path streams K-major panels through
/// [`crate::kernel::gemm`] without ever repacking.
struct PreparedConv {
    inp: usize,
    out: usize,
    stride: usize,
    cout: usize,
    act: String,
    packed: PackedConvW,
    bias: Vec<f32>,
    /// lw only: per-channel integer clip(6/S_acc) thresholds for relu6.
    relu6_thr: Option<Vec<f32>>,
    /// lw only: (F̂, qmin, qmax) for the multiplicative recode (Eq. 11).
    recode: Option<(f32, f32, f32)>,
}

/// lw decode/re-encode scales around a residual add (App. D item 1).
struct AddScales {
    sa: Vec<f32>,
    sb: Vec<f32>,
    sout: Vec<f32>,
    qmin: f32,
    qmax: f32,
}

enum PreparedOp {
    Conv(PreparedConv),
    Add { a: usize, b: usize, out: usize, act: String, dec: Option<AddScales> },
    Gap { inp: usize, out: usize, dec: Option<Vec<f32>> },
    Fc { inp: usize, w: PackedW, bias: Vec<f32> },
}

/// Reusable buffers for the integer forward: one activation tensor per graph
/// value plus the conv im2col scratch and the gap decode buffer.  After the
/// first call at a given batch size the online path allocates nothing.
///
/// The batch-parallel path ([`DeployedModel::forward_batch_pooled`]) splits
/// a batch into per-chunk sub-batches; each chunk owns one child scratch
/// from `par` (plus its `input` staging tensor), so chunks never share a
/// buffer and the same warm-buffer guarantee holds per chunk.
#[derive(Default)]
pub struct DeployScratch {
    vals: HashMap<usize, Tensor>,
    conv: ConvScratch,
    dec: Tensor,
    /// sub-batch input staging for the batch-parallel path.
    input: Tensor,
    /// per-chunk child scratches for the batch-parallel path.
    par: Vec<DeployScratch>,
}

impl DeployScratch {
    /// The one constructor: zero-state comes from the field types' own
    /// `Default`s (derived), so adding a scratch field cannot silently
    /// diverge between `new()` and `default()` — they are the same code.
    pub fn new() -> Self {
        Self::default()
    }
}

fn take_val(vals: &mut HashMap<usize, Tensor>, id: usize) -> Tensor {
    vals.remove(&id).unwrap_or(Tensor { shape: vec![0], data: Vec::new() })
}

/// Scratch types that can host one batch chunk of the shared batch-parallel
/// driver ([`exec_batch_par_generic`]): each chunk stages its sub-batch
/// input in a buffer owned by its child scratch (allocation-free once warm).
pub(crate) trait ChunkScratch: Default + Send {
    /// The chunk's input staging tensor (taken for the task, restored after).
    fn input_buf(&mut self) -> &mut Tensor;
}

impl ChunkScratch for DeployScratch {
    fn input_buf(&mut self) -> &mut Tensor {
        &mut self.input
    }
}

/// Batch-level parallel driver shared by every backend whose per-image
/// execution is independent ([`DeployedModel`] and the i8 engine): split
/// the batch into contiguous image chunks, run `exec` per chunk on its own
/// child scratch from `par`, and concatenate per-chunk outputs in order.
/// Because batched and single-image execution are bit-exactly equal per
/// image, the concatenation equals the serial full-batch result bit for
/// bit — ONE copy of that argument and of the chunking/staging/concat
/// machinery, so the backends cannot drift.
pub(crate) fn exec_batch_par_generic<S: ChunkScratch>(
    x: &Tensor,
    num_classes: usize,
    want_feat: bool,
    pool: &Pool,
    par: &mut Vec<S>,
    exec: impl Fn(&Tensor, &mut S, bool) -> (Tensor, Option<Tensor>) + Sync,
) -> (Tensor, Option<Tensor>) {
    let b = x.shape[0];
    let px = x.data.len() / b;
    let ranges = crate::par::chunk_ranges(b, pool.threads(), 1);
    let nch = ranges.len();
    if par.len() < nch {
        par.resize_with(nch, S::default);
    }
    let mut parts: Vec<Option<(Tensor, Option<Tensor>)>> = Vec::with_capacity(nch);
    parts.resize_with(nch, || None);
    {
        let children = &mut par[..nch];
        let exec = &exec;
        let mut tasks: Vec<crate::par::ScopedTask<'_>> = Vec::with_capacity(nch);
        for ((child, slot), r) in children.iter_mut().zip(parts.iter_mut()).zip(ranges) {
            let xdata = &x.data[r.start * px..r.end * px];
            let (bh, bw, bc) = (x.shape[1], x.shape[2], x.shape[3]);
            let bn = r.end - r.start;
            tasks.push(Box::new(move || {
                // stage the sub-batch in the child's own input buffer
                // (allocation-free once warm), then run the serial path
                let mut xin = std::mem::take(child.input_buf());
                xin.shape.clear();
                xin.shape.extend_from_slice(&[bn, bh, bw, bc]);
                xin.data.clear();
                xin.data.extend_from_slice(xdata);
                *slot = Some(exec(&xin, child, want_feat));
                *child.input_buf() = xin;
            }));
        }
        pool.scope(tasks);
    }
    let mut logits_data = Vec::with_capacity(b * num_classes);
    let mut feat_data = Vec::new();
    let mut feat_dims = [0usize; 3];
    for part in parts {
        let (l, f) = part.expect("parallel batch chunk produced no result");
        logits_data.extend_from_slice(&l.data);
        if want_feat {
            let f = f.expect("arch has gap");
            feat_dims = [f.shape[1], f.shape[2], f.shape[3]];
            feat_data.extend_from_slice(&f.data);
        }
    }
    let logits = Tensor::new(vec![b, num_classes], logits_data);
    let feat = want_feat
        .then(|| Tensor::new(vec![b, feat_dims[0], feat_dims[1], feat_dims[2]], feat_data));
    (logits, feat)
}

/// A network lowered for deployment: every constant the online subgraph needs
/// (weight/bias codes, recode factors, activation grids), frozen offline so
/// serving workers never re-derive anything per request.
pub struct DeployedModel {
    pub arch_name: String,
    pub mode: Mode,
    pub input_hw: usize,
    pub input_ch: usize,
    pub num_classes: usize,
    /// lw input encode: per-channel scales + activation grid.
    enc0: Option<(Vec<f32>, f32, f32)>,
    ops: Vec<PreparedOp>,
}

impl DeployedModel {
    /// Run the offline subgraph (Eqs. 2-4, 7, 11) once and freeze the result.
    pub fn prepare(arch: &ArchSpec, tm: &ParamMap, mode: Mode) -> Self {
        let enc0 = match mode {
            Mode::Lw => {
                let (qmin, qmax) = act_range(arch, 0);
                Some((sv_of(tm, 0), qmin, qmax))
            }
            Mode::Dch => None,
        };
        let mut ops = Vec::with_capacity(arch.ops.len());
        for op in &arch.ops {
            match op.kind() {
                OpKind::Conv => {
                    let w = tm.get(&format!("w:{}", op.name));
                    let b = tm.get(&format!("b:{}", op.name));
                    let (s_l, s_r) = kernel_covectors(arch, tm, mode, op);
                    let pc = match mode {
                        Mode::Lw => {
                            let f = pos(tm.get(&format!("f:{}", op.name)).data[0]);
                            let sv = sv_of(tm, op.out);
                            // accumulator scale per n: S_acc = S_v * F (Eq. 11)
                            let s_acc: Vec<f32> = sv.iter().map(|&s| s * f).collect();
                            // quantized bias at accumulator scale (Eq. 7,
                            // zero-points = 0 in our symmetric-code form)
                            let bias = b
                                .data
                                .iter()
                                .zip(&s_acc)
                                .map(|(&bv, &s)| (bv / s).round())
                                .collect();
                            let relu6_thr = (op.act == "relu6")
                                .then(|| s_acc.iter().map(|&s| (6.0 / s).round()).collect());
                            let (qmin, qmax) = act_range(arch, op.out);
                            PreparedConv {
                                inp: op.inp,
                                out: op.out,
                                stride: op.stride,
                                cout: op.cout,
                                act: op.act.clone(),
                                packed: PackedConvW::pack(
                                    &kernel_codes(w, &s_l, &s_r),
                                    op.groups,
                                ),
                                bias,
                                relu6_thr,
                                recode: Some((f, qmin, qmax)),
                            }
                        }
                        Mode::Dch => PreparedConv {
                            inp: op.inp,
                            out: op.out,
                            stride: op.stride,
                            cout: op.cout,
                            act: op.act.clone(),
                            // W4A32: ship 4b codes, accumulate FP32 over the
                            // dequantized kernel (== the fake-quant twin)
                            packed: PackedConvW::pack(&fq_kernel(w, &s_l, &s_r), op.groups),
                            bias: b.data.clone(),
                            relu6_thr: None,
                            recode: None,
                        },
                    };
                    ops.push(PreparedOp::Conv(pc));
                }
                OpKind::Add => {
                    let dec = match mode {
                        Mode::Lw => {
                            let (qmin, qmax) = act_range(arch, op.out);
                            Some(AddScales {
                                sa: sv_of(tm, op.a),
                                sb: sv_of(tm, op.b),
                                sout: sv_of(tm, op.out),
                                qmin,
                                qmax,
                            })
                        }
                        Mode::Dch => None,
                    };
                    ops.push(PreparedOp::Add {
                        a: op.a,
                        b: op.b,
                        out: op.out,
                        act: op.act.clone(),
                        dec,
                    });
                }
                OpKind::Gap => {
                    let dec = match mode {
                        Mode::Lw => Some(sv_of(tm, op.inp)),
                        Mode::Dch => None,
                    };
                    ops.push(PreparedOp::Gap { inp: op.inp, out: op.out, dec });
                }
                OpKind::Fc => {
                    let w = tm.get(&format!("w:{}", op.name));
                    assert_eq!(w.rank(), 2, "fc weight must be [k, classes]");
                    ops.push(PreparedOp::Fc {
                        inp: op.inp,
                        w: PackedW::pack(&w.data, w.shape[0], w.shape[1]),
                        bias: tm.get(&format!("b:{}", op.name)).data.clone(),
                    });
                }
            }
        }
        DeployedModel {
            arch_name: arch.name.clone(),
            mode,
            input_hw: arch.input_hw,
            input_ch: arch.input_ch,
            num_classes: arch.num_classes,
            enc0,
            ops,
        }
    }

    /// Pixels per image (`hw*hw*ch`), the request payload contract.
    pub fn image_len(&self) -> usize {
        self.input_hw * self.input_hw * self.input_ch
    }

    /// Batched online forward: logits `[batch, classes]`.  Results are
    /// bit-exactly independent of how images are grouped into batches.
    pub fn forward_batch(&self, x: &Tensor, scratch: &mut DeployScratch) -> Tensor {
        self.exec(x, scratch, false, None, None).0
    }

    /// As [`Self::forward_batch`] but also returns the decoded backbone
    /// feature map (the KD target tensor).
    pub fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
    ) -> (Tensor, Tensor) {
        let (logits, feat) = self.exec(x, scratch, true, None, None);
        (logits, feat.expect("arch has gap"))
    }

    /// [`Self::forward_batch`] accelerated by a shared [`Pool`], bit-identical
    /// to the serial path at any thread count: a multi-image batch is split
    /// into per-chunk sub-batches (each with its own child [`DeployScratch`]),
    /// a single image gets intra-op output-row parallelism inside each conv.
    pub fn forward_batch_pooled(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        pool: &Pool,
    ) -> Tensor {
        self.exec_pooled(x, scratch, false, pool, None).0
    }

    /// [`Self::forward_batch_pooled`] with optional per-layer timing: convs
    /// lap `im2col` / `gemm` inside the kernel and the integer
    /// activation+recode block into `recode`; the fc matmul is `gemm`.  On
    /// the batch-parallel path every chunk laps into the same shared
    /// atomics, so recorded nanoseconds (phases AND totals) are CPU time
    /// summed across pool threads.
    pub fn forward_batch_pooled_obs(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        pool: &Pool,
        obs: Option<&NetObs>,
    ) -> Tensor {
        self.exec_pooled(x, scratch, false, pool, obs).0
    }

    /// As [`Self::forward_batch_pooled`] but also returning the decoded
    /// backbone feature map.
    pub fn forward_batch_feat_pooled(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        pool: &Pool,
    ) -> (Tensor, Tensor) {
        let (logits, feat) = self.exec_pooled(x, scratch, true, pool, None);
        (logits, feat.expect("arch has gap"))
    }

    /// As [`Self::forward_batch_pooled_obs`] but also returning the decoded
    /// backbone feature map.
    pub fn forward_batch_feat_pooled_obs(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        pool: &Pool,
        obs: Option<&NetObs>,
    ) -> (Tensor, Tensor) {
        let (logits, feat) = self.exec_pooled(x, scratch, true, pool, obs);
        (logits, feat.expect("arch has gap"))
    }

    /// Dispatch between batch-level and intra-op parallelism (see
    /// [`Self::forward_batch_pooled`]).
    fn exec_pooled(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        want_feat: bool,
        pool: &Pool,
        obs: Option<&NetObs>,
    ) -> (Tensor, Option<Tensor>) {
        assert_eq!(x.rank(), 4, "input must be [b,h,w,c]");
        if pool.threads() <= 1 {
            return self.exec(x, scratch, want_feat, None, obs);
        }
        if x.shape[0] > 1 {
            return self.exec_batch_par(x, scratch, want_feat, pool, obs);
        }
        self.exec(x, scratch, want_feat, Some(pool), obs)
    }

    /// Batch-level parallel exec via the shared [`exec_batch_par_generic`]
    /// driver: contiguous image chunks run the serial per-image pipeline
    /// concurrently, each on its own child scratch, and the per-chunk
    /// outputs are concatenated in order (bit-identical to the serial full
    /// batch — the PR 1 invariant, kept under test).
    fn exec_batch_par(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        want_feat: bool,
        pool: &Pool,
        obs: Option<&NetObs>,
    ) -> (Tensor, Option<Tensor>) {
        exec_batch_par_generic(
            x,
            self.num_classes,
            want_feat,
            pool,
            &mut scratch.par,
            |xin, child, wf| self.exec(xin, child, wf, None, obs),
        )
    }

    fn exec(
        &self,
        x: &Tensor,
        scratch: &mut DeployScratch,
        want_feat: bool,
        pool: Option<&Pool>,
        obs: Option<&NetObs>,
    ) -> (Tensor, Option<Tensor>) {
        assert_eq!(x.rank(), 4, "input must be [b,h,w,c]");
        // input: encode to codes (lw) or pass through (dch)
        {
            let mut v0 = take_val(&mut scratch.vals, 0);
            v0.data.clear();
            match &self.enc0 {
                Some((sv, qmin, qmax)) => {
                    let c = *x.shape.last().unwrap();
                    v0.data.extend(
                        x.data
                            .iter()
                            .enumerate()
                            .map(|(i, &val)| (val / sv[i % c]).round().clamp(*qmin, *qmax)),
                    );
                }
                None => v0.data.extend_from_slice(&x.data),
            }
            v0.shape = x.shape.clone();
            scratch.vals.insert(0, v0);
        }

        let mut logits = None;
        let mut feat = None;
        for (i, pop) in self.ops.iter().enumerate() {
            // prepared ops are 1:1 with arch ops, so index i addresses the
            // matching per-layer timing slot on a sampled pass
            let lobs = obs.and_then(|o| o.layer(i));
            match pop {
                PreparedOp::Conv(pc) => {
                    let t0 = layer::start(lobs);
                    let mut acc = take_val(&mut scratch.vals, pc.out);
                    // intra-op (output-row) parallelism when a pool was
                    // handed down; identical results either way.  Weights
                    // were panel-packed once at prepare time.
                    match pool {
                        Some(p) => conv2d_packed_into_par_obs(
                            &scratch.vals[&pc.inp],
                            &pc.packed,
                            &pc.bias,
                            pc.stride,
                            &mut scratch.conv,
                            &mut acc,
                            p,
                            lobs,
                        ),
                        None => conv2d_packed_into_obs(
                            &scratch.vals[&pc.inp],
                            &pc.packed,
                            &pc.bias,
                            pc.stride,
                            &mut scratch.conv,
                            &mut acc,
                            lobs,
                        ),
                    }
                    let tr = layer::start(lobs);
                    match pc.recode {
                        Some((f, qmin, qmax)) => {
                            // integer activation on accumulator codes
                            match pc.act.as_str() {
                                "relu" => acc.map_inplace(|v| v.max(0.0)),
                                "relu6" => {
                                    let thr = pc.relu6_thr.as_ref().unwrap();
                                    let c = pc.cout;
                                    for (i, v) in acc.data.iter_mut().enumerate() {
                                        *v = v.clamp(0.0, thr[i % c]);
                                    }
                                }
                                _ => {}
                            }
                            // recode: out_code = clip(round(acc * F̂))
                            acc.map_inplace(|v| (v * f).round().clamp(qmin, qmax));
                        }
                        None => match pc.act.as_str() {
                            "relu" => acc.map_inplace(|v| v.max(0.0)),
                            "relu6" => acc.map_inplace(|v| v.clamp(0.0, 6.0)),
                            _ => {}
                        },
                    }
                    layer::lap(lobs, Phase::Recode, tr);
                    layer::finish(lobs, t0);
                    scratch.vals.insert(pc.out, acc);
                }
                PreparedOp::Add { a, b, out, act, dec } => {
                    // lossless FP ew-add (App. D item 1): decode, add,
                    // re-encode with the output's own scale (lw); plain FP
                    // add in dch
                    let mut o = take_val(&mut scratch.vals, *out);
                    let ta = &scratch.vals[a];
                    let tb = &scratch.vals[b];
                    assert_eq!(ta.shape, tb.shape);
                    o.data.clear();
                    match dec {
                        Some(s) => {
                            let c = *ta.shape.last().unwrap();
                            o.data.extend(ta.data.iter().zip(&tb.data).enumerate().map(
                                |(i, (&qa, &qb))| {
                                    let v = qa * s.sa[i % c] + qb * s.sb[i % c];
                                    (act_scalar(act, v) / s.sout[i % c])
                                        .round()
                                        .clamp(s.qmin, s.qmax)
                                },
                            ));
                        }
                        None => {
                            o.data.extend(
                                ta.data
                                    .iter()
                                    .zip(&tb.data)
                                    .map(|(&va, &vb)| act_scalar(act, va + vb)),
                            );
                        }
                    }
                    o.shape = ta.shape.clone();
                    scratch.vals.insert(*out, o);
                }
                PreparedOp::Gap { inp, out, dec } => {
                    // decode to FP for the head
                    let src = &scratch.vals[inp];
                    let fp = &mut scratch.dec;
                    fp.data.clear();
                    match dec {
                        Some(sv) => {
                            let c = *src.shape.last().unwrap();
                            fp.data.extend(
                                src.data.iter().enumerate().map(|(i, &q)| q * sv[i % c]),
                            );
                        }
                        None => fp.data.extend_from_slice(&src.data),
                    }
                    fp.shape = src.shape.clone();
                    if want_feat {
                        feat = Some(fp.clone());
                    }
                    let pooled = fp.global_avg_pool();
                    scratch.vals.insert(*out, pooled);
                }
                PreparedOp::Fc { inp, w, bias } => {
                    let src = &scratch.vals[inp];
                    assert_eq!(src.rank(), 2);
                    assert_eq!(src.shape[1], w.k());
                    let m = src.shape[0];
                    // logits leave the scratch (they are the return value),
                    // so this one buffer is allocated per call by design
                    let mut ydata = Vec::new();
                    let t0 = layer::start(lobs);
                    match pool {
                        Some(p) => {
                            crate::tensor::size_for_write(&mut ydata, m * w.n());
                            crate::tensor::matmul_packed_rows_par(&src.data, m, w, &mut ydata, p);
                        }
                        None => crate::tensor::matmul_packed_slices(&src.data, m, w, &mut ydata),
                    }
                    layer::lap(lobs, Phase::Gemm, t0);
                    let mut y = Tensor::new(vec![m, w.n()], ydata);
                    for row in y.data.chunks_mut(bias.len()) {
                        for (v, &bv) in row.iter_mut().zip(bias) {
                            *v += bv;
                        }
                    }
                    layer::finish(lobs, t0);
                    logits = Some(y);
                }
            }
        }
        (logits.expect("arch has fc"), feat)
    }
}

/// Rebuild a deployable trainable map from *observed* activation ranges.
///
/// The offline PTQ init and the live requantize path are the same
/// computation fed different statistics: both hand per-value, per-channel
/// absmax to [`crate::coordinator::state::init_trainables`], which derives
/// step sizes / preconditioning factors / bias codes from them.  Here the
/// statistics come from a [`crate::backend::CalibRanges`] capture instead
/// of offline calibration batches, closing the loop the paper assumes —
/// deployment constants fit to the ranges production traffic actually
/// exercises.  `params` may be a raw FP map or a previous trainable map:
/// only the `w:`/`b:` tensors are read, and every trainable map carries
/// them.
pub fn requantize_trainables(
    arch: &ArchSpec,
    params: &ParamMap,
    absmax: &HashMap<usize, Vec<f32>>,
    mode: Mode,
) -> ParamMap {
    use crate::coordinator::state::{init_trainables, WeightScaleInit};
    let winit = match mode {
        Mode::Lw => WeightScaleInit::Uniform,
        Mode::Dch => WeightScaleInit::DoublyChannelwise,
    };
    init_trainables(arch, params, absmax, mode, winit, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn covectors_lw_respect_eq2() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 0);
        let ds = crate::data::Dataset::new(0);
        let batches = vec![ds.batch(crate::data::Split::Calib, 0, 4).0];
        let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Lw,
                                        state::WeightScaleInit::Uniform, None);
        for op in arch.conv_ops().into_iter().filter(|o| o.groups == 1) {
            let (s_l, s_r) = kernel_covectors(arch, &tm, Mode::Lw, op);
            let s_l = s_l.unwrap();
            let su = &tm.get(&format!("sv:{}", op.inp)).data;
            let sv = &tm.get(&format!("sv:{}", op.out)).data;
            let f = tm.get(&format!("f:{}", op.name)).data[0];
            for (l, u) in s_l.iter().zip(su) {
                assert!((l - 1.0 / (u.abs() + EPS)).abs() < 1e-5 * l);
            }
            for (r, v) in s_r.iter().zip(sv) {
                assert!((r - (v.abs() + EPS) * (f.abs() + EPS)).abs() < 1e-5 * r);
            }
        }
    }

    #[test]
    fn fakequant_dch_runs_on_depthwise_arch() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["mobilenet_tiny"];
        let params = state::he_init_params(arch, 8);
        let ds = crate::data::Dataset::new(3);
        let (x, _, _) = ds.batch(crate::data::Split::Val, 0, 4);
        let batches = vec![x.clone()];
        let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Dch,
                                        state::WeightScaleInit::DoublyChannelwise, None);
        let (logits, feat) = forward_fakequant(arch, &tm, Mode::Dch, &x);
        assert_eq!(logits.shape, vec![4, arch.num_classes]);
        assert_eq!(feat.shape[3], arch.feat_channels);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dch_with_fine_grid_close_to_fp() {
        // dch with per-channel MMSE grids must track the FP forward closely
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 10);
        let ds = crate::data::Dataset::new(4);
        let (x, _, _) = ds.batch(crate::data::Split::Val, 0, 4);
        let absmax = state::absmax_from_rust_forward(arch, &params, &[x.clone()]);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Dch,
                                        state::WeightScaleInit::DoublyChannelwise, None);
        let (_, feat_q) = forward_fakequant(arch, &tm, Mode::Dch, &x);
        let fwd = crate::nn::fp_forward(arch, &params, &x);
        let rel = feat_q.sub(&fwd.feat).norm() / fwd.feat.norm().max(1e-6);
        assert!(rel < 0.5, "rel {rel}");
    }

    #[test]
    fn integer_matches_fakequant_sim() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 2);
        let ds = crate::data::Dataset::new(1);
        let (x, _, _) = ds.batch(crate::data::Split::Calib, 0, 4);
        let absmax = state::absmax_from_rust_forward(arch, &params, &[x.clone()]);
        let tm = state::init_trainables(
            arch,
            &params,
            &absmax,
            Mode::Lw,
            state::WeightScaleInit::Uniform,
            None,
        );
        let (lf, _) = forward_fakequant(arch, &tm, Mode::Lw, &x);
        let model = DeployedModel::prepare(arch, &tm, Mode::Lw);
        let (li, _) = model.forward_batch_feat(&x, &mut DeployScratch::new());
        // identical argmax on most rows; bias quantization is the only gap
        let af = lf.argmax_lastdim();
        let ai = li.argmax_lastdim();
        // integer logits are in *code* space for fc input; compare argmax only
        let agree = af.iter().zip(&ai).filter(|(a, b)| a == b).count();
        assert!(agree >= af.len() - 1, "agree {agree}/{}", af.len());
    }

    #[test]
    fn integer_dch_is_bit_exact_with_fakequant() {
        // dch deployment (4b codes + FP32 accumulate) IS the fake-quant graph
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["mobilenet_tiny"];
        let params = state::he_init_params(arch, 6);
        let ds = crate::data::Dataset::new(5);
        let (x, _, _) = ds.batch(crate::data::Split::Val, 0, 4);
        let absmax = state::absmax_from_rust_forward(arch, &params, &[x.clone()]);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Dch,
                                        state::WeightScaleInit::DoublyChannelwise, None);
        let (lf, ff) = forward_fakequant(arch, &tm, Mode::Dch, &x);
        let model = DeployedModel::prepare(arch, &tm, Mode::Dch);
        let (li, fi) = model.forward_batch_feat(&x, &mut DeployScratch::new());
        assert_eq!(lf.data, li.data);
        assert_eq!(ff.data, fi.data);
    }

    #[test]
    fn scratch_reuse_keeps_integer_forward_deterministic() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 2);
        let ds = crate::data::Dataset::new(1);
        let (x, _, _) = ds.batch(crate::data::Split::Calib, 0, 4);
        let absmax = state::absmax_from_rust_forward(arch, &params, &[x.clone()]);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Lw,
                                        state::WeightScaleInit::Uniform, None);
        let model = DeployedModel::prepare(arch, &tm, Mode::Lw);
        let mut scratch = DeployScratch::new();
        let a = model.forward_batch(&x, &mut scratch);
        let b = model.forward_batch(&x, &mut scratch);
        let fresh = model.forward_batch(&x, &mut DeployScratch::new());
        assert_eq!(a.data, b.data);
        assert_eq!(a.data, fresh.data);
    }
}
