//! Integer deployment simulator (S9) and the fake-quant twin (App. A rigor).
//!
//! Two forward paths over the same trainable set:
//!
//! * [`forward_fakequant`] — the FP32-represented simulation, a rust mirror
//!   of the L2 `qft.student_forward` graph (used for parity tests against
//!   the AOT `q_eval` executable and for the analysis figures).
//! * [`forward_integer`] — the fully-integer online pipeline: u8/i8 codes,
//!   integer accumulation, quantized bias at accumulator scale (Eq. 8),
//!   multiplicative recode by F̂ (Eq. 11), integer activation.  This is what
//!   actually ships on the accelerator; the gap between the two paths is the
//!   bias/threshold rounding the paper folds under "additional lossy
//!   elements".

use crate::nn::{apply_act, ArchSpec, OpKind, ParamMap};
use crate::tensor::{conv::conv2d, Tensor};
use crate::WEIGHT_QMAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// W4A8, layerwise (scalar) rescale factors; DoF {W, b, S_a, F}.
    Lw,
    /// W4A32, channelwise rescale: doubly-channelwise kernels; DoF
    /// {W, b, S_wL, S_wR}.
    Dch,
}

impl Mode {
    pub fn key(self) -> &'static str {
        match self {
            Mode::Lw => "lw",
            Mode::Dch => "dch",
        }
    }
}

const EPS: f32 = 1e-12;

fn pos(v: f32) -> f32 {
    v.abs() + EPS
}

/// Offline subgraph (Eq. 2 / Eqs. 3-4): kernel scale co-vectors for a conv.
/// Returns (s_l, s_r); depthwise convs get s_l = None (single channel axis).
pub fn kernel_covectors(
    _arch: &ArchSpec,
    tm: &ParamMap,
    mode: Mode,
    op: &crate::nn::OpSpec,
) -> (Option<Vec<f32>>, Vec<f32>) {
    match mode {
        Mode::Lw => {
            let su: Vec<f32> = tm.get(&format!("sv:{}", op.inp)).data.iter().map(|&v| pos(v)).collect();
            let sv: Vec<f32> = tm.get(&format!("sv:{}", op.out)).data.iter().map(|&v| pos(v)).collect();
            let f = pos(tm.get(&format!("f:{}", op.name)).data[0]);
            if op.groups == 1 {
                let s_l = su.iter().map(|&s| 1.0 / s).collect();
                let s_r = sv.iter().map(|&s| s * f).collect();
                (Some(s_l), s_r)
            } else {
                let s_r = sv.iter().zip(&su).map(|(&v, &u)| v * f / u).collect();
                (None, s_r)
            }
        }
        Mode::Dch => {
            let s_r: Vec<f32> = tm
                .get(&format!("swr:{}", op.name))
                .data
                .iter()
                .map(|&v| pos(v))
                .collect();
            if op.groups == 1 {
                let s_l = tm
                    .get(&format!("swl:{}", op.name))
                    .data
                    .iter()
                    .map(|&v| pos(v))
                    .collect();
                (Some(s_l), s_r)
            } else {
                (None, s_r)
            }
        }
    }
}

fn fq_kernel(w: &Tensor, s_l: &Option<Vec<f32>>, s_r: &[f32]) -> Tensor {
    match s_l {
        Some(l) => super::mmse::fq_outer(w, l, s_r, WEIGHT_QMAX),
        None => super::mmse::fq_per_out_channel(w, s_r, WEIGHT_QMAX),
    }
}

fn act_range(arch: &ArchSpec, v: usize) -> (f32, f32) {
    if arch.signed_of(v) {
        (-crate::ACT_SIGNED_QMAX, crate::ACT_SIGNED_QMAX)
    } else {
        (0.0, crate::ACT_UNSIGNED_QMAX)
    }
}

fn sv_of(tm: &ParamMap, v: usize) -> Vec<f32> {
    tm.get(&format!("sv:{v}")).data.iter().map(|&x| pos(x)).collect()
}

/// Fake-quant student forward: rust mirror of the L2 online subgraph.
pub fn forward_fakequant(
    arch: &ArchSpec,
    tm: &ParamMap,
    mode: Mode,
    x: &Tensor,
) -> (Tensor, Tensor) {
    let mut vals: std::collections::HashMap<usize, Tensor> = Default::default();
    let x0 = match mode {
        Mode::Lw => {
            let (qmin, qmax) = act_range(arch, 0);
            super::mmse::fq_act(x, &sv_of(tm, 0), qmin, qmax)
        }
        Mode::Dch => x.clone(),
    };
    vals.insert(0, x0);
    let mut logits = None;
    let mut feat = None;
    for op in &arch.ops {
        match op.kind() {
            OpKind::Conv => {
                let w = tm.get(&format!("w:{}", op.name));
                let b = tm.get(&format!("b:{}", op.name));
                let (s_l, s_r) = kernel_covectors(arch, tm, mode, op);
                let wq = fq_kernel(w, &s_l, &s_r);
                let y = conv2d(&vals[&op.inp], &wq, &b.data, op.stride, op.groups);
                let mut a = apply_act(&y, &op.act);
                if mode == Mode::Lw {
                    let (qmin, qmax) = act_range(arch, op.out);
                    a = super::mmse::fq_act(&a, &sv_of(tm, op.out), qmin, qmax);
                }
                vals.insert(op.out, a);
            }
            OpKind::Add => {
                let mut a = apply_act(&vals[&op.a].add(&vals[&op.b]), &op.act);
                if mode == Mode::Lw {
                    let (qmin, qmax) = act_range(arch, op.out);
                    a = super::mmse::fq_act(&a, &sv_of(tm, op.out), qmin, qmax);
                }
                vals.insert(op.out, a);
            }
            OpKind::Gap => {
                feat = Some(vals[&op.inp].clone());
                vals.insert(op.out, vals[&op.inp].global_avg_pool());
            }
            OpKind::Fc => {
                let w = tm.get(&format!("w:{}", op.name));
                let b = tm.get(&format!("b:{}", op.name));
                let mut y = vals[&op.inp].matmul(w);
                for row in y.data.chunks_mut(b.data.len()) {
                    for (v, &bv) in row.iter_mut().zip(&b.data) {
                        *v += bv;
                    }
                }
                logits = Some(y.clone());
                vals.insert(op.out, y);
            }
        }
    }
    (logits.unwrap(), feat.unwrap())
}

/// Fully-integer forward (lw mode): codes are f32-held integers (exact up to
/// 2^24, far above the worst-case accumulator here).
pub fn forward_integer(arch: &ArchSpec, tm: &ParamMap, x: &Tensor) -> (Tensor, Tensor) {
    // per-value integer codes
    let mut codes: std::collections::HashMap<usize, Tensor> = Default::default();
    let enc = |v: usize| -> Vec<f32> { sv_of(tm, v) };

    {
        let sv = enc(0);
        let (qmin, qmax) = act_range(arch, 0);
        let c = *x.shape.last().unwrap();
        let data = x
            .data
            .iter()
            .enumerate()
            .map(|(i, &val)| (val / sv[i % c]).round().clamp(qmin, qmax))
            .collect();
        codes.insert(0, Tensor::new(x.shape.clone(), data));
    }

    let mut logits = None;
    let mut feat = None;
    for op in &arch.ops {
        match op.kind() {
            OpKind::Conv => {
                let w = tm.get(&format!("w:{}", op.name));
                let b = tm.get(&format!("b:{}", op.name));
                let f = pos(tm.get(&format!("f:{}", op.name)).data[0]);
                let sv = enc(op.out);
                let (s_l, s_r) = kernel_covectors(arch, tm, Mode::Lw, op);
                // integer weight codes on the Eq. 2 grid
                let wcode = match &s_l {
                    Some(l) => {
                        let (cin, cout) = (w.shape[2], w.shape[3]);
                        let data = w
                            .data
                            .iter()
                            .enumerate()
                            .map(|(idx, &x)| {
                                let j = idx % cout;
                                let i = (idx / cout) % cin;
                                (x / (l[i] * s_r[j])).round().clamp(-WEIGHT_QMAX, WEIGHT_QMAX)
                            })
                            .collect();
                        Tensor::new(w.shape.clone(), data)
                    }
                    None => {
                        let cout = w.shape[3];
                        let data = w
                            .data
                            .iter()
                            .enumerate()
                            .map(|(idx, &x)| {
                                (x / s_r[idx % cout]).round().clamp(-WEIGHT_QMAX, WEIGHT_QMAX)
                            })
                            .collect();
                        Tensor::new(w.shape.clone(), data)
                    }
                };
                // accumulator scale per n: S_acc = S_v * F (Eq. 11)
                let s_acc: Vec<f32> = sv.iter().map(|&s| s * f).collect();
                // quantized bias at accumulator scale (Eq. 7, zero-points = 0
                // in our symmetric-activation-code formulation)
                let bcode: Vec<f32> = b
                    .data
                    .iter()
                    .zip(&s_acc)
                    .map(|(&bv, &s)| (bv / s).round())
                    .collect();
                let mut acc = conv2d(&codes[&op.inp], &wcode, &bcode, op.stride, op.groups);
                // integer activation
                match op.act.as_str() {
                    "relu" => acc.map_inplace(|v| v.max(0.0)),
                    "relu6" => {
                        let cout = op.cout;
                        let thr: Vec<f32> =
                            s_acc.iter().map(|&s| (6.0 / s).round()).collect();
                        for (i, v) in acc.data.iter_mut().enumerate() {
                            *v = v.clamp(0.0, thr[i % cout]);
                        }
                    }
                    _ => {}
                }
                // recode: out_code = clip(round(acc * F̂)), F̂ = S_acc/S_v = F
                let (qmin, qmax) = act_range(arch, op.out);
                acc.map_inplace(|v| (v * f).round().clamp(qmin, qmax));
                codes.insert(op.out, acc);
            }
            OpKind::Add => {
                // lossless FP ew-add (paper App. D item 1): decode, add,
                // re-encode with the output's own scale
                let dec = |vid: usize| -> Tensor {
                    let sv = enc(vid);
                    let c = *codes[&vid].shape.last().unwrap();
                    let data = codes[&vid]
                        .data
                        .iter()
                        .enumerate()
                        .map(|(i, &q)| q * sv[i % c])
                        .collect();
                    Tensor::new(codes[&vid].shape.clone(), data)
                };
                let a = apply_act(&dec(op.a).add(&dec(op.b)), &op.act);
                let sv = enc(op.out);
                let (qmin, qmax) = act_range(arch, op.out);
                let c = *a.shape.last().unwrap();
                let data = a
                    .data
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| (v / sv[i % c]).round().clamp(qmin, qmax))
                    .collect();
                codes.insert(op.out, Tensor::new(a.shape.clone(), data));
            }
            OpKind::Gap => {
                // decode to FP for the head
                let sv = enc(op.inp);
                let c = *codes[&op.inp].shape.last().unwrap();
                let data = codes[&op.inp]
                    .data
                    .iter()
                    .enumerate()
                    .map(|(i, &q)| q * sv[i % c])
                    .collect();
                let fp = Tensor::new(codes[&op.inp].shape.clone(), data);
                feat = Some(fp.clone());
                codes.insert(op.out, fp.global_avg_pool());
            }
            OpKind::Fc => {
                let w = tm.get(&format!("w:{}", op.name));
                let b = tm.get(&format!("b:{}", op.name));
                let mut y = codes[&op.inp].matmul(w);
                for row in y.data.chunks_mut(b.data.len()) {
                    for (v, &bv) in row.iter_mut().zip(&b.data) {
                        *v += bv;
                    }
                }
                logits = Some(y.clone());
                codes.insert(op.out, y);
            }
        }
    }
    (logits.unwrap(), feat.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn covectors_lw_respect_eq2() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 0);
        let ds = crate::data::Dataset::new(0);
        let batches = vec![ds.batch(crate::data::Split::Calib, 0, 4).0];
        let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Lw,
                                        state::WeightScaleInit::Uniform, None);
        for op in arch.conv_ops().into_iter().filter(|o| o.groups == 1) {
            let (s_l, s_r) = kernel_covectors(arch, &tm, Mode::Lw, op);
            let s_l = s_l.unwrap();
            let su = &tm.get(&format!("sv:{}", op.inp)).data;
            let sv = &tm.get(&format!("sv:{}", op.out)).data;
            let f = tm.get(&format!("f:{}", op.name)).data[0];
            for (l, u) in s_l.iter().zip(su) {
                assert!((l - 1.0 / (u.abs() + EPS)).abs() < 1e-5 * l);
            }
            for (r, v) in s_r.iter().zip(sv) {
                assert!((r - (v.abs() + EPS) * (f.abs() + EPS)).abs() < 1e-5 * r);
            }
        }
    }

    #[test]
    fn fakequant_dch_runs_on_depthwise_arch() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["mobilenet_tiny"];
        let params = state::he_init_params(arch, 8);
        let ds = crate::data::Dataset::new(3);
        let (x, _, _) = ds.batch(crate::data::Split::Val, 0, 4);
        let batches = vec![x.clone()];
        let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Dch,
                                        state::WeightScaleInit::DoublyChannelwise, None);
        let (logits, feat) = forward_fakequant(arch, &tm, Mode::Dch, &x);
        assert_eq!(logits.shape, vec![4, arch.num_classes]);
        assert_eq!(feat.shape[3], arch.feat_channels);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dch_with_fine_grid_close_to_fp() {
        // dch with per-channel MMSE grids must track the FP forward closely
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 10);
        let ds = crate::data::Dataset::new(4);
        let (x, _, _) = ds.batch(crate::data::Split::Val, 0, 4);
        let absmax = state::absmax_from_rust_forward(arch, &params, &[x.clone()]);
        let tm = state::init_trainables(arch, &params, &absmax, Mode::Dch,
                                        state::WeightScaleInit::DoublyChannelwise, None);
        let (_, feat_q) = forward_fakequant(arch, &tm, Mode::Dch, &x);
        let fwd = crate::nn::fp_forward(arch, &params, &x);
        let rel = feat_q.sub(&fwd.feat).norm() / fwd.feat.norm().max(1e-6);
        assert!(rel < 0.5, "rel {rel}");
    }

    #[test]
    fn integer_matches_fakequant_sim() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 2);
        let ds = crate::data::Dataset::new(1);
        let (x, _, _) = ds.batch(crate::data::Split::Calib, 0, 4);
        let absmax = state::absmax_from_rust_forward(arch, &params, &[x.clone()]);
        let tm = state::init_trainables(
            arch,
            &params,
            &absmax,
            Mode::Lw,
            state::WeightScaleInit::Uniform,
            None,
        );
        let (lf, _) = forward_fakequant(arch, &tm, Mode::Lw, &x);
        let (li, _) = forward_integer(arch, &tm, &x);
        // identical argmax on most rows; bias quantization is the only gap
        let af = lf.argmax_lastdim();
        let ai = li.argmax_lastdim();
        // integer logits are in *code* space for fc input; compare argmax only
        let agree = af.iter().zip(&ai).filter(|(a, b)| a == b).count();
        assert!(agree >= af.len() - 1, "agree {agree}/{}", af.len());
    }
}
