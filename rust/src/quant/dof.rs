//! Scale-tensor degrees-of-freedom algebra (S6): Eq. 2, its inversion
//! (Eqs. 3–4), and the accumulator-scale constraint (Eq. 8/9).
//!
//! The over-parameterized kernel scale `S_w[m,n]` is constrained by the HW
//! arithmetic to an outer product of *left* (per-input-channel) and *right*
//! (per-output-channel) co-vectors:
//!
//!   S_w[m,n] = S_wL[m] · S_wR[n],   S_wL[m] = 1/S_a^{l-1}[m],
//!   S_wR[n]  = S_a^l[n] · F^l[n]                                   (Eq. 2)
//!
//! and inversely, choosing the co-vectors as the independent DoF determines
//! the activation scales and rescale factors:
//!
//!   S_a^{l-1}[m] = 1/S_wL^l[m],  S_a^l[n] = 1/S_wL^{l+1}[n]        (Eq. 3)
//!   F^l[n] = S_wR^l[n] · S_wL^{l+1}[n]                             (Eq. 4)

/// Forward Eq. 2: derive kernel scale co-vectors from the {S_a, F} DoF set.
/// `f` may be a 1-element slice (layerwise) or per-channel (channelwise).
pub fn eq2_forward(s_a_prev: &[f32], s_a: &[f32], f: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let s_wl = s_a_prev.iter().map(|&s| 1.0 / s).collect();
    let s_wr = s_a
        .iter()
        .enumerate()
        .map(|(n, &s)| s * f[if f.len() == 1 { 0 } else { n }])
        .collect();
    (s_wl, s_wr)
}

/// Inverse (Eqs. 3–4): derive {S_a, F} from kernel co-vectors of this layer
/// and the left co-vector of the *next* layer.
pub fn eq34_invert(s_wl: &[f32], s_wr: &[f32], s_wl_next: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let s_a_prev: Vec<f32> = s_wl.iter().map(|&s| 1.0 / s).collect();
    let s_a: Vec<f32> = s_wl_next.iter().map(|&s| 1.0 / s).collect();
    assert_eq!(s_wr.len(), s_a.len(), "fan mismatch l -> l+1");
    let f: Vec<f32> = s_wr.iter().zip(&s_a).map(|(&r, &a)| r / a).collect();
    (s_a_prev, s_a, f)
}

/// The full over-parameterized grid S_w[m,n] = S_wL[m]·S_wR[n].
pub fn outer_grid(s_wl: &[f32], s_wr: &[f32]) -> Vec<f32> {
    let mut g = Vec::with_capacity(s_wl.len() * s_wr.len());
    for &l in s_wl {
        for &r in s_wr {
            g.push(l * r);
        }
    }
    g
}

/// Accumulator scale (Eq. 8): S_acc[n] = S_w[m,n]·S_a^{l-1}[m]; well-defined
/// (m-invariant) exactly when S_w is the Eq. 2 outer product.  Returns the
/// per-n accumulator scale, asserting m-invariance to `tol`.
pub fn accumulator_scale(
    s_w_grid: &[f32],
    s_a_prev: &[f32],
    cout: usize,
    tol: f32,
) -> Result<Vec<f32>, String> {
    let cin = s_a_prev.len();
    assert_eq!(s_w_grid.len(), cin * cout);
    let mut acc = vec![0.0f32; cout];
    for n in 0..cout {
        let first = s_w_grid[n] * s_a_prev[0];
        for m in 0..cin {
            let v = s_w_grid[m * cout + n] * s_a_prev[m];
            if (v - first).abs() > tol * first.abs().max(1e-12) {
                return Err(format!(
                    "accumulator scale not m-invariant at (m={m}, n={n}): {v} vs {first}"
                ));
            }
        }
        acc[n] = first;
    }
    Ok(acc)
}

/// Scalar rescale demotion (layerwise HW): F must be rank-0.
pub fn is_layerwise(f: &[f32], tol: f32) -> bool {
    f.iter().all(|&v| (v - f[0]).abs() <= tol * f[0].abs().max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    // Randomized property tests: the image's cargo cache has no proptest, so
    // we sweep 200 seeded cases per property with the in-repo RNG.
    const CASES: u64 = 200;

    fn pos_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range(0.01, 10.0)).collect()
    }

    #[test]
    fn prop_eq2_eq34_roundtrip() {
        for seed in 0..CASES {
            let mut rng = Rng::new(seed);
            let s_wl = pos_vec(&mut rng, 8);
            let s_wr = pos_vec(&mut rng, 6);
            let s_wl_next = pos_vec(&mut rng, 6);
            // invert then re-apply Eq. 2: co-vectors are recovered exactly
            let (s_a_prev, s_a, f) = eq34_invert(&s_wl, &s_wr, &s_wl_next);
            let (s_wl2, s_wr2) = eq2_forward(&s_a_prev, &s_a, &f);
            for (a, b) in s_wl.iter().zip(&s_wl2) {
                assert!((a - b).abs() < 1e-3 * a.abs(), "seed {seed}");
            }
            for (a, b) in s_wr.iter().zip(&s_wr2) {
                assert!((a - b).abs() < 1e-3 * a.abs(), "seed {seed}");
            }
        }
    }

    #[test]
    fn prop_outer_grid_accumulator_invariant() {
        for seed in 0..CASES {
            let mut rng = Rng::new(seed ^ 0xACC);
            let s_a_prev = pos_vec(&mut rng, 5);
            let s_a = pos_vec(&mut rng, 7);
            let f = pos_vec(&mut rng, 7);
            // any Eq. 2 grid satisfies the same-scale accumulation constraint
            let (s_wl, s_wr) = eq2_forward(&s_a_prev, &s_a, &f);
            let grid = outer_grid(&s_wl, &s_wr);
            let acc = accumulator_scale(&grid, &s_a_prev, 7, 1e-4).unwrap();
            // and the accumulator scale equals S_a * F (recode relation Eq. 11)
            for n in 0..7 {
                assert!((acc[n] - s_a[n] * f[n]).abs() < 1e-3 * acc[n], "seed {seed}");
            }
        }
    }

    #[test]
    fn prop_layerwise_f_is_scalar() {
        for seed in 0..CASES {
            let mut rng = Rng::new(seed ^ 0xF0);
            let s_a_prev = pos_vec(&mut rng, 4);
            let s_a = pos_vec(&mut rng, 4);
            let f0 = rng.range(0.01, 10.0);
            let (_, s_wr) = eq2_forward(&s_a_prev, &s_a, &[f0]);
            // right co-vector = S_a * scalar F: recovering F per-channel gives
            // a constant vector
            let f_rec: Vec<f32> = s_wr.iter().zip(&s_a).map(|(r, a)| r / a).collect();
            assert!(is_layerwise(&f_rec, 1e-4), "seed {seed}");
        }
    }

    #[test]
    fn non_outer_grid_rejected() {
        // a grid violating the outer-product constraint fails Eq. 8
        let grid = vec![1.0, 1.0, 1.0, 2.0]; // 2x2, not rank-1
        let err = accumulator_scale(&grid, &[1.0, 1.0], 2, 1e-6);
        assert!(err.is_err());
    }

    #[test]
    fn cle_freedom_is_null_direction() {
        // scaling S_a^{l-1} by per-channel C and the *previous* right
        // co-vector accordingly leaves this layer's grid consistent: the CLE
        // DoF (Corollary 1) is exactly the freedom to move S_a.
        let s_a_prev = [0.1f32, 0.2, 0.4];
        let s_a = [0.3f32, 0.5];
        let f = [1.5f32];
        let c = [2.0f32, 0.5, 4.0];
        let (s_wl, s_wr) = eq2_forward(&s_a_prev, &s_a, &f);
        let scaled_prev: Vec<f32> = s_a_prev.iter().zip(&c).map(|(s, c)| s * c).collect();
        let (s_wl2, s_wr2) = eq2_forward(&scaled_prev, &s_a, &f);
        // right co-vector unchanged, left scaled by 1/C
        assert_eq!(s_wr, s_wr2);
        for ((a, b), &ci) in s_wl.iter().zip(&s_wl2).zip(&c) {
            assert!((b * ci - a).abs() < 1e-6);
        }
    }
}
