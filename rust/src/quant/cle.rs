//! 4b-adapted Cross-Layer Equalization (S7) — Appendix D, Eqs. 19–21.
//!
//! Reformulated as the activation vector-scale DoF (Eq. 18): instead of
//! pre-conditioning weights, CLE factors C_m multiply the producer-side
//! activation scale `S_a^{l-1}`, with the kernel grids following via Eq. 2.
//! For 4-bit weights, the per-slice optimum is the *MMSE* range (PPQ), not
//! naive max — the geometric-mean heuristic is applied to MMSE ratios:
//!
//!   2·log C_m = (1+β)·log(Ŝ_wR^{l-1}_m / ŝ_w^{l-1})
//!             + (1−β)·log(ŝ_w^l / Ŝ_wL^l_m)                        (Eq. 21)
//!
//! β = 0 for a homogeneous pair; β = ±0.5 skews toward the lower-bitwidth
//! layer; β = 1 (producer-only) when the consumer is lossless (ew-add) or
//! has per-channel flexibility of its own (depthwise).  Fan-out replaces the
//! consumer term with the mean over all consumer convs (App. D item 2 —
//! consumers share S_a structurally in our IR).

use std::collections::HashMap;

use crate::nn::{conv_consumers, producers, ArchSpec, OpKind, ParamMap};
use crate::quant::{mmse, ppq};

/// Per-layer bit-width assignment (all 4b by default; supports the paper's
/// heterogeneous 8b-smallest-layers rule via [`eightbit_layer_set`]).
#[derive(Clone, Debug, Default)]
pub struct BitConfig {
    /// conv names quantized at 8b instead of 4b.
    pub eightbit: std::collections::HashSet<String>,
}

impl BitConfig {
    pub fn qmax(&self, conv_name: &str) -> f32 {
        if self.eightbit.contains(conv_name) {
            127.0
        } else {
            crate::WEIGHT_QMAX
        }
    }

    pub fn beta(&self, producer: &str, consumer: &str) -> f32 {
        match (
            self.eightbit.contains(producer),
            self.eightbit.contains(consumer),
        ) {
            (true, false) => -0.5, // producer 8b, consumer 4b: favor consumer
            (false, true) => 0.5,  // producer 4b: favor producer
            _ => 0.0,
        }
    }
}

/// §4's flat-overhead heterogeneous rule: smallest conv layers, by weight
/// count, until their cumulative footprint reaches `frac` of the backbone.
pub fn eightbit_layer_set(arch: &ArchSpec, frac: f32) -> BitConfig {
    let total: usize = arch.conv_weight_numel();
    let mut sizes: Vec<(usize, String)> = arch
        .conv_ops()
        .iter()
        .map(|o| (o.k * o.k * (o.cin / o.groups) * o.cout, o.name.clone()))
        .collect();
    sizes.sort();
    let mut cfg = BitConfig::default();
    let mut acc = 0usize;
    for (sz, name) in sizes {
        if (acc + sz) as f32 > frac * total as f32 {
            break;
        }
        acc += sz;
        cfg.eightbit.insert(name);
    }
    cfg
}

/// Compute per-quantized-value CLE factors C (len = channels of the value).
///
/// Returns a map value-id -> factors; values without a conv producer or
/// without usable consumer structure get all-ones (no-op).
pub fn cle_factors(
    arch: &ArchSpec,
    params: &ParamMap,
    bits: &BitConfig,
) -> HashMap<usize, Vec<f32>> {
    let prod = producers(arch);
    let cons = conv_consumers(arch);
    let mut out = HashMap::new();

    for &v in &arch.quantized_values {
        let ch = arch.channels_of(v);
        let mut c = vec![1.0f32; ch];

        // producer must be a groups==1 conv (depthwise has no right co-vector
        // freedom distinct from its single channel axis)
        let Some(&pi) = prod.get(&v) else {
            out.insert(v, c);
            continue;
        };
        let pop = &arch.ops[pi];
        if pop.kind() != OpKind::Conv || pop.groups != 1 {
            out.insert(v, c);
            continue;
        }
        let wp = params.get(&format!("w:{}", pop.name));
        let qmax_p = bits.qmax(&pop.name);
        let s_full_p = ppq::mmse_scale(&wp.data, qmax_p);

        // producer term per channel m: log(S_wR^{l-1}_m / s_w^{l-1})
        let mut terms_p = Vec::with_capacity(ch);
        for m in 0..ch {
            let slice = mmse::out_channel_slice(wp, m);
            let s = ppq::mmse_scale(&slice, qmax_p);
            terms_p.push((s / s_full_p).ln());
        }

        // consumer terms: mean over conv consumers of log(s_w^l / S_wL^l_m)
        let mut betas = Vec::new();
        let mut terms_c = vec![0.0f32; ch];
        let mut n_cons = 0usize;
        for &ci in cons.get(&v).map(|v| v.as_slice()).unwrap_or(&[]) {
            let cop = &arch.ops[ci];
            if cop.groups != 1 {
                continue; // depthwise consumer ~ per-channel flexible: skip
            }
            let wc = params.get(&format!("w:{}", cop.name));
            let qmax_c = bits.qmax(&cop.name);
            let s_full_c = ppq::mmse_scale(&wc.data, qmax_c);
            for (m, t) in terms_c.iter_mut().enumerate() {
                let slice = mmse::in_channel_slice(wc, m);
                let s = ppq::mmse_scale(&slice, qmax_c);
                *t += (s_full_c / s).ln();
            }
            betas.push(bits.beta(&pop.name, &cop.name));
            n_cons += 1;
        }

        if n_cons == 0 {
            // lossless consumers only (ew-add / gap): β = 1, full benefit of
            // the producer (App. D item 1)
            for (cm, tp) in c.iter_mut().zip(&terms_p) {
                *cm = tp.exp();
            }
        } else {
            let beta = betas.iter().sum::<f32>() / n_cons as f32;
            for m in 0..ch {
                let tc = terms_c[m] / n_cons as f32;
                let log_c = 0.5 * ((1.0 + beta) * terms_p[m] + (1.0 - beta) * tc);
                c[m] = log_c.exp();
            }
        }
        out.insert(v, c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::tensor::Tensor;

    #[test]
    fn beta_rules() {
        let mut bits = BitConfig::default();
        bits.eightbit.insert("conv8".into());
        assert_eq!(bits.beta("conv4", "conv4b"), 0.0);
        assert_eq!(bits.beta("conv8", "conv4"), -0.5);
        assert_eq!(bits.beta("conv4", "conv8"), 0.5);
        assert_eq!(bits.qmax("conv8"), 127.0);
        assert_eq!(bits.qmax("conv4"), 7.0);
    }

    #[test]
    fn geometric_mean_on_synthetic_pair() {
        // Toy case of Eq. 17: producer slice m has tiny range, consumer slice
        // m has large range; the factor must be > 1 (boost the weak slice).
        let (k, c) = (1usize, 4usize);
        let mut r = Rng::new(0);
        // producer kernel [1,1,4,4]: output channel 0 weak
        let mut wp = vec![0.0f32; c * c];
        for i in 0..c {
            for j in 0..c {
                let gain = if j == 0 { 1.0 / 32.0 } else { 1.0 };
                wp[i * c + j] = r.normal() * gain;
            }
        }
        // consumer kernel: input channel 0 strong
        let mut wc = vec![0.0f32; c * c];
        for i in 0..c {
            for j in 0..c {
                let gain = if i == 0 { 0.5 } else { 1.0 };
                wc[i * c + j] = r.normal() * gain;
            }
        }
        let wp = Tensor::new(vec![k, k, c, c], wp);
        let wc = Tensor::new(vec![k, k, c, c], wc);
        let s_full_p = ppq::mmse_scale(&wp.data, 7.0);
        let s_slice_p = ppq::mmse_scale(&mmse::out_channel_slice(&wp, 0), 7.0);
        let s_full_c = ppq::mmse_scale(&wc.data, 7.0);
        let s_slice_c = ppq::mmse_scale(&mmse::in_channel_slice(&wc, 0), 7.0);
        let log_c = 0.5 * ((s_slice_p / s_full_p).ln() + (s_full_c / s_slice_c).ln());
        // weak producer slice -> first term << 0... factor < 1 shrinks S_a,
        // boosting the producer's effective resolution on that channel.
        assert!(log_c < 0.0, "log_c = {log_c}");
    }

    #[test]
    fn eightbit_set_respects_budget() {
        // needs a manifest; skip silently when artifacts are absent
        let Ok(m) = crate::runtime::manifest::Manifest::load("artifacts/manifest.json") else {
            return;
        };
        for arch in m.archs.values() {
            let cfg = eightbit_layer_set(arch, 0.01);
            let total = arch.conv_weight_numel();
            let marked: usize = arch
                .conv_ops()
                .iter()
                .filter(|o| cfg.eightbit.contains(&o.name))
                .map(|o| o.k * o.k * (o.cin / o.groups) * o.cout)
                .sum();
            assert!(marked as f32 <= 0.01 * total as f32);
        }
    }

    #[test]
    fn cle_factors_are_positive_and_finite() {
        let Ok(m) = crate::runtime::manifest::Manifest::load("artifacts/manifest.json") else {
            return;
        };
        let arch = &m.archs["resnet_tiny"];
        let params = crate::coordinator::state::he_init_params(arch, 1);
        let f = cle_factors(arch, &params, &BitConfig::default());
        for (v, c) in &f {
            assert_eq!(c.len(), arch.channels_of(*v));
            assert!(c.iter().all(|x| x.is_finite() && *x > 0.0));
        }
    }

    #[test]
    fn cle_reduces_pairwise_error_on_skewed_net() {
        // Build a 2-conv toy net in tensors only and verify that applying the
        // factors reduces combined 4b error (the core CLE mechanism).
        let (c0, c1, c2) = (4usize, 6usize, 4usize);
        let mut r = Rng::new(3);
        let gains: Vec<f32> = (0..c1).map(|i| 4f32.powf(i as f32 / c1 as f32 - 0.5)).collect();
        let mut w1 = vec![0.0f32; c0 * c1];
        for i in 0..c0 {
            for (j, &g) in gains.iter().enumerate() {
                w1[i * c1 + j] = r.normal() * 0.1 * g;
            }
        }
        let mut w2 = vec![0.0f32; c1 * c2];
        for (i, &g) in gains.iter().enumerate() {
            for j in 0..c2 {
                w2[i * c2 + j] = r.normal() * 0.1 / g;
            }
        }
        let w1 = Tensor::new(vec![1, 1, c0, c1], w1);
        let w2 = Tensor::new(vec![1, 1, c1, c2], w2);

        let err = |w1: &Tensor, w2: &Tensor| {
            let s1 = ppq::mmse_scale(&w1.data, 7.0);
            let s2 = ppq::mmse_scale(&w2.data, 7.0);
            let e1 = ppq::quant_error(&w1.data, s1, 7.0);
            let e2 = ppq::quant_error(&w2.data, s2, 7.0);
            (e1 * e1 + e2 * e2).sqrt()
        };
        let before = err(&w1, &w2);

        // Eq. 19 factors from MMSE ratios
        let s_full_1 = ppq::mmse_scale(&w1.data, 7.0);
        let s_full_2 = ppq::mmse_scale(&w2.data, 7.0);
        let mut w1e = w1.clone();
        let mut w2e = w2.clone();
        for m in 0..c1 {
            let sr = ppq::mmse_scale(&mmse::out_channel_slice(&w1, m), 7.0);
            let sl = ppq::mmse_scale(&mmse::in_channel_slice(&w2, m), 7.0);
            let cm = (0.5 * ((sr / s_full_1).ln() + (s_full_2 / sl).ln())).exp();
            // equivalence transform Eq. 16: W1[:,m] /= C, W2[m,:] *= C
            for i in 0..c0 {
                w1e.data[i * c1 + m] /= cm;
            }
            for j in 0..c2 {
                w2e.data[m * c2 + j] *= cm;
            }
        }
        let after = err(&w1e, &w2e);
        assert!(after < before, "CLE did not reduce error: {after} vs {before}");
    }
}
