//! MMSE at all scale-tensor granularities (Eq. 5) + fake-quant helpers.
//!
//! `MMSE(W)` (layerwise, scalar scale), `MMSE_Ch(W)` (per-output-channel
//! right co-vector), `MMSE_dCh(W)` (left ⊗ right, via APQ) — the Fig. 3
//! hierarchy.  HWIO kernel layout throughout.

use crate::quant::apq::{apq, KernelView};
use crate::quant::ppq;
use crate::tensor::Tensor;

/// Layerwise scalar-MMSE scale + error for a kernel.
pub fn mmse_layerwise(w: &Tensor, qmax: f32) -> (f32, f32) {
    let s = ppq::mmse_scale(&w.data, qmax);
    (s, ppq::quant_error(&w.data, s, qmax))
}

/// Slice of an HWIO kernel along the *output* channel j (the standard
/// per-channel quantization axis, "right" co-vector).
pub fn out_channel_slice(w: &Tensor, j: usize) -> Vec<f32> {
    let cout = w.shape[3];
    w.data.iter().skip(j).step_by(cout).copied().collect()
}

/// Slice along the *input* channel i ("left" co-vector axis).
pub fn in_channel_slice(w: &Tensor, i: usize) -> Vec<f32> {
    let (cin, cout) = (w.shape[2], w.shape[3]);
    let k2 = w.shape[0] * w.shape[1];
    let mut out = Vec::with_capacity(k2 * cout);
    for e in 0..k2 {
        let base = (e * cin + i) * cout;
        out.extend_from_slice(&w.data[base..base + cout]);
    }
    out
}

/// Channelwise MMSE: per-output-channel PPQ. Returns (scales[cout], error).
pub fn mmse_channelwise(w: &Tensor, qmax: f32) -> (Vec<f32>, f32) {
    let cout = w.shape[3];
    let mut scales = Vec::with_capacity(cout);
    let mut e2 = 0.0f32;
    for j in 0..cout {
        let slice = out_channel_slice(w, j);
        let s = ppq::mmse_scale(&slice, qmax);
        let e = ppq::quant_error(&slice, s, qmax);
        e2 += e * e;
        scales.push(s);
    }
    (scales, e2.sqrt())
}

/// Doubly-channelwise MMSE via APQ. Returns (s_left[cin], s_right[cout], err).
pub fn mmse_dch(w: &Tensor, qmax: f32, iters: usize) -> (Vec<f32>, Vec<f32>, f32) {
    let view = KernelView::from_hwio(&w.data, w.shape[0], w.shape[2], w.shape[3]);
    let r = apq(&view, qmax, iters);
    (r.s, r.t, r.error)
}

/// Fake-quantize a tensor with a scalar scale.
pub fn fq_scalar(w: &Tensor, s: f32, qmax: f32) -> Tensor {
    w.map(|x| (x / s).round().clamp(-qmax, qmax) * s)
}

/// Fake-quantize an HWIO kernel with per-output-channel scales.
pub fn fq_per_out_channel(w: &Tensor, scales: &[f32], qmax: f32) -> Tensor {
    let cout = w.shape[3];
    assert_eq!(scales.len(), cout);
    let data = w
        .data
        .iter()
        .enumerate()
        .map(|(idx, &x)| {
            let s = scales[idx % cout];
            (x / s).round().clamp(-qmax, qmax) * s
        })
        .collect();
    Tensor::new(w.shape.clone(), data)
}

/// Fake-quantize an HWIO kernel with an outer-product (s_l ⊗ s_r) grid.
pub fn fq_outer(w: &Tensor, s_l: &[f32], s_r: &[f32], qmax: f32) -> Tensor {
    let (cin, cout) = (w.shape[2], w.shape[3]);
    assert_eq!(s_l.len(), cin);
    assert_eq!(s_r.len(), cout);
    let data = w
        .data
        .iter()
        .enumerate()
        .map(|(idx, &x)| {
            let j = idx % cout;
            let i = (idx / cout) % cin;
            let s = s_l[i] * s_r[j];
            (x / s).round().clamp(-qmax, qmax) * s
        })
        .collect();
    Tensor::new(w.shape.clone(), data)
}

/// Fake-quantize NHWC activations with a per-channel vector scale.
pub fn fq_act(x: &Tensor, scales: &[f32], qmin: f32, qmax: f32) -> Tensor {
    let c = *x.shape.last().unwrap();
    assert_eq!(scales.len(), c);
    let data = x
        .data
        .iter()
        .enumerate()
        .map(|(idx, &v)| {
            let s = scales[idx % c];
            (v / s).round().clamp(qmin, qmax) * s
        })
        .collect();
    Tensor::new(x.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn rand_kernel(k: usize, cin: usize, cout: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let gains: Vec<f32> = (0..cout).map(|_| 2f32.powf(r.range(-2.0, 2.0))).collect();
        let data = (0..k * k * cin * cout)
            .map(|idx| r.normal() * 0.1 * gains[idx % cout])
            .collect();
        Tensor::new(vec![k, k, cin, cout], data)
    }

    #[test]
    fn granularity_hierarchy() {
        // Fig. 3: every extra vector DoF reduces local error
        let w = rand_kernel(3, 8, 16, 1);
        let (_, e_lw) = mmse_layerwise(&w, 7.0);
        let (_, e_ch) = mmse_channelwise(&w, 7.0);
        let (_, _, e_dch) = mmse_dch(&w, 7.0, 10);
        assert!(e_ch <= e_lw);
        assert!(e_dch <= e_ch * 1.05);
    }

    #[test]
    fn slices_partition_kernel() {
        let w = rand_kernel(3, 4, 6, 2);
        let total: usize = (0..6).map(|j| out_channel_slice(&w, j).len()).sum();
        assert_eq!(total, w.len());
        let total_in: usize = (0..4).map(|i| in_channel_slice(&w, i).len()).sum();
        assert_eq!(total_in, w.len());
        // energy is preserved by slicing
        let e_out: f32 = (0..6)
            .map(|j| out_channel_slice(&w, j).iter().map(|v| v * v).sum::<f32>())
            .sum();
        assert!((e_out - w.sq_norm()).abs() < 1e-3);
    }

    #[test]
    fn fq_outer_matches_manual() {
        let w = Tensor::new(vec![1, 1, 2, 2], vec![0.5, -0.3, 0.2, 0.8]);
        let s_l = [1.0, 2.0];
        let s_r = [0.1, 0.05];
        let q = fq_outer(&w, &s_l, &s_r, 7.0);
        // element (i=0,j=0): s=0.1 -> round(5)=5 -> 0.5
        assert!((q.data[0] - 0.5).abs() < 1e-6);
        // element (i=1,j=1): s=0.1 -> round(8) clip 7 -> 0.7
        assert!((q.data[3] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn fq_per_out_channel_matches_slice_ppq() {
        let w = rand_kernel(3, 4, 4, 3);
        let (scales, err) = mmse_channelwise(&w, 7.0);
        let q = fq_per_out_channel(&w, &scales, 7.0);
        let direct = w.sub(&q).norm();
        assert!((direct - err).abs() < 1e-3, "{direct} vs {err}");
    }

    #[test]
    fn fq_act_unsigned_clips_negatives() {
        let x = Tensor::new(vec![1, 1, 1, 2], vec![-1.0, 0.5]);
        let q = fq_act(&x, &[0.01, 0.01], 0.0, 255.0);
        assert_eq!(q.data[0], 0.0);
        assert!((q.data[1] - 0.5).abs() < 0.01);
    }
}
