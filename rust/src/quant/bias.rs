//! Bias degrees of freedom (S8): empirical bias correction [29] and the
//! quantized-bias residue absorption of Eq. 7 / App. A.

use std::collections::HashMap;

use crate::nn::{fp_forward, ArchSpec, OpKind, ParamMap};
use crate::tensor::{conv::conv2d, Tensor};

/// Empirical bias correction ("BC*", Table 2): zero the first moment of the
/// per-channel quantization error,  b_n += E[conv(a, W)_n − conv(a, Ŵ)_n],
/// expectations over a few calibration batches of *FP* activations (the
/// local-proxy formulation of [29]).
///
/// `quant_weights` maps conv name -> fake-quantized kernel; biases in
/// `params_q` are adjusted in place.
pub fn bias_correct(
    arch: &ArchSpec,
    params_fp: &ParamMap,
    params_q: &mut ParamMap,
    quant_weights: &HashMap<String, Tensor>,
    calib_batches: &[Tensor],
) {
    // accumulate per-conv per-channel mean error over all batches
    let mut sums: HashMap<String, Vec<f64>> = HashMap::new();
    let mut counts: HashMap<String, u64> = HashMap::new();

    for x in calib_batches {
        let fwd = fp_forward(arch, params_fp, x);
        for op in &arch.ops {
            if op.kind() != OpKind::Conv {
                continue;
            }
            let a_in = &fwd.values[&op.inp];
            let w_fp = params_fp.get(&format!("w:{}", op.name));
            let w_q = &quant_weights[&op.name];
            let zeros = vec![0.0f32; op.cout];
            let y_fp = conv2d(a_in, w_fp, &zeros, op.stride, op.groups);
            let y_q = conv2d(a_in, w_q, &zeros, op.stride, op.groups);
            let diff = y_fp.sub(&y_q);
            let sum = sums
                .entry(op.name.clone())
                .or_insert_with(|| vec![0.0; op.cout]);
            for chunk in diff.data.chunks(op.cout) {
                for (s, &d) in sum.iter_mut().zip(chunk) {
                    *s += d as f64;
                }
            }
            *counts.entry(op.name.clone()).or_default() +=
                (diff.len() / op.cout) as u64;
        }
    }

    for (name, sum) in sums {
        let n = counts[&name] as f64;
        let b = params_q.get_mut(&format!("b:{name}"));
        for (bv, s) in b.data.iter_mut().zip(sum) {
            *bv += (s / n) as f32;
        }
    }
}

/// Quantized-bias residue absorption (Eq. 7 / App. A): for unsigned encoding
/// with zero-point Z(x), the requirement Z_n(y) = 0 solves to
///   b̂_n = b_n / S_acc_n − Σ_m Z_m(x) · Ŵ_{m,n}
/// Returns the integer bias codes given the accumulator scale per channel.
pub fn quantized_bias(
    bias: &[f32],
    s_acc: &[f32],
    zero_points: &[f32],
    w_codes: &Tensor, // HWIO integer codes
) -> Vec<f32> {
    let (cin, cout) = (w_codes.shape[2], w_codes.shape[3]);
    let k2 = w_codes.shape[0] * w_codes.shape[1];
    assert_eq!(bias.len(), cout);
    assert_eq!(s_acc.len(), cout);
    assert_eq!(zero_points.len(), cin);
    let mut out: Vec<f32> = bias
        .iter()
        .zip(s_acc)
        .map(|(&b, &s)| (b / s).round())
        .collect();
    for e in 0..k2 {
        for m in 0..cin {
            if zero_points[m] == 0.0 {
                continue;
            }
            let base = (e * cin + m) * cout;
            for n in 0..cout {
                out[n] -= zero_points[m] * w_codes.data[base + n];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantized_bias_no_zero_point_is_plain_rescale() {
        let w = Tensor::zeros(&[1, 1, 2, 2]);
        let b = quantized_bias(&[0.5, -0.25], &[0.1, 0.05], &[0.0, 0.0], &w);
        assert_eq!(b, vec![5.0, -5.0]);
    }

    #[test]
    fn quantized_bias_absorbs_residue() {
        // Ŵ = [[1,2],[3,4]], Z(x) = [1,1]: residue per n = sum_m Ŵ[m,n]
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = quantized_bias(&[0.0, 0.0], &[1.0, 1.0], &[1.0, 1.0], &w);
        assert_eq!(b, vec![-4.0, -6.0]);
    }

    #[test]
    fn bias_correct_zeroes_first_moment() {
        // one-conv toy arch built by hand through the manifest types is heavy;
        // emulate directly: conv with quantization error must get its mean
        // error folded into bias.
        let Ok(m) = crate::runtime::manifest::Manifest::load("artifacts/manifest.json") else {
            return;
        };
        let arch = &m.archs["convnet_tiny"];
        let params = crate::coordinator::state::he_init_params(arch, 5);
        let mut params_q = params.clone();

        // crude quantized weights: layerwise mmse
        let mut qw = HashMap::new();
        for op in arch.conv_ops() {
            let w = params.get(&format!("w:{}", op.name));
            let s = crate::quant::ppq::mmse_scale(&w.data, 7.0);
            qw.insert(op.name.clone(), crate::quant::mmse::fq_scalar(w, s, 7.0));
        }
        let ds = crate::data::Dataset::new(0);
        let batches: Vec<Tensor> = (0..2)
            .map(|i| ds.batch(crate::data::Split::Calib, i * 8, 8).0)
            .collect();
        bias_correct(arch, &params, &mut params_q, &qw, &batches);

        // after BC: per-channel mean of (fp-pre-act − q-pre-act) ~ 0 on the
        // same batches for the first conv
        let op = &arch.conv_ops()[0].clone();
        let fwd = fp_forward(arch, &params, &batches[0]);
        let a_in = &fwd.values[&op.inp];
        let bq = params_q.get(&format!("b:{}", op.name));
        let bfp = params.get(&format!("b:{}", op.name));
        let y_fp = conv2d(a_in, params.get(&format!("w:{}", op.name)), &bfp.data, op.stride, op.groups);
        let y_q = conv2d(a_in, &qw[&op.name], &bq.data, op.stride, op.groups);
        let diff = y_fp.sub(&y_q);
        let mut mean = vec![0.0f32; op.cout];
        for chunk in diff.data.chunks(op.cout) {
            for (s, &d) in mean.iter_mut().zip(chunk) {
                *s += d;
            }
        }
        let n = (diff.len() / op.cout) as f32;
        for v in &mut mean {
            *v /= n;
        }
        let before_mag = bq.data.iter().zip(&bfp.data).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(before_mag > 0.0, "BC did not modify biases at all");
        // residual first moment much smaller than the applied correction
        let resid = mean.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(resid < 0.35 * before_mag.max(1e-6), "resid {resid} corr {before_mag}");
    }
}
