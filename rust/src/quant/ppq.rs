//! Algorithm 1 — PPQ (Progressive Projection Quantization), from [14],
//! reproduced in the paper's Appendix C.
//!
//! Scalar-scale MMSE:  min_s ‖x − s·clip(round(x/s))‖.
//! Iterate  q ← clip(round(x/s));  s ← ⟨q,x⟩/⟨q,q⟩  — at convergence the
//! error e = s·q − x is orthogonal to q (the orthogonality principle for
//! linear estimators, Eq. 14), hence locally optimal.  Converges in a low
//! single-digit number of iterations in practice.

/// Solve scalar-MMSE for a symmetric grid with `qmax = 2^{b-1}-1`.
///
/// The projection iteration is local over a piecewise-smooth objective, so we
/// multi-start from several fractions of the naive max range (App. D notes
/// the 4b optimum typically sits near 1/4 of max(|.|)) and keep the best.
pub fn ppq_scale(x: &[f32], qmax: f32, iters: usize) -> f32 {
    let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if absmax == 0.0 {
        return 1e-8;
    }
    let mut best_s = absmax / qmax;
    let mut best_e = f32::MAX;
    for frac in [1.0f32, 0.5, 0.25] {
        let s = ppq_from(x, qmax, iters, absmax / qmax * frac);
        let e = quant_error(x, s, qmax);
        if e < best_e {
            best_e = e;
            best_s = s;
        }
    }
    best_s
}

/// One PPQ run from a given initial scale.
fn ppq_from(x: &[f32], qmax: f32, iters: usize, init: f32) -> f32 {
    let mut s = init;
    for _ in 0..iters {
        let (mut qx, mut qq) = (0.0f64, 0.0f64);
        for &v in x {
            let q = (v / s).round().clamp(-qmax, qmax) as f64;
            qx += q * v as f64;
            qq += q * q;
        }
        if qq == 0.0 {
            break;
        }
        let new_s = (qx / qq) as f32;
        if new_s <= 0.0 || !new_s.is_finite() {
            break;
        }
        if (new_s - s).abs() <= 1e-7 * s {
            s = new_s;
            break;
        }
        s = new_s;
    }
    s
}

/// MMSE error ‖x − s·clip(round(x/s))‖ for a given scale.
pub fn quant_error(x: &[f32], s: f32, qmax: f32) -> f32 {
    x.iter()
        .map(|&v| {
            let dq = (v / s).round().clamp(-qmax, qmax) * s;
            let e = v - dq;
            e * e
        })
        .sum::<f32>()
        .sqrt()
}

/// Convenience: PPQ with the paper's practical default iteration budget.
pub fn mmse_scale(x: &[f32], qmax: f32) -> f32 {
    ppq_scale(x, qmax, 20)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn ppq_beats_naive_max_at_4b() {
        // the 4b regime: optimal clipping ~1/4 of max (paper App. D)
        for seed in 0..5 {
            let x = randn(4096, seed);
            let naive = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 7.0;
            let opt = mmse_scale(&x, 7.0);
            assert!(
                quant_error(&x, opt, 7.0) < quant_error(&x, naive, 7.0),
                "seed {seed}"
            );
            // optimal range is a fraction of naive max for gaussian weights
            assert!(opt < naive, "opt {opt} naive {naive}");
        }
    }

    #[test]
    fn ppq_error_orthogonality() {
        // at convergence <e, q> ~= 0 (Eq. 14)
        let x = randn(2048, 42);
        let s = ppq_scale(&x, 7.0, 50);
        let (mut eq, mut qq) = (0.0f64, 0.0f64);
        for &v in &x {
            let q = (v / s).round().clamp(-7.0, 7.0);
            let e = s * q - v;
            eq += (e * q) as f64;
            qq += (q * q) as f64;
        }
        assert!((eq / qq).abs() < 1e-3, "{}", eq / qq);
    }

    #[test]
    fn ppq_near_global_optimum_vs_dense_scan() {
        // PPQ is a local projection method over a piecewise-smooth objective;
        // it need not hit the exact global optimum, but it must land within a
        // few percent of a dense 400-point scan over the plausible range.
        for seed in [7, 11, 23] {
            let x = randn(2048, seed);
            let naive = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 7.0;
            let s = mmse_scale(&x, 7.0);
            let e_ppq = quant_error(&x, s, 7.0);
            let mut best = f32::MAX;
            for i in 1..=400 {
                let cand = naive * (i as f32 / 400.0 * 1.2);
                best = best.min(quant_error(&x, cand, 7.0));
            }
            assert!(e_ppq <= best * 1.05, "seed {seed}: ppq {e_ppq} vs scan {best}");
        }
    }

    #[test]
    fn ppq_8b_close_to_naive() {
        // at 8b, MMSE ~ degenerate (little clipping) — App. D
        let x = randn(4096, 3);
        let naive = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())) / 127.0;
        let opt = mmse_scale(&x, 127.0);
        assert!(opt / naive > 0.5, "opt/naive = {}", opt / naive);
    }

    #[test]
    fn ppq_handles_zeros_and_constants() {
        assert!(mmse_scale(&[0.0; 16], 7.0) > 0.0);
        let s = mmse_scale(&[0.5; 16], 7.0);
        // constant vector: exact representation possible
        assert!(quant_error(&[0.5; 16], s, 7.0) < 1e-4);
    }
}
