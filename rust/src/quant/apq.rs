//! Algorithm 2 — APQ (Alternating Projection Quantization), the paper's
//! novel procedure for the *doubly-channelwise* MMSE problem (Appendix C):
//!
//!   min_{S, T} ‖ X[i,j,·] − S_i·T_j · clip(round(X[i,j,·]/(S_i·T_j))) ‖
//!
//! where i indexes input channels (rows), j output channels (columns) and ·
//! the k·k spatial taps folded into each (i,j) cell.  Alternate one linear-
//! projection update of T (per column, rows+taps pooled) with one of S (per
//! row), each being the PPQ orthogonality step with the other vector held
//! fixed.  The solution is non-unique up to a scalar moved between S and T.

/// A kernel viewed as rows=cin (i), cols=cout (j), depth=k*k taps per cell.
/// HWIO layout `[k,k,cin,cout]` maps to cell (i,j) holding the k*k taps.
pub struct KernelView<'a> {
    pub data: &'a [f32],
    pub k2: usize,
    pub cin: usize,
    pub cout: usize,
}

impl<'a> KernelView<'a> {
    pub fn from_hwio(data: &'a [f32], k: usize, cin: usize, cout: usize) -> Self {
        assert_eq!(data.len(), k * k * cin * cout);
        KernelView { data, k2: k * k, cin, cout }
    }

    /// Element at (tap e, row i, col j) in HWIO order.
    #[inline]
    pub fn at(&self, e: usize, i: usize, j: usize) -> f32 {
        self.data[(e * self.cin + i) * self.cout + j]
    }
}

/// Result of the alternating projections.
pub struct ApqResult {
    /// Left (per-input-channel) scale co-vector S_i.
    pub s: Vec<f32>,
    /// Right (per-output-channel) scale co-vector T_j.
    pub t: Vec<f32>,
    pub error: f32,
}

/// Run APQ for a symmetric grid with saturation `qmax`.
pub fn apq(view: &KernelView, qmax: f32, iters: usize) -> ApqResult {
    let (k2, cin, cout) = (view.k2, view.cin, view.cout);
    // init: T_j = max_i,e |X| / qmax ; S_i = max_j,e |X/T_j| / qmax
    let mut t = vec![0.0f32; cout];
    for e in 0..k2 {
        for i in 0..cin {
            for j in 0..cout {
                t[j] = t[j].max(view.at(e, i, j).abs());
            }
        }
    }
    for v in &mut t {
        *v = (*v / qmax).max(1e-8);
    }
    let mut s = vec![0.0f32; cin];
    for e in 0..k2 {
        for i in 0..cin {
            for j in 0..cout {
                s[i] = s[i].max((view.at(e, i, j) / t[j]).abs());
            }
        }
    }
    for v in &mut s {
        *v = (*v / qmax).max(1e-8);
    }

    for _ in 0..iters {
        // T_j <- sum_{i,e} Q * X/S_i / sum Q^2 (Q recomputed with current S,T)
        let mut num = vec![0.0f64; cout];
        let mut den = vec![0.0f64; cout];
        for e in 0..k2 {
            for i in 0..cin {
                for j in 0..cout {
                    let x = view.at(e, i, j);
                    let q = (x / (s[i] * t[j])).round().clamp(-qmax, qmax) as f64;
                    num[j] += q * (x / s[i]) as f64;
                    den[j] += q * q;
                }
            }
        }
        for j in 0..cout {
            if den[j] > 0.0 {
                let nt = (num[j] / den[j]) as f32;
                if nt > 0.0 && nt.is_finite() {
                    t[j] = nt;
                }
            }
        }
        // S_i <- sum_{j,e} Q * X/T_j / sum Q^2
        let mut num = vec![0.0f64; cin];
        let mut den = vec![0.0f64; cin];
        for e in 0..k2 {
            for i in 0..cin {
                for j in 0..cout {
                    let x = view.at(e, i, j);
                    let q = (x / (s[i] * t[j])).round().clamp(-qmax, qmax) as f64;
                    num[i] += q * (x / t[j]) as f64;
                    den[i] += q * q;
                }
            }
        }
        for i in 0..cin {
            if den[i] > 0.0 {
                let ns = (num[i] / den[i]) as f32;
                if ns > 0.0 && ns.is_finite() {
                    s[i] = ns;
                }
            }
        }
    }
    let error = apq_error(view, &s, &t, qmax);
    ApqResult { s, t, error }
}

/// ‖X − (S⊗T)·clip(round(X/(S⊗T)))‖ for given co-vectors.
pub fn apq_error(view: &KernelView, s: &[f32], t: &[f32], qmax: f32) -> f32 {
    let mut e2 = 0.0f64;
    for e in 0..view.k2 {
        for i in 0..view.cin {
            for j in 0..view.cout {
                let x = view.at(e, i, j);
                let sc = s[i] * t[j];
                let dq = (x / sc).round().clamp(-qmax, qmax) * sc;
                let d = (x - dq) as f64;
                e2 += d * d;
            }
        }
    }
    (e2 as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::quant::ppq;

    fn rand_kernel(k: usize, cin: usize, cout: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        // heterogeneous channel magnitudes to give dCh something to win on
        let row_gain: Vec<f32> = (0..cin).map(|_| 2f32.powf(r.range(-2.0, 2.0))).collect();
        let col_gain: Vec<f32> = (0..cout).map(|_| 2f32.powf(r.range(-2.0, 2.0))).collect();
        let mut w = vec![0.0f32; k * k * cin * cout];
        for e in 0..k * k {
            for i in 0..cin {
                for j in 0..cout {
                    w[(e * cin + i) * cout + j] = r.normal() * row_gain[i] * col_gain[j] * 0.1;
                }
            }
        }
        w
    }

    #[test]
    fn apq_beats_layerwise_and_channelwise() {
        // Fig. 3's claim: error(dCh) <= error(ch) <= error(lw)
        for seed in [0, 1, 2] {
            let (k, cin, cout) = (3, 8, 12);
            let w = rand_kernel(k, cin, cout, seed);
            let view = KernelView::from_hwio(&w, k, cin, cout);

            let s_lw = ppq::mmse_scale(&w, 7.0);
            let e_lw = ppq::quant_error(&w, s_lw, 7.0);

            // channelwise: PPQ per output-channel slice
            let mut e_ch2 = 0.0f32;
            for j in 0..cout {
                let slice: Vec<f32> = (0..k * k)
                    .flat_map(|e| (0..cin).map(move |i| (e, i)))
                    .map(|(e, i)| view.at(e, i, j))
                    .collect();
                let s = ppq::mmse_scale(&slice, 7.0);
                let er = ppq::quant_error(&slice, s, 7.0);
                e_ch2 += er * er;
            }
            let e_ch = e_ch2.sqrt();

            let r = apq(&view, 7.0, 10);
            assert!(e_ch <= e_lw * 1.001, "seed {seed}: ch {e_ch} vs lw {e_lw}");
            assert!(r.error <= e_ch * 1.05, "seed {seed}: dch {} vs ch {e_ch}", r.error);
            assert!(r.error < e_lw, "seed {seed}");
        }
    }

    #[test]
    fn apq_improves_over_its_own_init() {
        let (k, cin, cout) = (3, 6, 6);
        let w = rand_kernel(k, cin, cout, 9);
        let view = KernelView::from_hwio(&w, k, cin, cout);
        let r0 = apq(&view, 7.0, 0);
        let r = apq(&view, 7.0, 10);
        assert!(r.error <= r0.error);
    }

    #[test]
    fn apq_scalar_invariance() {
        // moving a scalar from S to T leaves the error unchanged
        let (k, cin, cout) = (1, 4, 4);
        let w = rand_kernel(k, cin, cout, 5);
        let view = KernelView::from_hwio(&w, k, cin, cout);
        let r = apq(&view, 7.0, 10);
        let s2: Vec<f32> = r.s.iter().map(|v| v * 2.0).collect();
        let t2: Vec<f32> = r.t.iter().map(|v| v / 2.0).collect();
        let e2 = apq_error(&view, &s2, &t2, 7.0);
        assert!((e2 - r.error).abs() < 1e-4 * r.error.max(1e-6));
    }

    #[test]
    fn apq_positive_scales() {
        let w = rand_kernel(3, 8, 8, 13);
        let view = KernelView::from_hwio(&w, 3, 8, 8);
        let r = apq(&view, 7.0, 10);
        assert!(r.s.iter().all(|&v| v > 0.0));
        assert!(r.t.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn apq_converges_fast() {
        // "often low single-digit iterations": 3 vs 10 within a few percent
        let w = rand_kernel(3, 8, 16, 21);
        let view = KernelView::from_hwio(&w, 3, 8, 16);
        let e3 = apq(&view, 7.0, 3).error;
        let e10 = apq(&view, 7.0, 10).error;
        assert!(e3 <= e10 * 1.05, "e3 {e3} e10 {e10}");
    }
}
