//! Baseline PTQ comparators (S15): the heuristic-only pipelines of Table 2
//! and the pre-QFT initializations of Table 1 / Figs. 8-9.
//!
//! Every baseline produces a full trainable set (manifest order) so it can be
//! evaluated on the exact same AOT `q_eval` executable — and fed to QFT as an
//! initialization, which is precisely the paper's framing (heuristics ≡
//! initializers of the DoF manifold).

use std::collections::HashMap;

use crate::coordinator::state::{self, WeightScaleInit};
use crate::nn::{ArchSpec, ParamMap};
use crate::quant::deploy::Mode;
use crate::quant::{bias, cle};
use crate::tensor::Tensor;

/// Named baseline configurations (Table 2 rows + Table 1 inits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    /// naive max(|.|) ranges everywhere, round-to-nearest.
    NaiveMax,
    /// layerwise / dch MMSE-optimal ranges (PPQ / APQ), round-to-nearest.
    Mmse,
    /// MMSE + empirical bias correction [29].
    MmseBc,
    /// MMSE + 4b-adapted CLE (App. D).
    MmseCle,
    /// MMSE + CLE + bias correction — the strongest non-trained pipeline.
    MmseCleBc,
}

impl Baseline {
    pub fn label(self) -> &'static str {
        match self {
            Baseline::NaiveMax => "naive-max",
            Baseline::Mmse => "mmse",
            Baseline::MmseBc => "mmse+bc",
            Baseline::MmseCle => "mmse+CLE",
            Baseline::MmseCleBc => "mmse+CLE+bc",
        }
    }

    pub fn uses_cle(self) -> bool {
        matches!(self, Baseline::MmseCle | Baseline::MmseCleBc)
    }

    pub fn uses_bc(self) -> bool {
        matches!(self, Baseline::MmseBc | Baseline::MmseCleBc)
    }
}

/// Build the trainable set for a baseline.
///
/// * `absmax` — calibration activation statistics (value id -> per-channel
///   max |.|), from `fp_stats` or the rust forward.
/// * In `dch` mode, MMSE means doubly-channelwise APQ vectors (Table 2
///   "according to the setting"); CLE is a lw-regime concept and is skipped.
pub fn build(
    arch: &ArchSpec,
    params: &ParamMap,
    absmax: &HashMap<usize, Vec<f32>>,
    mode: Mode,
    baseline: Baseline,
    calib_batches: &[Tensor],
) -> ParamMap {
    let winit = match (mode, baseline) {
        (_, Baseline::NaiveMax) => WeightScaleInit::NaiveMax,
        (Mode::Lw, _) => WeightScaleInit::Uniform,
        (Mode::Dch, _) => WeightScaleInit::DoublyChannelwise,
    };
    let cle_factors = if baseline.uses_cle() && mode == Mode::Lw {
        Some(cle::cle_factors(arch, params, &cle::BitConfig::default()))
    } else {
        None
    };
    let mut tm = state::init_trainables(arch, params, absmax, mode, winit, cle_factors.as_ref());

    if baseline.uses_bc() {
        // fake-quantized kernels under this baseline's grids
        let mut qw = HashMap::new();
        for op in arch.conv_ops() {
            let w = params.get(&format!("w:{}", op.name));
            let (s_l, s_r) = crate::quant::deploy::kernel_covectors(arch, &tm, mode, op);
            let wq = match &s_l {
                Some(l) => crate::quant::mmse::fq_outer(w, l, &s_r, crate::WEIGHT_QMAX),
                None => crate::quant::mmse::fq_per_out_channel(w, &s_r, crate::WEIGHT_QMAX),
            };
            qw.insert(op.name.clone(), wq);
        }
        let mut corrected = tm.clone();
        bias::bias_correct(arch, params, &mut corrected, &qw, calib_batches);
        tm = corrected;
    }
    tm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn baselines_produce_valid_trainables() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["resnet_tiny"];
        let params = state::he_init_params(arch, 3);
        let ds = crate::data::Dataset::new(2);
        let batches: Vec<Tensor> =
            (0..2).map(|i| ds.batch(crate::data::Split::Calib, i * 8, 8).0).collect();
        let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
        for mode in [Mode::Lw, Mode::Dch] {
            for b in [
                Baseline::NaiveMax,
                Baseline::Mmse,
                Baseline::MmseBc,
                Baseline::MmseCle,
                Baseline::MmseCleBc,
            ] {
                let tm = build(arch, &params, &absmax, mode, b, &batches);
                for spec in arch.trainable_specs(mode.key()) {
                    let t = tm.get(&spec.name);
                    assert_eq!(t.shape, spec.shape, "{b:?}/{mode:?}/{}", spec.name);
                    assert!(t.data.iter().all(|v| v.is_finite()), "{}", spec.name);
                }
            }
        }
    }

    #[test]
    fn mmse_beats_naive_max_on_kernel_error() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = state::he_init_params(arch, 4);
        let ds = crate::data::Dataset::new(2);
        let batches: Vec<Tensor> =
            vec![ds.batch(crate::data::Split::Calib, 0, 8).0];
        let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
        let naive = build(arch, &params, &absmax, Mode::Lw, Baseline::NaiveMax, &batches);
        let mmse = build(arch, &params, &absmax, Mode::Lw, Baseline::Mmse, &batches);
        let mut e_naive = 0.0f32;
        let mut e_mmse = 0.0f32;
        for op in arch.conv_ops() {
            let w = params.get(&format!("w:{}", op.name));
            for (tm, e) in [(&naive, &mut e_naive), (&mmse, &mut e_mmse)] {
                let (s_l, s_r) = crate::quant::deploy::kernel_covectors(arch, tm, Mode::Lw, op);
                let wq = match &s_l {
                    Some(l) => crate::quant::mmse::fq_outer(w, l, &s_r, 7.0),
                    None => crate::quant::mmse::fq_per_out_channel(w, &s_r, 7.0),
                };
                *e += w.sub(&wq).sq_norm();
            }
        }
        assert!(e_mmse < e_naive, "mmse {e_mmse} vs naive {e_naive}");
    }
}
