//! PJRT runtime (S13): load AOT HLO-text artifacts, compile once, execute
//! from the rust hot path.  Python is never involved at runtime.
//!
//! The interchange format is HLO *text* — see `aot.py` and
//! /opt/xla-example/README.md for why serialized protos are rejected by this
//! image's xla_extension 0.5.1.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::nn::arch::ArtifactSpec;
use crate::tensor::Tensor;
pub use manifest::Manifest;

/// Execution statistics for the duty-cycle metric (§Perf): time spent inside
/// PJRT vs. wall time lets us verify L3 is not the bottleneck.
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub executions: u64,
    pub exec_ns: u64,
    pub compile_ns: u64,
    pub compiles: u64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<ExecStats>,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: Default::default(), stats: Default::default() })
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = ExecStats::default();
    }

    fn artifact_spec(&self, arch: &str, entry: &str) -> Result<&ArtifactSpec> {
        if arch == "kernel" {
            return self
                .manifest
                .kernels
                .get(entry)
                .ok_or_else(|| anyhow::anyhow!("unknown kernel artifact {entry}"));
        }
        self.manifest
            .arch(arch)?
            .artifacts
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("arch {arch} has no artifact {entry}"))
    }

    /// Compile (or fetch from cache) an executable.
    pub fn executable(&self, arch: &str, entry: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{arch}/{entry}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let spec = self.artifact_spec(arch, entry)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {key}: {e}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.compile_ns += t0.elapsed().as_nanos() as u64;
            st.compiles += 1;
        }
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with shape-checked tensors; returns the decomposed
    /// output tuple as tensors (manifest output order).
    pub fn run(&self, arch: &str, entry: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.artifact_spec(arch, entry)?.clone();
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{arch}/{entry}: {} inputs given, {} expected",
            inputs.len(),
            spec.inputs.len()
        );
        for (t, p) in inputs.iter().zip(&spec.inputs) {
            anyhow::ensure!(
                t.shape == p.shape || (p.shape.is_empty() && t.len() == 1),
                "{arch}/{entry}: input {} shape {:?} != manifest {:?}",
                p.name,
                t.shape,
                p.shape
            );
        }
        let exe = self.executable(arch, entry)?;
        // NOTE: we upload host->device ourselves and run `execute_b`.  The
        // crate's `execute(&[Literal])` leaks every input device buffer
        // (xla_rs.cc `execute` releases the UniquePtr and never frees it) —
        // ~1 MB/step across a training run, enough to OOM the leader.
        // Buffers created here are owned by rust and freed on drop.
        let t0 = Instant::now();
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload input: {e}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow::anyhow!("execute {arch}/{entry}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.exec_ns += t0.elapsed().as_nanos() as u64;
            st.executions += 1;
        }

        let parts = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose tuple: {e}"))?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{arch}/{entry}: {} outputs, manifest says {}",
            parts.len(),
            spec.outputs.len()
        );
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, p)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("output {} to_vec: {e}", p.name))?;
                let shape = if p.shape.is_empty() { vec![1] } else { p.shape.clone() };
                Ok(Tensor::new(shape, data))
            })
            .collect()
    }

    /// Upload a tensor to a device buffer (for buffer-resident loops).
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("upload: {e}"))
    }

    /// Execute with raw device buffers; returns the per-leaf output buffers
    /// when PJRT untuples the root, or a single tuple buffer otherwise.
    /// Used by the buffer-resident training loop (§Perf): state buffers stay
    /// on device between steps, skipping the per-step host round-trip.
    pub fn run_buffers(
        &self,
        arch: &str,
        entry: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.executable(arch, entry)?;
        let t0 = Instant::now();
        let mut result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow::anyhow!("execute_b {arch}/{entry}: {e}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.exec_ns += t0.elapsed().as_nanos() as u64;
            st.executions += 1;
        }
        Ok(result.pop().expect("one replica"))
    }

    /// Fetch a device buffer into a host tensor with the given shape.
    pub fn fetch(&self, buf: &xla::PjRtBuffer, shape: &[usize]) -> Result<Tensor> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e}"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        let shape = if shape.is_empty() { vec![1] } else { shape.to_vec() };
        Ok(Tensor::new(shape, data))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifacts directory this runtime serves from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        Runtime::load("artifacts").ok()
    }

    #[test]
    fn kernel_fakequant_roundtrip() {
        let Some(rt) = runtime() else { return };
        let x = Tensor::full(&[256, 128], 0.33);
        let s = Tensor::full(&[128], 0.1);
        let out = rt.run("kernel", "fakequant", &[x, s]).unwrap();
        // 0.33/0.1 -> round(3.3)=3 -> 0.3
        assert!(out[0].data.iter().all(|&v| (v - 0.3).abs() < 1e-6));
    }

    #[test]
    fn kernel_qmatmul_matches_rust_oracle() {
        let Some(rt) = runtime() else { return };
        let mut rng = crate::data::Rng::new(0);
        let x = Tensor::new(vec![256, 128], (0..256 * 128).map(|_| rng.normal()).collect());
        let w = Tensor::new(vec![128, 128], (0..128 * 128).map(|_| rng.normal() * 0.2).collect());
        let s_l = Tensor::full(&[128], 1.0);
        let s_r = Tensor::full(&[128], 0.05);
        let out = rt
            .run("kernel", "qmatmul", &[x.clone(), w.clone(), s_l.clone(), s_r.clone()])
            .unwrap();
        let wq = crate::quant::mmse::fq_outer(
            &w.clone().reshape(&[1, 1, 128, 128]),
            &s_l.data,
            &s_r.data,
            7.0,
        )
        .reshape(&[128, 128]);
        let want = x.matmul(&wq);
        let err = out[0].sub(&want).norm() / want.norm();
        assert!(err < 1e-5, "rel err {err}");
    }

    #[test]
    fn wrong_arity_is_rejected() {
        let Some(rt) = runtime() else { return };
        let err = rt.run("kernel", "fakequant", &[Tensor::full(&[256, 128], 1.0)]);
        assert!(err.is_err());
    }

    #[test]
    fn wrong_shape_is_rejected() {
        let Some(rt) = runtime() else { return };
        let err = rt.run(
            "kernel",
            "fakequant",
            &[Tensor::full(&[2, 2], 1.0), Tensor::full(&[128], 0.1)],
        );
        assert!(err.is_err());
    }

    #[test]
    fn run_buffers_output_arity_probe() {
        // PJRT output-untupling probe: documents whether the buffer-resident
        // loop gets per-leaf buffers (n) or one tuple buffer (1).
        let Some(rt) = runtime() else { return };
        let x = rt.upload(&Tensor::full(&[256, 128], 0.5)).unwrap();
        let s = rt.upload(&Tensor::full(&[128], 0.1)).unwrap();
        let out = rt.run_buffers("kernel", "fakequant", &[&x, &s]).unwrap();
        println!("fakequant output buffers: {}", out.len());
        // measured: 1 — PJRT hands back a single tuple buffer (no
        // untupling), so device-resident train state is not expressible
        // through this crate (§Perf P4).  Do NOT fetch the tuple buffer as
        // an array: xla_extension's shape CHECK aborts the process.
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = runtime() else { return };
        let x = Tensor::full(&[256, 128], 0.5);
        let s = Tensor::full(&[128], 0.1);
        rt.run("kernel", "fakequant", &[x.clone(), s.clone()]).unwrap();
        let compiles = rt.stats().compiles;
        rt.run("kernel", "fakequant", &[x, s]).unwrap();
        assert_eq!(rt.stats().compiles, compiles);
        assert_eq!(rt.stats().executions, 2);
    }
}
