//! The AOT contract: model of `artifacts/manifest.json`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::arch::{ArchSpec, ArtifactSpec};
use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub input_hw: usize,
    pub input_ch: usize,
    pub num_classes: usize,
    pub archs: HashMap<String, ArchSpec>,
    pub kernels: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        let v = Value::parse(&text).context("parsing manifest JSON")?;
        let mut archs = HashMap::new();
        for (name, spec) in v.get("archs")?.obj()? {
            archs.insert(
                name.clone(),
                ArchSpec::from_json(spec).with_context(|| format!("arch {name}"))?,
            );
        }
        let mut kernels = HashMap::new();
        if let Some(ks) = v.opt("kernels") {
            for (name, spec) in ks.obj()? {
                kernels.insert(name.clone(), ArtifactSpec::from_json(spec)?);
            }
        }
        Ok(Manifest {
            batch: v.get("batch")?.usize()?,
            input_hw: v.get("input_hw")?.usize()?,
            input_ch: v.get("input_ch")?.usize()?,
            num_classes: v.get("num_classes")?.usize()?,
            archs,
            kernels,
        })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchSpec> {
        self.archs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown arch {name}; have {:?}", self.archs.keys()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        assert!(m.batch > 0);
        for (name, arch) in &m.archs {
            assert_eq!(&arch.name, name);
            for art in arch.artifacts.values() {
                for p in art.inputs.iter().chain(&art.outputs) {
                    assert!(p.shape.iter().all(|&d| d > 0) || p.shape.is_empty());
                }
            }
            assert!(arch.trainables.contains_key("lw"));
            assert!(arch.trainables.contains_key("dch"));
            // value maps cover every op output
            for op in &arch.ops {
                assert!(arch.value_channels.contains_key(&op.out.to_string()), "{name}");
            }
        }
        assert!(m.kernels.contains_key("qmatmul"));
    }
}
