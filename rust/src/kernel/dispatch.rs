//! Runtime CPU-feature dispatch for the integer kernels.
//!
//! The integer GEMMs ([`super::gemm_i8`], [`super::gemm_w4`]) have one
//! safe scalar implementation (the *twin*, ground truth) and explicit
//! SIMD implementations per ISA.  This module picks between them ONCE per
//! process: [`kernel_path`] probes the CPU with
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!` and caches
//! the best supported path in a `OnceLock`; the hot kernel entry points
//! then branch on a copy of that enum (a predictable two-instruction
//! dispatch, no per-call feature probing).
//!
//! ## Forcing a path
//!
//! `QFT_KERNEL=scalar|avx2|vnni|neon` forces the dispatch for the whole
//! process — the CI forced-dispatch matrix reruns the kernel + backend
//! parity suites under `scalar` and `avx2` so the fallback and each ISA
//! kernel stay tested on runners whose best path is better.  Forcing a
//! path the CPU does not support (or a name that is not a path) is a hard
//! panic, never a silent fallback: a forced CI leg that quietly degraded
//! to scalar would rot without anyone noticing.
//!
//! ## The parity contract
//!
//! Integer accumulation is exact and associative, so every path must be
//! **bit-identical** to the scalar twin on every shape — no tolerance.
//! [`gemm_i8_with`] / [`gemm_w4_with`] expose the per-path entry points
//! the parity tests iterate over [`supported_paths`], independent of the
//! process-wide dispatch choice.

use std::sync::OnceLock;

use super::{PackedW4, PackedWi8};

/// One integer-kernel implementation path (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// The safe scalar twins — always available, the ground truth every
    /// SIMD path is proven bit-identical against.
    Scalar,
    /// AVX2 `_mm256_maddubs_epi16` + `_mm256_madd_epi16` u8×i8 path
    /// (x86-64; the i16 pair sums stay exact under the pack-time
    /// `|w| ≤ 64` invariant).
    Avx2,
    /// AVX-512-VNNI `_mm256_dpbusd_epi32` at 256-bit width (requires
    /// AVX512VNNI + AVX512VL) — one non-saturating u8×i8→i32 instruction
    /// per quad.
    Vnni,
    /// NEON `vdotq_s32` signed×signed dot product (aarch64 `dotprod`) —
    /// no unsigned rebias, no compensation term.
    Neon,
}

impl KernelPath {
    /// Stable lowercase name — the `QFT_KERNEL` vocabulary, the
    /// `kernel_dispatch` obs/bench field, and the startup print.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Avx2 => "avx2",
            KernelPath::Vnni => "vnni",
            KernelPath::Neon => "neon",
        }
    }

    fn from_name(s: &str) -> Option<KernelPath> {
        match s {
            "scalar" => Some(KernelPath::Scalar),
            "avx2" => Some(KernelPath::Avx2),
            "vnni" => Some(KernelPath::Vnni),
            "neon" => Some(KernelPath::Neon),
            _ => None,
        }
    }
}

/// Every path this CPU supports, scalar first and the preferred path
/// last.  This is what the per-ISA parity tests iterate, so each kernel
/// is pinned against the scalar twin on whatever hardware runs the suite.
pub fn supported_paths() -> Vec<KernelPath> {
    let mut paths = vec![KernelPath::Scalar];
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            paths.push(KernelPath::Avx2);
        }
        if std::arch::is_x86_feature_detected!("avx512vnni")
            && std::arch::is_x86_feature_detected!("avx512vl")
        {
            paths.push(KernelPath::Vnni);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("dotprod") {
            paths.push(KernelPath::Neon);
        }
    }
    paths
}

/// Resolve the process dispatch: the `QFT_KERNEL` override (hard panic on
/// unknown or unsupported values) or the best autodetected path.
fn pick() -> KernelPath {
    let supported = supported_paths();
    if let Ok(forced) = std::env::var("QFT_KERNEL") {
        let path = KernelPath::from_name(&forced).unwrap_or_else(|| {
            panic!("QFT_KERNEL={forced}: unknown kernel path (scalar|avx2|vnni|neon)")
        });
        assert!(
            supported.contains(&path),
            "QFT_KERNEL={forced}: path unsupported on this CPU (supported: {supported:?})"
        );
        return path;
    }
    *supported.last().expect("scalar is always supported")
}

/// The process-wide kernel path: autodetected best (or the `QFT_KERNEL`
/// override), probed once and cached.
pub fn kernel_path() -> KernelPath {
    static PATH: OnceLock<KernelPath> = OnceLock::new();
    *PATH.get_or_init(pick)
}

/// The dispatch name (`"scalar"` / `"avx2"` / `"vnni"` / `"neon"`) —
/// carried by the obs snapshot and the `BENCH_gemm.json` summary, and
/// printed at `repro eval` / `serve` startup, so artifacts from different
/// machines are comparable.
pub fn kernel_dispatch() -> &'static str {
    kernel_path().name()
}

/// [`super::gemm_i8`] through an explicit path — the parity-test entry
/// point (the public kernel routes here with [`kernel_path`]).  Handles
/// the degenerate shapes once so every implementation may assume
/// `m, k, n > 0`.
pub fn gemm_i8_with(path: KernelPath, x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    debug_assert_eq!(x.len(), m * pw.k(), "x vs [m, k]");
    debug_assert_eq!(out.len(), m * pw.n(), "out vs [m, n]");
    if m == 0 || pw.n() == 0 {
        return;
    }
    if pw.k() == 0 {
        out.fill(0);
        return;
    }
    match path {
        KernelPath::Scalar => super::gemm_i8_scalar(x, m, pw, out),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelPath::Avx2 => super::avx2::gemm_i8(x, m, pw, out),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelPath::Vnni => super::vnni::gemm_i8(x, m, pw, out),
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => super::neon::gemm_i8(x, m, pw, out),
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel path {other:?} is not compiled for this target"),
    }
}

/// [`super::gemm_w4`] through an explicit path — see [`gemm_i8_with`].
pub fn gemm_w4_with(path: KernelPath, x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    debug_assert_eq!(x.len(), m * pw.k(), "x vs [m, k]");
    debug_assert_eq!(out.len(), m * pw.n(), "out vs [m, n]");
    if m == 0 || pw.n() == 0 {
        return;
    }
    if pw.k() == 0 {
        out.fill(0);
        return;
    }
    match path {
        KernelPath::Scalar => super::gemm_w4_scalar(x, m, pw, out),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelPath::Avx2 => super::avx2::gemm_w4(x, m, pw, out),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelPath::Vnni => super::vnni::gemm_w4(x, m, pw, out),
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => super::neon::gemm_w4(x, m, pw, out),
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel path {other:?} is not compiled for this target"),
    }
}
