//! `qft::kernel` — the register-blocked, panel-packed f32 GEMM micro-kernel
//! under every forward path (S17).
//!
//! Every path in the reproduction — the QFT training forwards, the integer
//! deployment twins, the [`crate::serve`] workers, and the [`crate::par`]
//! chunked kernels — bottoms out in one inner loop: rows of activations
//! against a `[k, n]` weight matrix.  This module owns that loop.  Two
//! kernels, one contract:
//!
//! * [`gemm_ref`] — the scalar reference: for each output row, walk `kk =
//!   0..k` ascending and axpy `x[kk] * w[kk, ..]` into the row, skipping
//!   zero activations.  This is byte-for-byte the historical
//!   `tensor::matmul_rows` loop; it exists as the baseline the packed
//!   kernel is proven against (tests and `BENCH_gemm.json`).
//! * [`gemm`] — the fast path: weights pre-packed into [`PackedW`] panels
//!   of [`NR`] columns so the `kk` walk streams K-major contiguous memory
//!   instead of striding `w[kk*n..]`, with an [`MR`]×[`NR`] accumulator
//!   tile held in registers across the whole `kk` reduction ([`LANES`]-wide
//!   unrolled f32 arrays the compiler auto-vectorizes — no unsafe, no
//!   intrinsics).  It is a *write-mode* (beta = 0) kernel: the tile is
//!   stored over `out`, so callers skip the zero-fill pass entirely.
//!
//! ## The bit-exactness contract
//!
//! Per output element `out[i, j]` both kernels compute exactly
//!
//! ```text
//! acc = 0.0;  for kk in 0..k ascending { if x[i,kk] != 0.0 { acc += x[i,kk] * w[kk,j] } }
//! ```
//!
//! with one `mul` and one `add` per step (rustc never contracts to FMA by
//! default).  Register blocking tiles *rows* and vectorization runs across
//! the *n* (output-column) lanes only — lanes never interact — so the
//! reduction order per element is identical to the scalar loop and the
//! packed result is bit-identical to [`gemm_ref`] for every shape,
//! including the zero-activation skip (which keeps `0 * NaN` / `0 * inf`
//! weight poison out of the accumulators, a property the deployment twins
//! rely on).  Parallel callers ([`crate::tensor::matmul_slices_par`], the
//! conv chunks) hand each pool task a disjoint output-row block running
//! this same kernel, so results stay bit-identical at any thread count.
//! `rust/tests/kernel.rs` enforces all of this, under default codegen and
//! `-Ctarget-cpu=native` in CI.
//!
//! ## Who packs, and when
//!
//! [`PackedW`] is cached wherever weights are long-lived:
//! [`crate::quant::deploy::DeployedModel::prepare`] packs every conv (per
//! group) and the fc head once, offline, so serving workers never repack;
//! the training-forward / heuristic paths pack per call into reusable
//! scratch ([`crate::tensor::conv::ConvScratch`] or the thread-local
//! [`with_pack_scratch`]), amortized over the `m = b*oh*ow` output rows of
//! the GEMM.

use std::cell::RefCell;

/// Auto-vectorization lane width the micro-kernel is written for: 8 f32s
/// (one AVX2 `ymm`; on narrower ISAs the compiler splits the lane loop).
pub const LANES: usize = 8;
/// Register-tile rows: output rows accumulated simultaneously per panel
/// sweep.  `MR * NR` f32 accumulators stay live across the `kk` loop.
pub const MR: usize = 4;
/// Register-tile columns — one packed panel width (two [`LANES`] vectors).
pub const NR: usize = 2 * LANES;

/// Panel-packed weights: a `[k, n]` row-major matrix rearranged into
/// `ceil(n / NR)` panels, each holding its [`NR`]-column slice K-major
/// (`panel[kk * NR + lane] = w[kk, j0 + lane]`), the ragged last panel
/// zero-padded to full width.  The micro-kernel then streams each panel
/// front-to-back — contiguous loads — instead of striding `w[kk * n ..]`.
///
/// Packing a `[k, n]` matrix is one O(k·n) copy; [`PackedW::pack_cols`]
/// reuses the buffer so repacking (training forwards, per-call paths)
/// allocates nothing once warm.
#[derive(Clone, Debug, Default)]
pub struct PackedW {
    k: usize,
    n: usize,
    /// `n.div_ceil(NR)` panels × `k * NR` floats.
    data: Vec<f32>,
}

impl PackedW {
    /// Pack a whole row-major `[k, n]` matrix.
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedW {
        let mut pw = PackedW::default();
        pw.pack_cols(w, k, n, 0, n);
        pw
    }

    /// (Re)pack columns `c0 .. c0 + ncols` of the row-major
    /// `[k, row_stride]` matrix `w`, reusing the existing buffer.  The
    /// column slice form is what grouped convs need: group `g` of an HWIO
    /// kernel is columns `g*cg_out .. (g+1)*cg_out` of the `[k*k*cg_in,
    /// cout]` matrix, packed without materializing a dense copy first.
    pub fn pack_cols(&mut self, w: &[f32], k: usize, row_stride: usize, c0: usize, ncols: usize) {
        assert!(c0 + ncols <= row_stride, "columns {c0}+{ncols} out of stride {row_stride}");
        assert_eq!(w.len(), k * row_stride, "weight buffer vs [k, row_stride]");
        self.k = k;
        self.n = ncols;
        let panels = ncols.div_ceil(NR);
        let len = panels * k * NR;
        if self.data.len() != len {
            self.data.clear();
            self.data.resize(len, 0.0);
        }
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(ncols - j0);
            let panel = &mut self.data[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                let src = kk * row_stride + c0 + j0;
                panel[kk * NR..kk * NR + nv].copy_from_slice(&w[src..src + nv]);
                // pad lanes must be re-zeroed explicitly: a warm buffer may
                // be repacked at a different (k, n) of the same total
                // length, leaving stale values where the padding now falls
                panel[kk * NR + nv..(kk + 1) * NR].fill(0.0);
            }
        }
    }

    /// Reduction depth (rows of the packed matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (un-padded logical width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the packed buffer (diagnostic / memory accounting).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// The scalar reference kernel (the historical `tensor::matmul_rows` inner
/// loop): `x` rows (each of length `k`) against row-major `w[k, n]`,
/// *accumulated* into `out` (callers pre-zero it).  Kept as the ground
/// truth [`gemm`] is tested and benchmarked against.
pub fn gemm_ref(x: &[f32], k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    if k == 0 || n == 0 {
        return;
    }
    for (xrow, orow) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// One `R`×[`NR`] register tile: `R` consecutive x rows (stride `k`)
/// against one packed panel, accumulators built from zero and *stored*
/// (write-mode) to `out` rows at stride `n_stride`, `nv` valid lanes.
#[inline(always)]
fn micro_tile<const R: usize>(
    x: &[f32],
    k: usize,
    panel: &[f32],
    out: &mut [f32],
    n_stride: usize,
    nv: usize,
) {
    let xr: [&[f32]; R] = std::array::from_fn(|r| &x[r * k..(r + 1) * k]);
    let mut acc = [[0.0f32; NR]; R];
    for kk in 0..k {
        let wrow = &panel[kk * NR..kk * NR + NR];
        for r in 0..R {
            let xv = xr[r][kk];
            // preserve the reference kernel's zero-activation skip: it is
            // load-bearing (0 * NaN/inf weights must not poison the tile)
            if xv == 0.0 {
                continue;
            }
            for (a, &wv) in acc[r].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        out[r * n_stride..r * n_stride + nv].copy_from_slice(&accr[..nv]);
    }
}

/// One panel narrower than a single vector lane group: run the identical
/// reduction over just the `nv` valid lanes instead of all [`NR`].  This is
/// the depthwise-conv case (`cg_out == 1`: one useful lane in a padded
/// panel) and the raggedest of ragged tails — full-width tiles would spend
/// `NR/nv`× the multiply work on zero pad lanes.
#[allow(clippy::too_many_arguments)]
fn micro_narrow(
    x: &[f32],
    m: usize,
    k: usize,
    panel: &[f32],
    out: &mut [f32],
    n_stride: usize,
    nv: usize,
) {
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let mut acc = [0.0f32; LANES];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &panel[kk * NR..kk * NR + nv];
            for (a, &wv) in acc[..nv].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
        out[i * n_stride..i * n_stride + nv].copy_from_slice(&acc[..nv]);
    }
}

/// Write-mode packed GEMM: `out[m, n] = x[m, k] @ w` with `w` pre-packed.
/// Every element of `out` is overwritten (beta = 0), so callers reuse
/// right-sized buffers without zero-filling them first.  Bit-identical to
/// [`gemm_ref`] over a zeroed buffer — see the module docs for why.
///
/// Loop order: panels outer, [`MR`]-row blocks inner, so one panel
/// (`k * NR` floats) stays cache-hot across all `m / MR` row blocks while
/// the accumulator tile pins the output in registers for the whole `kk`
/// reduction — the scalar loop instead re-walks the full `n`-wide output
/// row once per `kk`.  A panel with fewer than [`LANES`] valid lanes
/// (depthwise convs, the raggedest tails) drops to [`micro_narrow`] so pad
/// lanes cost no multiplies; per-element reduction order is the same
/// either way.
pub fn gemm(x: &[f32], m: usize, pw: &PackedW, out: &mut [f32]) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(x.len(), m * k, "x vs [m, k]");
    debug_assert_eq!(out.len(), m * n, "out vs [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let panels = n.div_ceil(NR);
    for p in 0..panels {
        let j0 = p * NR;
        let nv = NR.min(n - j0);
        let panel = &pw.data[p * k * NR..(p + 1) * k * NR];
        if nv < LANES {
            micro_narrow(x, m, k, panel, &mut out[j0..], n, nv);
            continue;
        }
        let mut i = 0;
        while i + MR <= m {
            micro_tile::<MR>(&x[i * k..(i + MR) * k], k, panel, &mut out[i * n + j0..], n, nv);
            i += MR;
        }
        // ragged row remainder (m % MR); arms must cover 1..MR
        match m - i {
            3 => micro_tile::<3>(&x[i * k..], k, panel, &mut out[i * n + j0..], n, nv),
            2 => micro_tile::<2>(&x[i * k..], k, panel, &mut out[i * n + j0..], n, nv),
            1 => micro_tile::<1>(&x[i * k..], k, panel, &mut out[i * n + j0..], n, nv),
            rem => debug_assert_eq!(
                rem, 0,
                "write-mode kernel left {rem} rows unwritten — remainder arms lag MR"
            ),
        }
    }
}

thread_local! {
    /// Per-thread pack buffer for call sites whose weights are not
    /// long-lived (training forwards, one-off heuristics): the pack is
    /// amortized over the GEMM's `m` rows and the buffer over the thread's
    /// lifetime.
    static PACK_SCRATCH: RefCell<PackedW> = RefCell::new(PackedW::default());
}

/// Run `f` with this thread's reusable [`PackedW`] scratch.  Re-entrant
/// calls (a packed caller invoking another packed caller mid-borrow) fall
/// back to a fresh buffer instead of panicking.
pub fn with_pack_scratch<R>(f: impl FnOnce(&mut PackedW) -> R) -> R {
    PACK_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut pw) => f(&mut pw),
        Err(_) => f(&mut PackedW::default()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn ref_out(x: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        gemm_ref(x, k, w, n, &mut out);
        out
    }

    #[test]
    fn packed_layout_streams_columns() {
        // [2, 3] matrix; single (padded) panel: lane j holds column j
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let pw = PackedW::pack(&w, 2, 3);
        assert_eq!((pw.k(), pw.n()), (2, 3));
        assert_eq!(pw.data.len(), 2 * NR);
        assert_eq!(&pw.data[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&pw.data[3..NR], &[0.0; NR - 3]);
        assert_eq!(&pw.data[NR..NR + 3], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn packed_matches_reference_bit_exactly() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, NR),
            (5, 7, NR + 1),
            (MR - 1, 16, NR - 1),
            (17, 33, 40),
            (MR * 3, 2, 2 * NR),
            (2, 64, 5),
        ] {
            let x = rand_vec(m * k, (m * 31 + k * 7 + n) as u64);
            let w = rand_vec(k * n, (m + k + n * 13) as u64);
            let pw = PackedW::pack(&w, k, n);
            // sentinel fill proves write-mode coverage of every element
            let mut got = vec![777.0f32; m * n];
            gemm(&x, m, &pw, &mut got);
            let want = ref_out(&x, m, k, &w, n);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn degenerate_shapes_are_safe() {
        // k = 0: write-mode must still zero the output
        let pw = PackedW::pack(&[], 0, 3);
        let mut out = vec![9.0f32; 2 * 3];
        gemm(&[], 2, &pw, &mut out);
        assert_eq!(out, vec![0.0; 6]);
        // n = 0 and m = 0: no-ops on empty outputs
        let pw = PackedW::pack(&[], 4, 0);
        gemm(&rand_vec(8, 1), 2, &pw, &mut []);
        let pw = PackedW::pack(&rand_vec(8, 2), 4, 2);
        gemm(&[], 0, &pw, &mut []);
    }

    #[test]
    fn zero_activations_mask_nonfinite_weights() {
        // column kk of x is all-zero exactly where w row kk is poisoned
        let (m, k, n) = (5usize, 6usize, NR + 3);
        let mut x = rand_vec(m * k, 3);
        let mut w = rand_vec(k * n, 4);
        for i in 0..m {
            x[i * k + 2] = 0.0;
            x[i * k + 5] = 0.0;
        }
        for j in 0..n {
            w[2 * n + j] = f32::NAN;
            w[5 * n + j] = if j % 2 == 0 { f32::INFINITY } else { f32::NEG_INFINITY };
        }
        let pw = PackedW::pack(&w, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm(&x, m, &pw, &mut got);
        assert!(got.iter().all(|v| v.is_finite()), "poisoned rows must be skipped");
        let want = ref_out(&x, m, k, &w, n);
        assert_eq!(want, got);
    }

    #[test]
    fn repacking_reuses_and_matches() {
        let mut pw = PackedW::default();
        // (4, 16) -> (2, 20) keeps the same buffer length (64 floats) while
        // moving where the ragged pad lanes fall: stale-pad regression guard
        for (k, n, seed) in
            [(9usize, 21usize, 5u64), (4, 3, 6), (9, 21, 7), (4, 16, 8), (2, 20, 9)]
        {
            let w = rand_vec(k * n, seed);
            pw.pack_cols(&w, k, n, 0, n);
            let fresh = PackedW::pack(&w, k, n);
            assert_eq!(pw.data, fresh.data, "k={k} n={n}");
            assert_eq!((pw.k(), pw.n()), (k, n));
        }
    }

    #[test]
    fn pack_cols_slices_groups() {
        // columns 2..5 of a [2, 6] matrix == packing the dense 3-col copy
        let (k, stride) = (2usize, 6usize);
        let w = rand_vec(k * stride, 8);
        let mut sliced = PackedW::default();
        sliced.pack_cols(&w, k, stride, 2, 3);
        let dense: Vec<f32> = (0..k)
            .flat_map(|kk| w[kk * stride + 2..kk * stride + 5].to_vec())
            .collect();
        let want = PackedW::pack(&dense, k, 3);
        assert_eq!(sliced.data, want.data);
    }
}
