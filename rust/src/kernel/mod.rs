//! `qft::kernel` — the register-blocked, panel-packed, KC-cache-blocked
//! GEMM micro-kernel under every forward path (S17).
//!
//! Every path in the reproduction — the QFT training forwards, the integer
//! deployment twins, the [`crate::serve`] workers, and the [`crate::par`]
//! chunked kernels — bottoms out in one inner loop: rows of activations
//! against a `[k, n]` weight matrix.  This module owns that loop.  Two
//! kernels, one contract:
//!
//! * [`gemm_ref`] — the scalar reference: for each output row, walk `kk =
//!   0..k` ascending and axpy `x[kk] * w[kk, ..]` into the row, skipping
//!   zero activations.  This is byte-for-byte the historical
//!   `tensor::matmul_rows` loop; it exists as the baseline the packed
//!   kernel is proven against (tests and `BENCH_gemm.json`).
//! * [`gemm`] — the fast path: weights pre-packed into [`PackedW`] panels
//!   of [`NR`] columns so the `kk` walk streams K-major contiguous memory
//!   instead of striding `w[kk*n..]`, with an [`MR`]×[`NR`] accumulator
//!   tile held in registers across the reduction ([`LANES`]-wide unrolled
//!   f32 arrays the compiler auto-vectorizes — no unsafe, no intrinsics).
//!   It is a *write-mode* (beta = 0) kernel: the first K-block's tile is
//!   stored over `out`, so callers skip the zero-fill pass entirely.
//!
//! A third kernel lives alongside the f32 pair: [`gemm_i8`] over
//! [`PackedWi8`] panels — the same panel geometry and loop structure with
//! i8 weight *codes* and i32 accumulators, serving the `lw-i8` deployment
//! backend ([`crate::backend::Int8Backend`]).  Its contract is stronger
//! and simpler: integer accumulation is exact and associative (no rounding
//! while the true sum fits i32), so no ordering discipline is needed.
//!
//! ## KC cache blocking
//!
//! Once the reduction depth outgrows the cache, a full-`k` panel (`k * NR`
//! floats) is evicted between [`MR`]-row tiles and every tile re-streams it
//! from L2/memory.  The packed layout is therefore *K-block major*: the
//! reduction is split into [`KC`]-row blocks, and each block holds its
//! panel sub-slices contiguously —
//!
//! ```text
//!   data = [ block 0: panel 0 | panel 1 | … ]  ← KC rows each, NR lanes
//!          [ block 1: panel 0 | panel 1 | … ]  ← next KC rows
//!          [ …                               ]  ← last block ragged (k % KC)
//! ```
//!
//! — so one sub-panel is `KC * NR` f32s (16 KiB at KC = 256; 4 KiB for the
//! i8 twin) and stays L1-resident across all `m / MR` row tiles of its
//! block, while the whole buffer is streamed strictly front-to-back.  Both
//! kernels drive the identical block walk through one generic panel walker
//! (`walk_blocked_panels`), so the f32 and i8 grids cannot drift
//! structurally.  Between K-blocks the accumulator tile is spilled to
//! `out` and reloaded (load-accumulate-store for every block after the
//! first) — an f32 store/load round trip is lossless, so the *per-element
//! sequence of arithmetic operations is unchanged* from the unblocked
//! kernel.  For `k <= KC` there is exactly one block and the walk is the
//! historical panels-outer/row-tiles-inner loop, bit for bit and
//! instruction for instruction.
//!
//! ## The bit-exactness contract
//!
//! Per output element `out[i, j]` both kernels compute exactly
//!
//! ```text
//! acc = 0.0;  for kk in 0..k ascending { if x[i,kk] != 0.0 { acc += x[i,kk] * w[kk,j] } }
//! ```
//!
//! with one `mul` and one `add` per step (rustc never contracts to FMA by
//! default).  K-blocks are visited in ascending `kk` order and the
//! inter-block accumulator spill/reload is exact (see above); register
//! blocking tiles *rows* and vectorization runs across the *n*
//! (output-column) lanes only — lanes never interact — so the reduction
//! order per element is identical to the scalar loop and the packed result
//! is bit-identical to [`gemm_ref`] for every shape, including the
//! zero-activation skip (which keeps `0 * NaN` / `0 * inf` weight poison
//! out of the accumulators in every K-block, a property the deployment
//! twins rely on).  Parallel callers ([`crate::tensor::matmul_slices_par`],
//! the conv chunks, the `lw-i8` intra-op row chunks) hand each pool task a
//! disjoint output-row block running this same kernel, so results stay
//! bit-identical at any thread count.  `rust/tests/kernel.rs` enforces all
//! of this — including shapes with `k ≫ KC` and `k % KC != 0` — under
//! default codegen and `-Ctarget-cpu=native` in CI.
//!
//! ## Who packs, and when
//!
//! [`PackedW`] is cached wherever weights are long-lived:
//! [`crate::quant::deploy::DeployedModel::prepare`] packs every conv (per
//! group) and the fc head once, offline, so serving workers never repack;
//! the training-forward / heuristic paths pack per call into reusable
//! scratch ([`crate::tensor::conv::ConvScratch`] or the thread-local
//! [`with_pack_scratch`]), amortized over the `m = b*oh*ow` output rows of
//! the GEMM.

use std::cell::RefCell;

/// Auto-vectorization lane width the micro-kernel is written for: 8 f32s
/// (one AVX2 `ymm`; on narrower ISAs the compiler splits the lane loop).
pub const LANES: usize = 8;
/// Register-tile rows: output rows accumulated simultaneously per panel
/// sweep.  `MR * NR` f32 accumulators stay live across the `kk` loop.
pub const MR: usize = 4;
/// Register-tile columns — one packed panel width (two [`LANES`] vectors).
pub const NR: usize = 2 * LANES;
/// Reduction-dimension cache block: the packed layout groups [`KC`] K-rows
/// of every panel contiguously, so one f32 sub-panel is `KC * NR * 4` =
/// 16 KiB (one quarter of it for the i8 twin) and stays L1-resident across
/// all row tiles of its block.  Between blocks the accumulator tile is
/// reloaded from `out` — lossless, so the f32 ordering contract holds.
pub const KC: usize = 256;

/// Iterate the K-blocks of a `[k, n]` packed buffer in ascending order,
/// yielding `(k0, kb, boff)` — each block's first reduction row, its row
/// count, and its element offset into the buffer.  ONE copy of the
/// block-advance arithmetic, shared by the packer, the kernel walker, and
/// [`PackedWi8::col_sums`], so the layout cannot drift between them.
#[inline(always)]
fn for_each_kblock(k: usize, panels: usize, mut f: impl FnMut(usize, usize, usize)) {
    let (mut k0, mut boff) = (0usize, 0usize);
    while k0 < k {
        let kb = KC.min(k - k0);
        f(k0, kb, boff);
        boff += panels * kb * NR;
        k0 += kb;
    }
}

/// Shared (re)packer behind [`PackedW::pack_cols`] and
/// [`PackedWi8::pack_cols`] — ONE copy of the K-block-major panel layout
/// (see the module docs), so the f32 and i8 grids cannot drift
/// geometrically.  Reuses the destination buffer when the total length is
/// unchanged; pad lanes are re-zeroed explicitly because a warm buffer may
/// be repacked at a different `(k, n)` of the same total length, leaving
/// stale values where the padding (or a block boundary) now falls.
fn pack_cols_blocked<T: Copy + Default>(
    data: &mut Vec<T>,
    w: &[T],
    k: usize,
    row_stride: usize,
    c0: usize,
    ncols: usize,
) {
    let panels = ncols.div_ceil(NR);
    let len = panels * k * NR;
    if data.len() != len {
        data.clear();
        data.resize(len, T::default());
    }
    for_each_kblock(k, panels, |k0, kb, boff| {
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(ncols - j0);
            let sub = &mut data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            for kk in 0..kb {
                let src = (k0 + kk) * row_stride + c0 + j0;
                sub[kk * NR..kk * NR + nv].copy_from_slice(&w[src..src + nv]);
                sub[kk * NR + nv..(kk + 1) * NR].fill(T::default());
            }
        }
    });
}

/// The generic K-blocked panel walk both kernels run: K-blocks ascending
/// (load-bearing for the f32 order-preservation contract), panels within a
/// block, [`MR`]-row register tiles innermost, with the narrow path for
/// panels thinner than one [`LANES`] group.  `full(i, rows, k0, sub, out,
/// nv, first)` runs one register tile of `rows ∈ 1..=MR` output rows
/// starting at row `i` (`out` already offset to `i * n + j0`); `narrow(k0,
/// sub, out, nv, first)` runs every row of one thin panel (`out` offset to
/// `j0`).  `first` is true exactly on the first K-block, where the kernels
/// *store* from-zero accumulators (write mode) instead of
/// load-accumulate-store.
fn walk_blocked_panels<T, A>(
    data: &[T],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [A],
    mut full: impl FnMut(usize, usize, usize, &[T], &mut [A], usize, bool),
    mut narrow: impl FnMut(usize, &[T], &mut [A], usize, bool),
) {
    let panels = n.div_ceil(NR);
    for_each_kblock(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            if nv < LANES {
                narrow(k0, sub, &mut out[j0..], nv, first);
                continue;
            }
            let mut i = 0;
            while i + MR <= m {
                full(i, MR, k0, sub, &mut out[i * n + j0..], nv, first);
                i += MR;
            }
            if i < m {
                full(i, m - i, k0, sub, &mut out[i * n + j0..], nv, first);
            }
        }
    });
}

/// Panel-packed weights: a `[k, n]` row-major matrix rearranged into the
/// K-block-major panel layout the module docs draw — `k.div_ceil(KC)`
/// blocks of up to [`KC`] K-rows, each block holding `ceil(n / NR)`
/// contiguous sub-panels with its [`NR`]-column slice K-major
/// (`sub[kk * NR + lane] = w[k0 + kk, j0 + lane]`), the ragged last panel
/// zero-padded to full width.  The micro-kernel then streams the whole
/// buffer front-to-back — contiguous loads — instead of striding
/// `w[kk * n ..]`.
///
/// Packing a `[k, n]` matrix is one O(k·n) copy; [`PackedW::pack_cols`]
/// reuses the buffer so repacking (training forwards, per-call paths)
/// allocates nothing once warm.
#[derive(Clone, Debug, Default)]
pub struct PackedW {
    k: usize,
    n: usize,
    /// `k.div_ceil(KC)` K-blocks × `n.div_ceil(NR)` sub-panels × `kb * NR`
    /// floats (`kb` = the block's row count; total `panels * k * NR`).
    data: Vec<f32>,
}

impl PackedW {
    /// Pack a whole row-major `[k, n]` matrix.
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedW {
        let mut pw = PackedW::default();
        pw.pack_cols(w, k, n, 0, n);
        pw
    }

    /// (Re)pack columns `c0 .. c0 + ncols` of the row-major
    /// `[k, row_stride]` matrix `w`, reusing the existing buffer.  The
    /// column slice form is what grouped convs need: group `g` of an HWIO
    /// kernel is columns `g*cg_out .. (g+1)*cg_out` of the `[k*k*cg_in,
    /// cout]` matrix, packed without materializing a dense copy first.
    pub fn pack_cols(&mut self, w: &[f32], k: usize, row_stride: usize, c0: usize, ncols: usize) {
        assert!(c0 + ncols <= row_stride, "columns {c0}+{ncols} out of stride {row_stride}");
        assert_eq!(w.len(), k * row_stride, "weight buffer vs [k, row_stride]");
        self.k = k;
        self.n = ncols;
        pack_cols_blocked(&mut self.data, w, k, row_stride, c0, ncols);
    }

    /// Reduction depth (rows of the packed matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (un-padded logical width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the packed buffer (diagnostic / memory accounting).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// The scalar reference kernel (the historical `tensor::matmul_rows` inner
/// loop): `x` rows (each of length `k`) against row-major `w[k, n]`,
/// *accumulated* into `out` (callers pre-zero it).  Kept as the ground
/// truth [`gemm`] is tested and benchmarked against.
pub fn gemm_ref(x: &[f32], k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    if k == 0 || n == 0 {
        return;
    }
    for (xrow, orow) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// One `R`×[`NR`] register tile over one K-block: `R` consecutive x rows
/// (stride `xstride`, already offset to the block's `k0`) against one
/// packed sub-panel of `kb` K-rows.  On the first block accumulators build
/// from zero and are *stored* (write mode); on later blocks they reload the
/// partial sums spilled to `out` — an exact f32 round trip, so per-element
/// operation order matches the unblocked kernel.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_tile<const R: usize>(
    x: &[f32],
    xstride: usize,
    kb: usize,
    panel: &[f32],
    out: &mut [f32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    let xr: [&[f32]; R] = std::array::from_fn(|r| &x[r * xstride..r * xstride + kb]);
    let mut acc = [[0.0f32; NR]; R];
    if !first {
        for (r, accr) in acc.iter_mut().enumerate() {
            accr[..nv].copy_from_slice(&out[r * n_stride..r * n_stride + nv]);
        }
    }
    for kk in 0..kb {
        let wrow = &panel[kk * NR..kk * NR + NR];
        for r in 0..R {
            let xv = xr[r][kk];
            // preserve the reference kernel's zero-activation skip: it is
            // load-bearing (0 * NaN/inf weights must not poison the tile)
            if xv == 0.0 {
                continue;
            }
            for (a, &wv) in acc[r].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        out[r * n_stride..r * n_stride + nv].copy_from_slice(&accr[..nv]);
    }
}

/// One panel narrower than a single vector lane group: run the identical
/// reduction over just the `nv` valid lanes instead of all [`NR`].  This is
/// the depthwise-conv case (`cg_out == 1`: one useful lane in a padded
/// panel) and the raggedest of ragged tails — full-width tiles would spend
/// `NR/nv`× the multiply work on zero pad lanes.  Same spill/reload rule
/// between K-blocks as [`micro_tile`].
#[allow(clippy::too_many_arguments)]
fn micro_narrow(
    x: &[f32],
    m: usize,
    xstride: usize,
    kb: usize,
    panel: &[f32],
    out: &mut [f32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    for i in 0..m {
        let xrow = &x[i * xstride..i * xstride + kb];
        let mut acc = [0.0f32; LANES];
        if !first {
            acc[..nv].copy_from_slice(&out[i * n_stride..i * n_stride + nv]);
        }
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &panel[kk * NR..kk * NR + nv];
            for (a, &wv) in acc[..nv].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
        out[i * n_stride..i * n_stride + nv].copy_from_slice(&acc[..nv]);
    }
}

/// Write-mode packed GEMM: `out[m, n] = x[m, k] @ w` with `w` pre-packed.
/// Every element of `out` is overwritten (beta = 0), so callers reuse
/// right-sized buffers without zero-filling them first.  Bit-identical to
/// [`gemm_ref`] over a zeroed buffer — see the module docs for why,
/// including across [`KC`] block boundaries.
///
/// Loop order: K-blocks outer (ascending — the ordering contract), panels
/// within a block, [`MR`]-row register tiles inner, so one sub-panel
/// (`kb * NR` floats, L1-sized) stays cache-hot across all `m / MR` row
/// tiles while the accumulator tile pins the output in registers for the
/// block's whole `kk` reduction — the scalar loop instead re-walks the
/// full `n`-wide output row once per `kk`.  A panel with fewer than
/// [`LANES`] valid lanes (depthwise convs, the raggedest tails) drops to
/// [`micro_narrow`] so pad lanes cost no multiplies; per-element reduction
/// order is the same either way.
pub fn gemm(x: &[f32], m: usize, pw: &PackedW, out: &mut [f32]) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(x.len(), m * k, "x vs [m, k]");
    debug_assert_eq!(out.len(), m * n, "out vs [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    walk_blocked_panels(
        &pw.data,
        m,
        k,
        n,
        out,
        |i, rows, k0, sub, o, nv, first| {
            let kb = sub.len() / NR;
            let xs = &x[i * k + k0..];
            match rows {
                MR => micro_tile::<MR>(xs, k, kb, sub, o, n, nv, first),
                3 => micro_tile::<3>(xs, k, kb, sub, o, n, nv, first),
                2 => micro_tile::<2>(xs, k, kb, sub, o, n, nv, first),
                1 => micro_tile::<1>(xs, k, kb, sub, o, n, nv, first),
                rows => unreachable!("register tiles cover 1..=MR rows, got {rows}"),
            }
        },
        |k0, sub, o, nv, first| {
            micro_narrow(&x[k0..], m, k, sub.len() / NR, sub, o, n, nv, first)
        },
    );
}

// ------------------------------------------------------------ integer twin

/// Panel-packed **i8** weights — the integer twin of [`PackedW`], identical
/// K-block-major panel geometry over `i8` weight *codes* instead of f32
/// values.  This is the storage the `lw` deployment grid actually implies:
/// weight codes live in `[-7, 7]` (4 bits), so an i8 panel holds 4× the
/// codes per cache line of the f32 layout (a [`KC`] sub-panel is 4 KiB),
/// and [`gemm_i8`] accumulates them in i32 without any float rounding.
/// Built by [`crate::backend::Int8Backend`] at prepare time; the f32 paths
/// never touch it.
#[derive(Clone, Debug, Default)]
pub struct PackedWi8 {
    k: usize,
    n: usize,
    /// Same K-block-major layout as the f32 `PackedW` buffer, in codes.
    data: Vec<i8>,
}

impl PackedWi8 {
    /// Pack a whole row-major `[k, n]` code matrix.
    pub fn pack(w: &[i8], k: usize, n: usize) -> PackedWi8 {
        let mut pw = PackedWi8::default();
        pw.pack_cols(w, k, n, 0, n);
        pw
    }

    /// (Re)pack columns `c0 .. c0 + ncols` of the row-major
    /// `[k, row_stride]` code matrix, reusing the buffer — the same column
    /// slicing [`PackedW::pack_cols`] does for grouped convs.
    pub fn pack_cols(&mut self, w: &[i8], k: usize, row_stride: usize, c0: usize, ncols: usize) {
        assert!(c0 + ncols <= row_stride, "columns {c0}+{ncols} out of stride {row_stride}");
        assert_eq!(w.len(), k * row_stride, "code buffer vs [k, row_stride]");
        self.k = k;
        self.n = ncols;
        pack_cols_blocked(&mut self.data, w, k, row_stride, c0, ncols);
    }

    /// Reduction depth (rows of the packed matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (un-padded logical width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-logical-column code sums (`sum_kk w[kk, j]` as i32) — the
    /// zero-point correction term: an activation stored offset by `zp`
    /// contributes `zp * col_sum` extra per output, which callers fold into
    /// the integer bias once at prepare time.  Walks the K-block-major
    /// layout, ignoring pad lanes.
    pub fn col_sums(&self) -> Vec<i32> {
        let mut sums = vec![0i32; self.n];
        let panels = self.n.div_ceil(NR);
        for_each_kblock(self.k, panels, |_k0, kb, boff| {
            for p in 0..panels {
                let j0 = p * NR;
                let nv = NR.min(self.n - j0);
                let sub = &self.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
                for kk in 0..kb {
                    let row = &sub[kk * NR..kk * NR + nv];
                    for (s, &c) in sums[j0..j0 + nv].iter_mut().zip(row) {
                        *s += c as i32;
                    }
                }
            }
        });
        sums
    }

    /// Bytes held by the packed buffer (4× denser than the f32 panels).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// One `R`×[`NR`] i32 register tile over one K-block: the integer mirror
/// of [`micro_tile`].  No zero-activation skip — in integer arithmetic
/// `0 * w` is exactly 0 for every representable `w` (there is no NaN/inf
/// to mask), so the branch the f32 kernel needs for correctness would only
/// cost the i8 kernel its vectorization.  The inter-block spill/reload is
/// trivially exact for i32.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_tile_i8<const R: usize>(
    x: &[i8],
    xstride: usize,
    kb: usize,
    panel: &[i8],
    out: &mut [i32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    let xr: [&[i8]; R] = std::array::from_fn(|r| &x[r * xstride..r * xstride + kb]);
    let mut acc = [[0i32; NR]; R];
    if !first {
        for (r, accr) in acc.iter_mut().enumerate() {
            accr[..nv].copy_from_slice(&out[r * n_stride..r * n_stride + nv]);
        }
    }
    for kk in 0..kb {
        let wrow = &panel[kk * NR..kk * NR + NR];
        for r in 0..R {
            let xv = xr[r][kk] as i32;
            for (a, &wv) in acc[r].iter_mut().zip(wrow) {
                *a += xv * wv as i32;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        out[r * n_stride..r * n_stride + nv].copy_from_slice(&accr[..nv]);
    }
}

/// Narrow-panel i8 path (`nv < LANES`): reduce only the valid lanes, the
/// depthwise-conv / ragged-tail case of [`micro_narrow`].
#[allow(clippy::too_many_arguments)]
fn micro_narrow_i8(
    x: &[i8],
    m: usize,
    xstride: usize,
    kb: usize,
    panel: &[i8],
    out: &mut [i32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    for i in 0..m {
        let xrow = &x[i * xstride..i * xstride + kb];
        let mut acc = [0i32; LANES];
        if !first {
            acc[..nv].copy_from_slice(&out[i * n_stride..i * n_stride + nv]);
        }
        for (kk, &xv) in xrow.iter().enumerate() {
            let xv = xv as i32;
            let wrow = &panel[kk * NR..kk * NR + nv];
            for (a, &wv) in acc[..nv].iter_mut().zip(wrow) {
                *a += xv * wv as i32;
            }
        }
        out[i * n_stride..i * n_stride + nv].copy_from_slice(&acc[..nv]);
    }
}

/// Write-mode i8×i8→i32 GEMM: `out[m, n] = x[m, k] @ w` with `w` pre-packed
/// as i8 codes and every product widened to i32 before accumulation.  Same
/// K-blocked loop structure as the f32 [`gemm`] (one generic walker drives
/// both), but the result is *exact*: as long as the true sum fits i32 there
/// is no rounding at all, and integer addition is associative, so any
/// blocking/vectorization the compiler picks yields bit-identical output.
/// The `lw` deployment shapes are far inside the safe range (|x| ≤ 255,
/// |w| ≤ 7 ⇒ k up to ~1.2M rows before i32 could saturate).
pub fn gemm_i8(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(x.len(), m * k, "x vs [m, k]");
    debug_assert_eq!(out.len(), m * n, "out vs [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0);
        return;
    }
    walk_blocked_panels(
        &pw.data,
        m,
        k,
        n,
        out,
        |i, rows, k0, sub, o, nv, first| {
            let kb = sub.len() / NR;
            let xs = &x[i * k + k0..];
            match rows {
                MR => micro_tile_i8::<MR>(xs, k, kb, sub, o, n, nv, first),
                3 => micro_tile_i8::<3>(xs, k, kb, sub, o, n, nv, first),
                2 => micro_tile_i8::<2>(xs, k, kb, sub, o, n, nv, first),
                1 => micro_tile_i8::<1>(xs, k, kb, sub, o, n, nv, first),
                rows => unreachable!("register tiles cover 1..=MR rows, got {rows}"),
            }
        },
        |k0, sub, o, nv, first| {
            micro_narrow_i8(&x[k0..], m, k, sub.len() / NR, sub, o, n, nv, first)
        },
    );
}

thread_local! {
    /// Per-thread pack buffer for call sites whose weights are not
    /// long-lived (training forwards, one-off heuristics): the pack is
    /// amortized over the GEMM's `m` rows and the buffer over the thread's
    /// lifetime.
    static PACK_SCRATCH: RefCell<PackedW> = RefCell::new(PackedW::default());
}

/// Run `f` with this thread's reusable [`PackedW`] scratch.  Re-entrant
/// calls (a packed caller invoking another packed caller mid-borrow) fall
/// back to a fresh buffer instead of panicking.
pub fn with_pack_scratch<R>(f: impl FnOnce(&mut PackedW) -> R) -> R {
    PACK_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut pw) => f(&mut pw),
        Err(_) => f(&mut PackedW::default()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn ref_out(x: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        gemm_ref(x, k, w, n, &mut out);
        out
    }

    #[test]
    fn packed_layout_streams_columns() {
        // [2, 3] matrix; single K-block, single (padded) panel: lane j
        // holds column j
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let pw = PackedW::pack(&w, 2, 3);
        assert_eq!((pw.k(), pw.n()), (2, 3));
        assert_eq!(pw.data.len(), 2 * NR);
        assert_eq!(&pw.data[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&pw.data[3..NR], &[0.0; NR - 3]);
        assert_eq!(&pw.data[NR..NR + 3], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn blocked_layout_panel_offsets() {
        // k spanning two K-blocks: block b starts at b*KC*panels*NR and
        // holds per-panel sub-slices of that block's row count
        let (k, n) = (KC + 3, NR + 2);
        let w = rand_vec(k * n, 77);
        let pw = PackedW::pack(&w, k, n);
        let panels = n.div_ceil(NR);
        assert_eq!(pw.data.len(), panels * k * NR);
        for &kk in &[0usize, 1, KC - 1, KC, KC + 2] {
            for &j in &[0usize, 1, NR - 1, NR, n - 1] {
                let (b, kl) = (kk / KC, kk % KC);
                let kb = KC.min(k - b * KC);
                let (p, lane) = (j / NR, j % NR);
                let idx = b * KC * panels * NR + p * kb * NR + kl * NR + lane;
                assert_eq!(pw.data[idx], w[kk * n + j], "kk={kk} j={j}");
            }
        }
    }

    #[test]
    fn packed_matches_reference_bit_exactly() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, NR),
            (5, 7, NR + 1),
            (MR - 1, 16, NR - 1),
            (17, 33, 40),
            (MR * 3, 2, 2 * NR),
            (2, 64, 5),
        ] {
            let x = rand_vec(m * k, (m * 31 + k * 7 + n) as u64);
            let w = rand_vec(k * n, (m + k + n * 13) as u64);
            let pw = PackedW::pack(&w, k, n);
            // sentinel fill proves write-mode coverage of every element
            let mut got = vec![777.0f32; m * n];
            gemm(&x, m, &pw, &mut got);
            let want = ref_out(&x, m, k, &w, n);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn kc_blocked_kernel_matches_reference_bit_exactly() {
        // shapes straddling the KC reduction block: k < KC, k == KC,
        // k % KC != 0, k a multiple of KC, k >> KC — with zeros sprinkled
        // so the skip path crosses block boundaries
        for &(m, k, n) in &[
            (5usize, KC - 1, NR + 1),
            (MR, KC, NR),
            (7, KC + 1, 2 * NR + 3),
            (MR + 2, 2 * KC, 5),
            (3, 4 * KC + 37, NR + 9),
            (1, 3 * KC, 1),
        ] {
            let mut x = rand_vec(m * k, (m * 13 + k + n * 7) as u64);
            for (i, v) in x.iter_mut().enumerate() {
                if i % 7 == 0 {
                    *v = 0.0;
                }
            }
            let w = rand_vec(k * n, (m + k * 3 + n) as u64);
            let pw = PackedW::pack(&w, k, n);
            let mut got = vec![f32::NAN; m * n];
            gemm(&x, m, &pw, &mut got);
            let want = ref_out(&x, m, k, &w, n);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn degenerate_shapes_are_safe() {
        // k = 0: write-mode must still zero the output
        let pw = PackedW::pack(&[], 0, 3);
        let mut out = vec![9.0f32; 2 * 3];
        gemm(&[], 2, &pw, &mut out);
        assert_eq!(out, vec![0.0; 6]);
        // n = 0 and m = 0: no-ops on empty outputs
        let pw = PackedW::pack(&[], 4, 0);
        gemm(&rand_vec(8, 1), 2, &pw, &mut []);
        let pw = PackedW::pack(&rand_vec(8, 2), 4, 2);
        gemm(&[], 0, &pw, &mut []);
        // m = 0 with a multi-KC-block, narrow-panel pack: the m/n guard
        // must fire before any K-block ever offsets into the empty x
        let pw = PackedW::pack(&rand_vec(2 * KC * 5, 3), 2 * KC, 5);
        gemm(&[], 0, &pw, &mut []);
    }

    #[test]
    fn zero_activations_mask_nonfinite_weights() {
        // column kk of x is all-zero exactly where w row kk is poisoned
        let (m, k, n) = (5usize, 6usize, NR + 3);
        let mut x = rand_vec(m * k, 3);
        let mut w = rand_vec(k * n, 4);
        for i in 0..m {
            x[i * k + 2] = 0.0;
            x[i * k + 5] = 0.0;
        }
        for j in 0..n {
            w[2 * n + j] = f32::NAN;
            w[5 * n + j] = if j % 2 == 0 { f32::INFINITY } else { f32::NEG_INFINITY };
        }
        let pw = PackedW::pack(&w, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm(&x, m, &pw, &mut got);
        assert!(got.iter().all(|v| v.is_finite()), "poisoned rows must be skipped");
        let want = ref_out(&x, m, k, &w, n);
        assert_eq!(want, got);
    }

    #[test]
    fn repacking_reuses_and_matches() {
        let mut pw = PackedW::default();
        // (4, 16) -> (2, 20) keeps the same buffer length (64 floats) while
        // moving where the ragged pad lanes fall; (2*KC, 16) -> (KC, 32)
        // keeps the length while moving a K-block boundary: stale-pad and
        // stale-block regression guards
        for (k, n, seed) in [
            (9usize, 21usize, 5u64),
            (4, 3, 6),
            (9, 21, 7),
            (4, 16, 8),
            (2, 20, 9),
            (2 * KC, 16, 10),
            (KC, 32, 11),
        ] {
            let w = rand_vec(k * n, seed);
            pw.pack_cols(&w, k, n, 0, n);
            let fresh = PackedW::pack(&w, k, n);
            assert_eq!(pw.data, fresh.data, "k={k} n={n}");
            assert_eq!((pw.k(), pw.n()), (k, n));
        }
    }

    fn rand_codes(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| (rng.normal() * 4.0).round().clamp(-7.0, 7.0) as i8).collect()
    }

    /// Naive i32 reference for the i8 kernel.
    fn ref_out_i8(x: &[i8], m: usize, k: usize, w: &[i8], n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk] as i32;
                for j in 0..n {
                    out[i * n + j] += xv * w[kk * n + j] as i32;
                }
            }
        }
        out
    }

    #[test]
    fn i8_kernel_matches_naive_reference_exactly() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, NR),
            (5, 7, NR + 1),
            (MR - 1, 16, NR - 1),
            (17, 33, 40),
            (MR * 3, 2, 2 * NR),
            (2, 64, 5),
            (9, 9, 1), // depthwise: one valid lane per panel
        ] {
            let x = rand_codes(m * k, (m * 37 + k * 11 + n) as u64);
            let w = rand_codes(k * n, (m + k * 3 + n * 17) as u64);
            let pw = PackedWi8::pack(&w, k, n);
            let mut got = vec![777i32; m * n];
            gemm_i8(&x, m, &pw, &mut got);
            assert_eq!(got, ref_out_i8(&x, m, k, &w, n), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn i8_kc_blocked_matches_naive_reference_exactly() {
        // the i8 twin across KC block boundaries (incl. the narrow path)
        for &(m, k, n) in &[
            (4usize, KC + 3, NR),
            (6, 2 * KC + 11, NR + 2),
            (MR + 1, KC, 2 * NR + 1),
            (2, 3 * KC, 1),
        ] {
            let x = rand_codes(m * k, (m * 41 + k + n) as u64);
            let w = rand_codes(k * n, (m + k + n * 23) as u64);
            let pw = PackedWi8::pack(&w, k, n);
            let mut got = vec![777i32; m * n];
            gemm_i8(&x, m, &pw, &mut got);
            assert_eq!(got, ref_out_i8(&x, m, k, &w, n), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn i8_degenerate_shapes_are_safe() {
        let pw = PackedWi8::pack(&[], 0, 3);
        let mut out = vec![9i32; 2 * 3];
        gemm_i8(&[], 2, &pw, &mut out);
        assert_eq!(out, vec![0; 6]);
        let pw = PackedWi8::pack(&[], 4, 0);
        gemm_i8(&rand_codes(8, 1), 2, &pw, &mut []);
        let pw = PackedWi8::pack(&rand_codes(8, 2), 4, 2);
        gemm_i8(&[], 0, &pw, &mut []);
    }

    #[test]
    fn i8_col_sums_and_repack_reuse() {
        // col_sums must ignore pad lanes and walk the blocked layout
        // correctly; repacking at a different (k, n) of the same total
        // length (incl. across a KC boundary) must not leak stale codes
        let mut pw = PackedWi8::default();
        for (k, n, seed) in [
            (9usize, 21usize, 5u64),
            (4, 3, 6),
            (4, 16, 8),
            (2, 20, 9),
            (KC + 5, 3, 12),
            (2 * KC, 16, 13),
            (KC, 32, 14),
        ] {
            let w = rand_codes(k * n, seed);
            pw.pack_cols(&w, k, n, 0, n);
            let want: Vec<i32> = (0..n)
                .map(|j| (0..k).map(|kk| w[kk * n + j] as i32).sum())
                .collect();
            assert_eq!(pw.col_sums(), want, "k={k} n={n}");
            let fresh = PackedWi8::pack(&w, k, n);
            assert_eq!(pw.data, fresh.data, "k={k} n={n}");
        }
    }

    #[test]
    fn i8_pack_cols_slices_groups() {
        let (k, stride) = (3usize, 8usize);
        let w = rand_codes(k * stride, 12);
        let mut sliced = PackedWi8::default();
        sliced.pack_cols(&w, k, stride, 2, 4);
        let dense: Vec<i8> = (0..k)
            .flat_map(|kk| w[kk * stride + 2..kk * stride + 6].to_vec())
            .collect();
        let want = PackedWi8::pack(&dense, k, 4);
        assert_eq!(sliced.data, want.data);
    }

    #[test]
    fn i8_matches_f32_kernel_on_code_matrices() {
        // on integer-valued inputs within f32's exact range the two kernels
        // must agree number-for-number — including across KC blocks
        for &(m, k, n) in &[(13usize, 57usize, NR + 5), (5, KC + 9, NR + 5)] {
            let xi = rand_codes(m * k, 21 + k as u64);
            let wi = rand_codes(k * n, 22 + k as u64);
            let xf: Vec<f32> = xi.iter().map(|&v| v as f32).collect();
            let wf: Vec<f32> = wi.iter().map(|&v| v as f32).collect();
            let pw8 = PackedWi8::pack(&wi, k, n);
            let pwf = PackedW::pack(&wf, k, n);
            let mut got8 = vec![0i32; m * n];
            gemm_i8(&xi, m, &pw8, &mut got8);
            let mut gotf = vec![0.0f32; m * n];
            gemm(&xf, m, &pwf, &mut gotf);
            for (a, b) in got8.iter().zip(&gotf) {
                assert_eq!(*a as f32, *b, "k={k}");
            }
        }
    }

    #[test]
    fn pack_cols_slices_groups() {
        // columns 2..5 of a [2, 6] matrix == packing the dense 3-col copy
        let (k, stride) = (2usize, 6usize);
        let w = rand_vec(k * stride, 8);
        let mut sliced = PackedW::default();
        sliced.pack_cols(&w, k, stride, 2, 3);
        let dense: Vec<f32> = (0..k)
            .flat_map(|kk| w[kk * stride + 2..kk * stride + 5].to_vec())
            .collect();
        let want = PackedW::pack(&dense, k, 3);
        assert_eq!(sliced.data, want.data);
    }
}
