//! `qft::kernel` — the register-blocked, panel-packed, KC-cache-blocked
//! GEMM micro-kernel under every forward path (S17).
//!
//! Every path in the reproduction — the QFT training forwards, the integer
//! deployment twins, the [`crate::serve`] workers, and the [`crate::par`]
//! chunked kernels — bottoms out in one inner loop: rows of activations
//! against a `[k, n]` weight matrix.  This module owns that loop.  Two
//! kernels, one contract:
//!
//! * [`gemm_ref`] — the scalar reference: for each output row, walk `kk =
//!   0..k` ascending and axpy `x[kk] * w[kk, ..]` into the row, skipping
//!   zero activations.  This is byte-for-byte the historical
//!   `tensor::matmul_rows` loop; it exists as the baseline the packed
//!   kernel is proven against (tests and `BENCH_gemm.json`).
//! * [`gemm`] — the fast path: weights pre-packed into [`PackedW`] panels
//!   of [`NR`] columns so the `kk` walk streams K-major contiguous memory
//!   instead of striding `w[kk*n..]`, with an [`MR`]×[`NR`] accumulator
//!   tile held in registers across the reduction ([`LANES`]-wide unrolled
//!   f32 arrays the compiler auto-vectorizes — no unsafe, no intrinsics).
//!   It is a *write-mode* (beta = 0) kernel: the first K-block's tile is
//!   stored over `out`, so callers skip the zero-fill pass entirely.
//!
//! A third kernel lives alongside the f32 pair: [`gemm_i8`] over
//! [`PackedWi8`] panels — the same panel geometry and loop structure with
//! i8 weight *codes* and i32 accumulators, serving the `lw-i8` deployment
//! backend ([`crate::backend::Int8Backend`]).  Its contract is stronger
//! and simpler: integer accumulation is exact and associative (no rounding
//! while the true sum fits i32), so no ordering discipline is needed.  A
//! fourth, [`gemm_w4`] over [`PackedW4`], packs two 4-bit codes per byte in
//! the same K-block-major geometry — half the weight bandwidth of the i8
//! panels, which is the lever on large-K shapes where the panel stream, not
//! the multiplies, bounds throughput.
//!
//! ## Runtime dispatch ([`dispatch`])
//!
//! The integer kernels are *runtime-dispatched*: [`kernel_path`] probes the
//! CPU once (`is_x86_feature_detected!` / `is_aarch64_feature_detected!`,
//! cached in a `OnceLock`) and [`gemm_i8`] / [`gemm_w4`] route to explicit
//! u8×i8 dot-product micro-kernels — AVX2 `_mm256_maddubs_epi16`
//! ([`avx2`]), AVX-512-VNNI `_mm256_dpbusd_epi32` ([`vnni`]), NEON
//! `vdotq_s32` ([`neon`]) — falling back to the safe scalar twins
//! everywhere else.  `QFT_KERNEL=scalar|avx2|vnni|neon` forces any path
//! (panicking if the CPU lacks it, so a forced CI leg can never silently
//! rot into the fallback).  Because integer accumulation is exact and
//! associative, every path returns **bit-identical** results to the scalar
//! kernel on every shape at any thread count — no tolerance; the per-ISA
//! parity tests in `rust/tests/kernel.rs` pin it.
//!
//! These ISA modules are the only place in the crate where `unsafe`
//! appears for kernels (see the crate-level policy in the README): every
//! `unsafe` block is confined to `#[target_feature]` functions guarded by
//! a runtime feature assert, carries a `SAFETY:` comment, and is pinned by
//! a scalar-twin parity test.
//!
//! ## KC cache blocking
//!
//! Once the reduction depth outgrows the cache, a full-`k` panel (`k * NR`
//! floats) is evicted between [`MR`]-row tiles and every tile re-streams it
//! from L2/memory.  The packed layout is therefore *K-block major*: the
//! reduction is split into [`KC`]-row blocks, and each block holds its
//! panel sub-slices contiguously —
//!
//! ```text
//!   data = [ block 0: panel 0 | panel 1 | … ]  ← KC rows each, NR lanes
//!          [ block 1: panel 0 | panel 1 | … ]  ← next KC rows
//!          [ …                               ]  ← last block ragged (k % KC)
//! ```
//!
//! — so one sub-panel is `KC * NR` f32s (16 KiB at KC = 256; 4 KiB for the
//! i8 twin) and stays L1-resident across all `m / MR` row tiles of its
//! block, while the whole buffer is streamed strictly front-to-back.  Both
//! kernels drive the identical block walk through one generic panel walker
//! (`walk_blocked_panels`), so the f32 and i8 grids cannot drift
//! structurally.  Between K-blocks the accumulator tile is spilled to
//! `out` and reloaded (load-accumulate-store for every block after the
//! first) — an f32 store/load round trip is lossless, so the *per-element
//! sequence of arithmetic operations is unchanged* from the unblocked
//! kernel.  For `k <= KC` there is exactly one block and the walk is the
//! historical panels-outer/row-tiles-inner loop, bit for bit and
//! instruction for instruction.
//!
//! ## The bit-exactness contract
//!
//! Per output element `out[i, j]` both kernels compute exactly
//!
//! ```text
//! acc = 0.0;  for kk in 0..k ascending { if x[i,kk] != 0.0 { acc += x[i,kk] * w[kk,j] } }
//! ```
//!
//! with one `mul` and one `add` per step (rustc never contracts to FMA by
//! default).  K-blocks are visited in ascending `kk` order and the
//! inter-block accumulator spill/reload is exact (see above); register
//! blocking tiles *rows* and vectorization runs across the *n*
//! (output-column) lanes only — lanes never interact — so the reduction
//! order per element is identical to the scalar loop and the packed result
//! is bit-identical to [`gemm_ref`] for every shape, including the
//! zero-activation skip (which keeps `0 * NaN` / `0 * inf` weight poison
//! out of the accumulators in every K-block, a property the deployment
//! twins rely on).  Parallel callers ([`crate::tensor::matmul_slices_par`],
//! the conv chunks, the `lw-i8` intra-op row chunks) hand each pool task a
//! disjoint output-row block running this same kernel, so results stay
//! bit-identical at any thread count.  `rust/tests/kernel.rs` enforces all
//! of this — including shapes with `k ≫ KC` and `k % KC != 0` — under
//! default codegen and `-Ctarget-cpu=native` in CI.
//!
//! ## Who packs, and when
//!
//! [`PackedW`] is cached wherever weights are long-lived:
//! [`crate::quant::deploy::DeployedModel::prepare`] packs every conv (per
//! group) and the fc head once, offline, so serving workers never repack;
//! the training-forward / heuristic paths pack per call into reusable
//! scratch ([`crate::tensor::conv::ConvScratch`] or the thread-local
//! [`with_pack_scratch`]), amortized over the `m = b*oh*ow` output rows of
//! the GEMM.

use std::cell::RefCell;

pub mod dispatch;

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod vnni;

pub use dispatch::{
    gemm_i8_with, gemm_w4_with, kernel_dispatch, kernel_path, supported_paths, KernelPath,
};

/// Auto-vectorization lane width the micro-kernel is written for: 8 f32s
/// (one AVX2 `ymm`; on narrower ISAs the compiler splits the lane loop).
pub const LANES: usize = 8;
/// Register-tile rows: output rows accumulated simultaneously per panel
/// sweep.  `MR * NR` f32 accumulators stay live across the `kk` loop.
pub const MR: usize = 4;
/// Register-tile columns — one packed panel width (two [`LANES`] vectors).
pub const NR: usize = 2 * LANES;
/// Reduction-dimension cache block: the packed layout groups [`KC`] K-rows
/// of every panel contiguously, so one f32 sub-panel is `KC * NR * 4` =
/// 16 KiB (one quarter of it for the i8 twin) and stays L1-resident across
/// all row tiles of its block.  Between blocks the accumulator tile is
/// reloaded from `out` — lossless, so the f32 ordering contract holds.
pub const KC: usize = 256;

/// Iterate the K-blocks of a `[k, n]` packed buffer in ascending order,
/// yielding `(k0, kb, boff)` — each block's first reduction row, its row
/// count, and its element offset into the buffer.  ONE copy of the
/// block-advance arithmetic, shared by the packer, the kernel walker, and
/// [`PackedWi8::col_sums`], so the layout cannot drift between them.
#[inline(always)]
fn for_each_kblock(k: usize, panels: usize, mut f: impl FnMut(usize, usize, usize)) {
    let (mut k0, mut boff) = (0usize, 0usize);
    while k0 < k {
        let kb = KC.min(k - k0);
        f(k0, kb, boff);
        boff += panels * kb * NR;
        k0 += kb;
    }
}

/// [`for_each_kblock`] for the nibble-packed [`PackedW4`] buffer, whose
/// per-(block, panel) sub-slice holds `kb.div_ceil(2) * NR` *bytes* (two
/// codes per byte) instead of `kb * NR`.  Same ascending-`k0` walk, its own
/// block-advance arithmetic — kept next to its sibling so the two cannot
/// drift.
#[inline(always)]
fn for_each_kblock_w4(k: usize, panels: usize, mut f: impl FnMut(usize, usize, usize)) {
    let (mut k0, mut boff) = (0usize, 0usize);
    while k0 < k {
        let kb = KC.min(k - k0);
        f(k0, kb, boff);
        boff += panels * kb.div_ceil(2) * NR;
        k0 += kb;
    }
}

/// Byte offset of logical element `(kk, lane)` inside one quad-interleaved
/// i8 sub-panel of `kb` reduction rows (see [`PackedWi8`] for the layout).
/// ONE copy of the placement arithmetic, shared by the packer,
/// [`PackedWi8::col_sums`] and the layout tests.
#[inline(always)]
fn i8_sub_index(kb: usize, kk: usize, lane: usize) -> usize {
    let nq = kb / 4;
    if kk < 4 * nq {
        (kk / 4 * NR + lane) * 4 + kk % 4
    } else {
        4 * nq * NR + (kk - 4 * nq) * NR + lane
    }
}

/// `(byte offset, is_high_nibble)` of logical code `(kk, lane)` inside one
/// nibble-packed W4 sub-panel of `kb` reduction rows (see [`PackedW4`] for
/// the layout).  Shared by the packer, the scalar kernel's tail walk,
/// [`PackedW4::unpack`] and the layout tests.
#[inline(always)]
fn w4_sub_index(kb: usize, kk: usize, lane: usize) -> (usize, bool) {
    let noct = kb / 8;
    if kk < 8 * noct {
        let (o, j) = (kk / 8, kk % 8);
        ((o * NR + lane) * 4 + j % 4, j >= 4)
    } else {
        let r = kk - 8 * noct;
        (4 * noct * NR + r / 2 * NR + lane, r % 2 == 1)
    }
}

/// Decode the low / high two's-complement nibble of a W4 byte.
#[inline(always)]
fn w4_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}
#[inline(always)]
fn w4_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// The f32 (re)packer behind [`PackedW::pack_cols`] — the K-block-major
/// panel layout (see the module docs) with rows K-major inside each
/// sub-panel.  The integer packers ([`PackedWi8::pack_cols`],
/// [`PackedW4::pack_cols`]) share the same block walk
/// ([`for_each_kblock`] / [`for_each_kblock_w4`]) but interleave elements
/// inside the sub-panel for the SIMD dot-product instructions.  Reuses the
/// destination buffer when the total length is unchanged; pad lanes are
/// re-zeroed explicitly because a warm buffer may be repacked at a
/// different `(k, n)` of the same total length, leaving stale values where
/// the padding (or a block boundary) now falls.
fn pack_cols_blocked<T: Copy + Default>(
    data: &mut Vec<T>,
    w: &[T],
    k: usize,
    row_stride: usize,
    c0: usize,
    ncols: usize,
) {
    let panels = ncols.div_ceil(NR);
    let len = panels * k * NR;
    if data.len() != len {
        data.clear();
        data.resize(len, T::default());
    }
    for_each_kblock(k, panels, |k0, kb, boff| {
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(ncols - j0);
            let sub = &mut data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            for kk in 0..kb {
                let src = (k0 + kk) * row_stride + c0 + j0;
                sub[kk * NR..kk * NR + nv].copy_from_slice(&w[src..src + nv]);
                sub[kk * NR + nv..(kk + 1) * NR].fill(T::default());
            }
        }
    });
}

/// The generic K-blocked panel walk both kernels run: K-blocks ascending
/// (load-bearing for the f32 order-preservation contract), panels within a
/// block, [`MR`]-row register tiles innermost, with the narrow path for
/// panels thinner than one [`LANES`] group.  `full(i, rows, k0, sub, out,
/// nv, first)` runs one register tile of `rows ∈ 1..=MR` output rows
/// starting at row `i` (`out` already offset to `i * n + j0`); `narrow(k0,
/// sub, out, nv, first)` runs every row of one thin panel (`out` offset to
/// `j0`).  `first` is true exactly on the first K-block, where the kernels
/// *store* from-zero accumulators (write mode) instead of
/// load-accumulate-store.
fn walk_blocked_panels<T, A>(
    data: &[T],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [A],
    mut full: impl FnMut(usize, usize, usize, &[T], &mut [A], usize, bool),
    mut narrow: impl FnMut(usize, &[T], &mut [A], usize, bool),
) {
    let panels = n.div_ceil(NR);
    for_each_kblock(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            if nv < LANES {
                narrow(k0, sub, &mut out[j0..], nv, first);
                continue;
            }
            let mut i = 0;
            while i + MR <= m {
                full(i, MR, k0, sub, &mut out[i * n + j0..], nv, first);
                i += MR;
            }
            if i < m {
                full(i, m - i, k0, sub, &mut out[i * n + j0..], nv, first);
            }
        }
    });
}

/// Panel-packed weights: a `[k, n]` row-major matrix rearranged into the
/// K-block-major panel layout the module docs draw — `k.div_ceil(KC)`
/// blocks of up to [`KC`] K-rows, each block holding `ceil(n / NR)`
/// contiguous sub-panels with its [`NR`]-column slice K-major
/// (`sub[kk * NR + lane] = w[k0 + kk, j0 + lane]`), the ragged last panel
/// zero-padded to full width.  The micro-kernel then streams the whole
/// buffer front-to-back — contiguous loads — instead of striding
/// `w[kk * n ..]`.
///
/// Packing a `[k, n]` matrix is one O(k·n) copy; [`PackedW::pack_cols`]
/// reuses the buffer so repacking (training forwards, per-call paths)
/// allocates nothing once warm.
#[derive(Clone, Debug, Default)]
pub struct PackedW {
    k: usize,
    n: usize,
    /// `k.div_ceil(KC)` K-blocks × `n.div_ceil(NR)` sub-panels × `kb * NR`
    /// floats (`kb` = the block's row count; total `panels * k * NR`).
    data: Vec<f32>,
}

impl PackedW {
    /// Pack a whole row-major `[k, n]` matrix.
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedW {
        let mut pw = PackedW::default();
        pw.pack_cols(w, k, n, 0, n);
        pw
    }

    /// (Re)pack columns `c0 .. c0 + ncols` of the row-major
    /// `[k, row_stride]` matrix `w`, reusing the existing buffer.  The
    /// column slice form is what grouped convs need: group `g` of an HWIO
    /// kernel is columns `g*cg_out .. (g+1)*cg_out` of the `[k*k*cg_in,
    /// cout]` matrix, packed without materializing a dense copy first.
    pub fn pack_cols(&mut self, w: &[f32], k: usize, row_stride: usize, c0: usize, ncols: usize) {
        assert!(c0 + ncols <= row_stride, "columns {c0}+{ncols} out of stride {row_stride}");
        assert_eq!(w.len(), k * row_stride, "weight buffer vs [k, row_stride]");
        self.k = k;
        self.n = ncols;
        pack_cols_blocked(&mut self.data, w, k, row_stride, c0, ncols);
    }

    /// Reduction depth (rows of the packed matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (un-padded logical width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the packed buffer (diagnostic / memory accounting).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// The scalar reference kernel (the historical `tensor::matmul_rows` inner
/// loop): `x` rows (each of length `k`) against row-major `w[k, n]`,
/// *accumulated* into `out` (callers pre-zero it).  Kept as the ground
/// truth [`gemm`] is tested and benchmarked against.
pub fn gemm_ref(x: &[f32], k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    if k == 0 || n == 0 {
        return;
    }
    for (xrow, orow) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// One `R`×[`NR`] register tile over one K-block: `R` consecutive x rows
/// (stride `xstride`, already offset to the block's `k0`) against one
/// packed sub-panel of `kb` K-rows.  On the first block accumulators build
/// from zero and are *stored* (write mode); on later blocks they reload the
/// partial sums spilled to `out` — an exact f32 round trip, so per-element
/// operation order matches the unblocked kernel.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_tile<const R: usize>(
    x: &[f32],
    xstride: usize,
    kb: usize,
    panel: &[f32],
    out: &mut [f32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    let xr: [&[f32]; R] = std::array::from_fn(|r| &x[r * xstride..r * xstride + kb]);
    let mut acc = [[0.0f32; NR]; R];
    if !first {
        for (r, accr) in acc.iter_mut().enumerate() {
            accr[..nv].copy_from_slice(&out[r * n_stride..r * n_stride + nv]);
        }
    }
    for kk in 0..kb {
        let wrow = &panel[kk * NR..kk * NR + NR];
        for r in 0..R {
            let xv = xr[r][kk];
            // preserve the reference kernel's zero-activation skip: it is
            // load-bearing (0 * NaN/inf weights must not poison the tile)
            if xv == 0.0 {
                continue;
            }
            for (a, &wv) in acc[r].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        out[r * n_stride..r * n_stride + nv].copy_from_slice(&accr[..nv]);
    }
}

/// One panel narrower than a single vector lane group: run the identical
/// reduction over just the `nv` valid lanes instead of all [`NR`].  This is
/// the depthwise-conv case (`cg_out == 1`: one useful lane in a padded
/// panel) and the raggedest of ragged tails — full-width tiles would spend
/// `NR/nv`× the multiply work on zero pad lanes.  Same spill/reload rule
/// between K-blocks as [`micro_tile`].
#[allow(clippy::too_many_arguments)]
fn micro_narrow(
    x: &[f32],
    m: usize,
    xstride: usize,
    kb: usize,
    panel: &[f32],
    out: &mut [f32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    for i in 0..m {
        let xrow = &x[i * xstride..i * xstride + kb];
        let mut acc = [0.0f32; LANES];
        if !first {
            acc[..nv].copy_from_slice(&out[i * n_stride..i * n_stride + nv]);
        }
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &panel[kk * NR..kk * NR + nv];
            for (a, &wv) in acc[..nv].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
        out[i * n_stride..i * n_stride + nv].copy_from_slice(&acc[..nv]);
    }
}

/// Write-mode packed GEMM: `out[m, n] = x[m, k] @ w` with `w` pre-packed.
/// Every element of `out` is overwritten (beta = 0), so callers reuse
/// right-sized buffers without zero-filling them first.  Bit-identical to
/// [`gemm_ref`] over a zeroed buffer — see the module docs for why,
/// including across [`KC`] block boundaries.
///
/// Loop order: K-blocks outer (ascending — the ordering contract), panels
/// within a block, [`MR`]-row register tiles inner, so one sub-panel
/// (`kb * NR` floats, L1-sized) stays cache-hot across all `m / MR` row
/// tiles while the accumulator tile pins the output in registers for the
/// block's whole `kk` reduction — the scalar loop instead re-walks the
/// full `n`-wide output row once per `kk`.  A panel with fewer than
/// [`LANES`] valid lanes (depthwise convs, the raggedest tails) drops to
/// [`micro_narrow`] so pad lanes cost no multiplies; per-element reduction
/// order is the same either way.
pub fn gemm(x: &[f32], m: usize, pw: &PackedW, out: &mut [f32]) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(x.len(), m * k, "x vs [m, k]");
    debug_assert_eq!(out.len(), m * n, "out vs [m, n]");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    walk_blocked_panels(
        &pw.data,
        m,
        k,
        n,
        out,
        |i, rows, k0, sub, o, nv, first| {
            let kb = sub.len() / NR;
            let xs = &x[i * k + k0..];
            match rows {
                MR => micro_tile::<MR>(xs, k, kb, sub, o, n, nv, first),
                3 => micro_tile::<3>(xs, k, kb, sub, o, n, nv, first),
                2 => micro_tile::<2>(xs, k, kb, sub, o, n, nv, first),
                1 => micro_tile::<1>(xs, k, kb, sub, o, n, nv, first),
                rows => unreachable!("register tiles cover 1..=MR rows, got {rows}"),
            }
        },
        |k0, sub, o, nv, first| {
            micro_narrow(&x[k0..], m, k, sub.len() / NR, sub, o, n, nv, first)
        },
    );
}

// ------------------------------------------------------------ integer twin

/// Panel-packed **i8** weights — the integer twin of [`PackedW`], identical
/// K-block-major *block* geometry over `i8` weight *codes* instead of f32
/// values.  This is the storage the `lw` deployment grid actually implies:
/// weight codes live in `[-7, 7]` (4 bits), so an i8 panel holds 4× the
/// codes per cache line of the f32 layout (a [`KC`] sub-panel is 4 KiB),
/// and [`gemm_i8`] accumulates them in i32 without any float rounding.
/// Built by [`crate::backend::Int8Backend`] at prepare time; the f32 paths
/// never touch it.
///
/// ## In-panel layout: K-quad interleaved
///
/// Inside one `(block, panel)` sub-slice the codes are *quad-interleaved*
/// for the u8×i8 dot-product instructions (`vpdpbusd` / `maddubs` /
/// `sdot`), which each consume **4 consecutive K-rows per output lane**:
///
/// ```text
///   quads (kk < 4*(kb/4)):  sub[(kk/4 * NR + lane) * 4 + kk%4]
///   tail  (kb % 4 rows)  :  sub[4*(kb/4)*NR + r*NR + lane]   (row-major)
/// ```
///
/// — so a 32-byte SIMD load at `q*4*NR + lane0*4` yields 8 output lanes ×
/// 4 K-rows, exactly one dot-product operand.  The sub-slice is still
/// `kb * NR` bytes, so the block walk ([`for_each_kblock`]) is shared with
/// the f32 layout unchanged; the tail rows only exist in the final block
/// ([`KC`] is a multiple of 4) and every kernel handles them scalar.
///
/// ## The unsigned-rebias compensation (`ucomp`)
///
/// The x86 dot products are u8×i8: the SIMD kernels re-bias the stored
/// signed activations `x_s = q - zp` to `u = x_s + 128` in-register (one
/// XOR), compute `Σ u·w`, and subtract `128 · Σ w` afterwards.  That
/// per-lane correction over each block's quad region is precomputed here
/// at pack time (`ucomp[(block*panels + p)*NR + lane]`); the scalar and
/// NEON kernels (signed×signed) never read it.
#[derive(Clone, Debug, Default)]
pub struct PackedWi8 {
    k: usize,
    n: usize,
    /// K-block-major blocks of quad-interleaved sub-panels (see above).
    data: Vec<i8>,
    /// `128 · Σ_quad-region w[kk, lane]` per (block, panel, lane).
    ucomp: Vec<i32>,
}

impl PackedWi8 {
    /// Pack a whole row-major `[k, n]` code matrix.
    pub fn pack(w: &[i8], k: usize, n: usize) -> PackedWi8 {
        let mut pw = PackedWi8::default();
        pw.pack_cols(w, k, n, 0, n);
        pw
    }

    /// (Re)pack columns `c0 .. c0 + ncols` of the row-major
    /// `[k, row_stride]` code matrix, reusing the buffer — the same column
    /// slicing [`PackedW::pack_cols`] does for grouped convs.  Codes must
    /// lie in `[-64, 64]`: the AVX2 kernel's `maddubs` i16 pair sums
    /// saturate beyond `255·|w1| + 255·|w2|` = 32640, so the bound is a
    /// pack-time invariant, not a per-call check (the deployment grids use
    /// `[-7, 7]`, far inside it).
    pub fn pack_cols(&mut self, w: &[i8], k: usize, row_stride: usize, c0: usize, ncols: usize) {
        assert!(c0 + ncols <= row_stride, "columns {c0}+{ncols} out of stride {row_stride}");
        assert_eq!(w.len(), k * row_stride, "code buffer vs [k, row_stride]");
        self.k = k;
        self.n = ncols;
        let panels = ncols.div_ceil(NR);
        let len = panels * k * NR;
        if self.data.len() != len {
            self.data.clear();
            self.data.resize(len, 0);
        }
        let nuc = k.div_ceil(KC) * panels * NR;
        if self.ucomp.len() != nuc {
            self.ucomp.clear();
            self.ucomp.resize(nuc, 0);
        }
        for_each_kblock(k, panels, |k0, kb, boff| {
            let b = k0 / KC;
            for p in 0..panels {
                let j0 = p * NR;
                let nv = NR.min(ncols - j0);
                let sub = &mut self.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
                sub.fill(0);
                for kk in 0..kb {
                    let src = (k0 + kk) * row_stride + c0 + j0;
                    for (lane, &c) in w[src..src + nv].iter().enumerate() {
                        assert!((-64..=64).contains(&c), "i8 panel code {c} out of [-64, 64]");
                        sub[i8_sub_index(kb, kk, lane)] = c;
                    }
                }
                let uc = &mut self.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
                uc.fill(0);
                for kk in 0..kb / 4 * 4 {
                    for (lane, u) in uc.iter_mut().enumerate() {
                        *u += sub[i8_sub_index(kb, kk, lane)] as i32;
                    }
                }
                for u in uc.iter_mut() {
                    *u *= 128;
                }
            }
        });
    }

    /// Reduction depth (rows of the packed matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (un-padded logical width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-logical-column code sums (`sum_kk w[kk, j]` as i32) — the
    /// zero-point correction term: an activation stored offset by `zp`
    /// contributes `zp * col_sum` extra per output, which callers fold into
    /// the integer bias once at prepare time.  Walks the K-block-major
    /// quad-interleaved layout, ignoring pad lanes.
    pub fn col_sums(&self) -> Vec<i32> {
        let mut sums = vec![0i32; self.n];
        let panels = self.n.div_ceil(NR);
        for_each_kblock(self.k, panels, |_k0, kb, boff| {
            for p in 0..panels {
                let j0 = p * NR;
                let nv = NR.min(self.n - j0);
                let sub = &self.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
                for kk in 0..kb {
                    for (lane, s) in sums[j0..j0 + nv].iter_mut().enumerate() {
                        *s += sub[i8_sub_index(kb, kk, lane)] as i32;
                    }
                }
            }
        });
        sums
    }

    /// Bytes held by the packed buffer (4× denser than the f32 panels).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// One `R`×[`NR`] i32 register tile over one K-block of the
/// quad-interleaved i8 layout (the scalar twin every SIMD path is proven
/// against): quads stream 4 contiguous weight bytes per lane, the `kb % 4`
/// tail rows go row-major.  No zero-activation skip — in integer arithmetic
/// `0 * w` is exactly 0 for every representable `w` (there is no NaN/inf
/// to mask), so the branch the f32 kernel needs for correctness would only
/// cost the i8 kernel its vectorization.  The inter-block spill/reload is
/// trivially exact for i32.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_tile_i8<const R: usize>(
    x: &[i8],
    xstride: usize,
    kb: usize,
    panel: &[i8],
    out: &mut [i32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    let xr: [&[i8]; R] = std::array::from_fn(|r| &x[r * xstride..r * xstride + kb]);
    let mut acc = [[0i32; NR]; R];
    if !first {
        for (r, accr) in acc.iter_mut().enumerate() {
            accr[..nv].copy_from_slice(&out[r * n_stride..r * n_stride + nv]);
        }
    }
    let nq = kb / 4;
    for q in 0..nq {
        let base = q * 4 * NR;
        for r in 0..R {
            let xq = &xr[r][4 * q..4 * q + 4];
            let (x0, x1, x2, x3) = (xq[0] as i32, xq[1] as i32, xq[2] as i32, xq[3] as i32);
            for (lane, a) in acc[r].iter_mut().enumerate() {
                let wq = &panel[base + lane * 4..base + lane * 4 + 4];
                *a += x0 * wq[0] as i32
                    + x1 * wq[1] as i32
                    + x2 * wq[2] as i32
                    + x3 * wq[3] as i32;
            }
        }
    }
    for kk in 4 * nq..kb {
        let roff = 4 * nq * NR + (kk - 4 * nq) * NR;
        let wrow = &panel[roff..roff + NR];
        for r in 0..R {
            let xv = xr[r][kk] as i32;
            for (a, &wv) in acc[r].iter_mut().zip(wrow) {
                *a += xv * wv as i32;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        out[r * n_stride..r * n_stride + nv].copy_from_slice(&accr[..nv]);
    }
}

/// Narrow-panel i8 path (`nv < LANES`): reduce only the valid lanes, the
/// depthwise-conv / ragged-tail case of [`micro_narrow`].
#[allow(clippy::too_many_arguments)]
fn micro_narrow_i8(
    x: &[i8],
    m: usize,
    xstride: usize,
    kb: usize,
    panel: &[i8],
    out: &mut [i32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    let nq = kb / 4;
    for i in 0..m {
        let xrow = &x[i * xstride..i * xstride + kb];
        let mut acc = [0i32; LANES];
        if !first {
            acc[..nv].copy_from_slice(&out[i * n_stride..i * n_stride + nv]);
        }
        for q in 0..nq {
            let base = q * 4 * NR;
            let xq = &xrow[4 * q..4 * q + 4];
            let (x0, x1, x2, x3) = (xq[0] as i32, xq[1] as i32, xq[2] as i32, xq[3] as i32);
            for (lane, a) in acc[..nv].iter_mut().enumerate() {
                let wq = &panel[base + lane * 4..base + lane * 4 + 4];
                *a += x0 * wq[0] as i32
                    + x1 * wq[1] as i32
                    + x2 * wq[2] as i32
                    + x3 * wq[3] as i32;
            }
        }
        for kk in 4 * nq..kb {
            let xv = xrow[kk] as i32;
            let roff = 4 * nq * NR + (kk - 4 * nq) * NR;
            for (a, &wv) in acc[..nv].iter_mut().zip(&panel[roff..roff + nv]) {
                *a += xv * wv as i32;
            }
        }
        out[i * n_stride..i * n_stride + nv].copy_from_slice(&acc[..nv]);
    }
}

/// Write-mode i8×i8→i32 GEMM: `out[m, n] = x[m, k] @ w` with `w` pre-packed
/// as i8 codes and every product widened to i32 before accumulation.  The
/// result is *exact*: as long as the true sum fits i32 there is no rounding
/// at all, and integer addition is associative, so every dispatch path
/// (scalar twin, AVX2, VNNI, NEON — see [`dispatch`]) yields bit-identical
/// output.  The `lw` deployment shapes are far inside the safe range
/// (|x| ≤ 255, |w| ≤ 7 ⇒ k up to ~1.2M rows before i32 could saturate).
pub fn gemm_i8(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    gemm_i8_with(kernel_path(), x, m, pw, out)
}

/// The safe scalar `gemm_i8` twin — the K-blocked walker over the
/// quad-interleaved panels, ground truth for every SIMD path.
fn gemm_i8_scalar(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    walk_blocked_panels(
        &pw.data,
        m,
        k,
        n,
        out,
        |i, rows, k0, sub, o, nv, first| {
            let kb = sub.len() / NR;
            let xs = &x[i * k + k0..];
            match rows {
                MR => micro_tile_i8::<MR>(xs, k, kb, sub, o, n, nv, first),
                3 => micro_tile_i8::<3>(xs, k, kb, sub, o, n, nv, first),
                2 => micro_tile_i8::<2>(xs, k, kb, sub, o, n, nv, first),
                1 => micro_tile_i8::<1>(xs, k, kb, sub, o, n, nv, first),
                rows => unreachable!("register tiles cover 1..=MR rows, got {rows}"),
            }
        },
        |k0, sub, o, nv, first| {
            micro_narrow_i8(&x[k0..], m, k, sub.len() / NR, sub, o, n, nv, first)
        },
    );
}

// ------------------------------------------------------------ W4 panels

/// Nibble-packed **4-bit** weight panels — two codes per byte in the same
/// K-block-major panel geometry as [`PackedWi8`], *halving* weight
/// bandwidth.  The paper's grids are ≤4-bit weight codes (`[-7, 7]`), so a
/// byte per code wastes half the panel stream; on large-K KC-blocked
/// shapes the stream, not the multiplies, bounds throughput, and W4 panels
/// let them run from L2 instead of memory.  Built by
/// [`crate::backend::Int8Backend`] when the codebook fits 4 bits
/// (two's-complement nibbles, `[-8, 7]`).
///
/// ## Byte layout (per `(block, panel)` sub-slice)
///
/// Octets — groups of 8 K-rows — interleave so one in-register nibble
/// unpack yields two dot-product quad operands:
///
/// ```text
///   octet o, lane L, byte j (= (o*NR + L)*4 + j,  j in 0..4):
///     low  nibble = code[k0 + 8o + j,     lane L]   (K-quad j   of o)
///     high nibble = code[k0 + 8o + 4 + j, lane L]   (K-quad j+4 of o)
///   tail (kb % 8 rows, pair-packed row-major after the octets):
///     byte[4*(kb/8)*NR + r/2*NR + L]: low = row r even, high = row r odd
/// ```
///
/// — a 32-byte load covers 8 lanes × 4 bytes; `v & 0x0F` is the
/// quad-interleaved i8 operand for K-rows `8o..8o+4` and `(v >> 4) & 0x0F`
/// the one for `8o+4..8o+8`, each sign-fixed bytewise via `(nib ^ 8) - 8`.
/// The sub-slice is `kb.div_ceil(2) * NR` bytes ([`for_each_kblock_w4`]);
/// tail rows only exist in the final block and every kernel handles them
/// scalar.  `ucomp` mirrors [`PackedWi8`]'s unsigned-rebias correction
/// over each block's octet region.
#[derive(Clone, Debug, Default)]
pub struct PackedW4 {
    k: usize,
    n: usize,
    /// K-block-major blocks of nibble-packed sub-panels (see above).
    data: Vec<u8>,
    /// `128 · Σ_octet-region code[kk, lane]` per (block, panel, lane).
    ucomp: Vec<i32>,
}

impl PackedW4 {
    /// Pack a whole row-major `[k, n]` code matrix.
    pub fn pack(w: &[i8], k: usize, n: usize) -> PackedW4 {
        let mut pw = PackedW4::default();
        pw.pack_cols(w, k, n, 0, n);
        pw
    }

    /// (Re)pack columns `c0 .. c0 + ncols` of the row-major
    /// `[k, row_stride]` code matrix — the same grouped-conv column slicing
    /// as [`PackedWi8::pack_cols`].  Codes must fit the two's-complement
    /// nibble range `[-8, 7]` (the deployment grids use `[-7, 7]`).
    pub fn pack_cols(&mut self, w: &[i8], k: usize, row_stride: usize, c0: usize, ncols: usize) {
        assert!(c0 + ncols <= row_stride, "columns {c0}+{ncols} out of stride {row_stride}");
        assert_eq!(w.len(), k * row_stride, "code buffer vs [k, row_stride]");
        self.k = k;
        self.n = ncols;
        let panels = ncols.div_ceil(NR);
        let len = panels * k.div_ceil(2) * NR;
        if self.data.len() != len {
            self.data.clear();
            self.data.resize(len, 0);
        }
        let nuc = k.div_ceil(KC) * panels * NR;
        if self.ucomp.len() != nuc {
            self.ucomp.clear();
            self.ucomp.resize(nuc, 0);
        }
        for_each_kblock_w4(k, panels, |k0, kb, boff| {
            let b = k0 / KC;
            let pbytes = kb.div_ceil(2) * NR;
            let octrows = kb / 8 * 8;
            for p in 0..panels {
                let j0 = p * NR;
                let nv = NR.min(ncols - j0);
                let sub = &mut self.data[boff + p * pbytes..boff + (p + 1) * pbytes];
                sub.fill(0);
                let uc = &mut self.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
                uc.fill(0);
                for kk in 0..kb {
                    let src = (k0 + kk) * row_stride + c0 + j0;
                    for (lane, &c) in w[src..src + nv].iter().enumerate() {
                        assert!((-8..=7).contains(&c), "W4 code {c} out of nibble range [-8, 7]");
                        let (byte, hi) = w4_sub_index(kb, kk, lane);
                        let nib = (c as u8) & 0x0F;
                        sub[byte] |= if hi { nib << 4 } else { nib };
                        if kk < octrows {
                            uc[lane] += c as i32;
                        }
                    }
                }
                for u in uc.iter_mut() {
                    *u *= 128;
                }
            }
        });
    }

    /// Reduction depth (rows of the packed matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns (un-padded logical width).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Decode back to the dense row-major `[k, n]` code matrix — the
    /// round-trip half of the pack/unpack property tests, and the one
    /// decode loop [`PackedW4::col_sums`] reuses.
    pub fn unpack(&self) -> Vec<i8> {
        let mut out = vec![0i8; self.k * self.n];
        let panels = self.n.div_ceil(NR);
        for_each_kblock_w4(self.k, panels, |k0, kb, boff| {
            let pbytes = kb.div_ceil(2) * NR;
            for p in 0..panels {
                let j0 = p * NR;
                let nv = NR.min(self.n - j0);
                let sub = &self.data[boff + p * pbytes..boff + (p + 1) * pbytes];
                for kk in 0..kb {
                    for lane in 0..nv {
                        let (byte, hi) = w4_sub_index(kb, kk, lane);
                        let b = sub[byte];
                        out[(k0 + kk) * self.n + j0 + lane] =
                            if hi { w4_hi(b) } else { w4_lo(b) };
                    }
                }
            }
        });
        out
    }

    /// Per-logical-column code sums — the same zero-point fold term as
    /// [`PackedWi8::col_sums`], decoded from the nibble panels.
    pub fn col_sums(&self) -> Vec<i32> {
        let mut sums = vec![0i32; self.n];
        if self.n == 0 {
            return sums;
        }
        for row in self.unpack().chunks_exact(self.n) {
            for (s, &c) in sums.iter_mut().zip(row) {
                *s += c as i32;
            }
        }
        sums
    }

    /// Bytes held by the packed buffer (half the i8 panels).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Write-mode W4×i8→i32 GEMM: `out[m, n] = x[m, k] @ w` with `w` packed as
/// two 4-bit codes per byte ([`PackedW4`]).  Decode happens in-register
/// (shift/mask + sign-fix) inside the dispatched micro-kernel; integer
/// accumulation is exact, so every path is bit-identical to the scalar
/// twin — and to [`gemm_i8`] over the same codes.
pub fn gemm_w4(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    gemm_w4_with(kernel_path(), x, m, pw, out)
}

/// The safe scalar `gemm_w4` twin: the identical K-block/panel walk with
/// scalar nibble decode, ground truth for the SIMD W4 paths.
fn gemm_w4_scalar(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock_w4(k, panels, |k0, kb, boff| {
        let pbytes = kb.div_ceil(2) * NR;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * pbytes..boff + (p + 1) * pbytes];
            micro_w4(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, k0 == 0);
        }
    });
}

/// Scalar W4 micro-kernel over one `(block, panel)`: every output row
/// reduced across the block's `kb` K-rows with scalar nibble decode —
/// octets first (4 low-nibble + 4 high-nibble codes per lane byte group),
/// then the pair-packed `kb % 8` tail.  Shared by the scalar twin and the
/// SIMD paths' narrow-panel (`nv <` [`LANES`]) fallback.
#[allow(clippy::too_many_arguments)]
fn micro_w4(
    x: &[i8],
    m: usize,
    xstride: usize,
    kb: usize,
    panel: &[u8],
    out: &mut [i32],
    n_stride: usize,
    nv: usize,
    first: bool,
) {
    let noct = kb / 8;
    for i in 0..m {
        let xrow = &x[i * xstride..i * xstride + kb];
        let mut acc = [0i32; NR];
        if !first {
            acc[..nv].copy_from_slice(&out[i * n_stride..i * n_stride + nv]);
        }
        for o in 0..noct {
            let base = o * 4 * NR;
            let xo = &xrow[8 * o..8 * o + 8];
            for (lane, a) in acc[..nv].iter_mut().enumerate() {
                let wb = &panel[base + lane * 4..base + lane * 4 + 4];
                let mut s = 0i32;
                for j in 0..4 {
                    s += xo[j] as i32 * w4_lo(wb[j]) as i32;
                    s += xo[4 + j] as i32 * w4_hi(wb[j]) as i32;
                }
                *a += s;
            }
        }
        for kk in 8 * noct..kb {
            let r = kk - 8 * noct;
            let xv = xrow[kk] as i32;
            let roff = 4 * noct * NR + r / 2 * NR;
            for (lane, a) in acc[..nv].iter_mut().enumerate() {
                let b = panel[roff + lane];
                let c = if r % 2 == 0 { w4_lo(b) } else { w4_hi(b) };
                *a += xv * c as i32;
            }
        }
        out[i * n_stride..i * n_stride + nv].copy_from_slice(&acc[..nv]);
    }
}

/// Merge one spilled accumulator row into `out` — write-mode on the first
/// K-block, accumulate after.  The ragged-panel / K-tail exit every SIMD
/// row kernel shares.
#[cfg(any(target_arch = "x86", target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn merge_spill(orow: &mut [i32], buf: &[i32; NR], nv: usize, first: bool) {
    if first {
        orow[..nv].copy_from_slice(&buf[..nv]);
    } else {
        for (o, v) in orow[..nv].iter_mut().zip(buf) {
            *o += v;
        }
    }
}

thread_local! {
    /// Per-thread pack buffer for call sites whose weights are not
    /// long-lived (training forwards, one-off heuristics): the pack is
    /// amortized over the GEMM's `m` rows and the buffer over the thread's
    /// lifetime.
    static PACK_SCRATCH: RefCell<PackedW> = RefCell::new(PackedW::default());
}

/// Run `f` with this thread's reusable [`PackedW`] scratch.  Re-entrant
/// calls (a packed caller invoking another packed caller mid-borrow) fall
/// back to a fresh buffer instead of panicking.
pub fn with_pack_scratch<R>(f: impl FnOnce(&mut PackedW) -> R) -> R {
    PACK_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut pw) => f(&mut pw),
        Err(_) => f(&mut PackedW::default()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn ref_out(x: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        gemm_ref(x, k, w, n, &mut out);
        out
    }

    #[test]
    fn packed_layout_streams_columns() {
        // [2, 3] matrix; single K-block, single (padded) panel: lane j
        // holds column j
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let pw = PackedW::pack(&w, 2, 3);
        assert_eq!((pw.k(), pw.n()), (2, 3));
        assert_eq!(pw.data.len(), 2 * NR);
        assert_eq!(&pw.data[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&pw.data[3..NR], &[0.0; NR - 3]);
        assert_eq!(&pw.data[NR..NR + 3], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn blocked_layout_panel_offsets() {
        // k spanning two K-blocks: block b starts at b*KC*panels*NR and
        // holds per-panel sub-slices of that block's row count
        let (k, n) = (KC + 3, NR + 2);
        let w = rand_vec(k * n, 77);
        let pw = PackedW::pack(&w, k, n);
        let panels = n.div_ceil(NR);
        assert_eq!(pw.data.len(), panels * k * NR);
        for &kk in &[0usize, 1, KC - 1, KC, KC + 2] {
            for &j in &[0usize, 1, NR - 1, NR, n - 1] {
                let (b, kl) = (kk / KC, kk % KC);
                let kb = KC.min(k - b * KC);
                let (p, lane) = (j / NR, j % NR);
                let idx = b * KC * panels * NR + p * kb * NR + kl * NR + lane;
                assert_eq!(pw.data[idx], w[kk * n + j], "kk={kk} j={j}");
            }
        }
    }

    #[test]
    fn packed_matches_reference_bit_exactly() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, NR),
            (5, 7, NR + 1),
            (MR - 1, 16, NR - 1),
            (17, 33, 40),
            (MR * 3, 2, 2 * NR),
            (2, 64, 5),
        ] {
            let x = rand_vec(m * k, (m * 31 + k * 7 + n) as u64);
            let w = rand_vec(k * n, (m + k + n * 13) as u64);
            let pw = PackedW::pack(&w, k, n);
            // sentinel fill proves write-mode coverage of every element
            let mut got = vec![777.0f32; m * n];
            gemm(&x, m, &pw, &mut got);
            let want = ref_out(&x, m, k, &w, n);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn kc_blocked_kernel_matches_reference_bit_exactly() {
        // shapes straddling the KC reduction block: k < KC, k == KC,
        // k % KC != 0, k a multiple of KC, k >> KC — with zeros sprinkled
        // so the skip path crosses block boundaries
        for &(m, k, n) in &[
            (5usize, KC - 1, NR + 1),
            (MR, KC, NR),
            (7, KC + 1, 2 * NR + 3),
            (MR + 2, 2 * KC, 5),
            (3, 4 * KC + 37, NR + 9),
            (1, 3 * KC, 1),
        ] {
            let mut x = rand_vec(m * k, (m * 13 + k + n * 7) as u64);
            for (i, v) in x.iter_mut().enumerate() {
                if i % 7 == 0 {
                    *v = 0.0;
                }
            }
            let w = rand_vec(k * n, (m + k * 3 + n) as u64);
            let pw = PackedW::pack(&w, k, n);
            let mut got = vec![f32::NAN; m * n];
            gemm(&x, m, &pw, &mut got);
            let want = ref_out(&x, m, k, &w, n);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn degenerate_shapes_are_safe() {
        // k = 0: write-mode must still zero the output
        let pw = PackedW::pack(&[], 0, 3);
        let mut out = vec![9.0f32; 2 * 3];
        gemm(&[], 2, &pw, &mut out);
        assert_eq!(out, vec![0.0; 6]);
        // n = 0 and m = 0: no-ops on empty outputs
        let pw = PackedW::pack(&[], 4, 0);
        gemm(&rand_vec(8, 1), 2, &pw, &mut []);
        let pw = PackedW::pack(&rand_vec(8, 2), 4, 2);
        gemm(&[], 0, &pw, &mut []);
        // m = 0 with a multi-KC-block, narrow-panel pack: the m/n guard
        // must fire before any K-block ever offsets into the empty x
        let pw = PackedW::pack(&rand_vec(2 * KC * 5, 3), 2 * KC, 5);
        gemm(&[], 0, &pw, &mut []);
    }

    #[test]
    fn zero_activations_mask_nonfinite_weights() {
        // column kk of x is all-zero exactly where w row kk is poisoned
        let (m, k, n) = (5usize, 6usize, NR + 3);
        let mut x = rand_vec(m * k, 3);
        let mut w = rand_vec(k * n, 4);
        for i in 0..m {
            x[i * k + 2] = 0.0;
            x[i * k + 5] = 0.0;
        }
        for j in 0..n {
            w[2 * n + j] = f32::NAN;
            w[5 * n + j] = if j % 2 == 0 { f32::INFINITY } else { f32::NEG_INFINITY };
        }
        let pw = PackedW::pack(&w, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm(&x, m, &pw, &mut got);
        assert!(got.iter().all(|v| v.is_finite()), "poisoned rows must be skipped");
        let want = ref_out(&x, m, k, &w, n);
        assert_eq!(want, got);
    }

    #[test]
    fn repacking_reuses_and_matches() {
        let mut pw = PackedW::default();
        // (4, 16) -> (2, 20) keeps the same buffer length (64 floats) while
        // moving where the ragged pad lanes fall; (2*KC, 16) -> (KC, 32)
        // keeps the length while moving a K-block boundary: stale-pad and
        // stale-block regression guards
        for (k, n, seed) in [
            (9usize, 21usize, 5u64),
            (4, 3, 6),
            (9, 21, 7),
            (4, 16, 8),
            (2, 20, 9),
            (2 * KC, 16, 10),
            (KC, 32, 11),
        ] {
            let w = rand_vec(k * n, seed);
            pw.pack_cols(&w, k, n, 0, n);
            let fresh = PackedW::pack(&w, k, n);
            assert_eq!(pw.data, fresh.data, "k={k} n={n}");
            assert_eq!((pw.k(), pw.n()), (k, n));
        }
    }

    fn rand_codes(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = crate::data::Rng::new(seed);
        (0..n).map(|_| (rng.normal() * 4.0).round().clamp(-7.0, 7.0) as i8).collect()
    }

    /// Naive i32 reference for the i8 kernel.
    fn ref_out_i8(x: &[i8], m: usize, k: usize, w: &[i8], n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk] as i32;
                for j in 0..n {
                    out[i * n + j] += xv * w[kk * n + j] as i32;
                }
            }
        }
        out
    }

    #[test]
    fn i8_kernel_matches_naive_reference_exactly() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, NR),
            (5, 7, NR + 1),
            (MR - 1, 16, NR - 1),
            (17, 33, 40),
            (MR * 3, 2, 2 * NR),
            (2, 64, 5),
            (9, 9, 1), // depthwise: one valid lane per panel
        ] {
            let x = rand_codes(m * k, (m * 37 + k * 11 + n) as u64);
            let w = rand_codes(k * n, (m + k * 3 + n * 17) as u64);
            let pw = PackedWi8::pack(&w, k, n);
            let mut got = vec![777i32; m * n];
            gemm_i8(&x, m, &pw, &mut got);
            assert_eq!(got, ref_out_i8(&x, m, k, &w, n), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn i8_kc_blocked_matches_naive_reference_exactly() {
        // the i8 twin across KC block boundaries (incl. the narrow path)
        for &(m, k, n) in &[
            (4usize, KC + 3, NR),
            (6, 2 * KC + 11, NR + 2),
            (MR + 1, KC, 2 * NR + 1),
            (2, 3 * KC, 1),
        ] {
            let x = rand_codes(m * k, (m * 41 + k + n) as u64);
            let w = rand_codes(k * n, (m + k + n * 23) as u64);
            let pw = PackedWi8::pack(&w, k, n);
            let mut got = vec![777i32; m * n];
            gemm_i8(&x, m, &pw, &mut got);
            assert_eq!(got, ref_out_i8(&x, m, k, &w, n), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn i8_degenerate_shapes_are_safe() {
        let pw = PackedWi8::pack(&[], 0, 3);
        let mut out = vec![9i32; 2 * 3];
        gemm_i8(&[], 2, &pw, &mut out);
        assert_eq!(out, vec![0; 6]);
        let pw = PackedWi8::pack(&[], 4, 0);
        gemm_i8(&rand_codes(8, 1), 2, &pw, &mut []);
        let pw = PackedWi8::pack(&rand_codes(8, 2), 4, 2);
        gemm_i8(&[], 0, &pw, &mut []);
    }

    #[test]
    fn i8_col_sums_and_repack_reuse() {
        // col_sums must ignore pad lanes and walk the blocked layout
        // correctly; repacking at a different (k, n) of the same total
        // length (incl. across a KC boundary) must not leak stale codes
        let mut pw = PackedWi8::default();
        for (k, n, seed) in [
            (9usize, 21usize, 5u64),
            (4, 3, 6),
            (4, 16, 8),
            (2, 20, 9),
            (KC + 5, 3, 12),
            (2 * KC, 16, 13),
            (KC, 32, 14),
        ] {
            let w = rand_codes(k * n, seed);
            pw.pack_cols(&w, k, n, 0, n);
            let want: Vec<i32> = (0..n)
                .map(|j| (0..k).map(|kk| w[kk * n + j] as i32).sum())
                .collect();
            assert_eq!(pw.col_sums(), want, "k={k} n={n}");
            let fresh = PackedWi8::pack(&w, k, n);
            assert_eq!(pw.data, fresh.data, "k={k} n={n}");
        }
    }

    #[test]
    fn i8_pack_cols_slices_groups() {
        let (k, stride) = (3usize, 8usize);
        let w = rand_codes(k * stride, 12);
        let mut sliced = PackedWi8::default();
        sliced.pack_cols(&w, k, stride, 2, 4);
        let dense: Vec<i8> = (0..k)
            .flat_map(|kk| w[kk * stride + 2..kk * stride + 6].to_vec())
            .collect();
        let want = PackedWi8::pack(&dense, k, 4);
        assert_eq!(sliced.data, want.data);
    }

    #[test]
    fn i8_matches_f32_kernel_on_code_matrices() {
        // on integer-valued inputs within f32's exact range the two kernels
        // must agree number-for-number — including across KC blocks
        for &(m, k, n) in &[(13usize, 57usize, NR + 5), (5, KC + 9, NR + 5)] {
            let xi = rand_codes(m * k, 21 + k as u64);
            let wi = rand_codes(k * n, 22 + k as u64);
            let xf: Vec<f32> = xi.iter().map(|&v| v as f32).collect();
            let wf: Vec<f32> = wi.iter().map(|&v| v as f32).collect();
            let pw8 = PackedWi8::pack(&wi, k, n);
            let pwf = PackedW::pack(&wf, k, n);
            let mut got8 = vec![0i32; m * n];
            gemm_i8(&xi, m, &pw8, &mut got8);
            let mut gotf = vec![0.0f32; m * n];
            gemm(&xf, m, &pwf, &mut gotf);
            for (a, b) in got8.iter().zip(&gotf) {
                assert_eq!(*a as f32, *b, "k={k}");
            }
        }
    }

    #[test]
    fn pack_cols_slices_groups() {
        // columns 2..5 of a [2, 6] matrix == packing the dense 3-col copy
        let (k, stride) = (2usize, 6usize);
        let w = rand_vec(k * stride, 8);
        let mut sliced = PackedW::default();
        sliced.pack_cols(&w, k, stride, 2, 3);
        let dense: Vec<f32> = (0..k)
            .flat_map(|kk| w[kk * stride + 2..kk * stride + 5].to_vec())
            .collect();
        let want = PackedW::pack(&dense, k, 3);
        assert_eq!(sliced.data, want.data);
    }

    #[test]
    fn i8_quad_layout_pin() {
        // pin the quad-interleave placement byte-for-byte: quads first
        // (4 K-rows per lane), then the kb % 4 tail rows row-major — and
        // the ucomp table as 128 * the quad-region column sums per block
        let (k, n) = (KC + 7, NR + 3);
        let w = rand_codes(k * n, 31);
        let pw = PackedWi8::pack(&w, k, n);
        let panels = n.div_ceil(NR);
        for_each_kblock(k, panels, |k0, kb, boff| {
            let b = k0 / KC;
            for p in 0..panels {
                let j0 = p * NR;
                let nv = NR.min(n - j0);
                let sub = &pw.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
                for kk in 0..kb {
                    for lane in 0..nv {
                        let want = w[(k0 + kk) * n + j0 + lane];
                        assert_eq!(sub[i8_sub_index(kb, kk, lane)], want, "kk={kk} lane={lane}");
                    }
                }
                let uc = &pw.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
                for (lane, &u) in uc.iter().enumerate() {
                    let want: i32 = if lane < nv {
                        (0..kb / 4 * 4).map(|kk| w[(k0 + kk) * n + j0 + lane] as i32).sum()
                    } else {
                        0
                    };
                    assert_eq!(u, 128 * want, "b={b} p={p} lane={lane}");
                }
            }
        });
    }

    #[test]
    fn w4_pack_unpack_round_trips() {
        // every tail class: k % 2 != 0 (half-filled final byte), k % 8 != 0
        // (pair-packed tail rows), k % KC != 0 (ragged final block), k > KC
        for &(k, n) in &[
            (1usize, 1usize),
            (7, NR + 3),
            (8, NR),
            (9, 2 * NR + 1),
            (KC, NR + 5),
            (KC + 13, NR - 1),
            (2 * KC + 5, 2 * NR + 7),
        ] {
            let w = rand_codes(k * n, (k * 7 + n) as u64);
            let pw = PackedW4::pack(&w, k, n);
            assert_eq!((pw.k(), pw.n()), (k, n));
            assert_eq!(pw.packed_bytes(), n.div_ceil(NR) * k.div_ceil(2) * NR);
            assert_eq!(pw.unpack(), w, "k={k} n={n}");
            let want: Vec<i32> = (0..n)
                .map(|j| (0..k).map(|kk| w[kk * n + j] as i32).sum())
                .collect();
            assert_eq!(pw.col_sums(), want, "k={k} n={n}");
        }
    }

    #[test]
    fn w4_full_nibble_range_round_trips() {
        // all 16 two's-complement nibble values in both byte halves
        let n = NR;
        let k = 32;
        let w: Vec<i8> = (0..k * n).map(|i| (i % 16) as i8 - 8).collect();
        let pw = PackedW4::pack(&w, k, n);
        assert_eq!(pw.unpack(), w);
    }

    #[test]
    fn w4_pack_cols_slices_groups() {
        // the grouped-conv column slice must equal packing the dense copy
        let (k, stride) = (11usize, 8usize);
        let w = rand_codes(k * stride, 19);
        let mut sliced = PackedW4::default();
        sliced.pack_cols(&w, k, stride, 2, 4);
        let dense: Vec<i8> = (0..k)
            .flat_map(|kk| w[kk * stride + 2..kk * stride + 6].to_vec())
            .collect();
        let want = PackedW4::pack(&dense, k, 4);
        assert_eq!(sliced.data, want.data);
        assert_eq!(sliced.ucomp, want.ucomp);
    }

    #[test]
    fn w4_kernel_matches_naive_and_i8() {
        // gemm_w4 (dispatched) and its scalar twin vs the naive i32
        // reference AND gemm_i8 over the same codes — bit-identical, with
        // odd-K tails and KC straddles
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 7, NR),
            (5, 9, NR + 1),
            (3, 16, NR - 1),
            (17, 33, 40),
            (9, 9, 1), // depthwise: one valid lane per panel
            (4, KC + 3, NR),
            (6, 2 * KC + 11, NR + 2),
            (2, 3 * KC, 1),
        ] {
            let x = rand_codes(m * k, (m * 37 + k * 11 + n) as u64);
            let w = rand_codes(k * n, (m + k * 3 + n * 17) as u64);
            let pw4 = PackedW4::pack(&w, k, n);
            let pw8 = PackedWi8::pack(&w, k, n);
            let want = ref_out_i8(&x, m, k, &w, n);
            let mut got = vec![777i32; m * n];
            gemm_w4(&x, m, &pw4, &mut got);
            assert_eq!(got, want, "dispatched m={m} k={k} n={n}");
            let mut got_s = vec![777i32; m * n];
            gemm_w4_with(KernelPath::Scalar, &x, m, &pw4, &mut got_s);
            assert_eq!(got_s, want, "scalar m={m} k={k} n={n}");
            let mut got8 = vec![777i32; m * n];
            gemm_i8(&x, m, &pw8, &mut got8);
            assert_eq!(got, got8, "w4 vs i8 m={m} k={k} n={n}");
        }
    }

    #[test]
    fn w4_degenerate_shapes_are_safe() {
        let pw = PackedW4::pack(&[], 0, 3);
        let mut out = vec![9i32; 2 * 3];
        gemm_w4(&[], 2, &pw, &mut out);
        assert_eq!(out, vec![0; 6]);
        let pw = PackedW4::pack(&[], 4, 0);
        gemm_w4(&rand_codes(8, 1), 2, &pw, &mut []);
        let pw = PackedW4::pack(&rand_codes(8, 2), 4, 2);
        gemm_w4(&[], 0, &pw, &mut []);
        assert!(PackedW4::pack(&[], 4, 0).col_sums().is_empty());
    }

    #[test]
    fn every_supported_path_is_bit_identical_in_module() {
        // the cheap in-module parity smoke (the full sweep lives in
        // rust/tests/kernel.rs): every path this CPU supports vs scalar
        let (m, k, n) = (5usize, KC + 9, NR + 3);
        let x = rand_codes(m * k, 61);
        let w = rand_codes(k * n, 62);
        let pw8 = PackedWi8::pack(&w, k, n);
        let pw4 = PackedW4::pack(&w, k, n);
        let mut want8 = vec![0i32; m * n];
        gemm_i8_with(KernelPath::Scalar, &x, m, &pw8, &mut want8);
        let mut want4 = vec![0i32; m * n];
        gemm_w4_with(KernelPath::Scalar, &x, m, &pw4, &mut want4);
        assert_eq!(want8, want4);
        for path in supported_paths() {
            let mut got = vec![777i32; m * n];
            gemm_i8_with(path, &x, m, &pw8, &mut got);
            assert_eq!(got, want8, "i8 path {path:?}");
            let mut got = vec![777i32; m * n];
            gemm_w4_with(path, &x, m, &pw4, &mut got);
            assert_eq!(got, want4, "w4 path {path:?}");
        }
    }
}
