//! NEON integer kernels (aarch64 `dotprod`) — `vdotq_s32` signed×signed
//! dot products, four `int32x4_t` accumulators per output row.
//!
//! Unlike the x86 paths there is no unsigned rebias and no compensation
//! term: `vdotq_s32` multiplies signed i8 directly, so the stored
//! activations are consumed as-is and the `ucomp` table in the packs is
//! simply ignored.  The layout walk, tail handling and narrow-panel
//! fallback mirror [`super::avx2`].  Same `unsafe` policy: runtime
//! feature-asserted safe wrappers, `SAFETY:` comments on every block,
//! bit-identical to the scalar twin by test (integer accumulation is
//! exact, so ordering is free).
#![allow(unsafe_code)]

use std::arch::aarch64::*;

use super::{
    for_each_kblock, for_each_kblock_w4, merge_spill, micro_narrow_i8, micro_w4, w4_hi, w4_lo,
    PackedW4, PackedWi8, LANES, NR,
};

fn assert_dotprod() {
    assert!(
        std::arch::is_aarch64_feature_detected!("dotprod"),
        "neon kernel dispatched without the dotprod feature"
    );
}

/// Safe entry: assert `dotprod` once, then run the gated kernel.
pub(super) fn gemm_i8(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    assert_dotprod();
    // SAFETY: dotprod support was just asserted at runtime — the only
    // precondition of the target_feature function.
    unsafe { gemm_i8_neon(x, m, pw, out) }
}

/// Safe entry for the W4 kernel — same runtime gate as [`gemm_i8`].
pub(super) fn gemm_w4(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    assert_dotprod();
    // SAFETY: dotprod support was just asserted at runtime — the only
    // precondition of the target_feature function.
    unsafe { gemm_w4_neon(x, m, pw, out) }
}

/// The K-blocked panel walk over NEON row kernels.
#[target_feature(enable = "dotprod")]
unsafe fn gemm_i8_neon(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            if nv < LANES {
                micro_narrow_i8(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, first);
                continue;
            }
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kb];
                // SAFETY: dotprod is enabled for this caller (same
                // target_feature), and `out[i*n + j0..]` holds at least
                // `nv` elements for every row `i < m`.
                unsafe { row_i8(xrow, kb, sub, &mut out[i * n + j0..], nv, first) };
            }
        }
    });
}

/// One output row over one i8 `(block, panel)`: `vdotq_s32` per quad and
/// lane group, signed activations straight from memory.
#[target_feature(enable = "dotprod")]
unsafe fn row_i8(xrow: &[i8], kb: usize, sub: &[i8], orow: &mut [i32], nv: usize, first: bool) {
    let nq = kb / 4;
    // SAFETY: in-bounds by layout — `sub` holds `kb * NR` bytes (`nq`
    // quads of 64 bytes plus the tail rows), `xrow` holds `kb` bytes,
    // and callers guarantee `orow` holds at least `nv` i32s.  NEON loads
    // and stores are unaligned-tolerant.
    unsafe {
        let mut acc0 = vdupq_n_s32(0);
        let mut acc1 = vdupq_n_s32(0);
        let mut acc2 = vdupq_n_s32(0);
        let mut acc3 = vdupq_n_s32(0);
        let xp = xrow.as_ptr();
        let wp = sub.as_ptr();
        for q in 0..nq {
            let xq = (xp.add(4 * q) as *const u32).read_unaligned();
            let xv = vreinterpretq_s8_u32(vdupq_n_u32(xq));
            acc0 = vdotq_s32(acc0, vld1q_s8(wp.add(64 * q)), xv);
            acc1 = vdotq_s32(acc1, vld1q_s8(wp.add(64 * q + 16)), xv);
            acc2 = vdotq_s32(acc2, vld1q_s8(wp.add(64 * q + 32)), xv);
            acc3 = vdotq_s32(acc3, vld1q_s8(wp.add(64 * q + 48)), xv);
        }
        if kb == 4 * nq && nv == NR {
            let op = orow.as_mut_ptr();
            if !first {
                acc0 = vaddq_s32(acc0, vld1q_s32(op));
                acc1 = vaddq_s32(acc1, vld1q_s32(op.add(4)));
                acc2 = vaddq_s32(acc2, vld1q_s32(op.add(8)));
                acc3 = vaddq_s32(acc3, vld1q_s32(op.add(12)));
            }
            vst1q_s32(op, acc0);
            vst1q_s32(op.add(4), acc1);
            vst1q_s32(op.add(8), acc2);
            vst1q_s32(op.add(12), acc3);
            return;
        }
        let mut buf = [0i32; NR];
        vst1q_s32(buf.as_mut_ptr(), acc0);
        vst1q_s32(buf.as_mut_ptr().add(4), acc1);
        vst1q_s32(buf.as_mut_ptr().add(8), acc2);
        vst1q_s32(buf.as_mut_ptr().add(12), acc3);
        for kk in 4 * nq..kb {
            let xv = xrow[kk] as i32;
            let roff = 4 * nq * NR + (kk - 4 * nq) * NR;
            for (lane, a) in buf.iter_mut().enumerate() {
                *a += xv * sub[roff + lane] as i32;
            }
        }
        merge_spill(orow, &buf, nv, first);
    }
}

/// The K-blocked panel walk over NEON W4 row kernels.
#[target_feature(enable = "dotprod")]
unsafe fn gemm_w4_neon(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock_w4(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        let pbytes = kb.div_ceil(2) * NR;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * pbytes..boff + (p + 1) * pbytes];
            if nv < LANES {
                micro_w4(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, first);
                continue;
            }
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kb];
                // SAFETY: dotprod is enabled for this caller (same
                // target_feature), and `out[i*n + j0..]` holds at least
                // `nv` elements for every row `i < m`.
                unsafe { row_w4(xrow, kb, sub, &mut out[i * n + j0..], nv, first) };
            }
        }
    });
}

/// Sign-extend the low nibbles of 16 packed lanes: `(nib ^ 8) - 8`.
#[target_feature(enable = "dotprod")]
#[inline]
unsafe fn sign4(v: uint8x16_t) -> int8x16_t {
    // SAFETY: pure register arithmetic; the caller has NEON enabled.
    unsafe { vsubq_s8(vreinterpretq_s8_u8(veorq_u8(v, vdupq_n_u8(8))), vdupq_n_s8(8)) }
}

/// One output row over one W4 `(block, panel)`: nibble unpack with
/// `vandq_u8` / `vshrq_n_u8`, then `vdotq_s32` per half-octet.
#[target_feature(enable = "dotprod")]
unsafe fn row_w4(xrow: &[i8], kb: usize, sub: &[u8], orow: &mut [i32], nv: usize, first: bool) {
    let noct = kb / 8;
    // SAFETY: in-bounds by layout — `sub` holds `kb.div_ceil(2) * NR`
    // bytes (`noct` octets of 64 bytes plus the pair-packed tail), `xrow`
    // holds `kb` bytes, and callers guarantee `orow` holds at least `nv`
    // i32s.  NEON loads and stores are unaligned-tolerant.
    unsafe {
        let lomask = vdupq_n_u8(0x0F);
        let mut acc0 = vdupq_n_s32(0);
        let mut acc1 = vdupq_n_s32(0);
        let mut acc2 = vdupq_n_s32(0);
        let mut acc3 = vdupq_n_s32(0);
        let xp = xrow.as_ptr();
        let wp = sub.as_ptr();
        for o in 0..noct {
            let xlo = (xp.add(8 * o) as *const u32).read_unaligned();
            let xhi = (xp.add(8 * o + 4) as *const u32).read_unaligned();
            let xl = vreinterpretq_s8_u32(vdupq_n_u32(xlo));
            let xh = vreinterpretq_s8_u32(vdupq_n_u32(xhi));
            let v0 = vld1q_u8(wp.add(64 * o));
            let v1 = vld1q_u8(wp.add(64 * o + 16));
            let v2 = vld1q_u8(wp.add(64 * o + 32));
            let v3 = vld1q_u8(wp.add(64 * o + 48));
            acc0 = vdotq_s32(acc0, sign4(vandq_u8(v0, lomask)), xl);
            acc1 = vdotq_s32(acc1, sign4(vandq_u8(v1, lomask)), xl);
            acc2 = vdotq_s32(acc2, sign4(vandq_u8(v2, lomask)), xl);
            acc3 = vdotq_s32(acc3, sign4(vandq_u8(v3, lomask)), xl);
            acc0 = vdotq_s32(acc0, sign4(vshrq_n_u8(v0, 4)), xh);
            acc1 = vdotq_s32(acc1, sign4(vshrq_n_u8(v1, 4)), xh);
            acc2 = vdotq_s32(acc2, sign4(vshrq_n_u8(v2, 4)), xh);
            acc3 = vdotq_s32(acc3, sign4(vshrq_n_u8(v3, 4)), xh);
        }
        if kb == 8 * noct && nv == NR {
            let op = orow.as_mut_ptr();
            if !first {
                acc0 = vaddq_s32(acc0, vld1q_s32(op));
                acc1 = vaddq_s32(acc1, vld1q_s32(op.add(4)));
                acc2 = vaddq_s32(acc2, vld1q_s32(op.add(8)));
                acc3 = vaddq_s32(acc3, vld1q_s32(op.add(12)));
            }
            vst1q_s32(op, acc0);
            vst1q_s32(op.add(4), acc1);
            vst1q_s32(op.add(8), acc2);
            vst1q_s32(op.add(12), acc3);
            return;
        }
        let mut buf = [0i32; NR];
        vst1q_s32(buf.as_mut_ptr(), acc0);
        vst1q_s32(buf.as_mut_ptr().add(4), acc1);
        vst1q_s32(buf.as_mut_ptr().add(8), acc2);
        vst1q_s32(buf.as_mut_ptr().add(12), acc3);
        for kk in 8 * noct..kb {
            let r = kk - 8 * noct;
            let xv = xrow[kk] as i32;
            let roff = 4 * noct * NR + r / 2 * NR;
            for (lane, a) in buf.iter_mut().enumerate() {
                let bb = sub[roff + lane];
                let c = if r % 2 == 0 { w4_lo(bb) } else { w4_hi(bb) };
                *a += xv * c as i32;
            }
        }
        merge_spill(orow, &buf, nv, first);
    }
}
