//! AVX-512-VNNI integer kernels at 256-bit width — one non-saturating
//! `_mm256_dpbusd_epi32` (u8×i8 → i32 accumulate) per quad, replacing the
//! AVX2 `maddubs`/`madd` pair.
//!
//! Identical structure, layout walk, unsigned-rebias compensation, tail
//! and narrow-panel handling as the [`super::avx2`] module — only the
//! inner dot product differs (`vpdpbusd` never saturates, so the
//! `|w| ≤ 64` pack invariant is not even needed here; it is kept anyway
//! because one pack serves every path).  Requires AVX512VNNI + AVX512VL
//! (the 256-bit encodings); the nibble unpack reuses the AVX2 ops.  Same
//! `unsafe` policy as the sibling: feature-asserted safe wrappers,
//! `SAFETY:` comments, bit-identical to the scalar twin by test.
#![allow(unsafe_code)]

#[cfg(target_arch = "x86")]
use std::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use super::avx2::sign4;
use super::{
    for_each_kblock, for_each_kblock_w4, merge_spill, micro_narrow_i8, micro_w4, w4_hi, w4_lo,
    PackedW4, PackedWi8, KC, LANES, NR,
};

fn assert_vnni() {
    assert!(
        std::arch::is_x86_feature_detected!("avx512vnni")
            && std::arch::is_x86_feature_detected!("avx512vl")
            && std::arch::is_x86_feature_detected!("avx2"),
        "vnni kernel dispatched without AVX512VNNI+AVX512VL"
    );
}

/// Safe entry: assert the VNNI features once, then run the gated kernel.
pub(super) fn gemm_i8(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    assert_vnni();
    // SAFETY: AVX512VNNI + AVX512VL + AVX2 support was just asserted at
    // runtime — the only precondition of the target_feature function.
    unsafe { gemm_i8_vnni(x, m, pw, out) }
}

/// Safe entry for the W4 kernel — same runtime gate as [`gemm_i8`].
pub(super) fn gemm_w4(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    assert_vnni();
    // SAFETY: AVX512VNNI + AVX512VL + AVX2 support was just asserted at
    // runtime — the only precondition of the target_feature function.
    unsafe { gemm_w4_vnni(x, m, pw, out) }
}

/// The K-blocked panel walk over VNNI row kernels.
#[target_feature(enable = "avx512vnni,avx512vl,avx2")]
unsafe fn gemm_i8_vnni(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        let b = k0 / KC;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            if nv < LANES {
                micro_narrow_i8(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, first);
                continue;
            }
            let uc = &pw.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kb];
                // SAFETY: the VNNI features are enabled for this caller
                // (same target_feature), and `out[i*n + j0..]` holds at
                // least `nv` elements for every row `i < m`.
                unsafe { row_i8(xrow, kb, sub, uc, &mut out[i * n + j0..], nv, first) };
            }
        }
    });
}

/// One output row over one i8 `(block, panel)`: `vpdpbusd` accumulates
/// each quad straight into the i32 lanes.
#[target_feature(enable = "avx512vnni,avx512vl,avx2")]
unsafe fn row_i8(
    xrow: &[i8],
    kb: usize,
    sub: &[i8],
    uc: &[i32],
    orow: &mut [i32],
    nv: usize,
    first: bool,
) {
    let nq = kb / 4;
    // SAFETY: in-bounds by layout — `sub` holds `kb * NR` bytes (`nq`
    // quads of 64 bytes plus the tail rows), `xrow` holds `kb` bytes,
    // `uc` holds NR i32, and callers guarantee `orow` holds at least
    // `nv` i32s.  All memory ops are unaligned-tolerant.
    unsafe {
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let xp = xrow.as_ptr();
        let wp = sub.as_ptr();
        for q in 0..nq {
            let xq = (xp.add(4 * q) as *const u32).read_unaligned() ^ 0x8080_8080;
            let xv = _mm256_set1_epi32(xq as i32);
            let w0 = _mm256_loadu_si256(wp.add(64 * q) as *const __m256i);
            let w1 = _mm256_loadu_si256(wp.add(64 * q + 32) as *const __m256i);
            acc0 = _mm256_dpbusd_epi32(acc0, xv, w0);
            acc1 = _mm256_dpbusd_epi32(acc1, xv, w1);
        }
        let ucp = uc.as_ptr();
        acc0 = _mm256_sub_epi32(acc0, _mm256_loadu_si256(ucp as *const __m256i));
        acc1 = _mm256_sub_epi32(acc1, _mm256_loadu_si256(ucp.add(8) as *const __m256i));
        if kb == 4 * nq && nv == NR {
            let op = orow.as_mut_ptr() as *mut __m256i;
            if !first {
                acc0 = _mm256_add_epi32(acc0, _mm256_loadu_si256(op));
                acc1 = _mm256_add_epi32(acc1, _mm256_loadu_si256(op.add(1)));
            }
            _mm256_storeu_si256(op, acc0);
            _mm256_storeu_si256(op.add(1), acc1);
            return;
        }
        let mut buf = [0i32; NR];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc0);
        _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc1);
        for kk in 4 * nq..kb {
            let xv = xrow[kk] as i32;
            let roff = 4 * nq * NR + (kk - 4 * nq) * NR;
            for (lane, a) in buf.iter_mut().enumerate() {
                *a += xv * sub[roff + lane] as i32;
            }
        }
        merge_spill(orow, &buf, nv, first);
    }
}

/// The K-blocked panel walk over VNNI W4 row kernels.
#[target_feature(enable = "avx512vnni,avx512vl,avx2")]
unsafe fn gemm_w4_vnni(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock_w4(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        let b = k0 / KC;
        let pbytes = kb.div_ceil(2) * NR;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * pbytes..boff + (p + 1) * pbytes];
            if nv < LANES {
                micro_w4(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, first);
                continue;
            }
            let uc = &pw.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kb];
                // SAFETY: the VNNI features are enabled for this caller
                // (same target_feature), and `out[i*n + j0..]` holds at
                // least `nv` elements for every row `i < m`.
                unsafe { row_w4(xrow, kb, sub, uc, &mut out[i * n + j0..], nv, first) };
            }
        }
    });
}

/// One output row over one W4 `(block, panel)`: AVX2 nibble unpack, then
/// `vpdpbusd` per half-octet.
#[target_feature(enable = "avx512vnni,avx512vl,avx2")]
unsafe fn row_w4(
    xrow: &[i8],
    kb: usize,
    sub: &[u8],
    uc: &[i32],
    orow: &mut [i32],
    nv: usize,
    first: bool,
) {
    let noct = kb / 8;
    // SAFETY: in-bounds by layout — `sub` holds `kb.div_ceil(2) * NR`
    // bytes (`noct` octets of 64 bytes plus the pair-packed tail), `xrow`
    // holds `kb` bytes, `uc` holds NR i32, and callers guarantee `orow`
    // holds at least `nv` i32s.  All memory ops are unaligned-tolerant.
    unsafe {
        let lomask = _mm256_set1_epi8(0x0F);
        let eight = _mm256_set1_epi8(8);
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let xp = xrow.as_ptr();
        let wp = sub.as_ptr();
        for o in 0..noct {
            let xlo = (xp.add(8 * o) as *const u32).read_unaligned() ^ 0x8080_8080;
            let xhi = (xp.add(8 * o + 4) as *const u32).read_unaligned() ^ 0x8080_8080;
            let xl = _mm256_set1_epi32(xlo as i32);
            let xh = _mm256_set1_epi32(xhi as i32);
            let v0 = _mm256_loadu_si256(wp.add(64 * o) as *const __m256i);
            let v1 = _mm256_loadu_si256(wp.add(64 * o + 32) as *const __m256i);
            let lo0 = sign4(_mm256_and_si256(v0, lomask), eight);
            let lo1 = sign4(_mm256_and_si256(v1, lomask), eight);
            let hi0 = sign4(_mm256_and_si256(_mm256_srli_epi16(v0, 4), lomask), eight);
            let hi1 = sign4(_mm256_and_si256(_mm256_srli_epi16(v1, 4), lomask), eight);
            acc0 = _mm256_dpbusd_epi32(acc0, xl, lo0);
            acc0 = _mm256_dpbusd_epi32(acc0, xh, hi0);
            acc1 = _mm256_dpbusd_epi32(acc1, xl, lo1);
            acc1 = _mm256_dpbusd_epi32(acc1, xh, hi1);
        }
        let ucp = uc.as_ptr();
        acc0 = _mm256_sub_epi32(acc0, _mm256_loadu_si256(ucp as *const __m256i));
        acc1 = _mm256_sub_epi32(acc1, _mm256_loadu_si256(ucp.add(8) as *const __m256i));
        if kb == 8 * noct && nv == NR {
            let op = orow.as_mut_ptr() as *mut __m256i;
            if !first {
                acc0 = _mm256_add_epi32(acc0, _mm256_loadu_si256(op));
                acc1 = _mm256_add_epi32(acc1, _mm256_loadu_si256(op.add(1)));
            }
            _mm256_storeu_si256(op, acc0);
            _mm256_storeu_si256(op.add(1), acc1);
            return;
        }
        let mut buf = [0i32; NR];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc0);
        _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc1);
        for kk in 8 * noct..kb {
            let r = kk - 8 * noct;
            let xv = xrow[kk] as i32;
            let roff = 4 * noct * NR + r / 2 * NR;
            for (lane, a) in buf.iter_mut().enumerate() {
                let bb = sub[roff + lane];
                let c = if r % 2 == 0 { w4_lo(bb) } else { w4_hi(bb) };
                *a += xv * c as i32;
            }
        }
        merge_spill(orow, &buf, nv, first);
    }
}
