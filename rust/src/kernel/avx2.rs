//! AVX2 u8×i8 integer kernels — `_mm256_maddubs_epi16` dot products over
//! the quad-interleaved [`PackedWi8`] / nibble-packed [`PackedW4`] panels.
//!
//! Per quad of 4 K-rows and 8 output lanes, one 32-byte weight load feeds
//! `maddubs` (u8×i8 → saturating i16 pairs) + `madd` (i16 pairs → i32) —
//! exact under the pack-time `|w| ≤ 64` invariant, since the worst i16
//! pair sum is `255·64·2 = 32640 < 32767`.  Activations are stored signed
//! (`q - zp`); the kernel re-biases them to unsigned in-register (one XOR
//! with `0x80` per byte, i.e. `+128`) and subtracts the pack-time
//! compensation `128 · Σ w` per lane afterwards, so results are
//! bit-identical to the signed scalar twin (integer arithmetic is exact).
//! `kb % 4` (i8) / `kb % 8` (W4) tail rows and sub-[`LANES`] panels run
//! the scalar twins directly.
//!
//! ## `unsafe` policy
//!
//! This module (with its `vnni`/`neon` siblings) is the only place the
//! crate allows `unsafe`: every block sits inside a `#[target_feature]`
//! function whose safe wrapper asserts the feature at runtime, carries a
//! `SAFETY:` comment, and is pinned bit-for-bit against the scalar twin
//! by `rust/tests/kernel.rs`.
#![allow(unsafe_code)]

#[cfg(target_arch = "x86")]
use std::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use super::{
    for_each_kblock, for_each_kblock_w4, merge_spill, micro_narrow_i8, micro_w4, w4_hi, w4_lo,
    PackedW4, PackedWi8, KC, LANES, NR,
};

/// `acc += Σ_quad u8(x)·i8(w)` per i32 lane: `maddubs` (exact under
/// `|w| ≤ 64`) then `madd` against ones.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn dot_u8i8(acc: __m256i, xv: __m256i, w: __m256i, ones: __m256i) -> __m256i {
    // SAFETY: pure register arithmetic; the caller has AVX2 enabled.
    unsafe { _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(xv, w), ones)) }
}

/// Bytewise two's-complement sign fix for unpacked nibbles: `(v ^ 8) - 8`
/// maps `0..=15` onto `-8..=7`.
#[target_feature(enable = "avx2")]
#[inline]
pub(super) unsafe fn sign4(v: __m256i, eight: __m256i) -> __m256i {
    // SAFETY: pure register arithmetic; the caller has AVX2 enabled.
    unsafe { _mm256_sub_epi8(_mm256_xor_si256(v, eight), eight) }
}

/// Safe entry: assert AVX2 once, then run the feature-gated kernel.
pub(super) fn gemm_i8(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    assert!(std::arch::is_x86_feature_detected!("avx2"), "avx2 kernel dispatched without AVX2");
    // SAFETY: AVX2 support was just asserted at runtime — the only
    // precondition of the target_feature function.
    unsafe { gemm_i8_avx2(x, m, pw, out) }
}

/// Safe entry for the W4 kernel — same runtime gate as [`gemm_i8`].
pub(super) fn gemm_w4(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    assert!(std::arch::is_x86_feature_detected!("avx2"), "avx2 kernel dispatched without AVX2");
    // SAFETY: AVX2 support was just asserted at runtime — the only
    // precondition of the target_feature function.
    unsafe { gemm_w4_avx2(x, m, pw, out) }
}

/// The K-blocked panel walk over AVX2 row kernels.  Callers (the dispatch
/// layer) guarantee `m, k, n > 0` and the `x`/`out` shape contracts.
#[target_feature(enable = "avx2")]
unsafe fn gemm_i8_avx2(x: &[i8], m: usize, pw: &PackedWi8, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        let b = k0 / KC;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * kb * NR..boff + (p + 1) * kb * NR];
            if nv < LANES {
                // thin panels (depthwise convs): the scalar narrow twin —
                // integer accumulation is exact, so values are identical
                micro_narrow_i8(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, first);
                continue;
            }
            let uc = &pw.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kb];
                // SAFETY: AVX2 is enabled for this caller (same
                // target_feature), and `out[i*n + j0..]` holds at least
                // `nv` elements for every row `i < m`.
                unsafe { row_i8(xrow, kb, sub, uc, &mut out[i * n + j0..], nv, first) };
            }
        }
    });
}

/// One output row over one i8 `(block, panel)`: 16 i32 lanes in two ymm
/// accumulators across the quad region, compensation subtract, scalar
/// signed tail, then a write-mode store or a load-add-store merge.
#[target_feature(enable = "avx2")]
unsafe fn row_i8(
    xrow: &[i8],
    kb: usize,
    sub: &[i8],
    uc: &[i32],
    orow: &mut [i32],
    nv: usize,
    first: bool,
) {
    let nq = kb / 4;
    // SAFETY: every pointer access below is in-bounds — `sub` holds
    // `kb * NR` bytes (so `nq` quads of 64 bytes plus the tail rows),
    // `xrow` holds `kb` bytes (4 per quad), `uc` holds NR i32, and the
    // callers guarantee `orow` holds at least `nv` (NR on the vector
    // store path) i32s.  Unaligned access uses read_unaligned / loadu /
    // storeu throughout.
    unsafe {
        let ones = _mm256_set1_epi16(1);
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let xp = xrow.as_ptr();
        let wp = sub.as_ptr();
        for q in 0..nq {
            // 4 consecutive signed x bytes, re-biased to u8 by +128 (XOR
            // 0x80 per byte), broadcast to every 32-bit lane
            let xq = (xp.add(4 * q) as *const u32).read_unaligned() ^ 0x8080_8080;
            let xv = _mm256_set1_epi32(xq as i32);
            let w0 = _mm256_loadu_si256(wp.add(64 * q) as *const __m256i);
            let w1 = _mm256_loadu_si256(wp.add(64 * q + 32) as *const __m256i);
            acc0 = dot_u8i8(acc0, xv, w0, ones);
            acc1 = dot_u8i8(acc1, xv, w1, ones);
        }
        // undo the unsigned re-bias: acc holds Σ (x+128)·w, the true sum
        // is Σ x·w = acc - 128·Σw (pack-time per-lane constant)
        let ucp = uc.as_ptr();
        acc0 = _mm256_sub_epi32(acc0, _mm256_loadu_si256(ucp as *const __m256i));
        acc1 = _mm256_sub_epi32(acc1, _mm256_loadu_si256(ucp.add(8) as *const __m256i));
        if kb == 4 * nq && nv == NR {
            let op = orow.as_mut_ptr() as *mut __m256i;
            if !first {
                acc0 = _mm256_add_epi32(acc0, _mm256_loadu_si256(op));
                acc1 = _mm256_add_epi32(acc1, _mm256_loadu_si256(op.add(1)));
            }
            _mm256_storeu_si256(op, acc0);
            _mm256_storeu_si256(op.add(1), acc1);
            return;
        }
        // ragged panel (nv < NR) and/or K tail (kb % 4 != 0, final block
        // only): spill, finish the tail scalar-signed, merge nv lanes
        let mut buf = [0i32; NR];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc0);
        _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc1);
        for kk in 4 * nq..kb {
            let xv = xrow[kk] as i32;
            let roff = 4 * nq * NR + (kk - 4 * nq) * NR;
            for (lane, a) in buf.iter_mut().enumerate() {
                *a += xv * sub[roff + lane] as i32;
            }
        }
        merge_spill(orow, &buf, nv, first);
    }
}

/// The K-blocked panel walk over AVX2 W4 row kernels.
#[target_feature(enable = "avx2")]
unsafe fn gemm_w4_avx2(x: &[i8], m: usize, pw: &PackedW4, out: &mut [i32]) {
    let (k, n) = (pw.k, pw.n);
    let panels = n.div_ceil(NR);
    for_each_kblock_w4(k, panels, |k0, kb, boff| {
        let first = k0 == 0;
        let b = k0 / KC;
        let pbytes = kb.div_ceil(2) * NR;
        for p in 0..panels {
            let j0 = p * NR;
            let nv = NR.min(n - j0);
            let sub = &pw.data[boff + p * pbytes..boff + (p + 1) * pbytes];
            if nv < LANES {
                micro_w4(&x[k0..], m, k, kb, sub, &mut out[j0..], n, nv, first);
                continue;
            }
            let uc = &pw.ucomp[(b * panels + p) * NR..(b * panels + p + 1) * NR];
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kb];
                // SAFETY: AVX2 is enabled for this caller (same
                // target_feature), and `out[i*n + j0..]` holds at least
                // `nv` elements for every row `i < m`.
                unsafe { row_w4(xrow, kb, sub, uc, &mut out[i * n + j0..], nv, first) };
            }
        }
    });
}

/// One output row over one W4 `(block, panel)`: 32-byte octet loads are
/// nibble-unpacked in-register (`& 0x0F` / `>> 4`, sign-fix
/// `(v ^ 8) - 8`) into the same quad-interleaved operands the i8 path
/// streams, at half the bandwidth.
#[target_feature(enable = "avx2")]
unsafe fn row_w4(
    xrow: &[i8],
    kb: usize,
    sub: &[u8],
    uc: &[i32],
    orow: &mut [i32],
    nv: usize,
    first: bool,
) {
    let noct = kb / 8;
    // SAFETY: in-bounds by layout — `sub` holds `kb.div_ceil(2) * NR`
    // bytes (`noct` octets of 64 bytes plus the pair-packed tail), `xrow`
    // holds `kb` bytes (8 per octet), `uc` holds NR i32, and callers
    // guarantee `orow` holds at least `nv` i32s.  All memory ops are
    // unaligned-tolerant (read_unaligned / loadu / storeu).
    unsafe {
        let ones = _mm256_set1_epi16(1);
        let lomask = _mm256_set1_epi8(0x0F);
        let eight = _mm256_set1_epi8(8);
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let xp = xrow.as_ptr();
        let wp = sub.as_ptr();
        for o in 0..noct {
            let xlo = (xp.add(8 * o) as *const u32).read_unaligned() ^ 0x8080_8080;
            let xhi = (xp.add(8 * o + 4) as *const u32).read_unaligned() ^ 0x8080_8080;
            let xl = _mm256_set1_epi32(xlo as i32);
            let xh = _mm256_set1_epi32(xhi as i32);
            let v0 = _mm256_loadu_si256(wp.add(64 * o) as *const __m256i);
            let v1 = _mm256_loadu_si256(wp.add(64 * o + 32) as *const __m256i);
            // nibble unpack + two's-complement sign fix
            let lo0 = sign4(_mm256_and_si256(v0, lomask), eight);
            let lo1 = sign4(_mm256_and_si256(v1, lomask), eight);
            let hi0 = sign4(_mm256_and_si256(_mm256_srli_epi16(v0, 4), lomask), eight);
            let hi1 = sign4(_mm256_and_si256(_mm256_srli_epi16(v1, 4), lomask), eight);
            acc0 = dot_u8i8(acc0, xl, lo0, ones);
            acc0 = dot_u8i8(acc0, xh, hi0, ones);
            acc1 = dot_u8i8(acc1, xl, lo1, ones);
            acc1 = dot_u8i8(acc1, xh, hi1, ones);
        }
        let ucp = uc.as_ptr();
        acc0 = _mm256_sub_epi32(acc0, _mm256_loadu_si256(ucp as *const __m256i));
        acc1 = _mm256_sub_epi32(acc1, _mm256_loadu_si256(ucp.add(8) as *const __m256i));
        if kb == 8 * noct && nv == NR {
            let op = orow.as_mut_ptr() as *mut __m256i;
            if !first {
                acc0 = _mm256_add_epi32(acc0, _mm256_loadu_si256(op));
                acc1 = _mm256_add_epi32(acc1, _mm256_loadu_si256(op.add(1)));
            }
            _mm256_storeu_si256(op, acc0);
            _mm256_storeu_si256(op.add(1), acc1);
            return;
        }
        let mut buf = [0i32; NR];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc0);
        _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc1);
        for kk in 8 * noct..kb {
            let r = kk - 8 * noct;
            let xv = xrow[kk] as i32;
            let roff = 4 * noct * NR + r / 2 * NR;
            for (lane, a) in buf.iter_mut().enumerate() {
                let bb = sub[roff + lane];
                let c = if r % 2 == 0 { w4_lo(bb) } else { w4_hi(bb) };
                *a += xv * c as i32;
            }
        }
        merge_spill(orow, &buf, nv, first);
    }
}
