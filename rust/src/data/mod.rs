//! Synthetic image-classification workload (S3): the repo's substitution for
//! ImageNet-1K (see DESIGN.md §Substitutions).
//!
//! Deterministic, dependency-free generation: each of the 10 classes owns a
//! smooth low-frequency 16x16x3 template (random sinusoid mixture from a
//! class-seeded RNG); a sample is `template ⊙ gain + shift + noise`, clamped
//! to [0, 1].  The task is learnable to >90% by the tiny FP nets yet hard
//! enough that 4b-weight round-to-nearest degrades measurably — the property
//! the paper's evaluation depends on.
//!
//! Calibration subsets (the PTQ "small unlabeled data") and the held-out val
//! set are disjoint by construction via the per-sample seed offsets.

use crate::tensor::Tensor;

pub const HW: usize = 16;
pub const CH: usize = 3;
pub const NUM_CLASSES: usize = 10;

/// splitmix64: tiny, deterministic, platform-independent.
#[derive(Clone, Debug)]
pub struct Rng(pub u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// Shared sinusoid basis: all classes mix the SAME spatial basis functions
/// with class-specific weights, making classes confusable enough that the
/// FP nets land in the low-to-mid-90s and 4b round-to-nearest degrades
/// measurably (the regime the paper's evaluation lives in).
const BASIS: usize = 8;

fn basis_fn(b: usize, world_seed: u64) -> [f32; 4] {
    let mut rng = Rng::new(world_seed ^ (0xBA515 + b as u64 * 104729));
    [
        rng.range(0.5, 3.0),                       // fx
        rng.range(0.5, 3.0),                       // fy
        rng.range(0.0, std::f32::consts::TAU),     // px
        rng.range(0.0, std::f32::consts::TAU),     // py
    ]
}

/// Per-class template: class-weighted mixture over the shared basis.
fn class_template(class: usize, world_seed: u64) -> Vec<f32> {
    let basis: Vec<[f32; 4]> = (0..BASIS).map(|b| basis_fn(b, world_seed)).collect();
    let mut rng = Rng::new(world_seed ^ (0xC1A55 + class as u64 * 7919));
    let mut t = vec![0.0f32; HW * HW * CH];
    for c in 0..CH {
        // sparse-ish class signature over the shared basis
        let weights: Vec<f32> = (0..BASIS).map(|_| rng.normal() / BASIS as f32).collect();
        for (bi, &[fx, fy, px, py]) in basis.iter().enumerate() {
            let amp = weights[bi];
            for y in 0..HW {
                for x in 0..HW {
                    let v = amp
                        * ((fx * x as f32 / HW as f32 * std::f32::consts::TAU + px).sin()
                            * (fy * y as f32 / HW as f32 * std::f32::consts::TAU + py).sin());
                    t[(y * HW + x) * CH + c] += v;
                }
            }
        }
    }
    // normalize template to [0, 1]
    let (mut lo, mut hi) = (f32::MAX, f32::MIN);
    for &v in &t {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-6);
    for v in &mut t {
        *v = (*v - lo) / span;
    }
    t
}

/// The synthetic dataset: templates are generated once, samples on demand.
pub struct Dataset {
    templates: Vec<Vec<f32>>,
    pub world_seed: u64,
    noise: f32,
}

/// Disjoint sample-index spaces per split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// Teacher pretraining set (labeled).
    Train,
    /// PTQ calibration set (unlabeled in spirit; labels never used by QFT).
    Calib,
    /// Held-out evaluation set.
    Val,
}

impl Split {
    fn base(self) -> u64 {
        match self {
            Split::Train => 0x1000_0000,
            Split::Calib => 0x2000_0000,
            Split::Val => 0x3000_0000,
        }
    }
}

impl Dataset {
    pub fn new(world_seed: u64) -> Self {
        let templates = (0..NUM_CLASSES)
            .map(|c| class_template(c, world_seed))
            .collect();
        Dataset { templates, world_seed, noise: 0.30 }
    }

    /// Deterministic (image, label) for a split-local index.  Augmentations
    /// (gain/shift jitter, circular translation, pixel noise) are part of the
    /// generative model, not a training-time option.
    pub fn sample(&self, split: Split, index: u64) -> (Vec<f32>, usize) {
        let mut rng = Rng::new(self.world_seed ^ (split.base() + index).wrapping_mul(0x5851F42D4C957F2D));
        let label = rng.below(NUM_CLASSES);
        let tpl = &self.templates[label];
        let gain = rng.range(0.6, 1.2);
        let shift = rng.range(-0.15, 0.15);
        let (dx, dy) = (rng.below(5) as isize - 2, rng.below(5) as isize - 2);
        let mut img = vec![0.0f32; HW * HW * CH];
        for y in 0..HW {
            let sy = ((y as isize + dy).rem_euclid(HW as isize)) as usize;
            for x in 0..HW {
                let sx = ((x as isize + dx).rem_euclid(HW as isize)) as usize;
                for c in 0..CH {
                    let t = tpl[(sy * HW + sx) * CH + c];
                    let v = t * gain + shift + self.noise * rng.normal();
                    img[(y * HW + x) * CH + c] = v.clamp(0.0, 1.0);
                }
            }
        }
        (img, label)
    }

    /// A batch as NHWC tensor + labels-as-f32 (the AOT contract).
    pub fn batch(&self, split: Split, start: u64, bsz: usize) -> (Tensor, Tensor, Vec<usize>) {
        // a u64::MAX pool makes the modulo the identity for every
        // reachable index — one batch-assembly loop for both entry points
        self.batch_wrapped(split, start, bsz, u64::MAX)
    }

    /// As [`Self::batch`] but split-local indices wrap modulo a pool of
    /// `pool_images`: sample `i` is `(start + i) % pool_images`.  This is
    /// what keeps a fixed train/calibration pool truly fixed when the batch
    /// size does not divide it — the trailing partial batch re-reads the
    /// pool head instead of minting fresh images beyond the pool budget.
    /// Identical to [`Self::batch`] whenever `start + bsz <= pool_images`.
    pub fn batch_wrapped(
        &self,
        split: Split,
        start: u64,
        bsz: usize,
        pool_images: u64,
    ) -> (Tensor, Tensor, Vec<usize>) {
        let pool = pool_images.max(1);
        let mut imgs = Vec::with_capacity(bsz * HW * HW * CH);
        let mut labels_f = Vec::with_capacity(bsz);
        let mut labels = Vec::with_capacity(bsz);
        for i in 0..bsz {
            let (img, lab) = self.sample(split, (start + i as u64) % pool);
            imgs.extend_from_slice(&img);
            labels_f.push(lab as f32);
            labels.push(lab);
        }
        (
            Tensor::new(vec![bsz, HW, HW, CH], imgs),
            Tensor::new(vec![bsz], labels_f),
            labels,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let d1 = Dataset::new(7);
        let d2 = Dataset::new(7);
        let (a, la) = d1.sample(Split::Train, 42);
        let (b, lb) = d2.sample(Split::Train, 42);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn wrapped_batch_reuses_pool_head_instead_of_minting_images() {
        let ds = Dataset::new(3);
        let pool = 512u64;
        // trailing partial batch: starts 2 before the pool end, wraps
        let (wx, _, wl) = ds.batch_wrapped(Split::Calib, pool - 2, 5, pool);
        let (head, _, hl) = ds.batch(Split::Calib, 0, 3);
        let px = HW * HW * CH;
        // rows 2..5 must be pool images 0..3, NOT images 512..515
        assert_eq!(&wx.data[2 * px..], &head.data[..]);
        assert_eq!(&wl[2..], &hl[..]);
        // inside the pool it is plain `batch`
        let (a, _, _) = ds.batch_wrapped(Split::Calib, 17, 8, pool);
        let (b, _, _) = ds.batch(Split::Calib, 17, 8);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn different_worlds_differ() {
        let (a, _) = Dataset::new(1).sample(Split::Train, 0);
        let (b, _) = Dataset::new(2).sample(Split::Train, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let d = Dataset::new(3);
        let (a, _) = d.sample(Split::Train, 5);
        let (b, _) = d.sample(Split::Calib, 5);
        let (c, _) = d.sample(Split::Val, 5);
        assert_ne!(a, b);
        assert_ne!(b, c);
    }

    #[test]
    fn images_in_unit_range() {
        let d = Dataset::new(0);
        for i in 0..50 {
            let (img, lab) = d.sample(Split::Val, i);
            assert!(lab < NUM_CLASSES);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = Dataset::new(11);
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..2000 {
            counts[d.sample(Split::Train, i).1] += 1;
        }
        for &c in &counts {
            assert!(c > 100, "{counts:?}");
        }
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // mean intra-class distance < mean inter-class distance
        let d = Dataset::new(5);
        let mut samples: Vec<(Vec<f32>, usize)> = Vec::new();
        for i in 0..200 {
            samples.push(d.sample(Split::Train, i));
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let (mut intra, mut ni, mut inter, mut nx) = (0.0, 0, 0.0, 0);
        for i in 0..samples.len() {
            for j in (i + 1)..samples.len() {
                let dd = dist(&samples[i].0, &samples[j].0);
                if samples[i].1 == samples[j].1 {
                    intra += dd;
                    ni += 1;
                } else {
                    inter += dd;
                    nx += 1;
                }
            }
        }
        // shared-basis templates + shift/noise augmentation make classes
        // deliberately confusable; separability need only be directional
        assert!(intra / (ni as f32) < inter / nx as f32);
    }

    #[test]
    fn batch_shapes() {
        let d = Dataset::new(0);
        let (x, yf, y) = d.batch(Split::Train, 0, 8);
        assert_eq!(x.shape, vec![8, HW, HW, CH]);
        assert_eq!(yf.shape, vec![8]);
        assert_eq!(y.len(), 8);
    }

    #[test]
    fn rng_uniform_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(10);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }
}
