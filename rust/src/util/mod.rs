//! Small self-contained utilities (the image is offline — see Cargo.toml).

pub mod json;
