//! Minimal JSON parser/serializer (vendored: the image's cargo cache has no
//! serde_json).  Supports the full JSON grammar the AOT manifest uses:
//! objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::HashMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(HashMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (want key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn obj(&self) -> Result<&HashMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    /// Compact serialization (enough for the weights-bundle header).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                let mut keys: Vec<_> = m.keys().collect();
                keys.sort();
                for (i, k) in keys.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str((*k).clone()).write(out);
                    out.push(':');
                    m[*k].write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected , or ] at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                c => {
                    // collect the full utf-8 sequence
                    let len = match c {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    let start = self.i - 1;
                    self.i += len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n\"x\""}, "d": true, "e": null}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap()[1].num().unwrap(), 2.5);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().str().unwrap(), "hi\n\"x\"");
        assert!(v.get("d").unwrap().boolean().unwrap());
        assert_eq!(*v.get("e").unwrap(), Value::Null);
        // serialize + reparse = fixed point
        let s = v.to_string_compact();
        assert_eq!(Value::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{} x").is_err());
        assert!(Value::parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""Aéø""#).unwrap();
        assert_eq!(v.str().unwrap(), "Aéø");
    }

    #[test]
    fn nested_arrays() {
        let v = Value::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(v.arr().unwrap()[1].arr().unwrap()[1].arr().unwrap()[0].num().unwrap(), 4.0);
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Value::parse("[3, 3, 8, 16]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![3, 3, 8, 16]);
    }
}
