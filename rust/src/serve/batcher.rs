//! Bounded request queue + dynamic micro-batch assembly.
//!
//! Policy: a worker blocks until at least one request is queued, then keeps
//! the batch open for up to `max_wait` for it to fill to `max_batch`.
//! Admission is bounded by `queue_cap`: submitters block (backpressure)
//! until a slot frees, so a burst can never grow the queue without bound.
//! Pure std — one `Mutex<VecDeque>` and two `Condvar`s; no work-stealing,
//! no lock-free cleverness, because batch assembly is O(µs) next to a
//! forward pass.
//!
//! The *pool-aware* refinement ([`Batcher::next_batch_pool_aware`] +
//! [`BatchPolicy::effective_wait`]): once a head request is in hand, the
//! batcher samples how loaded the shared [`crate::par`] kernel pool is.
//! An idle pool means an under-filled batch can still use the whole
//! machine through intra-op parallelism, so holding it open only adds
//! latency — the wait shrinks.  A contended pool (several kernel scopes
//! interleaving) means per-batch overhead is the scarce resource, so the
//! wait grows to fill micro-batches (throughput).  This only moves the
//! *dispatch moment*; replies are bit-identical either way.
//!
//! Invariant the tests lean on: every submitted request is handed to exactly
//! one worker batch (pop happens under the same lock as push), so requests
//! are never dropped or duplicated, and FIFO order is preserved.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One classification request: an image for a fleet slot, plus the reply
/// channel.  The [`crate::obs::Trace`] anchors the end-to-end latency
/// measurement and the per-request queue-wait stage.
pub struct InferRequest {
    pub id: u64,
    /// Fleet slot of the (arch × backend) deployment to run.
    pub model: usize,
    /// Flat NHWC image, `hw*hw*ch` of the target model.
    pub image: Vec<f32>,
    /// Lifecycle stamps, starting with the client-side enqueue instant.
    pub trace: crate::obs::Trace,
    pub resp: Sender<InferResult>,
}

/// What comes back over a request's reply channel: the reply, or a typed
/// rejection.  [`crate::serve::Client`] validates at admission, so its
/// callers only ever see `Err` for requests that bypassed it (raw
/// [`Batcher::submit`]) — a worker answers those instead of dropping them
/// (and instead of panicking, which a bad slot id once caused).
pub type InferResult = Result<InferReply, Reject>;

/// Typed worker-side rejection of a malformed request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reject {
    /// The request named a fleet slot that does not exist.
    UnknownSlot { slot: usize, slots: usize },
    /// The payload length does not match the slot's image contract.
    PayloadSize { slot: usize, got: usize, want: usize },
    /// Admission control shed the request: the bounded queue was full and
    /// the submitter chose shedding ([`Batcher::try_submit`]) over blocking.
    Busy { depth: usize, cap: usize },
    /// The engine is shutting down; the request was not (or will not be)
    /// executed.
    Shutdown,
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::UnknownSlot { slot, slots } => {
                write!(f, "unknown model slot {slot} (fleet has {slots})")
            }
            Reject::PayloadSize { slot, got, want } => {
                write!(f, "payload is {got} floats, slot {slot} expects {want}")
            }
            Reject::Busy { depth, cap } => {
                write!(f, "queue full ({depth}/{cap}), request shed")
            }
            Reject::Shutdown => write!(f, "serve engine is shutting down"),
        }
    }
}

impl std::error::Error for Reject {}

/// Reply to one [`InferRequest`].
#[derive(Clone, Debug)]
pub struct InferReply {
    pub id: u64,
    /// argmax class.
    pub top1: usize,
    /// Raw logits row.
    pub logits: Vec<f32>,
    /// Queue + batching + execution time.
    pub latency: Duration,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest micro-batch a worker will assemble.
    pub max_batch: usize,
    /// How long a worker holds a non-full batch open for stragglers.
    pub max_wait: Duration,
    /// Bounded-queue capacity (backpressure threshold).
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 256,
        }
    }
}

impl BatchPolicy {
    /// Pool-aware hold time for the next micro-batch, from `busy_scopes` —
    /// the number of kernel scopes concurrently in flight on the shared
    /// pool — and `depth`, the requests already queued.
    ///
    /// * queue already holds a full batch → no wait at all (it fills now);
    /// * pool idle (`busy == 0`) → `max_wait / 4`: dispatch small batches
    ///   quickly, the idle pool parallelizes them intra-op;
    /// * pool contended (`busy >= 2`: several scopes interleaving on one
    ///   worker set, so per-scope throughput is already divided) →
    ///   `max_wait * 4`: hold for stragglers and amortize per-batch cost;
    /// * exactly one scope in flight → the configured `max_wait`.
    ///
    /// Scope count is compared against *other concurrent work*, not the
    /// pool width: a scope saturates the whole pool by itself, so width
    /// says nothing about contention.
    pub fn effective_wait(&self, busy_scopes: usize, depth: usize) -> Duration {
        if depth >= self.max_batch {
            return Duration::ZERO;
        }
        match busy_scopes {
            0 => self.max_wait / 4,
            1 => self.max_wait,
            _ => self.max_wait.saturating_mul(4),
        }
    }
}

struct State {
    q: VecDeque<InferRequest>,
    closed: bool,
}

/// The shared request queue between clients and the worker pool.
pub struct Batcher {
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Batches handed to workers and not yet reported done — what
    /// [`Self::idle`] adds to the queue depth so a drain can tell "queue
    /// empty" apart from "queue empty but a forward pass is in flight".
    executing: AtomicUsize,
    pub policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        assert!(policy.queue_cap >= 1);
        Batcher {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            executing: AtomicUsize::new(0),
            policy,
        }
    }

    /// Current queue depth (diagnostic; racy by nature).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    /// Blocking submit with backpressure.  Returns the post-enqueue queue
    /// depth, or the request back if the batcher is closed.
    pub fn submit(&self, req: InferRequest) -> Result<usize, InferRequest> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(req);
            }
            if st.q.len() < self.policy.queue_cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.q.push_back(req);
        let depth = st.q.len();
        drop(st);
        crate::obs::queue_depth().set(depth as i64);
        crate::obs::submitted().add(1);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Non-blocking submit — admission control for the wire.  Where
    /// [`Self::submit`] blocks a full queue (backpressure for in-process
    /// callers), this *sheds*: a full queue hands the request straight back
    /// with [`Reject::Busy`] so the front-end can answer with an explicit
    /// busy frame instead of stalling the connection, and a closed batcher
    /// hands it back with [`Reject::Shutdown`].  Returns the post-enqueue
    /// queue depth on success.
    pub fn try_submit(&self, req: InferRequest) -> Result<usize, (InferRequest, Reject)> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err((req, Reject::Shutdown));
        }
        let depth = st.q.len();
        if depth >= self.policy.queue_cap {
            return Err((req, Reject::Busy { depth, cap: self.policy.queue_cap }));
        }
        st.q.push_back(req);
        let depth = st.q.len();
        drop(st);
        crate::obs::queue_depth().set(depth as i64);
        crate::obs::submitted().add(1);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// A worker finished the batch it took (every exit path of the worker
    /// body must call this exactly once per batch, or [`Self::idle`] never
    /// turns true and a drain waits out its full deadline).
    pub fn batch_done(&self) {
        self.executing.fetch_sub(1, Ordering::SeqCst);
    }

    /// True when nothing is queued and no worker holds an unfinished batch.
    /// Meaningful only after [`Self::close`] (while open, new submits can
    /// flip it back at any moment).
    pub fn idle(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.q.is_empty() && self.executing.load(Ordering::SeqCst) == 0
    }

    /// Rip all still-queued requests out (for a drain that hit its
    /// deadline): the caller owns answering each with a typed
    /// [`Reject::Shutdown`].  Zeroes the depth gauge and wakes everyone.
    pub fn purge(&self) -> Vec<InferRequest> {
        let mut st = self.state.lock().unwrap();
        let dropped: Vec<InferRequest> = st.q.drain(..).collect();
        drop(st);
        crate::obs::queue_depth().set(0);
        self.not_empty.notify_all();
        self.not_full.notify_all();
        dropped
    }

    /// Next micro-batch for a worker, holding a non-full batch open for up
    /// to the configured `max_wait`.  See [`Self::next_batch_wait`].
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        self.next_batch_wait(self.policy.max_wait)
    }

    /// [`Self::next_batch_wait`] with the hold time chosen by
    /// [`BatchPolicy::effective_wait`] from `pool`'s load, sampled *after*
    /// the head request has arrived — a worker can block here indefinitely
    /// waiting for traffic, so sampling any earlier would act on
    /// arbitrarily stale saturation.
    pub fn next_batch_pool_aware(&self, pool: &crate::par::Pool) -> Option<Vec<InferRequest>> {
        let st = self.wait_head()?;
        let wait = self.policy.effective_wait(pool.active_scopes(), st.q.len());
        Some(self.drain_batch(st, wait))
    }

    /// Next micro-batch for a worker.  Blocks for work; once a head request
    /// exists, drains same-model requests up to `max_batch`, holding the
    /// batch open up to `max_wait` if the queue runs dry first.  Requests
    /// for a *different* model than the batch head are left queued (FIFO
    /// across models is preserved — the next worker picks them up).
    /// Returns `None` once closed and fully drained.
    pub fn next_batch_wait(&self, max_wait: Duration) -> Option<Vec<InferRequest>> {
        let st = self.wait_head()?;
        Some(self.drain_batch(st, max_wait))
    }

    /// Block until the queue is non-empty (returning the held lock) or
    /// closed-and-drained (`None`).
    fn wait_head(&self) -> Option<std::sync::MutexGuard<'_, State>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.q.is_empty() {
                return Some(st);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Assemble one micro-batch starting from the (non-empty) queue head,
    /// holding it open up to `max_wait` to fill.
    fn drain_batch(
        &self,
        mut st: std::sync::MutexGuard<'_, State>,
        max_wait: Duration,
    ) -> Vec<InferRequest> {
        let head_model = st.q.front().unwrap().model;
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        let deadline = Instant::now() + max_wait;
        loop {
            while batch.len() < self.policy.max_batch
                && st.q.front().map(|r| r.model == head_model).unwrap_or(false)
            {
                batch.push(st.q.pop_front().unwrap());
            }
            if batch.len() >= self.policy.max_batch {
                break;
            }
            // head-of-queue is another model: dispatch what we have
            if !st.q.is_empty() {
                break;
            }
            if st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                // grab anything that raced in, then dispatch
                while batch.len() < self.policy.max_batch
                    && st.q.front().map(|r| r.model == head_model).unwrap_or(false)
                {
                    batch.push(st.q.pop_front().unwrap());
                }
                break;
            }
        }
        // if we left requests queued (another model's, or beyond max_batch),
        // make sure an idle worker hears about them even though this thread
        // may have consumed the submitter's notification
        let leftovers = !st.q.is_empty();
        crate::obs::queue_depth().set(st.q.len() as i64);
        // counted while the queue lock is still held, so `idle` can never
        // observe the window between the pop and the in-flight mark
        self.executing.fetch_add(1, Ordering::SeqCst);
        drop(st);
        self.not_full.notify_all();
        if leftovers {
            self.not_empty.notify_one();
        }
        batch
    }

    /// Stop admitting requests and wake everyone; workers drain what's
    /// queued, then their `next_batch` returns `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, model: usize) -> (InferRequest, mpsc::Receiver<InferResult>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest {
                id,
                model,
                image: vec![0.0; 4],
                trace: crate::obs::Trace::start(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_cap_at_max_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_micros(1),
            queue_cap: 16,
        });
        let mut rxs = Vec::new();
        for i in 0..7 {
            let (r, rx) = req(i, 0);
            b.submit(r).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let sizes: Vec<usize> = (0..3).map(|_| b.next_batch().unwrap().len()).collect();
        assert_eq!(sizes, vec![3, 3, 1]);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn fifo_order_and_model_affinity() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(1),
            queue_cap: 16,
        });
        let mut rxs = Vec::new();
        for (i, m) in [(0u64, 0usize), (1, 0), (2, 1), (3, 1), (4, 0)] {
            let (r, rx) = req(i, m);
            b.submit(r).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
    }

    #[test]
    fn effective_wait_tracks_pool_load() {
        let p = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(400),
            queue_cap: 64,
        };
        // full queue: dispatch immediately regardless of pool state
        assert_eq!(p.effective_wait(0, 8), Duration::ZERO);
        assert_eq!(p.effective_wait(9, 20), Duration::ZERO);
        // idle pool: shrink; one scope in flight: base; contended: grow
        let idle = p.effective_wait(0, 1);
        let base = p.effective_wait(1, 1);
        let contended = p.effective_wait(2, 1);
        assert!(idle < base, "idle pool must shorten the hold ({idle:?} vs {base:?})");
        assert_eq!(base, p.max_wait);
        assert!(contended > base, "contention must lengthen the hold ({contended:?} vs {base:?})");
        assert_eq!(p.effective_wait(16, 1), contended, "growth saturates, no overflow");
    }

    #[test]
    fn next_batch_wait_zero_dispatches_what_is_queued() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(250),
            queue_cap: 16,
        });
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 0);
            b.submit(r).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        // a zero hold must not sleep the configured 250 ms
        let t0 = Instant::now();
        let batch = b.next_batch_wait(Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(t0.elapsed() < Duration::from_millis(200), "zero wait must not hold");
    }

    #[test]
    fn close_rejects_new_and_drains_old() {
        let b = Batcher::new(BatchPolicy::default());
        let (r, _rx) = req(0, 0);
        b.submit(r).map_err(|_| ()).unwrap();
        b.close();
        let (r2, _rx2) = req(1, 0);
        assert!(b.submit(r2).is_err());
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn try_submit_sheds_on_full_and_closed() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(1),
            queue_cap: 2,
        });
        let mut rxs = Vec::new();
        for i in 0..2 {
            let (r, rx) = req(i, 0);
            assert!(b.try_submit(r).is_ok());
            rxs.push(rx);
        }
        // full queue: shed with Busy, never block
        let (r, _rx) = req(2, 0);
        match b.try_submit(r) {
            Err((back, Reject::Busy { depth, cap })) => {
                assert_eq!(back.id, 2);
                assert_eq!((depth, cap), (2, 2));
            }
            other => panic!("expected Busy shed, got {:?}", other.err().map(|e| e.1)),
        }
        // workers drain it, batch_done closes the in-flight window
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert!(!b.idle(), "batch taken but not done");
        b.batch_done();
        assert!(b.idle());
        // closed: typed Shutdown instead of Busy
        b.close();
        let (r, _rx) = req(3, 0);
        match b.try_submit(r) {
            Err((_, Reject::Shutdown)) => {}
            other => panic!("expected Shutdown, got {:?}", other.err().map(|e| e.1)),
        }
        assert!(b.purge().is_empty());
    }
}
