//! Bounded request queue + dynamic micro-batch assembly.
//!
//! Policy: a worker blocks until at least one request is queued, then keeps
//! the batch open for up to `max_wait` for it to fill to `max_batch`.
//! Admission is bounded by `queue_cap`: submitters block (backpressure)
//! until a slot frees, so a burst can never grow the queue without bound.
//! Pure std — one `Mutex<VecDeque>` and two `Condvar`s; no work-stealing,
//! no lock-free cleverness, because batch assembly is O(µs) next to a
//! forward pass.
//!
//! Invariant the tests lean on: every submitted request is handed to exactly
//! one worker batch (pop happens under the same lock as push), so requests
//! are never dropped or duplicated, and FIFO order is preserved.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One classification request: an image for a registry slot, plus the reply
/// channel.  `enqueued` anchors the end-to-end latency measurement.
pub struct InferRequest {
    pub id: u64,
    /// Registry slot of the (arch × mode) deployment to run.
    pub model: usize,
    /// Flat NHWC image, `hw*hw*ch` of the target model.
    pub image: Vec<f32>,
    pub enqueued: Instant,
    pub resp: Sender<InferReply>,
}

/// Reply to one [`InferRequest`].
#[derive(Clone, Debug)]
pub struct InferReply {
    pub id: u64,
    /// argmax class.
    pub top1: usize,
    /// Raw logits row.
    pub logits: Vec<f32>,
    /// Queue + batching + execution time.
    pub latency: Duration,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest micro-batch a worker will assemble.
    pub max_batch: usize,
    /// How long a worker holds a non-full batch open for stragglers.
    pub max_wait: Duration,
    /// Bounded-queue capacity (backpressure threshold).
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 256,
        }
    }
}

struct State {
    q: VecDeque<InferRequest>,
    closed: bool,
}

/// The shared request queue between clients and the worker pool.
pub struct Batcher {
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
    pub policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        assert!(policy.queue_cap >= 1);
        Batcher {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            policy,
        }
    }

    /// Current queue depth (diagnostic; racy by nature).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    /// Blocking submit with backpressure.  Returns the post-enqueue queue
    /// depth, or the request back if the batcher is closed.
    pub fn submit(&self, req: InferRequest) -> Result<usize, InferRequest> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(req);
            }
            if st.q.len() < self.policy.queue_cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.q.push_back(req);
        let depth = st.q.len();
        drop(st);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Next micro-batch for a worker.  Blocks for work; once a head request
    /// exists, drains same-model requests up to `max_batch`, holding the
    /// batch open up to `max_wait` if the queue runs dry first.  Requests
    /// for a *different* model than the batch head are left queued (FIFO
    /// across models is preserved — the next worker picks them up).
    /// Returns `None` once closed and fully drained.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.q.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let head_model = st.q.front().unwrap().model;
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        let deadline = Instant::now() + self.policy.max_wait;
        loop {
            while batch.len() < self.policy.max_batch
                && st.q.front().map(|r| r.model == head_model).unwrap_or(false)
            {
                batch.push(st.q.pop_front().unwrap());
            }
            if batch.len() >= self.policy.max_batch {
                break;
            }
            // head-of-queue is another model: dispatch what we have
            if !st.q.is_empty() {
                break;
            }
            if st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                // grab anything that raced in, then dispatch
                while batch.len() < self.policy.max_batch
                    && st.q.front().map(|r| r.model == head_model).unwrap_or(false)
                {
                    batch.push(st.q.pop_front().unwrap());
                }
                break;
            }
        }
        // if we left requests queued (another model's, or beyond max_batch),
        // make sure an idle worker hears about them even though this thread
        // may have consumed the submitter's notification
        let leftovers = !st.q.is_empty();
        drop(st);
        self.not_full.notify_all();
        if leftovers {
            self.not_empty.notify_one();
        }
        Some(batch)
    }

    /// Stop admitting requests and wake everyone; workers drain what's
    /// queued, then their `next_batch` returns `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, model: usize) -> (InferRequest, mpsc::Receiver<InferReply>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest {
                id,
                model,
                image: vec![0.0; 4],
                enqueued: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn batches_cap_at_max_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_micros(1),
            queue_cap: 16,
        });
        let mut rxs = Vec::new();
        for i in 0..7 {
            let (r, rx) = req(i, 0);
            b.submit(r).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let sizes: Vec<usize> = (0..3).map(|_| b.next_batch().unwrap().len()).collect();
        assert_eq!(sizes, vec![3, 3, 1]);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn fifo_order_and_model_affinity() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(1),
            queue_cap: 16,
        });
        let mut rxs = Vec::new();
        for (i, m) in [(0u64, 0usize), (1, 0), (2, 1), (3, 1), (4, 0)] {
            let (r, rx) = req(i, m);
            b.submit(r).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
    }

    #[test]
    fn close_rejects_new_and_drains_old() {
        let b = Batcher::new(BatchPolicy::default());
        let (r, _rx) = req(0, 0);
        b.submit(r).map_err(|_| ()).unwrap();
        b.close();
        let (r2, _rx2) = req(1, 0);
        assert!(b.submit(r2).is_err());
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }
}
