//! Model registry: `(arch × backend)` → frozen execution state.
//!
//! All offline-subgraph work (kernel co-vectors, integer weight/bias codes,
//! i8 panel packing, recode factors) happens here at load time via
//! [`crate::backend::Backend::prepare`]; serving workers only ever touch
//! the frozen [`PreparedNet`]s through immutable references, so the hot
//! path is lock-free and never re-derives a constant.  The registry is
//! backend-agnostic: one engine serves `fp`, fake-quant, integer and
//! `lw-i8` models side by side.
//!
//! Weight resolution per model, in order:
//! 1. `{artifacts}/weights/{arch}.{mode}.qftw` — the trainable set exported
//!    by `repro qft` (the real deployment artifact; `lw-i8` shares the `lw`
//!    export — same DoF, different engine);
//! 2. `{artifacts}/weights/{arch}.qftw` — the cached FP teacher, pushed
//!    through the offline PTQ init (naive-max calibration on the synthetic
//!    calib split + MMSE weight scales);
//! 3. He-init weights through the same PTQ init — accuracy is meaningless
//!    but every serving code path still runs (smoke/bench mode).
//!
//! The `fp` backend consumes raw FP parameters, so it resolves the teacher
//! file (2) directly, else he-init, with no PTQ init.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::backend::{self, BackendKind, PreparedNet};
use crate::coordinator::{state, weights_io};
use crate::data::{Dataset, Split};
use crate::nn::ArchSpec;
use crate::quant::deploy::Mode;
use crate::runtime::manifest::Manifest;

/// One loaded model plus its provenance.
pub struct ModelEntry {
    /// `"arch/backend-key"`, the wire name clients resolve.
    pub key: String,
    pub model: Box<dyn PreparedNet>,
    /// Where the weights came from (export / teacher / he-init).
    pub source: String,
    /// Per-model stage histograms (queue wait / batch form / compute /
    /// reply), shared with the global [`crate::obs`] registry under `key`
    /// so warm-up and measured engines accumulate into the same cells.
    pub stage: Arc<crate::obs::StageMetrics>,
}

/// Immutable collection of prepared models, shared by all workers.
#[derive(Default)]
pub struct Registry {
    entries: Vec<ModelEntry>,
    by_key: HashMap<String, usize>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an entry; returns its slot id (what requests carry).
    pub fn insert(&mut self, entry: ModelEntry) -> usize {
        let slot = self.entries.len();
        self.by_key.insert(entry.key.clone(), slot);
        self.entries.push(entry);
        slot
    }

    pub fn get(&self, slot: usize) -> &ModelEntry {
        &self.entries[slot]
    }

    /// Non-panicking [`Self::get`] (worker-side defense for raw submits).
    pub fn try_get(&self, slot: usize) -> Option<&ModelEntry> {
        self.entries.get(slot)
    }

    /// Slot for a `"arch/backend-key"` key.
    pub fn resolve(&self, key: &str) -> Option<usize> {
        self.by_key.get(key).copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.key.as_str())
    }

    /// Load `(arch name, backend)` pairs from an artifacts dir into a
    /// shareable registry.  Arch specs come from the AOT manifest when
    /// present; the name `"synthetic"` (or any name when no manifest
    /// exists) falls back to [`crate::serve::synthetic_arch`] so serving
    /// runs artifact-free.
    pub fn load(dir: &Path, specs: &[(String, BackendKind)]) -> Result<Arc<Registry>> {
        anyhow::ensure!(!specs.is_empty(), "registry: no models requested");
        let manifest = Manifest::load(dir.join("manifest.json")).ok();
        let mut reg = Registry::new();
        for (name, kind) in specs {
            let arch: ArchSpec = match &manifest {
                Some(m) => match m.archs.get(name) {
                    Some(a) => a.clone(),
                    None if name == "synthetic" => crate::serve::synthetic_arch(),
                    None => bail!(
                        "unknown arch {name}; manifest has {:?} (plus the built-in \"synthetic\")",
                        m.archs.keys().collect::<Vec<_>>()
                    ),
                },
                None => {
                    eprintln!(
                        "registry: no manifest under {dir:?}; using the built-in \
                         synthetic arch for {name:?}"
                    );
                    // keep the wire key the caller asked for, even though the
                    // graph underneath is the synthetic one
                    let mut a = crate::serve::synthetic_arch();
                    a.name = name.clone();
                    a
                }
            };
            let entry = load_model(dir, &arch, *kind)?;
            if reg.resolve(&entry.key).is_some() {
                bail!("model {} requested twice", entry.key);
            }
            eprintln!("registry: {} <- {}", entry.key, entry.source);
            reg.insert(entry);
        }
        Ok(Arc::new(reg))
    }
}

/// Resolve weights for one arch × backend and freeze them behind the
/// uniform [`PreparedNet`] contract.
pub fn load_model(dir: &Path, arch: &ArchSpec, kind: BackendKind) -> Result<ModelEntry> {
    let key = format!("{}/{}", arch.name, kind.key());
    let teacher = dir.join("weights").join(format!("{}.qftw", arch.name));
    let (params, source) = match kind.mode() {
        // quantized grids consume the mode's trainable set
        Some(mode) => {
            let export =
                dir.join("weights").join(format!("{}.{}.qftw", arch.name, mode.key()));
            if export.is_file() {
                (weights_io::load(&export)?, format!("qft export {export:?}"))
            } else {
                let (params, source) = if teacher.is_file() {
                    (
                        weights_io::load(&teacher)?,
                        format!("fp teacher {teacher:?} + offline PTQ init"),
                    )
                } else {
                    (
                        state::he_init_params(arch, 0),
                        "he-init + offline PTQ init (untrained: smoke/bench only)".to_string(),
                    )
                };
                let ds = Dataset::new(0);
                let batches: Vec<_> = (0..4)
                    .map(|i| ds.batch(Split::Calib, (i * arch.batch) as u64, arch.batch).0)
                    .collect();
                let absmax = state::absmax_from_rust_forward(arch, &params, &batches);
                let winit = match mode {
                    Mode::Lw => state::WeightScaleInit::Uniform,
                    Mode::Dch => state::WeightScaleInit::DoublyChannelwise,
                };
                (state::init_trainables(arch, &params, &absmax, mode, winit, None), source)
            }
        }
        // the fp grid runs raw FP parameters — no PTQ init
        None => {
            if teacher.is_file() {
                (weights_io::load(&teacher)?, format!("fp teacher {teacher:?}"))
            } else {
                (
                    state::he_init_params(arch, 0),
                    "he-init (untrained: smoke/bench only)".to_string(),
                )
            }
        }
    };
    let stage = crate::obs::stage_metrics(&key);
    Ok(ModelEntry { key, model: backend::prepare(kind, arch, &params), source, stage })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_fallback_loads_both_modes() {
        let dir = std::env::temp_dir().join("qft_registry_test_nonexistent");
        let reg = Registry::load(
            &dir,
            &[
                ("synthetic".to_string(), BackendKind::Int(Mode::Lw)),
                ("synthetic".to_string(), BackendKind::Int(Mode::Dch)),
            ],
        )
        .unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.resolve("synthetic/lw"), Some(0));
        assert_eq!(reg.resolve("synthetic/dch"), Some(1));
        assert_eq!(reg.get(0).model.image_len(), 16 * 16 * 3);
    }

    #[test]
    fn every_backend_kind_loads_artifact_free() {
        let dir = std::env::temp_dir().join("qft_registry_test_nonexistent");
        let specs: Vec<(String, BackendKind)> = BackendKind::ALL
            .iter()
            .map(|k| ("synthetic".to_string(), *k))
            .collect();
        let reg = Registry::load(&dir, &specs).unwrap();
        assert_eq!(reg.len(), BackendKind::ALL.len());
        for kind in BackendKind::ALL {
            let slot = reg.resolve(&format!("synthetic/{}", kind.key())).unwrap();
            assert_eq!(reg.get(slot).model.kind(), kind);
            assert_eq!(reg.get(slot).model.image_len(), 16 * 16 * 3);
        }
    }
}
