//! Serving metrics: end-to-end latency percentiles, throughput, batch-size
//! and queue-depth histograms — the [`crate::runtime::ExecStats`] idiom
//! (cheap counters sampled on the hot path, reported at the end) made
//! thread-safe for the worker pool.
//!
//! Two latency families are recorded per request ([`ServeStats::record_batch`]):
//! *completion* (enqueue → forward done, the historical `p50_us` the bench
//! gate pins) and *reply-inclusive* (enqueue → reply handed to the channel),
//! so reply-channel time is measured instead of invisible.  Stage-level
//! breakdowns (queue wait / batch formation / compute / reply) live in
//! [`crate::obs::StageMetrics`]; this type keeps the end-to-end view.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Raw values a [`Pow2Histogram`] keeps verbatim; at or below this count
/// quantiles are exact (nearest-rank over the sorted values).
const POW2_EXACT: usize = 64;

/// Power-of-two bucketed histogram over small positive integers (queue
/// depths, batch sizes).  Bucket `i` covers `[2^(i-1), 2^i)`, bucket 0 is
/// exactly 0.
///
/// Quantiles ([`Self::quantile`]) are exact while every sample is still in
/// the [`POW2_EXACT`] window, and rank-interpolated within the owning
/// bucket (clamped to the observed min/max) past it — a raw bucket bound
/// would overstate p50 by up to 2× at low counts.
#[derive(Clone, Debug)]
pub struct Pow2Histogram {
    counts: Vec<u64>,
    /// First [`POW2_EXACT`] raw values, unsorted.
    exact: Vec<u64>,
    total: u64,
    min: usize,
    max: usize,
}

impl Default for Pow2Histogram {
    fn default() -> Self {
        Pow2Histogram {
            counts: Vec::new(),
            exact: Vec::new(),
            total: 0,
            min: usize::MAX,
            max: 0,
        }
    }
}

impl Pow2Histogram {
    pub fn record(&mut self, v: usize) {
        let b = (usize::BITS - v.leading_zeros()) as usize;
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
        self.total += 1;
        if self.exact.len() < POW2_EXACT {
            self.exact.push(v as u64);
        }
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// `(lo..=hi, count)` rows for non-empty buckets.
    pub fn rows(&self) -> Vec<(usize, usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(b, &c)| {
                let (lo, hi) = if b == 0 { (0, 0) } else { (1 << (b - 1), (1 << b) - 1) };
                (lo, hi, c)
            })
            .collect()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Quantile `q ∈ [0, 1]`: nearest-rank over the raw values while all
    /// of them are retained, otherwise interpolated within the owning
    /// power-of-two bucket, with the bucket range clamped to the observed
    /// `[min, max]`.
    pub fn quantile(&self, q: f64) -> usize {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        if self.exact.len() as u64 == self.total {
            let mut sorted = self.exact.clone();
            sorted.sort_unstable();
            return sorted[rank as usize - 1] as usize;
        }
        let mut cum = 0u64;
        for (lo, hi, c) in self.rows() {
            if cum + c >= rank {
                let lo = lo.max(self.min);
                let hi = hi.min(self.max).max(lo);
                if c <= 1 || hi == lo {
                    return lo;
                }
                let frac = (rank - cum - 1) as f64 / (c - 1) as f64;
                return lo + (frac * (hi - lo) as f64).round() as usize;
            }
            cum += c;
        }
        self.max
    }
}

/// Latency sample cap: bounds a long-lived engine's memory (reservoir
/// sampling keeps the percentile estimate unbiased past the cap).
const LAT_RESERVOIR: usize = 1 << 16;

struct Inner {
    lat_us: Vec<u64>,
    /// total latencies observed (>= lat_us.len() once the reservoir is full)
    lat_seen: u64,
    /// reply-inclusive latencies (enqueue → reply handed to the channel)
    reply_us: Vec<u64>,
    reply_seen: u64,
    rng: crate::data::Rng,
    requests: u64,
    batches: u64,
    batch_hist: Pow2Histogram,
    depth_hist: Pow2Histogram,
    first_enqueue: Option<Instant>,
    last_done: Option<Instant>,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            lat_us: Vec::new(),
            lat_seen: 0,
            reply_us: Vec::new(),
            reply_seen: 0,
            rng: crate::data::Rng::new(0x5E4E),
            requests: 0,
            batches: 0,
            batch_hist: Pow2Histogram::default(),
            depth_hist: Pow2Histogram::default(),
            first_enqueue: None,
            last_done: None,
        }
    }
}

impl Inner {
    /// Algorithm-R reservoir insert.
    fn record_latency(&mut self, us: u64) {
        self.lat_seen += 1;
        if self.lat_us.len() < LAT_RESERVOIR {
            self.lat_us.push(us);
        } else {
            let j = self.rng.below(self.lat_seen as usize);
            if j < LAT_RESERVOIR {
                self.lat_us[j] = us;
            }
        }
    }

    fn record_reply(&mut self, us: u64) {
        self.reply_seen += 1;
        if self.reply_us.len() < LAT_RESERVOIR {
            self.reply_us.push(us);
        } else {
            let j = self.rng.below(self.reply_seen as usize);
            if j < LAT_RESERVOIR {
                self.reply_us[j] = us;
            }
        }
    }
}

/// Shared serving counters; one per [`crate::serve::Engine`].
pub struct ServeStats {
    inner: Mutex<Inner>,
    /// Width of the shared kernel pool ([`crate::par`]) the engine's
    /// workers submit parallel conv/GEMM scopes to.  Fixed at engine start;
    /// surfaced in every [`ServeReport`] so `--threads` is observable.
    pool_threads: usize,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::with_pool(1)
    }
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stats tagged with the kernel-pool width the owning engine uses.
    pub fn with_pool(pool_threads: usize) -> Self {
        ServeStats { inner: Mutex::new(Inner::default()), pool_threads: pool_threads.max(1) }
    }

    /// Called by clients on submit with the post-enqueue queue depth.
    pub fn record_enqueue(&self, depth: usize) {
        let mut st = self.inner.lock().unwrap();
        st.first_enqueue.get_or_insert_with(Instant::now);
        st.depth_hist.record(depth);
    }

    /// Called by workers once per executed micro-batch.  `completion` are
    /// enqueue → forward-done latencies (stamped *before* replies are
    /// sent); `replied` are the reply-inclusive enqueue → reply-sent
    /// latencies for the same requests.
    pub fn record_batch(&self, batch: usize, completion: &[Duration], replied: &[Duration]) {
        let mut st = self.inner.lock().unwrap();
        st.batches += 1;
        st.requests += completion.len() as u64;
        st.batch_hist.record(batch);
        for l in completion {
            st.record_latency(l.as_micros() as u64);
        }
        for l in replied {
            st.record_reply(l.as_micros() as u64);
        }
        st.last_done = Some(Instant::now());
    }

    /// Snapshot of everything recorded so far.
    pub fn report(&self) -> ServeReport {
        let st = self.inner.lock().unwrap();
        let mut sorted = st.lat_us.clone();
        sorted.sort_unstable();
        let mut rsorted = st.reply_us.clone();
        rsorted.sort_unstable();
        // nearest-rank: smallest value with at least p% of samples <= it
        let pct = |sorted: &[u64], p: f64| -> u64 {
            if sorted.is_empty() {
                return 0;
            }
            let rank = (p / 100.0 * sorted.len() as f64).ceil() as usize;
            sorted[rank.clamp(1, sorted.len()) - 1]
        };
        let wall = match (st.first_enqueue, st.last_done) {
            (Some(a), Some(b)) => b.saturating_duration_since(a),
            _ => Duration::ZERO,
        };
        let secs = wall.as_secs_f64();
        ServeReport {
            pool_threads: self.pool_threads,
            requests: st.requests,
            batches: st.batches,
            wall,
            throughput_ips: if secs > 0.0 { st.requests as f64 / secs } else { 0.0 },
            p50_us: pct(&sorted, 50.0),
            p95_us: pct(&sorted, 95.0),
            p99_us: pct(&sorted, 99.0),
            max_us: sorted.last().copied().unwrap_or(0),
            reply_p50_us: pct(&rsorted, 50.0),
            reply_p99_us: pct(&rsorted, 99.0),
            reply_max_us: rsorted.last().copied().unwrap_or(0),
            mean_batch: if st.batches > 0 {
                st.requests as f64 / st.batches as f64
            } else {
                0.0
            },
            batch_hist: st.batch_hist.clone(),
            depth_hist: st.depth_hist.clone(),
        }
    }
}

/// Point-in-time serving report (also the `BENCH_serve.json` row shape).
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Shared kernel-pool width the engine's workers cooperate on.
    pub pool_threads: usize,
    pub requests: u64,
    pub batches: u64,
    pub wall: Duration,
    pub throughput_ips: f64,
    /// Completion latency (enqueue → forward done), the historical series
    /// the bench gate pins.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Reply-inclusive latency (enqueue → reply handed to the channel).
    pub reply_p50_us: u64,
    pub reply_p99_us: u64,
    pub reply_max_us: u64,
    pub mean_batch: f64,
    pub batch_hist: Pow2Histogram,
    pub depth_hist: Pow2Histogram,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} reqs in {} batches over {:.2} s | {:.0} images/s | \
             latency µs p50 {} p95 {} p99 {} max {} | \
             reply-incl p50 {} p99 {} | mean batch {:.2} | pool {}",
            self.requests,
            self.batches,
            self.wall.as_secs_f64(),
            self.throughput_ips,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.max_us,
            self.reply_p50_us,
            self.reply_p99_us,
            self.mean_batch,
            self.pool_threads,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_distribution() {
        let s = ServeStats::new();
        s.record_enqueue(1);
        let lats: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let replies: Vec<Duration> = (1..=100).map(|v| Duration::from_micros(v + 10)).collect();
        s.record_batch(4, &lats, &replies);
        let r = s.report();
        assert_eq!(r.requests, 100);
        assert_eq!(r.batches, 1);
        assert_eq!(r.p50_us, 50);
        assert_eq!(r.p99_us, 99);
        assert_eq!(r.max_us, 100);
        assert_eq!(r.reply_p50_us, 60);
        assert_eq!(r.reply_p99_us, 109);
        assert_eq!(r.reply_max_us, 110);
        assert!((r.mean_batch - 100.0).abs() < 1e-9);
    }

    #[test]
    fn pow2_histogram_buckets() {
        let mut h = Pow2Histogram::default();
        for v in [0, 1, 2, 3, 4, 7, 8] {
            h.record(v);
        }
        let rows = h.rows();
        assert_eq!(rows, vec![(0, 0, 1), (1, 1, 1), (2, 3, 2), (4, 7, 2), (8, 15, 1)]);
    }

    #[test]
    fn pow2_small_sample_quantiles_are_exact() {
        // the old bucket-bound readout would answer 7 for p50 of [3, 1000]
        // style data; the exact window must return true sample values
        let mut h = Pow2Histogram::default();
        for v in [1000, 3, 7] {
            h.record(v);
        }
        assert_eq!(h.quantile(0.50), 7);
        assert_eq!(h.quantile(0.99), 1000);
        assert_eq!(h.quantile(0.01), 3);
        let mut one = Pow2Histogram::default();
        one.record(5);
        assert_eq!(one.quantile(0.5), 5);
        assert_eq!(Pow2Histogram::default().quantile(0.5), 0);
    }

    #[test]
    fn pow2_interpolated_quantiles_match_sorted_ground_truth() {
        // 1..=1000: far past the exact window; uniform integers make
        // within-bucket interpolation land exactly on the sorted value
        let mut h = Pow2Histogram::default();
        for v in 1..=1000usize {
            h.record(v);
        }
        let sorted: Vec<usize> = (1..=1000).collect();
        for q in [0.50, 0.95, 0.99] {
            let rank = ((q * 1000.0).ceil() as usize).clamp(1, 1000);
            assert_eq!(h.quantile(q), sorted[rank - 1], "q={q}");
        }
        assert_eq!(h.total(), 1000);
    }

    #[test]
    fn empty_report_is_sane() {
        let r = ServeStats::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.p99_us, 0);
        assert_eq!(r.reply_p99_us, 0);
        assert_eq!(r.throughput_ips, 0.0);
    }

    #[test]
    fn pool_size_is_reported() {
        assert_eq!(ServeStats::with_pool(4).report().pool_threads, 4);
        // a pool is never narrower than the submitting thread itself
        assert_eq!(ServeStats::new().report().pool_threads, 1);
        assert_eq!(ServeStats::with_pool(0).report().pool_threads, 1);
        let txt = ServeStats::with_pool(4).report().to_string();
        assert!(txt.contains("pool 4"), "{txt}");
    }
}
