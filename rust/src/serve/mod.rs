//! `qft::serve` — multi-threaded dynamic-batching inference serving over the
//! integer deployment path (S15).
//!
//! The paper's HW-aware split is: *offline*, derive every deployment
//! constant from the trained DoF set; *online*, run the cheap frozen integer
//! graph.  This module is the online half grown into a serving engine:
//!
//! * [`crate::fleet`] — [`Fleet`]: `(arch × backend)` → a versioned
//!   [`crate::fleet::Slot`] of frozen [`crate::backend::PreparedNet`] trait
//!   objects, all constants derived at load time (weights resolved from
//!   `repro qft` exports, the cached FP teacher, or he-init smoke weights).
//!   One engine serves any [`crate::backend::BackendKind`] — `fp`,
//!   fake-quant, integer, `lw-i8` — and can install / promote / A/B /
//!   rollback versions while serving.
//! * [`batcher`] — [`Batcher`]: bounded request queue with dynamic
//!   micro-batch assembly under a max-batch / max-wait policy and
//!   blocking backpressure.  The policy is *pool-aware*
//!   ([`BatchPolicy::effective_wait`]): workers shrink the batch hold
//!   time while the shared [`crate::par`] kernel pool is idle and grow
//!   it when the pool is saturated, trading latency against throughput
//!   from live load instead of a fixed knob.
//! * [`engine`] — [`Engine`]: std-thread worker pool; each worker owns a
//!   [`crate::backend::Scratch`] so steady-state execution does not
//!   allocate, and submits its conv/GEMM work to the process-wide
//!   [`crate::par`] pool (shared with the integer eval path, so callers
//!   cooperate instead of oversubscribing); [`run_closed_loop`] is the
//!   load-generator used by `repro bench-serve` and the `serve_throughput`
//!   bench.
//! * [`stats`] — [`ServeStats`]/[`ServeReport`]: p50/p95/p99 latency,
//!   throughput, batch-size and queue-depth histograms, kernel-pool width.
//!
//! Everything is std-only (threads + channels + condvars): the image's
//! cargo cache has no async runtime, and a forward pass is milliseconds —
//! thread-per-worker with a locked queue is the right tool.

pub mod batcher;
pub mod engine;
pub mod stats;

pub use crate::fleet::{Fleet, FleetOptions, Slot, Version};
pub use batcher::{BatchPolicy, Batcher, InferReply, InferRequest, InferResult, Reject};
pub use engine::{run_closed_loop, Client, DrainReport, Engine, ServeConfig};
pub use stats::{Pow2Histogram, ServeReport, ServeStats};

use crate::nn::arch::{ArchSpec, OpSpec, ParamSpec};
use crate::quant::deploy::DeployedModel;

/// A small self-contained conv / residual / depthwise arch over the same IR
/// as the manifest archs.  It lets the whole serving stack (and its tests
/// and benches) run without AOT artifacts: [`Fleet::load`] falls back to it
/// when no manifest is present, and tests build trainables for it with the
/// regular [`crate::coordinator::state`] machinery.
pub fn synthetic_arch() -> ArchSpec {
    use std::collections::HashMap;

    let conv = |name: &str, inp: usize, out: usize, stride: usize, cin: usize, cout: usize,
                groups: usize, act: &str| OpSpec {
        op: "conv".to_string(),
        name: name.to_string(),
        out,
        inp,
        k: 3,
        stride,
        cin,
        cout,
        groups,
        act: act.to_string(),
        a: 0,
        b: 0,
    };
    let ops = vec![
        conv("conv0", 0, 1, 1, 3, 8, 1, "relu"),
        conv("conv1", 1, 2, 2, 8, 8, 1, "relu6"),
        conv("dw", 2, 3, 1, 8, 8, 8, "relu"),
        OpSpec {
            op: "add".to_string(),
            name: "add0".to_string(),
            out: 4,
            inp: 0,
            k: 0,
            stride: 1,
            cin: 0,
            cout: 0,
            groups: 1,
            act: "relu".to_string(),
            a: 2,
            b: 3,
        },
        OpSpec {
            op: "gap".to_string(),
            name: "gap".to_string(),
            out: 5,
            inp: 4,
            k: 0,
            stride: 1,
            cin: 0,
            cout: 0,
            groups: 1,
            act: "none".to_string(),
            a: 0,
            b: 0,
        },
        OpSpec {
            op: "fc".to_string(),
            name: "fc".to_string(),
            out: 6,
            inp: 5,
            k: 0,
            stride: 1,
            cin: 8,
            cout: crate::data::NUM_CLASSES,
            groups: 1,
            act: "none".to_string(),
            a: 0,
            b: 0,
        },
    ];

    let spec = |name: &str, shape: &[usize]| ParamSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
    };
    let nc = crate::data::NUM_CLASSES;
    let params = vec![
        spec("w:conv0", &[3, 3, 3, 8]),
        spec("b:conv0", &[8]),
        spec("w:conv1", &[3, 3, 8, 8]),
        spec("b:conv1", &[8]),
        spec("w:dw", &[3, 3, 1, 8]),
        spec("b:dw", &[8]),
        spec("w:fc", &[8, nc]),
        spec("b:fc", &[nc]),
    ];

    let mut lw = params.clone();
    for (v, c) in [(0usize, 3usize), (1, 8), (2, 8), (3, 8), (4, 8)] {
        lw.push(spec(&format!("sv:{v}"), &[c]));
    }
    for op in ["conv0", "conv1", "dw"] {
        lw.push(spec(&format!("f:{op}"), &[1]));
    }
    let mut dch = params.clone();
    dch.push(spec("swl:conv0", &[3]));
    dch.push(spec("swr:conv0", &[8]));
    dch.push(spec("swl:conv1", &[8]));
    dch.push(spec("swr:conv1", &[8]));
    dch.push(spec("swr:dw", &[8]));

    let mut trainables = HashMap::new();
    trainables.insert("lw".to_string(), lw);
    trainables.insert("dch".to_string(), dch);

    let mut value_channels = HashMap::new();
    let mut value_signed = HashMap::new();
    for (v, c) in [(0usize, 3usize), (1, 8), (2, 8), (3, 8), (4, 8), (5, 8), (6, nc)] {
        value_channels.insert(v.to_string(), c);
        value_signed.insert(v.to_string(), false);
    }

    ArchSpec {
        name: "synthetic".to_string(),
        input_hw: crate::data::HW,
        input_ch: crate::data::CH,
        num_classes: nc,
        batch: 8,
        nvals: 7,
        backbone_value: 4,
        feat_channels: 8,
        ops,
        params,
        trainables,
        quantized_values: vec![0, 1, 2, 3, 4],
        value_channels,
        value_signed,
        artifacts: HashMap::new(),
    }
}

/// Seeded he-init weights for [`synthetic_arch`] pushed through the standard
/// offline PTQ init — the shared fixture behind [`synthetic_model`] and the
/// hermetic serving/parity tests.
pub fn synthetic_trainables(
    mode: crate::quant::deploy::Mode,
    seed: u64,
) -> (ArchSpec, crate::nn::ParamMap) {
    use crate::coordinator::state;
    let arch = synthetic_arch();
    let params = state::he_init_params(&arch, seed);
    let ds = crate::data::Dataset::new(seed);
    let batches: Vec<_> = (0..2)
        .map(|i| ds.batch(crate::data::Split::Calib, i * arch.batch as u64, arch.batch).0)
        .collect();
    let absmax = state::absmax_from_rust_forward(&arch, &params, &batches);
    let winit = match mode {
        crate::quant::deploy::Mode::Lw => state::WeightScaleInit::Uniform,
        crate::quant::deploy::Mode::Dch => state::WeightScaleInit::DoublyChannelwise,
    };
    let tm = state::init_trainables(&arch, &params, &absmax, mode, winit, None);
    (arch, tm)
}

/// Build the synthetic arch's [`DeployedModel`] directly from seeded he-init
/// weights — the one-call fixture used by tests and examples.
pub fn synthetic_model(mode: crate::quant::deploy::Mode, seed: u64) -> DeployedModel {
    let (arch, tm) = synthetic_trainables(mode, seed);
    DeployedModel::prepare(&arch, &tm, mode)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_arch_fp_forward_runs() {
        let arch = synthetic_arch();
        let params = crate::coordinator::state::he_init_params(&arch, 0);
        let x = crate::tensor::Tensor::full(&[2, arch.input_hw, arch.input_hw, arch.input_ch], 0.5);
        let f = crate::nn::fp_forward(&arch, &params, &x);
        assert_eq!(f.logits.shape, vec![2, arch.num_classes]);
        assert_eq!(f.feat.shape, vec![2, 8, 8, 8]);
    }

    #[test]
    fn synthetic_model_prepares_both_modes() {
        for mode in [crate::quant::deploy::Mode::Lw, crate::quant::deploy::Mode::Dch] {
            let m = synthetic_model(mode, 3);
            let x = crate::tensor::Tensor::full(&[1, 16, 16, 3], 0.3);
            let logits =
                m.forward_batch(&x, &mut crate::quant::deploy::DeployScratch::new());
            assert_eq!(logits.shape, vec![1, crate::data::NUM_CLASSES]);
            assert!(logits.data.iter().all(|v| v.is_finite()));
        }
    }
}
