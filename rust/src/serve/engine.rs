//! The serving engine: a std-thread worker pool executing dynamic
//! micro-batches through any frozen [`crate::backend::PreparedNet`].
//!
//! Each worker owns one [`crate::backend::Scratch`] plus an input staging
//! buffer for its whole lifetime, so a warm worker executes
//! [`crate::backend::PreparedNet::forward_batch`] with zero hot-path
//! allocation beyond the per-reply logits rows on the deployment grids
//! (`lw` / `dch` / `lw-i8`; the `fp` / fake-quant reference grids allocate
//! per call — see [`crate::backend::Scratch`]) — and because fleet slots
//! store trait objects, ONE engine serves fp, fake-quant, integer and
//! `lw-i8` models side by side.  All workers submit their parallel
//! conv/GEMM scopes to the ONE process-wide [`crate::par::global`] pool
//! (sized by `--threads`), so a large micro-batch fans out across the
//! machine while concurrent workers cooperate on the same worker set
//! instead of oversubscribing it — and because every backend's parallel
//! path is bit-identical to its serial twin, replies do not depend on the
//! pool width.
//!
//! Versioning: workers route each micro-batch through
//! [`crate::fleet::Slot::select`] — one atomic load when a slot serves a
//! single version — and clone the routed `Arc<Version>` *once per batch*,
//! so a concurrent promote/rollback never touches a batch already in
//! flight: it finishes on the version it started on, and the demoted
//! version is retired when its in-flight references drain.  Replies are
//! bit-identical across swaps to bit-identical versions at any worker
//! count (the fleet suite pins this).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::Scratch;
use crate::fleet::Fleet;
use crate::obs;
use crate::serve::batcher::{BatchPolicy, Batcher, InferReply, InferRequest, InferResult, Reject};
use crate::serve::stats::{ServeReport, ServeStats};
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    /// Pool-aware batching ([`BatchPolicy::effective_wait`]): workers scale
    /// the batch hold time by the live [`crate::par::global`] pool load —
    /// idle pool dispatches fast (latency), saturated pool holds for full
    /// batches (throughput).  Replies are bit-identical either way; off
    /// (`--no-adaptive`) pins the hold at `max_wait`.
    pub adaptive: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 256,
            adaptive: true,
        }
    }
}

/// Running worker pool over a shared [`Fleet`].
pub struct Engine {
    fleet: Arc<Fleet>,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    next_id: Arc<AtomicU64>,
    workers: Vec<JoinHandle<u64>>,
}

impl Engine {
    /// Spawn the worker pool (at least one worker).
    pub fn start(fleet: Arc<Fleet>, cfg: &ServeConfig) -> Engine {
        assert!(!fleet.is_empty(), "engine started with an empty fleet");
        let batcher = Arc::new(Batcher::new(BatchPolicy {
            max_batch: cfg.max_batch.max(1),
            max_wait: cfg.max_wait,
            queue_cap: cfg.queue_cap.max(1),
        }));
        let stats = Arc::new(ServeStats::with_pool(crate::par::global().threads()));
        let adaptive = cfg.adaptive;
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let fl = fleet.clone();
                let bat = batcher.clone();
                let st = stats.clone();
                std::thread::spawn(move || worker_loop(&fl, &bat, &st, adaptive))
            })
            .collect();
        Engine {
            fleet,
            batcher,
            stats,
            next_id: Arc::new(AtomicU64::new(0)),
            workers,
        }
    }

    /// A cheap, cloneable submission handle (one per client thread).
    pub fn client(&self) -> Client {
        Client {
            fleet: self.fleet.clone(),
            batcher: self.batcher.clone(),
            stats: self.stats.clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// The fleet this engine serves — lifecycle verbs (install / promote /
    /// A/B / rollback) go through it while the engine is live.
    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// Live stats snapshot.
    pub fn stats(&self) -> ServeReport {
        self.stats.report()
    }

    /// Close the queue, drain, join all workers, and return the final report.
    pub fn shutdown(self) -> ServeReport {
        self.batcher.close();
        for h in self.workers {
            let _ = h.join();
        }
        self.stats.report()
    }

    /// Graceful drain with a deadline: stop intake immediately, give
    /// in-flight and queued work up to `timeout` to finish, then purge
    /// whatever is still queued and answer each dropped request with a
    /// typed [`Reject::Shutdown`] before joining the workers.
    ///
    /// Unlike [`Self::shutdown`] (which waits for workers to drain the
    /// queue naturally, however long that takes), this bounds shutdown
    /// time and *reports* what it cost: the returned
    /// [`DrainReport::dropped`] is the number of requests shed at the
    /// deadline, and `timed_out` says whether the deadline fired at all.
    pub fn drain(self, timeout: Duration) -> DrainReport {
        self.batcher.close();
        let deadline = Instant::now() + timeout;
        let mut timed_out = true;
        while Instant::now() < deadline {
            if self.batcher.idle() {
                timed_out = false;
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        // deadline fired (or everything already finished): anything still
        // queued is answered, not silently dropped
        let purged = self.batcher.purge();
        let dropped = purged.len();
        for req in purged {
            let _ = req.resp.send(Err(Reject::Shutdown));
        }
        // queue is closed and empty, so workers fall out of next_batch
        for h in self.workers {
            let _ = h.join();
        }
        DrainReport {
            report: self.stats.report(),
            dropped,
            timed_out: timed_out && dropped > 0,
        }
    }
}

/// What a bounded [`Engine::drain`] cost: the final serving report, plus
/// how many queued requests had to be shed at the deadline (each one was
/// answered with [`Reject::Shutdown`], never silently dropped).
#[derive(Clone, Debug)]
pub struct DrainReport {
    pub report: ServeReport,
    /// Requests still queued at the deadline, answered with
    /// [`Reject::Shutdown`].
    pub dropped: usize,
    /// True when the deadline fired with work still queued.
    pub timed_out: bool,
}

/// Submission handle: closed-loop `infer` plus the raw async pieces.
#[derive(Clone)]
pub struct Client {
    fleet: Arc<Fleet>,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    next_id: Arc<AtomicU64>,
}

impl Client {
    /// Submit one image and block for its reply (30 s default deadline).
    pub fn infer(&self, model: usize, image: Vec<f32>) -> Result<InferReply> {
        self.infer_timeout(model, image, Duration::from_secs(30))
    }

    /// Submit one image; error if the engine is shut down or the reply does
    /// not arrive within `timeout`.  Slot and payload size are validated
    /// here, at admission — a malformed request should never reach a worker
    /// (workers answer anything that slips past with a typed
    /// [`Reject`], which surfaces here as an error too).
    pub fn infer_timeout(
        &self,
        model: usize,
        image: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferReply> {
        let Some(slot) = self.fleet.slot(model) else {
            return Err(anyhow!("unknown model slot {model} (fleet has {})", self.fleet.len()));
        };
        let want = slot.image_len();
        if image.len() != want {
            return Err(anyhow!(
                "payload is {} floats, model {} expects {want}",
                image.len(),
                slot.key
            ));
        }
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model,
            image,
            trace: obs::Trace::start(),
            resp: tx,
        };
        let depth = self
            .batcher
            .submit(req)
            .map_err(|_| anyhow!("serve engine is shut down"))?;
        self.stats.record_enqueue(depth);
        Ok(rx
            .recv_timeout(timeout)
            .map_err(|e| anyhow!("no reply within {timeout:?}: {e}"))??)
    }

    /// Non-blocking submission with full admission validation — the wire
    /// front-end's entry point.  Where [`Self::infer`] blocks on a full
    /// queue (backpressure), this sheds: a full queue comes back as
    /// [`Reject::Busy`] and a closed engine as [`Reject::Shutdown`], both
    /// of which [`crate::net`] turns into typed wire frames.  On success
    /// the reply arrives on the returned channel.
    pub fn try_submit(
        &self,
        model: usize,
        image: Vec<f32>,
    ) -> Result<mpsc::Receiver<InferResult>, Reject> {
        let Some(slot) = self.fleet.slot(model) else {
            return Err(Reject::UnknownSlot { slot: model, slots: self.fleet.len() });
        };
        let want = slot.image_len();
        if image.len() != want {
            return Err(Reject::PayloadSize { slot: model, got: image.len(), want });
        }
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model,
            image,
            trace: obs::Trace::start(),
            resp: tx,
        };
        match self.batcher.try_submit(req) {
            Ok(depth) => {
                self.stats.record_enqueue(depth);
                Ok(rx)
            }
            Err((_, reject)) => Err(reject),
        }
    }

    /// Raw submission with NO admission validation — what a non-`Client`
    /// producer (or a buggy one) amounts to.  Workers answer malformed
    /// requests with a typed [`Reject`] on the returned channel instead of
    /// dropping them or dying; the fleet suite pins that contract here.
    pub fn submit_raw(&self, model: usize, image: Vec<f32>) -> Result<mpsc::Receiver<InferResult>> {
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model,
            image,
            trace: obs::Trace::start(),
            resp: tx,
        };
        let depth = self
            .batcher
            .submit(req)
            .map_err(|_| anyhow!("serve engine is shut down"))?;
        self.stats.record_enqueue(depth);
        Ok(rx)
    }
}

/// Worker body: assemble → route → stack → batched backend forward → reply.
/// Returns the number of batches it executed (join-side diagnostic).
///
/// Stage stamps: `formed` (batch in hand) → `fwd_start` (tensor staged) →
/// `fwd_end` (logits ready; this is the completion stamp end-to-end
/// latency uses, taken *before* any reply is sent) → `replied` (last reply
/// handed to its channel).  [`obs::StageMetrics::record_span`] splits them
/// into per-version queue-wait / batch-form / compute / reply histograms,
/// and [`ServeStats::record_batch`] records completion and reply-inclusive
/// end-to-end latency side by side.
fn worker_loop(fleet: &Fleet, batcher: &Batcher, stats: &ServeStats, adaptive: bool) -> u64 {
    let pool = crate::par::global();
    let mut scratch = Scratch::new();
    let mut staging: Vec<f32> = Vec::new();
    let mut latencies: Vec<Duration> = Vec::new();
    let mut reply_lats: Vec<Duration> = Vec::new();
    let mut enqueues: Vec<Instant> = Vec::new();
    let mut executed = 0u64;
    loop {
        // pool-aware hold: the batcher samples the shared kernel pool's
        // live load once the head request is in hand (not before blocking
        // for traffic, which could make the sample arbitrarily stale)
        let next = if adaptive {
            batcher.next_batch_pool_aware(pool)
        } else {
            batcher.next_batch()
        };
        let Some(mut batch) = next else { break };
        let formed = Instant::now();
        // invalid slot (possible only via a raw Batcher submit): answer the
        // whole batch with a typed rejection instead of dropping it — and
        // never abort the worker
        let slot_id = batch.first().map(|r| r.model).unwrap_or(0);
        let Some(slot) = fleet.slot(slot_id) else {
            let reject = Reject::UnknownSlot { slot: slot_id, slots: fleet.len() };
            for req in batch {
                let _ = req.resp.send(Err(reject.clone()));
            }
            batcher.batch_done();
            continue;
        };
        // payload checks come BEFORE routing: `select` charges the chosen
        // arm's request counter, so only requests that will execute count
        let px = slot.image_len();
        batch.retain(|r| {
            if r.image.len() == px {
                return true;
            }
            let _ = r.resp.send(Err(Reject::PayloadSize {
                slot: slot_id,
                got: r.image.len(),
                want: px,
            }));
            false
        });
        if batch.is_empty() {
            batcher.batch_done();
            continue;
        }
        let n = batch.len();
        // route the whole micro-batch to one version and hold the Arc until
        // every reply is out: a promote/rollback racing with us cannot
        // retire this version until the clone drops
        let version = slot.select(n);
        let model = &version.model;
        staging.clear();
        for r in &batch {
            staging.extend_from_slice(&r.image);
        }
        let x = Tensor::new(
            vec![n, model.input_hw(), model.input_hw(), model.input_ch()],
            std::mem::take(&mut staging),
        );
        let fwd_start = Instant::now();
        let logits = model.forward_batch(&x, &mut scratch, pool);
        staging = x.data; // reclaim the staging buffer
        let done = Instant::now();
        let nc = model.num_classes();
        let top1s = logits.argmax_lastdim();
        latencies.clear();
        reply_lats.clear();
        enqueues.clear();
        for (i, req) in batch.into_iter().enumerate() {
            let row = logits.data[i * nc..(i + 1) * nc].to_vec();
            let latency = done.saturating_duration_since(req.trace.enqueued);
            latencies.push(latency);
            enqueues.push(req.trace.enqueued);
            // a disappeared client (dropped receiver) is not a worker error,
            // but the version's error counter records it
            if req
                .resp
                .send(Ok(InferReply {
                    id: req.id,
                    top1: top1s[i],
                    logits: row,
                    latency,
                    batch_size: n,
                }))
                .is_err()
            {
                version.errors.add(1);
            }
            // stamped after the send, so reply-channel time is measured
            // instead of invisible
            reply_lats.push(Instant::now().saturating_duration_since(enqueues[i]));
        }
        let replied = Instant::now();
        stats.record_batch(n, &latencies, &reply_lats);
        version.batches.add(1);
        version.stage.record_span(
            &obs::BatchSpan { formed, fwd_start, fwd_end: done, replied },
            enqueues.iter().copied(),
        );
        batcher.batch_done();
        executed += 1;
    }
    executed
}

/// Closed-loop load generator: `clients` threads each push
/// `requests_per_client` back-to-back requests at fleet slot `slot`, then
/// the engine is drained and its report returned.  This is the
/// `repro bench-serve` / `cargo bench serve_throughput` core.
pub fn run_closed_loop(
    fleet: &Arc<Fleet>,
    cfg: &ServeConfig,
    clients: usize,
    requests_per_client: usize,
    slot: usize,
) -> ServeReport {
    let engine = Engine::start(fleet.clone(), cfg);
    std::thread::scope(|s| {
        for c in 0..clients {
            let client = engine.client();
            s.spawn(move || {
                let ds = crate::data::Dataset::new(c as u64 + 1);
                for i in 0..requests_per_client {
                    let (img, _) = ds.sample(crate::data::Split::Val, i as u64);
                    if client.infer(slot, img).is_err() {
                        break;
                    }
                }
            });
        }
    });
    engine.shutdown()
}
