//! Lock-free metric primitives: [`Counter`], [`Gauge`], and the sharded
//! atomic [`LogHistogram`].
//!
//! Everything here is built from relaxed atomics only — recording on the
//! serving hot path is a handful of uncontended `fetch_add`s, never a lock.
//! The histogram generalizes the power-of-two
//! [`crate::serve::Pow2Histogram`] two ways:
//!
//! * **sub-bucket resolution** — each power-of-two octave splits into
//!   [`SUB`] log-linear sub-buckets ([`SUB_BITS`] = 4), bounding the
//!   relative quantization error of any interpolated quantile at
//!   `2^-SUB_BITS` = 6.25% (HDR-histogram layout), which is what makes
//!   p99/p99.9 reported from buckets trustworthy;
//! * **exact small samples** — the first [`EXACT_N`] raw values are kept
//!   verbatim, so quantiles over few samples are *exact* (nearest-rank over
//!   the sorted values) instead of bucket-biased.
//!
//! Counts are sharded across [`NSHARDS`] cache-line-separated shard arrays
//! (each thread hashes to a shard via a process-wide thread counter), so
//! concurrent recorders do not ping-pong the same cache lines; a snapshot
//! sums the shards.

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};

/// Monotone event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Zero the counter (bench / test plumbing, not a hot-path operation).
    pub fn clear(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins instantaneous value (queue depth, pool load).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Sub-bucket resolution: each power-of-two octave splits into `2^SUB_BITS`
/// log-linear sub-buckets.
pub const SUB_BITS: u32 = 4;
/// Sub-buckets per octave.
pub const SUB: usize = 1 << SUB_BITS;
/// Total bucket count covering the full `u64` range.
const NBUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB;
/// Raw values kept verbatim for exact small-sample quantiles.
pub const EXACT_N: usize = 64;
/// Count shards (power of two); threads hash to a shard by a process-wide
/// registration counter, so the common case is one thread per shard.
const NSHARDS: usize = 8;

/// Bucket index of `v` (log-linear / HDR layout): exact below [`SUB`], then
/// [`SUB`] sub-buckets per octave.
fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as u64;
        let shift = msb - SUB_BITS as u64;
        ((shift + 1) as usize) * SUB + ((v >> shift) as usize - SUB)
    }
}

/// Inclusive `[lo, hi]` value range of bucket `i` (inverse of [`bucket_of`]).
fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB {
        (i as u64, i as u64)
    } else {
        let shift = (i / SUB - 1) as u32;
        let lo = ((SUB + i % SUB) as u64) << shift;
        (lo, lo + (1u64 << shift) - 1)
    }
}

/// One shard of bucket counts plus its own count/sum accumulators.
struct Shard {
    counts: Box<[AtomicU64]>,
    sum: AtomicU64,
}

impl Default for Shard {
    fn default() -> Self {
        let counts = (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect();
        Shard { counts, sum: AtomicU64::new(0) }
    }
}

/// Process-wide thread registration counter backing the per-thread shard
/// choice (round-robin at thread birth — stable for the thread's lifetime).
static SHARD_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static MY_SHARD: usize = SHARD_SEQ.fetch_add(1, Ordering::Relaxed) & (NSHARDS - 1);
}

/// Sharded atomic log-linear histogram over `u64` values (latencies in µs
/// or ns — the metric name declares the unit).  See the module docs for the
/// layout; [`Self::snapshot`] produces the queryable [`HistSnapshot`].
pub struct LogHistogram {
    shards: Box<[Shard]>,
    /// First [`EXACT_N`] raw values, stored as `v + 1` so a racing snapshot
    /// reads an unwritten slot as "empty" instead of as a spurious zero.
    exact: Box<[AtomicU64]>,
    exact_len: AtomicUsize,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            shards: (0..NSHARDS).map(|_| Shard::default()).collect(),
            exact: (0..EXACT_N).map(|_| AtomicU64::new(0)).collect(),
            exact_len: AtomicUsize::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value — a few relaxed atomic RMWs, no locks, no
    /// allocation; safe from any number of threads concurrently.
    pub fn record(&self, v: u64) {
        let shard = &self.shards[MY_SHARD.with(|s| *s)];
        shard.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        shard.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        if self.exact_len.load(Ordering::Relaxed) < EXACT_N {
            let i = self.exact_len.fetch_add(1, Ordering::Relaxed);
            if i < EXACT_N {
                self.exact[i].store(v + 1, Ordering::Relaxed);
            }
        }
    }

    /// Zero every cell in place (bench plumbing between runs — racing
    /// recorders will not corrupt anything, but counts taken across a clear
    /// are obviously mixed).
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            for c in shard.counts.iter() {
                c.store(0, Ordering::Relaxed);
            }
            shard.sum.store(0, Ordering::Relaxed);
        }
        for e in self.exact.iter() {
            e.store(0, Ordering::Relaxed);
        }
        self.exact_len.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Point-in-time copy: shard counts summed per bucket, exact values
    /// collected and sorted.  The snapshot's `count` is the bucket-sum, so
    /// quantile ranks are always internally consistent even if recorders
    /// are racing the snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets: Vec<(u64, u64, u64)> = Vec::new();
        let mut count = 0u64;
        let mut sum = 0u64;
        for i in 0..NBUCKETS {
            let c: u64 =
                self.shards.iter().map(|s| s.counts[i].load(Ordering::Relaxed)).sum();
            if c > 0 {
                let (lo, hi) = bucket_bounds(i);
                buckets.push((lo, hi, c));
                count += c;
            }
        }
        for s in self.shards.iter() {
            sum += s.sum.load(Ordering::Relaxed);
        }
        let mut exact: Vec<u64> = self
            .exact
            .iter()
            .map(|e| e.load(Ordering::Relaxed))
            .filter(|&v| v > 0)
            .map(|v| v - 1)
            .collect();
        exact.sort_unstable();
        let min = self.min.load(Ordering::Relaxed);
        HistSnapshot {
            count,
            sum,
            min: if count == 0 { 0 } else { min },
            max: self.max.load(Ordering::Relaxed),
            buckets,
            exact,
        }
    }

    /// [`HistSnapshot::stats`] in one call.
    pub fn stats(&self) -> HistStats {
        self.snapshot().stats()
    }
}

/// Point-in-time histogram contents, queryable for quantiles.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    /// `(lo, hi, count)` for non-empty buckets, ascending.
    pub buckets: Vec<(u64, u64, u64)>,
    /// Sorted raw values — complete iff `exact.len() as u64 == count`.
    pub exact: Vec<u64>,
}

impl HistSnapshot {
    /// Quantile `q ∈ [0, 1]`.  Exact (nearest-rank over the raw values)
    /// while every sample is still in the exact window; otherwise
    /// rank-interpolated *within* the owning bucket, with the bucket range
    /// clamped to the observed `[min, max]` so tail quantiles never report
    /// a bucket bound no sample reached.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        if self.exact.len() as u64 == self.count {
            return self.exact[rank as usize - 1];
        }
        let mut cum = 0u64;
        for &(lo, hi, c) in &self.buckets {
            if cum + c >= rank {
                let lo = lo.max(self.min);
                let hi = hi.min(self.max).max(lo);
                if c <= 1 || hi == lo {
                    return lo;
                }
                let frac = (rank - cum - 1) as f64 / (c - 1) as f64;
                return lo + (frac * (hi - lo) as f64).round() as u64;
            }
            cum += c;
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The fixed stat bundle every exposition format reports.
    pub fn stats(&self) -> HistStats {
        HistStats {
            count: self.count,
            sum: self.sum,
            max: self.max,
            mean: self.mean(),
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
        }
    }
}

/// Rendered histogram stats — what snapshots serialize (quantiles are
/// computed at snapshot time; buckets are not shipped).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistStats {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub p999: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_contiguous_and_invertible() {
        // every bucket's bounds map back to its own index, and consecutive
        // buckets tile the value space with no gaps
        let mut expect_lo = 0u64;
        for i in 0..NBUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expect_lo, "bucket {i}");
            assert_eq!(bucket_of(lo), i);
            assert_eq!(bucket_of(hi), i);
            expect_lo = hi.wrapping_add(1);
        }
        assert_eq!(expect_lo, 0, "last bucket must end at u64::MAX");
    }

    #[test]
    fn sub_bucket_relative_error_is_bounded() {
        for v in [17u64, 100, 999, 12_345, 1 << 30, u64::MAX / 3] {
            let (lo, hi) = bucket_bounds(bucket_of(v));
            assert!(lo <= v && v <= hi);
            // bucket width / lo <= 2^-SUB_BITS
            assert!(((hi - lo) as f64) <= lo as f64 / SUB as f64 + 1.0, "v={v} lo={lo} hi={hi}");
        }
    }

    #[test]
    fn exact_window_gives_exact_quantiles() {
        let h = LogHistogram::new();
        let vals = [900u64, 5, 42, 7, 7, 123, 0, 31];
        for &v in &vals {
            h.record(v);
        }
        let snap = h.snapshot();
        let mut sorted = vals.to_vec();
        sorted.sort_unstable();
        for (q, rank) in [(0.5, 4usize), (0.99, 8), (0.001, 1)] {
            assert_eq!(snap.quantile(q), sorted[rank - 1], "q={q}");
        }
        assert_eq!(snap.count, vals.len() as u64);
        assert_eq!(snap.sum, vals.iter().sum::<u64>());
        assert_eq!(snap.max, 900);
        assert_eq!(snap.min, 0);
    }

    #[test]
    fn interpolated_quantiles_track_sorted_ground_truth() {
        // 1..=1000 uniform: far past the exact window, so quantiles come
        // from bucket interpolation — pin them against the sorted vector
        let h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let snap = h.snapshot();
        let sorted: Vec<u64> = (1..=1000).collect();
        for q in [0.50, 0.95, 0.99, 0.999] {
            let rank = ((q * 1000.0).ceil() as usize).clamp(1, 1000);
            let truth = sorted[rank - 1] as f64;
            let got = snap.quantile(q) as f64;
            let rel = (got - truth).abs() / truth;
            assert!(rel <= 1.0 / SUB as f64, "q={q}: got {got}, truth {truth}, rel {rel}");
        }
        assert_eq!(snap.count, 1000);
    }

    #[test]
    fn clear_resets_everything() {
        let h = LogHistogram::new();
        for v in 0..200u64 {
            h.record(v);
        }
        h.clear();
        let snap = h.snapshot();
        assert_eq!(snap.count, 0);
        assert_eq!(snap.sum, 0);
        assert_eq!(snap.quantile(0.5), 0);
        h.record(9);
        assert_eq!(h.snapshot().quantile(0.5), 9);
    }
}
