//! Per-layer kernel timing: phase-split wall-time accumulators threaded
//! through every backend's forward path.
//!
//! A [`NetObs`] mirrors one prepared model (one [`LayerObs`] per arch op);
//! it lives behind an `Arc` inside the prepared net and in the global
//! [`crate::obs`] registry, so serving workers accumulate into the same
//! cells the exposition layer reads.  Accumulators are relaxed atomics —
//! parallel conv chunks add their own im2col/GEMM nanos concurrently, which
//! means phase times are *CPU time summed across pool threads*, not
//! elapsed wall time (a 4-way-parallel GEMM contributes ~4× its wall time).
//! `total_ns` is stamped once per op at the top level, so it *is* wall
//! time; the two views together show both cost and parallel efficiency.
//!
//! Sampling: forwards are timed 1-in-N ([`crate::obs::sample_every`],
//! default 16) so `Instant::now()` calls stay out of the hot path's noise
//! floor.  The per-scratch [`LayerTimer`] countdown decides, once per
//! forward, whether this pass is sampled; unsampled passes run the exact
//! non-obs code (an `Option` that is `None`).

use std::time::Instant;

use super::metrics::Counter;
use std::sync::atomic::{AtomicU64, Ordering};

/// Kernel phase of a conv/fc op.  `Pack` is weight/covector preparation
/// (per-call repack in the fp/fake-quant grids), `Im2col` the patch
/// gather, `Gemm` the matmul itself, `Recode` the post-GEMM elementwise
/// epilogue (bias/act/requant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Pack = 0,
    Im2col = 1,
    Gemm = 2,
    Recode = 3,
}

/// Exposition names, indexed by `Phase as usize`.
pub const PHASE_NAMES: [&str; 4] = ["pack", "im2col", "gemm", "recode"];

/// Phase-split time accumulators for one op (relaxed atomics; safe to add
/// into from any number of pool threads).
pub struct LayerObs {
    /// Op name from the arch spec (`conv0`, `fc`, ...).
    pub name: String,
    phase_ns: [AtomicU64; 4],
    total_ns: AtomicU64,
}

impl LayerObs {
    pub fn new(name: &str) -> Self {
        LayerObs {
            name: name.to_string(),
            phase_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            total_ns: AtomicU64::new(0),
        }
    }

    pub fn add_phase_ns(&self, phase: Phase, ns: u64) {
        self.phase_ns[phase as usize].fetch_add(ns, Ordering::Relaxed);
    }

    pub fn add_total_ns(&self, ns: u64) {
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn phase_ns(&self, phase: Phase) -> u64 {
        self.phase_ns[phase as usize].load(Ordering::Relaxed)
    }

    pub fn total_ns(&self) -> u64 {
        self.total_ns.load(Ordering::Relaxed)
    }

    pub fn clear(&self) {
        for p in &self.phase_ns {
            p.store(0, Ordering::Relaxed);
        }
        self.total_ns.store(0, Ordering::Relaxed);
    }
}

/// Per-model layer timing: one [`LayerObs`] per arch op, plus how many
/// forwards (and images) were actually sampled — divide by `passes` to get
/// per-pass averages.
pub struct NetObs {
    /// `"arch/backend-key"`, same wire key the registry uses.
    pub key: String,
    pub passes: Counter,
    pub images: Counter,
    pub layers: Vec<LayerObs>,
}

impl NetObs {
    pub fn new(key: &str, layer_names: &[String]) -> Self {
        NetObs {
            key: key.to_string(),
            passes: Counter::new(),
            images: Counter::new(),
            layers: layer_names.iter().map(|n| LayerObs::new(n)).collect(),
        }
    }

    /// Accumulator for op `i` (index into the arch's op list).
    pub fn layer(&self, i: usize) -> Option<&LayerObs> {
        self.layers.get(i)
    }

    pub fn clear(&self) {
        self.passes.clear();
        self.images.clear();
        for l in &self.layers {
            l.clear();
        }
    }
}

/// Per-scratch sampling countdown deciding, once per forward pass, whether
/// this pass gets timed.  Lives in [`crate::backend::Scratch`] so each
/// worker samples independently of the others; the first pass on a fresh
/// scratch is always sampled (countdown starts at zero).
#[derive(Default)]
pub struct LayerTimer {
    countdown: u32,
}

impl LayerTimer {
    /// `true` ⇒ time this forward.  Consults the global enable flag and
    /// sampling period on every call, so `--obs-sample` / `--no-obs` take
    /// effect without rebuilding scratches.
    pub fn tick(&mut self) -> bool {
        if !super::enabled() {
            return false;
        }
        self.tick_every(super::sample_every())
    }

    /// Countdown step for period `n` (`0` = never) — the global-free core
    /// of [`Self::tick`].
    fn tick_every(&mut self, n: u32) -> bool {
        if n == 0 {
            return false;
        }
        if self.countdown == 0 {
            self.countdown = n - 1;
            true
        } else {
            self.countdown -= 1;
            false
        }
    }
}

/// Start a phase clock — `None` (and therefore zero work) when not sampling.
#[inline]
pub fn start(obs: Option<&LayerObs>) -> Option<Instant> {
    obs.map(|_| Instant::now())
}

/// Close the current phase and start the next: charges `t0 → now` to
/// `phase` and returns the new clock.  No-op when not sampling.
#[inline]
pub fn lap(obs: Option<&LayerObs>, phase: Phase, t0: Option<Instant>) -> Option<Instant> {
    match (obs, t0) {
        (Some(o), Some(t)) => {
            let now = Instant::now();
            o.add_phase_ns(phase, now.saturating_duration_since(t).as_nanos() as u64);
            Some(now)
        }
        _ => None,
    }
}

/// Charge `t0 → now` to the op's wall-time total.  No-op when not sampling.
#[inline]
pub fn finish(obs: Option<&LayerObs>, t0: Option<Instant>) {
    if let (Some(o), Some(t)) = (obs, t0) {
        o.add_total_ns(t.elapsed().as_nanos() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_independently() {
        let l = LayerObs::new("conv0");
        l.add_phase_ns(Phase::Im2col, 10);
        l.add_phase_ns(Phase::Gemm, 20);
        l.add_phase_ns(Phase::Gemm, 5);
        l.add_total_ns(40);
        assert_eq!(l.phase_ns(Phase::Im2col), 10);
        assert_eq!(l.phase_ns(Phase::Gemm), 25);
        assert_eq!(l.phase_ns(Phase::Pack), 0);
        assert_eq!(l.total_ns(), 40);
        l.clear();
        assert_eq!(l.phase_ns(Phase::Gemm), 0);
        assert_eq!(l.total_ns(), 0);
    }

    #[test]
    fn lap_chains_and_none_is_free() {
        let l = LayerObs::new("x");
        let t0 = start(Some(&l));
        let t1 = lap(Some(&l), Phase::Im2col, t0);
        lap(Some(&l), Phase::Gemm, t1);
        finish(Some(&l), t0);
        // both phases got *some* time and the chain reused the clock
        assert!(t1.is_some());
        // the None path must stay None end to end
        let n0 = start(None);
        assert!(n0.is_none());
        assert!(lap(None, Phase::Gemm, n0).is_none());
    }

    #[test]
    fn timer_samples_one_in_n() {
        // tick_every is the countdown core tick() drives with the global
        // period — testing it directly avoids racing other tests over the
        // process-wide knob
        let mut t = LayerTimer::default();
        let hits: Vec<bool> = (0..9).map(|_| t.tick_every(4)).collect();
        assert_eq!(hits, vec![true, false, false, false, true, false, false, false, true]);
        let mut z = LayerTimer::default();
        assert!(!z.tick_every(0), "period 0 must disable sampling");
        let mut one = LayerTimer::default();
        assert!(one.tick_every(1) && one.tick_every(1), "period 1 samples every pass");
    }
}
