//! `qft::obs` — stage-level tracing, per-layer kernel timing, and metric
//! exposition for the serving engine.
//!
//! Std-only, always compiled, near-zero overhead when idle:
//!
//! * [`metrics`] — lock-free primitives: [`Counter`], [`Gauge`], and the
//!   sharded atomic [`LogHistogram`] (log-linear sub-buckets for accurate
//!   p99/p99.9, exact small samples, relaxed-atomic recording);
//! * request lifecycle — every [`crate::serve::InferRequest`] carries a
//!   [`Trace`]; the worker stamps a [`BatchSpan`] at batch-formed →
//!   forward-start → forward-end → replied, and
//!   [`StageMetrics::record_span`] turns the stamps into per-model
//!   queue-wait / batch-form / compute / reply histograms;
//! * [`layer`] — per-layer pack/im2col/gemm/recode wall-time accumulators
//!   ([`NetObs`]) threaded through all six backends' forward paths,
//!   sampled 1-in-N (default [`DEFAULT_SAMPLE_EVERY`]) by a [`LayerTimer`]
//!   living in [`crate::backend::Scratch`];
//! * exposition — [`snapshot`] freezes everything into a [`Snapshot`];
//!   every render goes through the one [`Exposition`] trait
//!   ([`Exposition::render`] with a [`Format`]), implemented by
//!   [`Snapshot`], [`NetMetrics`], and the merged cluster view
//!   ([`crate::cluster::ClusterStats`]).  Prometheus text is checked by
//!   [`validate_prometheus`]; JSON parses back with
//!   [`Snapshot::from_json`] — quantiles are computed at snapshot time, so
//!   a flushed file re-renders without the buckets.
//!
//! Metric handles are process-global (a `BTreeMap` registry keyed by the
//! serving wire key `"arch/backend"`), so warm-up and measured runs in one
//! process accumulate into the same cells; [`reset`] zeroes everything in
//! place between bench configurations.

pub mod layer;
pub mod metrics;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use layer::{LayerObs, LayerTimer, NetObs, Phase, PHASE_NAMES};
pub use metrics::{Counter, Gauge, HistSnapshot, HistStats, LogHistogram};

use crate::util::json::Value;

/// Default layer-timing sampling period: 1 forward in 16 is timed.
pub const DEFAULT_SAMPLE_EVERY: u32 = 16;

static ENABLED: AtomicBool = AtomicBool::new(true);
static SAMPLE_EVERY: AtomicU32 = AtomicU32::new(DEFAULT_SAMPLE_EVERY);

/// Master switch (`--no-obs`).  When off, stage recording and layer timing
/// are both skipped — the residual cost is one relaxed load per call site.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Layer-timing sampling period (`--obs-sample N`): every Nth forward pass
/// per scratch is timed.  `1` times everything, `0` disables layer timing
/// while leaving stage histograms on.
pub fn sample_every() -> u32 {
    SAMPLE_EVERY.load(Ordering::Relaxed)
}

pub fn set_sample_every(n: u32) {
    SAMPLE_EVERY.store(n, Ordering::Relaxed);
}

fn replica_cell() -> &'static Mutex<String> {
    static R: OnceLock<Mutex<String>> = OnceLock::new();
    R.get_or_init(Mutex::default)
}

/// Hex id of this process's serving replica
/// ([`crate::cluster::ReplicaId`]), set by [`crate::net::NetServer`] when
/// it starts listening; empty when nothing listened.  Carried in every
/// [`Snapshot`] so flushed stats files say which replica produced them.
pub fn replica() -> String {
    replica_cell().lock().unwrap().clone()
}

pub fn set_replica(hex: &str) {
    *replica_cell().lock().unwrap() = hex.to_string();
}

// ---------------------------------------------------------------------------
// request lifecycle
// ---------------------------------------------------------------------------

/// Per-request lifecycle anchor, carried inside every
/// [`crate::serve::InferRequest`] from client submit onward.
#[derive(Clone, Copy, Debug)]
pub struct Trace {
    /// Client-side submit stamp; queue wait and end-to-end latency both
    /// anchor here.
    pub enqueued: Instant,
}

impl Trace {
    pub fn start() -> Self {
        Trace { enqueued: Instant::now() }
    }
}

/// Batch-level stage stamps, taken by the worker that executes one
/// micro-batch.  Every request in the batch shares these four instants;
/// per-request queue wait comes from its own [`Trace`].
#[derive(Clone, Copy, Debug)]
pub struct BatchSpan {
    /// The batcher handed the assembled batch to the worker.
    pub formed: Instant,
    /// Tensor staged, forward about to run.
    pub fwd_start: Instant,
    /// Forward returned (logits ready) — this is the completion stamp
    /// end-to-end latency uses.
    pub fwd_end: Instant,
    /// Last reply handed to its channel.
    pub replied: Instant,
}

/// Per-model stage histograms (all in µs) plus request/batch counters.
/// One per registry entry, shared via `Arc` between the engine and the
/// exposition layer.
#[derive(Default)]
pub struct StageMetrics {
    /// enqueue → batch formed, one sample per request.
    pub queue_wait_us: LogHistogram,
    /// batch formed → forward start, one sample per batch.
    pub batch_form_us: LogHistogram,
    /// forward start → forward end, one sample per batch.
    pub compute_us: LogHistogram,
    /// forward end → last reply sent, one sample per batch.
    pub reply_us: LogHistogram,
    pub requests: Counter,
    pub batches: Counter,
}

impl StageMetrics {
    /// Record one executed micro-batch: the shared [`BatchSpan`] stamps
    /// plus each member request's enqueue instant.  No-op when obs is
    /// disabled.
    pub fn record_span<I: IntoIterator<Item = Instant>>(&self, span: &BatchSpan, enqueued: I) {
        if !enabled() {
            return;
        }
        let us = |a: Instant, b: Instant| b.saturating_duration_since(a).as_micros() as u64;
        let mut n = 0u64;
        for enq in enqueued {
            self.queue_wait_us.record(us(enq, span.formed));
            n += 1;
        }
        self.batch_form_us.record(us(span.formed, span.fwd_start));
        self.compute_us.record(us(span.fwd_start, span.fwd_end));
        self.reply_us.record(us(span.fwd_end, span.replied));
        self.requests.add(n);
        self.batches.add(1);
    }

    pub fn clear(&self) {
        self.queue_wait_us.clear();
        self.batch_form_us.clear();
        self.compute_us.clear();
        self.reply_us.clear();
        self.requests.clear();
        self.batches.clear();
    }
}

// ---------------------------------------------------------------------------
// global registry
// ---------------------------------------------------------------------------

/// Engine-wide instantaneous queue depth (set by the batcher on every
/// submit/drain).
pub fn queue_depth() -> &'static Gauge {
    static G: Gauge = Gauge::new();
    &G
}

/// Engine-wide total of admitted requests.
pub fn submitted() -> &'static Counter {
    static C: Counter = Counter::new();
    &C
}

/// Fleet-wide total of route-word changes (promote / A/B split / rollback
/// across every [`crate::fleet::Slot`]); per-slot counts live on the slots
/// themselves.
pub fn route_changes() -> &'static Counter {
    static C: Counter = Counter::new();
    &C
}

/// Wire-layer metrics for the [`crate::net`] TCP front-end: connection
/// lifecycle, admission-control sheds, byte totals, and per-request wire
/// read/write time.  One process-global set — the front-end serves one
/// listener per process.
#[derive(Default)]
pub struct NetMetrics {
    /// Connections accepted since start (both protocols).
    pub conns_accepted: Counter,
    /// Connections currently open.
    pub conns_active: Gauge,
    /// Requests shed by admission control (queue-full `Busy` frames and
    /// over-cap connection sheds).
    pub shed: Counter,
    /// Payload + header bytes read off the wire.
    pub bytes_in: Counter,
    /// Bytes written to the wire (replies, error frames, HTTP responses).
    pub bytes_out: Counter,
    /// Per-request wire-read time (µs): first header byte → full frame in
    /// hand.  Idle time between requests is *not* counted.
    pub wire_read_us: LogHistogram,
    /// Per-request wire-write time (µs): reply serialized → flushed.
    pub wire_write_us: LogHistogram,
}

impl NetMetrics {
    pub fn clear(&self) {
        self.conns_accepted.clear();
        self.conns_active.set(0);
        self.shed.clear();
        self.bytes_in.clear();
        self.bytes_out.clear();
        self.wire_read_us.clear();
        self.wire_write_us.clear();
    }

    /// Freeze the live cells into a rendered [`NetIoSnapshot`] (histogram
    /// quantiles computed here).
    pub fn io_snapshot(&self) -> NetIoSnapshot {
        NetIoSnapshot {
            conns_accepted: self.conns_accepted.get(),
            conns_active: self.conns_active.get(),
            shed: self.shed.get(),
            bytes_in: self.bytes_in.get(),
            bytes_out: self.bytes_out.get(),
            wire_read: self.wire_read_us.stats(),
            wire_write: self.wire_write_us.stats(),
        }
    }
}

/// The process-global [`NetMetrics`] cell.  `OnceLock` rather than a
/// `static`: [`LogHistogram`] heap-allocates its shards, so it has no
/// `const` constructor.
pub fn net_metrics() -> &'static NetMetrics {
    static M: OnceLock<NetMetrics> = OnceLock::new();
    M.get_or_init(NetMetrics::default)
}

#[derive(Default)]
struct Maps {
    stages: BTreeMap<String, Arc<StageMetrics>>,
    nets: BTreeMap<String, Arc<NetObs>>,
}

fn maps() -> &'static Mutex<Maps> {
    static M: OnceLock<Mutex<Maps>> = OnceLock::new();
    M.get_or_init(Mutex::default)
}

/// Get-or-create the stage histograms for a serving wire key
/// (`"arch/backend"`).  The returned handle is lock-free to record into;
/// the registry lock is only taken here and at snapshot time.
pub fn stage_metrics(key: &str) -> Arc<StageMetrics> {
    let mut m = maps().lock().unwrap();
    m.stages.entry(key.to_string()).or_default().clone()
}

/// Get-or-create the per-layer accumulators for a prepared model.  Keyed
/// like [`stage_metrics`]; re-preparing the same `arch × backend` (warm-up
/// vs measured registry) reuses the same cells.
pub fn net_obs(key: &str, layer_names: &[String]) -> Arc<NetObs> {
    let mut m = maps().lock().unwrap();
    m.nets
        .entry(key.to_string())
        .or_insert_with(|| Arc::new(NetObs::new(key, layer_names)))
        .clone()
}

/// Zero every registered metric in place (registrations survive — live
/// `Arc` handles keep pointing at the same, now-zeroed, cells).  Bench
/// plumbing between configurations; not meant to race active recording.
pub fn reset() {
    queue_depth().set(0);
    submitted().clear();
    route_changes().clear();
    net_metrics().clear();
    let m = maps().lock().unwrap();
    for s in m.stages.values() {
        s.clear();
    }
    for n in m.nets.values() {
        n.clear();
    }
}

// ---------------------------------------------------------------------------
// snapshot + exposition
// ---------------------------------------------------------------------------

/// Rendered stage stats for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSnapshot {
    pub model: String,
    pub requests: u64,
    pub batches: u64,
    /// `(stage, stats in µs)` in fixed order:
    /// queue_wait, batch_form, compute, reply.
    pub stages: Vec<(String, HistStats)>,
}

impl StageSnapshot {
    /// Stats for one stage by name, if present.
    pub fn stage(&self, name: &str) -> Option<&HistStats> {
        self.stages.iter().find(|(n, _)| n == name).map(|(_, s)| s)
    }
}

/// Accumulated phase nanos for one op.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct LayerRow {
    pub pack_ns: u64,
    pub im2col_ns: u64,
    pub gemm_ns: u64,
    pub recode_ns: u64,
    pub total_ns: u64,
}

/// Rendered layer timing for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct NetSnapshot {
    pub model: String,
    pub passes: u64,
    pub images: u64,
    pub layers: Vec<(String, LayerRow)>,
}

/// Rendered wire-layer ([`NetMetrics`]) stats.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct NetIoSnapshot {
    pub conns_accepted: u64,
    pub conns_active: i64,
    pub shed: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Wire-read time stats (µs).
    pub wire_read: HistStats,
    /// Wire-write time stats (µs).
    pub wire_write: HistStats,
}

impl NetIoSnapshot {
    /// Append the `qft_net_*` Prometheus family (shared by
    /// [`Snapshot::to_prometheus`] and [`NetMetrics`]'s [`Exposition`]).
    fn prometheus_into(&self, o: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(o, "# HELP qft_net_conns_accepted_total TCP connections accepted");
        let _ = writeln!(o, "# TYPE qft_net_conns_accepted_total counter");
        let _ = writeln!(o, "qft_net_conns_accepted_total {}", self.conns_accepted);
        let _ = writeln!(o, "# HELP qft_net_conns_active TCP connections currently open");
        let _ = writeln!(o, "# TYPE qft_net_conns_active gauge");
        let _ = writeln!(o, "qft_net_conns_active {}", self.conns_active);
        let _ = writeln!(o, "# HELP qft_net_shed_total requests shed by admission control");
        let _ = writeln!(o, "# TYPE qft_net_shed_total counter");
        let _ = writeln!(o, "qft_net_shed_total {}", self.shed);
        let _ = writeln!(o, "# HELP qft_net_bytes_in_total bytes read off the wire");
        let _ = writeln!(o, "# TYPE qft_net_bytes_in_total counter");
        let _ = writeln!(o, "qft_net_bytes_in_total {}", self.bytes_in);
        let _ = writeln!(o, "# HELP qft_net_bytes_out_total bytes written to the wire");
        let _ = writeln!(o, "# TYPE qft_net_bytes_out_total counter");
        let _ = writeln!(o, "qft_net_bytes_out_total {}", self.bytes_out);
        let _ = writeln!(o, "# HELP qft_net_wire_us per-request wire read/write time (µs)");
        let _ = writeln!(o, "# TYPE qft_net_wire_us summary");
        for (dir, h) in [("read", &self.wire_read), ("write", &self.wire_write)] {
            let base = format!("dir=\"{dir}\"");
            for (q, v) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99), ("0.999", h.p999)] {
                let _ = writeln!(o, "qft_net_wire_us{{{base},quantile=\"{q}\"}} {v}");
            }
            let _ = writeln!(o, "qft_net_wire_us_sum{{{base}}} {}", h.sum);
            let _ = writeln!(o, "qft_net_wire_us_count{{{base}}} {}", h.count);
            let _ = writeln!(o, "qft_net_wire_us_max{{{base}}} {}", h.max);
        }
    }

    /// The `"net"` JSON object (shared by [`Snapshot::to_json`] and
    /// [`NetMetrics`]'s [`Exposition`]).
    fn json_value(&self) -> Value {
        obj([
            ("conns_accepted", Value::Num(self.conns_accepted as f64)),
            ("conns_active", Value::Num(self.conns_active as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("bytes_in", Value::Num(self.bytes_in as f64)),
            ("bytes_out", Value::Num(self.bytes_out as f64)),
            ("wire_read_us", hist_json(&self.wire_read)),
            ("wire_write_us", hist_json(&self.wire_write)),
        ])
    }

    /// One-line table summary.
    fn table_line(&self) -> String {
        format!(
            "net: {} conns accepted ({} active) | {} shed | {} B in / {} B out \
             | wire read p99 {}us / write p99 {}us\n",
            self.conns_accepted,
            self.conns_active,
            self.shed,
            self.bytes_in,
            self.bytes_out,
            self.wire_read.p99,
            self.wire_write.p99,
        )
    }
}

/// Point-in-time copy of every registered metric, with histogram quantiles
/// already computed — this is what both exposition formats serialize, and
/// what [`Snapshot::from_json`] reconstructs from a flushed file.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub enabled: bool,
    pub sample_every: u32,
    pub queue_depth: i64,
    pub submitted: u64,
    pub route_changes: u64,
    /// The dispatched integer-kernel path name
    /// ([`crate::kernel::kernel_dispatch`]) — carried in every flush so
    /// artifacts from different machines stay comparable.
    pub kernel_dispatch: String,
    /// Hex [`crate::cluster::ReplicaId`] of the serving replica ([`replica`]);
    /// empty when this process never listened.
    pub replica: String,
    /// Wire-layer totals from the [`crate::net`] front-end (all zero when
    /// nothing listened).
    pub net: NetIoSnapshot,
    pub stages: Vec<StageSnapshot>,
    pub nets: Vec<NetSnapshot>,
}

/// Stage names in exposition order.
pub const STAGE_NAMES: [&str; 4] = ["queue_wait", "batch_form", "compute", "reply"];

/// Freeze every registered metric.
pub fn snapshot() -> Snapshot {
    let m = maps().lock().unwrap();
    let stages = m
        .stages
        .iter()
        .map(|(key, s)| StageSnapshot {
            model: key.clone(),
            requests: s.requests.get(),
            batches: s.batches.get(),
            stages: vec![
                ("queue_wait".to_string(), s.queue_wait_us.stats()),
                ("batch_form".to_string(), s.batch_form_us.stats()),
                ("compute".to_string(), s.compute_us.stats()),
                ("reply".to_string(), s.reply_us.stats()),
            ],
        })
        .collect();
    let nets = m
        .nets
        .iter()
        .map(|(key, n)| NetSnapshot {
            model: key.clone(),
            passes: n.passes.get(),
            images: n.images.get(),
            layers: n
                .layers
                .iter()
                .map(|l| {
                    (
                        l.name.clone(),
                        LayerRow {
                            pack_ns: l.phase_ns(Phase::Pack),
                            im2col_ns: l.phase_ns(Phase::Im2col),
                            gemm_ns: l.phase_ns(Phase::Gemm),
                            recode_ns: l.phase_ns(Phase::Recode),
                            total_ns: l.total_ns(),
                        },
                    )
                })
                .collect(),
        })
        .collect();
    Snapshot {
        enabled: enabled(),
        sample_every: sample_every(),
        queue_depth: queue_depth().get(),
        submitted: submitted().get(),
        route_changes: route_changes().get(),
        kernel_dispatch: crate::kernel::kernel_dispatch().to_string(),
        replica: replica(),
        net: net_metrics().io_snapshot(),
        stages,
        nets,
    }
}

/// The exposition surfaces every renderable stats view offers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Human-readable table (CLI default, shutdown dump).
    Table,
    /// Compact JSON (`--stats-json` flushes; [`Snapshot`]s parse back with
    /// [`Snapshot::from_json`]).
    Json,
    /// Prometheus text exposition (`/metrics`; checked by
    /// [`validate_prometheus`]).
    Prometheus,
}

/// The one render API every exposition surface goes through: [`Snapshot`],
/// [`NetMetrics`], and the merged cluster view
/// ([`crate::cluster::ClusterStats`]) all implement it, so the CLI `stats`
/// command, `GET /metrics`, and `--stats-json` share a single
/// [`Format`]-driven code path instead of growing per-type method trios.
pub trait Exposition {
    fn render(&self, fmt: Format) -> String;
}

impl Exposition for Snapshot {
    fn render(&self, fmt: Format) -> String {
        match fmt {
            Format::Table => self.to_table(),
            Format::Json => self.to_json(),
            Format::Prometheus => self.to_prometheus(),
        }
    }
}

impl Exposition for NetMetrics {
    fn render(&self, fmt: Format) -> String {
        let io = self.io_snapshot();
        match fmt {
            Format::Table => io.table_line(),
            Format::Json => io.json_value().to_string_compact(),
            Format::Prometheus => {
                let mut o = String::new();
                io.prometheus_into(&mut o);
                o
            }
        }
    }
}

/// [`Exposition::render`] of a fresh [`snapshot`] as Prometheus text.
pub fn render_prometheus() -> String {
    snapshot().render(Format::Prometheus)
}

/// [`Exposition::render`] of a fresh [`snapshot`] as compact JSON.
pub fn render_json() -> String {
    snapshot().render(Format::Json)
}

impl Snapshot {
    /// Stage snapshot for a wire key, if present.
    pub fn stage_for(&self, model: &str) -> Option<&StageSnapshot> {
        self.stages.iter().find(|s| s.model == model)
    }

    /// Net snapshot for a wire key, if present.
    pub fn net_for(&self, model: &str) -> Option<&NetSnapshot> {
        self.nets.iter().find(|n| n.model == model)
    }

    /// Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut o = String::new();
        let _ = writeln!(o, "# HELP qft_obs_enabled whether obs recording is on");
        let _ = writeln!(o, "# TYPE qft_obs_enabled gauge");
        let _ = writeln!(o, "qft_obs_enabled {}", self.enabled as u8);
        let _ = writeln!(o, "# HELP qft_obs_sample_every layer-timing sampling period (0 = off)");
        let _ = writeln!(o, "# TYPE qft_obs_sample_every gauge");
        let _ = writeln!(o, "qft_obs_sample_every {}", self.sample_every);
        let _ = writeln!(o, "# HELP qft_queue_depth instantaneous engine queue depth");
        let _ = writeln!(o, "# TYPE qft_queue_depth gauge");
        let _ = writeln!(o, "qft_queue_depth {}", self.queue_depth);
        let _ = writeln!(o, "# HELP qft_submitted_total requests admitted by the batcher");
        let _ = writeln!(o, "# TYPE qft_submitted_total counter");
        let _ = writeln!(o, "qft_submitted_total {}", self.submitted);
        let _ = writeln!(o, "# HELP qft_route_changes_total fleet route changes (promote/ab)");
        let _ = writeln!(o, "# TYPE qft_route_changes_total counter");
        let _ = writeln!(o, "qft_route_changes_total {}", self.route_changes);
        let _ = writeln!(o, "# HELP qft_kernel_dispatch dispatched integer kernel path");
        let _ = writeln!(o, "# TYPE qft_kernel_dispatch gauge");
        let _ = writeln!(
            o,
            "qft_kernel_dispatch{{path=\"{}\"}} 1",
            esc(&self.kernel_dispatch)
        );
        if !self.replica.is_empty() {
            let _ = writeln!(o, "# HELP qft_replica serving replica id");
            let _ = writeln!(o, "# TYPE qft_replica gauge");
            let _ = writeln!(o, "qft_replica{{id=\"{}\"}} 1", esc(&self.replica));
        }
        self.net.prometheus_into(&mut o);
        if !self.stages.is_empty() {
            let _ = writeln!(o, "# HELP qft_requests_total requests executed per model");
            let _ = writeln!(o, "# TYPE qft_requests_total counter");
            for s in &self.stages {
                let _ =
                    writeln!(o, "qft_requests_total{{model=\"{}\"}} {}", esc(&s.model), s.requests);
            }
            let _ = writeln!(o, "# HELP qft_batches_total micro-batches executed per model");
            let _ = writeln!(o, "# TYPE qft_batches_total counter");
            for s in &self.stages {
                let _ =
                    writeln!(o, "qft_batches_total{{model=\"{}\"}} {}", esc(&s.model), s.batches);
            }
            let _ = writeln!(o, "# HELP qft_stage_latency_us per-stage latency summary (µs)");
            let _ = writeln!(o, "# TYPE qft_stage_latency_us summary");
            for s in &self.stages {
                for (stage, h) in &s.stages {
                    let base = format!("model=\"{}\",stage=\"{stage}\"", esc(&s.model));
                    for (q, v) in
                        [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99), ("0.999", h.p999)]
                    {
                        let _ = writeln!(
                            o,
                            "qft_stage_latency_us{{{base},quantile=\"{q}\"}} {v}"
                        );
                    }
                    let _ = writeln!(o, "qft_stage_latency_us_sum{{{base}}} {}", h.sum);
                    let _ = writeln!(o, "qft_stage_latency_us_count{{{base}}} {}", h.count);
                    let _ = writeln!(o, "qft_stage_latency_us_max{{{base}}} {}", h.max);
                }
            }
        }
        if !self.nets.is_empty() {
            let _ = writeln!(o, "# HELP qft_layer_sampled_passes_total sampled forward passes");
            let _ = writeln!(o, "# TYPE qft_layer_sampled_passes_total counter");
            for n in &self.nets {
                let _ = writeln!(
                    o,
                    "qft_layer_sampled_passes_total{{model=\"{}\"}} {}",
                    esc(&n.model),
                    n.passes
                );
            }
            let _ = writeln!(o, "# HELP qft_layer_sampled_images_total images in sampled passes");
            let _ = writeln!(o, "# TYPE qft_layer_sampled_images_total counter");
            for n in &self.nets {
                let _ = writeln!(
                    o,
                    "qft_layer_sampled_images_total{{model=\"{}\"}} {}",
                    esc(&n.model),
                    n.images
                );
            }
            let _ = writeln!(
                o,
                "# HELP qft_layer_phase_ns_total accumulated ns per layer and kernel phase"
            );
            let _ = writeln!(o, "# TYPE qft_layer_phase_ns_total counter");
            for n in &self.nets {
                for (name, row) in &n.layers {
                    let base = format!("model=\"{}\",layer=\"{}\"", esc(&n.model), esc(name));
                    for (phase, v) in [
                        ("pack", row.pack_ns),
                        ("im2col", row.im2col_ns),
                        ("gemm", row.gemm_ns),
                        ("recode", row.recode_ns),
                        ("total", row.total_ns),
                    ] {
                        let _ = writeln!(
                            o,
                            "qft_layer_phase_ns_total{{{base},phase=\"{phase}\"}} {v}"
                        );
                    }
                }
            }
        }
        o
    }

    /// Compact JSON exposition (parse back with [`Snapshot::from_json`]).
    pub fn to_json(&self) -> String {
        let stages = self
            .stages
            .iter()
            .map(|s| {
                let mut kv: Vec<(String, Value)> = vec![
                    ("model".to_string(), Value::Str(s.model.clone())),
                    ("requests".to_string(), Value::Num(s.requests as f64)),
                    ("batches".to_string(), Value::Num(s.batches as f64)),
                ];
                for (name, h) in &s.stages {
                    kv.push((stage_json_key(name), hist_json(h)));
                }
                obj(kv)
            })
            .collect();
        let nets = self
            .nets
            .iter()
            .map(|n| {
                let layers = n
                    .layers
                    .iter()
                    .map(|(name, r)| {
                        obj([
                            ("name", Value::Str(name.clone())),
                            ("pack_ns", Value::Num(r.pack_ns as f64)),
                            ("im2col_ns", Value::Num(r.im2col_ns as f64)),
                            ("gemm_ns", Value::Num(r.gemm_ns as f64)),
                            ("recode_ns", Value::Num(r.recode_ns as f64)),
                            ("total_ns", Value::Num(r.total_ns as f64)),
                        ])
                    })
                    .collect();
                obj([
                    ("model", Value::Str(n.model.clone())),
                    ("passes", Value::Num(n.passes as f64)),
                    ("images", Value::Num(n.images as f64)),
                    ("layers", Value::Arr(layers)),
                ])
            })
            .collect();
        obj([
            ("enabled", Value::Bool(self.enabled)),
            ("sample_every", Value::Num(self.sample_every as f64)),
            (
                "engine",
                obj([
                    ("queue_depth", Value::Num(self.queue_depth as f64)),
                    ("submitted", Value::Num(self.submitted as f64)),
                    ("route_changes", Value::Num(self.route_changes as f64)),
                    ("kernel_dispatch", Value::Str(self.kernel_dispatch.clone())),
                    ("replica", Value::Str(self.replica.clone())),
                ]),
            ),
            ("net", self.net.json_value()),
            ("stages", Value::Arr(stages)),
            ("nets", Value::Arr(nets)),
        ])
        .to_string_compact()
    }

    /// Parse a [`Snapshot::to_json`] document back (what `repro stats`
    /// does to a `--stats-json` flush file).
    pub fn from_json(text: &str) -> Result<Snapshot> {
        let v = Value::parse(text).context("obs snapshot: invalid JSON")?;
        let hist = |v: &Value| -> Result<HistStats> {
            Ok(HistStats {
                count: v.get("count")?.num()? as u64,
                sum: v.get("sum")?.num()? as u64,
                max: v.get("max")?.num()? as u64,
                mean: v.get("mean")?.num()?,
                p50: v.get("p50")?.num()? as u64,
                p95: v.get("p95")?.num()? as u64,
                p99: v.get("p99")?.num()? as u64,
                p999: v.get("p999")?.num()? as u64,
            })
        };
        let engine = v.get("engine")?;
        // absent in pre-net flush files — read as all-zero
        let net = match v.opt("net") {
            Some(n) => NetIoSnapshot {
                conns_accepted: n.get("conns_accepted")?.num()? as u64,
                conns_active: n.get("conns_active")?.num()? as i64,
                shed: n.get("shed")?.num()? as u64,
                bytes_in: n.get("bytes_in")?.num()? as u64,
                bytes_out: n.get("bytes_out")?.num()? as u64,
                wire_read: hist(n.get("wire_read_us")?)?,
                wire_write: hist(n.get("wire_write_us")?)?,
            },
            None => NetIoSnapshot::default(),
        };
        let mut stages = Vec::new();
        for s in v.get("stages")?.arr()? {
            let mut rows = Vec::new();
            for name in STAGE_NAMES {
                rows.push((name.to_string(), hist(s.get(&stage_json_key(name))?)?));
            }
            stages.push(StageSnapshot {
                model: s.get("model")?.str()?.to_string(),
                requests: s.get("requests")?.num()? as u64,
                batches: s.get("batches")?.num()? as u64,
                stages: rows,
            });
        }
        let mut nets = Vec::new();
        for n in v.get("nets")?.arr()? {
            let mut layers = Vec::new();
            for l in n.get("layers")?.arr()? {
                layers.push((
                    l.get("name")?.str()?.to_string(),
                    LayerRow {
                        pack_ns: l.get("pack_ns")?.num()? as u64,
                        im2col_ns: l.get("im2col_ns")?.num()? as u64,
                        gemm_ns: l.get("gemm_ns")?.num()? as u64,
                        recode_ns: l.get("recode_ns")?.num()? as u64,
                        total_ns: l.get("total_ns")?.num()? as u64,
                    },
                ));
            }
            nets.push(NetSnapshot {
                model: n.get("model")?.str()?.to_string(),
                passes: n.get("passes")?.num()? as u64,
                images: n.get("images")?.num()? as u64,
                layers,
            });
        }
        Ok(Snapshot {
            enabled: v.get("enabled")?.boolean()?,
            sample_every: v.get("sample_every")?.num()? as u32,
            queue_depth: engine.get("queue_depth")?.num()? as i64,
            submitted: engine.get("submitted")?.num()? as u64,
            // absent in pre-fleet flush files — read them as zero
            route_changes: engine
                .get("route_changes")
                .and_then(|v| v.num())
                .map(|n| n as u64)
                .unwrap_or(0),
            // absent in pre-dispatch flush files — read as unknown
            kernel_dispatch: engine
                .get("kernel_dispatch")
                .and_then(|v| v.str())
                .map(str::to_string)
                .unwrap_or_default(),
            // absent in pre-cluster flush files — read as never-listened
            replica: engine
                .get("replica")
                .and_then(|v| v.str())
                .map(str::to_string)
                .unwrap_or_default(),
            net,
            stages,
            nets,
        })
    }

    /// Human-readable table (the `repro stats` default and the shutdown
    /// dump).
    pub fn to_table(&self) -> String {
        use std::fmt::Write as _;
        let mut o = String::new();
        let _ = writeln!(
            o,
            "obs: {}, layer sampling {} | queue depth {} | {} submitted | {} route changes \
             | kernel {}",
            if self.enabled { "enabled" } else { "disabled" },
            match self.sample_every {
                0 => "off".to_string(),
                n => format!("1-in-{n}"),
            },
            self.queue_depth,
            self.submitted,
            self.route_changes,
            if self.kernel_dispatch.is_empty() { "?" } else { &self.kernel_dispatch },
        );
        if !self.replica.is_empty() {
            let _ = writeln!(o, "replica: {}", self.replica);
        }
        if self.net.conns_accepted > 0 {
            o.push_str(&self.net.table_line());
        }
        if !self.stages.is_empty() {
            let _ = writeln!(o, "\n== request stages (µs) ==");
            for s in &self.stages {
                let _ = writeln!(
                    o,
                    "model {}: {} requests / {} batches",
                    s.model, s.requests, s.batches
                );
                let _ = writeln!(
                    o,
                    "  {:<12} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}",
                    "stage", "count", "p50", "p95", "p99", "p999", "max", "mean"
                );
                for (name, h) in &s.stages {
                    let _ = writeln!(
                        o,
                        "  {:<12} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9.1}",
                        name, h.count, h.p50, h.p95, h.p99, h.p999, h.max, h.mean
                    );
                }
            }
        }
        let timed: Vec<_> = self.nets.iter().filter(|n| n.passes > 0).collect();
        if !timed.is_empty() {
            let _ = writeln!(o, "\n== sampled layer timings (µs per sampled pass) ==");
            for n in timed {
                let _ = writeln!(
                    o,
                    "model {}: {} passes / {} images",
                    n.model, n.passes, n.images
                );
                let _ = writeln!(
                    o,
                    "  {:<12} {:>9} {:>9} {:>9} {:>9} {:>9}",
                    "layer", "pack", "im2col", "gemm", "recode", "total"
                );
                let per = |ns: u64| ns as f64 / n.passes as f64 / 1e3;
                for (name, r) in &n.layers {
                    let _ = writeln!(
                        o,
                        "  {:<12} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9.1}",
                        name,
                        per(r.pack_ns),
                        per(r.im2col_ns),
                        per(r.gemm_ns),
                        per(r.recode_ns),
                        per(r.total_ns)
                    );
                }
            }
        }
        o
    }
}

/// JSON object key for a stage histogram (unit-suffixed).
fn stage_json_key(stage: &str) -> String {
    format!("{stage}_us")
}

fn obj<K: Into<String>, I: IntoIterator<Item = (K, Value)>>(kv: I) -> Value {
    Value::Obj(kv.into_iter().map(|(k, v)| (k.into(), v)).collect())
}

/// JSON object for one histogram (shared by the engine and net expositions).
fn hist_json(h: &HistStats) -> Value {
    obj([
        ("count", Value::Num(h.count as f64)),
        ("sum", Value::Num(h.sum as f64)),
        ("max", Value::Num(h.max as f64)),
        ("mean", Value::Num(h.mean)),
        ("p50", Value::Num(h.p50 as f64)),
        ("p95", Value::Num(h.p95 as f64)),
        ("p99", Value::Num(h.p99 as f64)),
        ("p999", Value::Num(h.p999 as f64)),
    ])
}

/// Escape a Prometheus label value.
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// ---------------------------------------------------------------------------
// exposition-format validation
// ---------------------------------------------------------------------------

/// Line-format check for the Prometheus text exposition: every non-empty
/// line must be a well-formed `# HELP` / `# TYPE` comment or a
/// `name{labels} value` sample.  Used by the `obs-overhead` bench to
/// validate the artifact it uploads, and by the test suite.
pub fn validate_prometheus(text: &str) -> Result<()> {
    for (i, raw) in text.lines().enumerate() {
        let ln = i + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            let mut it = rest.splitn(3, ' ');
            let kw = it.next().unwrap_or("");
            let name = it.next().unwrap_or("");
            if !matches!(kw, "HELP" | "TYPE") {
                bail!("line {ln}: comment is neither HELP nor TYPE: {line:?}");
            }
            if !valid_metric_name(name) {
                bail!("line {ln}: bad metric name {name:?}");
            }
            let third = it.next().unwrap_or("");
            if kw == "TYPE"
                && !matches!(third, "counter" | "gauge" | "summary" | "histogram" | "untyped")
            {
                bail!("line {ln}: bad metric type {third:?}");
            }
            continue;
        }
        parse_sample_line(line).with_context(|| format!("line {ln}: {line:?}"))?;
    }
    Ok(())
}

fn valid_metric_name(s: &str) -> bool {
    let mut ch = s.chars();
    match ch.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    ch.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn parse_sample_line(line: &str) -> Result<()> {
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] == b':') {
        i += 1;
    }
    if !valid_metric_name(&line[..i]) {
        bail!("bad metric name");
    }
    if i < b.len() && b[i] == b'{' {
        i += 1;
        loop {
            let s = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            if i == s {
                bail!("empty label name");
            }
            if i >= b.len() || b[i] != b'=' {
                bail!("label missing '='");
            }
            i += 1;
            if i >= b.len() || b[i] != b'"' {
                bail!("label value not quoted");
            }
            i += 1;
            while i < b.len() && b[i] != b'"' {
                if b[i] == b'\\' {
                    i += 1;
                }
                i += 1;
            }
            if i >= b.len() {
                bail!("unterminated label value");
            }
            i += 1;
            match b.get(i) {
                Some(b',') => i += 1,
                Some(b'}') => {
                    i += 1;
                    break;
                }
                _ => bail!("label list missing ',' or '}}'"),
            }
        }
    }
    if i >= b.len() || b[i] != b' ' {
        bail!("missing space before value");
    }
    let val = line[i + 1..].trim();
    if matches!(val, "+Inf" | "-Inf" | "NaN") {
        return Ok(());
    }
    val.parse::<f64>().map(|_| ()).map_err(|_| anyhow::anyhow!("bad sample value {val:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stage_metrics_split_the_span() {
        let sm = StageMetrics::default();
        let t0 = Instant::now();
        let span = BatchSpan {
            formed: t0 + Duration::from_micros(100),
            fwd_start: t0 + Duration::from_micros(150),
            fwd_end: t0 + Duration::from_micros(950),
            replied: t0 + Duration::from_micros(1000),
        };
        sm.record_span(&span, [t0, t0 + Duration::from_micros(60)]);
        assert_eq!(sm.requests.get(), 2);
        assert_eq!(sm.batches.get(), 1);
        let qw = sm.queue_wait_us.snapshot();
        assert_eq!(qw.count, 2);
        assert_eq!(qw.max, 100);
        assert_eq!(qw.min, 40);
        assert_eq!(sm.batch_form_us.snapshot().max, 50);
        assert_eq!(sm.compute_us.snapshot().max, 800);
        assert_eq!(sm.reply_us.snapshot().max, 50);
    }

    #[test]
    fn json_round_trips_through_from_json() {
        let key = "jsontest/lw";
        let sm = stage_metrics(key);
        let no = net_obs(key, &["conv0".to_string(), "fc".to_string()]);
        let t0 = Instant::now();
        let span = BatchSpan {
            formed: t0 + Duration::from_micros(10),
            fwd_start: t0 + Duration::from_micros(20),
            fwd_end: t0 + Duration::from_micros(500),
            replied: t0 + Duration::from_micros(510),
        };
        sm.record_span(&span, [t0]);
        no.passes.add(3);
        no.images.add(24);
        no.layers[0].add_phase_ns(Phase::Gemm, 1234);
        no.layers[0].add_total_ns(2000);
        let snap = snapshot();
        assert_eq!(snap.kernel_dispatch, crate::kernel::kernel_dispatch());
        let back = Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.stage_for(key), snap.stage_for(key));
        assert_eq!(back.net_for(key), snap.net_for(key));
        assert_eq!(back.net_for(key).unwrap().layers[0].1.gemm_ns, 1234);
        assert_eq!(back.kernel_dispatch, snap.kernel_dispatch);
        // the table renderer shouldn't panic on real data
        assert!(back.to_table().contains(key));
        assert!(back.to_table().contains(&format!("kernel {}", snap.kernel_dispatch)));
    }

    #[test]
    fn prometheus_output_validates() {
        let key = "promtest/dch";
        let sm = stage_metrics(key);
        let t0 = Instant::now();
        let span =
            BatchSpan { formed: t0, fwd_start: t0, fwd_end: t0, replied: t0 };
        sm.record_span(&span, [t0]);
        let text = render_prometheus();
        validate_prometheus(&text).unwrap();
        let want =
            "qft_stage_latency_us{model=\"promtest/dch\",stage=\"compute\",quantile=\"0.99\"}";
        assert!(text.contains(want));
        assert!(text.contains("# TYPE qft_stage_latency_us summary"));
        let disp = format!(
            "qft_kernel_dispatch{{path=\"{}\"}} 1",
            crate::kernel::kernel_dispatch()
        );
        assert!(text.contains(&disp));
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(validate_prometheus("ok_metric 1\n").is_ok());
        assert!(validate_prometheus("ok{a=\"b\",c=\"d/e\"} 2.5\n").is_ok());
        assert!(validate_prometheus("# TYPE x counter\nx 1\n").is_ok());
        assert!(validate_prometheus("9bad 1\n").is_err());
        assert!(validate_prometheus("no_value\n").is_err());
        assert!(validate_prometheus("unquoted{a=b} 1\n").is_err());
        assert!(validate_prometheus("bad{a=\"b\"} one\n").is_err());
        assert!(validate_prometheus("# BANANA x y\n").is_err());
        assert!(validate_prometheus("# TYPE x fruit\n").is_err());
        assert!(validate_prometheus("open{a=\"b\" 1\n").is_err());
    }

    #[test]
    fn net_metrics_round_trip_json_and_prometheus() {
        let nm = net_metrics();
        nm.conns_accepted.add(3);
        nm.conns_active.set(2);
        nm.shed.add(1);
        nm.bytes_in.add(4096);
        nm.bytes_out.add(1024);
        nm.wire_read_us.record(40);
        nm.wire_read_us.record(90);
        nm.wire_write_us.record(15);
        let snap = snapshot();
        assert!(snap.net.conns_accepted >= 3);
        assert!(snap.net.wire_read.count >= 2);
        // JSON round-trip reproduces the wire stats exactly
        let back = Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.net, snap.net);
        // pre-net flush files (no "net" key) read back as all-zero
        let mut doc = Value::parse(&snap.to_json()).unwrap();
        if let Value::Obj(m) = &mut doc {
            m.remove("net");
        }
        let parsed = Snapshot::from_json(&doc.to_string_compact()).unwrap();
        assert_eq!(parsed.net, NetIoSnapshot::default());
        // Prometheus exposition carries the net family and still validates
        let text = snap.to_prometheus();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("qft_net_conns_accepted_total"));
        assert!(text.contains("qft_net_wire_us{dir=\"read\",quantile=\"0.99\"}"));
        assert!(snap.to_table().contains("net: "));
    }

    #[test]
    fn config_knobs_round_trip() {
        let prev = sample_every();
        set_sample_every(5);
        assert_eq!(sample_every(), 5);
        set_sample_every(prev);
        assert!(enabled(), "tests assume the default-on state");
    }
}
