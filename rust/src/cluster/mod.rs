//! `qft::cluster` — delta-state CRDT replication of fleet stats and
//! calibration ranges across serving replicas.
//!
//! Since `qft::net` put the engine on a wire, a deployment is N processes
//! behind a balancer — but the counters ([`crate::fleet::Version`] request /
//! batch / error totals, the admission-control shed count) and the shadow
//! calibration ranges ([`crate::backend::CalibRanges`]) each live in one
//! process.  `repro requantize` rebuilding the grid from a single replica's
//! ranges fits constants to a biased shard of traffic — exactly the
//! data-dependence the paper's calibration premise warns about.
//!
//! This module makes that state *mergeable* with two join-semilattices:
//!
//! * [`GCounter`] — a grow-only counter: one `u64` per [`ReplicaId`], merge
//!   is pointwise max, value is the sum.  Local counters are monotone, so
//!   snapshotting a replica's own total into its entry and max-merging is
//!   exact; re-delivering a delta (gossip is at-least-once) is a no-op, and
//!   a stale delta replayed after newer state is absorbed changes nothing.
//! * [`RangeDelta`] — a min/max-register lattice over per-value, per-channel
//!   activation ranges: merge is pointwise `min` of mins / `max` of maxes.
//!   That is commutative, associative, and idempotent by construction, and
//!   it is *exactly* the fold [`crate::backend::CalibRanges`] already
//!   applies locally — so ranges captured on N replicas and lattice-merged
//!   are identical to the ranges one process would have captured over the
//!   concatenated traffic, and pooled requantize is bit-identical to
//!   single-process requantize.
//!
//! [`ClusterStats`] bundles both under stable names, with a version-tagged
//! binary codec ([`ClusterStats::encode`] / [`ClusterStats::decode`]) whose
//! decode is total — any byte sequence yields a value or a typed error,
//! never a panic.  The wire carries it in the `QFN1` stats frame family
//! (`stats-pull` / `stats-delta` / `stats-ack`, [`crate::net::frame`]):
//! every [`crate::net::NetServer`] owns a [`ClusterNode`] that answers pulls
//! with its merged state (in delta-state CRDTs the full state is a valid
//! delta) and folds incoming deltas in.  [`pull_stats`] / [`pull_merged`]
//! are the client side (`repro stats --pull`, `repro requantize --pool`).
//!
//! One caveat: obs process-globals (`submitted`, the net counters) are
//! tagged with the serving [`ClusterNode`]'s replica id, so run one
//! [`crate::net::NetServer`] per process in production (the per-slot and
//! per-version counters are per-[`crate::fleet::Fleet`] and merge exactly
//! either way).

use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::fleet::Fleet;
use crate::net::frame::{self, Frame};
use crate::obs;
use crate::util::json::Value;

/// Version byte leading every stats payload on the wire.
pub const STATS_VERSION: u8 = 1;

/// Stable identity of one serving replica — the key G-Counter entries live
/// under.  Derived once per [`ClusterNode`] from pid, wall clock, and a
/// process-local sequence number, so two replicas (even forked in the same
/// second, even two nodes in one test process) get distinct ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ReplicaId(pub u64);

impl ReplicaId {
    /// Mint a fresh id.  `QFT_REPLICA_ID` (u64) pins the *first* id minted
    /// by a process — deterministic wire fixtures; later mints still
    /// perturb it so in-process twins stay distinct.
    pub fn fresh() -> ReplicaId {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let pinned = std::env::var("QFT_REPLICA_ID").ok().and_then(|v| v.parse::<u64>().ok());
        if let Some(base) = pinned {
            return ReplicaId(base.wrapping_add(seq));
        }
        let pid = std::process::id() as u64;
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        ReplicaId(splitmix(pid ^ t.rotate_left(17) ^ ((seq << 1) | 1)))
    }

    /// Fixed-width hex rendering (label values, JSON keys).
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// SplitMix64 finalizer — a cheap, well-distributed 64-bit mix.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Grow-only counter CRDT: per-replica monotone totals, merged by pointwise
/// max, read as the sum.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GCounter {
    entries: BTreeMap<u64, u64>,
}

impl GCounter {
    pub fn new() -> GCounter {
        GCounter::default()
    }

    /// Fold a replica's *current total* in (entries only grow — a smaller
    /// observation than what is already held is kept at the held value, so
    /// replaying a stale snapshot cannot regress the counter).
    pub fn observe(&mut self, replica: ReplicaId, total: u64) {
        let e = self.entries.entry(replica.0).or_insert(0);
        *e = (*e).max(total);
    }

    /// Lattice join: pointwise max over the union of replicas.
    pub fn merge(&mut self, other: &GCounter) {
        for (&r, &v) in &other.entries {
            let e = self.entries.entry(r).or_insert(0);
            *e = (*e).max(v);
        }
    }

    /// The merged reading: sum over replicas (saturating).
    pub fn value(&self) -> u64 {
        self.entries.values().fold(0u64, |a, &v| a.saturating_add(v))
    }

    /// One replica's entry (0 if it never reported).
    pub fn entry(&self, replica: ReplicaId) -> u64 {
        self.entries.get(&replica.0).copied().unwrap_or(0)
    }

    /// Replicas contributing to this counter, ascending.
    pub fn replicas(&self) -> impl Iterator<Item = ReplicaId> + '_ {
        self.entries.keys().map(|&r| ReplicaId(r))
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Min/max-register lattice over one fleet slot's calibration state: the
/// per-value, per-channel `(min, max)` registers plus shadow-traffic
/// G-Counters.  [`RangeDelta::merge`] is the same pointwise fold
/// [`crate::backend::CalibRanges`] applies per shadowed batch, so merge
/// order, delivery count, and traffic partitioning cannot change the
/// result.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RangeDelta {
    /// value id → per-channel `(min, max)` over everything any replica
    /// shadowed.
    pub ranges: BTreeMap<u32, Vec<(f32, f32)>>,
    /// Micro-batches mirrored into shadow forwards, per replica.
    pub shadow_batches: GCounter,
    /// Images those batches carried, per replica.
    pub shadow_images: GCounter,
}

impl RangeDelta {
    /// Lattice join: pointwise min of mins / max of maxes; channel vectors
    /// of unequal length join over the union of channels.
    pub fn merge(&mut self, other: &RangeDelta) {
        for (&v, ch) in &other.ranges {
            match self.ranges.get_mut(&v) {
                None => {
                    self.ranges.insert(v, ch.clone());
                }
                Some(mine) => {
                    if mine.len() < ch.len() {
                        mine.resize(ch.len(), (f32::INFINITY, f32::NEG_INFINITY));
                    }
                    for (m, &(lo, hi)) in mine.iter_mut().zip(ch) {
                        m.0 = m.0.min(lo);
                        m.1 = m.1.max(hi);
                    }
                }
            }
        }
        self.shadow_batches.merge(&other.shadow_batches);
        self.shadow_images.merge(&other.shadow_images);
    }

    /// The merged ranges in [`crate::backend::CalibRanges`] shape (for
    /// [`crate::backend::CalibRanges::merge_ranges`]).
    pub fn ranges_map(&self) -> HashMap<usize, Vec<(f32, f32)>> {
        self.ranges.iter().map(|(&v, ch)| (v as usize, ch.clone())).collect()
    }

    /// Per-channel `max(|min|, |max|)` — the exact statistics
    /// [`crate::fleet::Slot::install_requantized`] consumes.
    pub fn absmax(&self) -> HashMap<usize, Vec<f32>> {
        self.ranges
            .iter()
            .map(|(&v, ch)| {
                (v as usize, ch.iter().map(|&(lo, hi)| lo.abs().max(hi.abs())).collect())
            })
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

/// The replicated state: named G-Counters plus per-slot range lattices.
/// The whole struct is a join-semilattice ([`ClusterStats::merge`]), and in
/// delta-state CRDTs the full state is itself a valid delta — which is what
/// a `stats-pull` answers with.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterStats {
    /// Counter name (`"engine/submitted"`, `"slot/{key}/v{id}/requests"`,
    /// ...) → per-replica totals.
    pub counters: BTreeMap<String, GCounter>,
    /// Fleet slot key → merged calibration lattice.
    pub calib: BTreeMap<String, RangeDelta>,
}

impl ClusterStats {
    pub fn new() -> ClusterStats {
        ClusterStats::default()
    }

    /// Fold one replica's current total for a named counter in.
    pub fn observe(&mut self, name: &str, replica: ReplicaId, total: u64) {
        self.counters.entry(name.to_string()).or_default().observe(replica, total);
    }

    /// Merged reading of a named counter (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).map(GCounter::value).unwrap_or(0)
    }

    /// Lattice join with another state/delta.  Commutative, associative,
    /// idempotent — delivery order and repetition cannot change the result.
    pub fn merge(&mut self, other: &ClusterStats) {
        for (name, gc) in &other.counters {
            self.counters.entry(name.clone()).or_default().merge(gc);
        }
        for (slot, rd) in &other.calib {
            self.calib.entry(slot.clone()).or_default().merge(rd);
        }
    }

    /// Every replica that contributed to any counter, ascending.
    pub fn replicas(&self) -> Vec<ReplicaId> {
        let mut ids: Vec<ReplicaId> =
            self.counters.values().flat_map(|gc| gc.replicas()).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.calib.is_empty()
    }

    /// Version-tagged binary encoding (all integers little-endian):
    ///
    /// ```text
    /// [ver: u8 = 1]
    /// [n_counters: u32] then per counter:
    ///   [name_len: u16][name: utf8][n_entries: u32]([replica: u64][total: u64])*
    /// [n_slots: u32] then per slot:
    ///   [key_len: u16][key: utf8]
    ///   [n_values: u32]([value_id: u32][n_channels: u32]([min: f32][max: f32])*)*
    ///   [shadow_batches g-counter][shadow_images g-counter]
    /// ```
    pub fn encode(&self) -> Vec<u8> {
        let mut p = vec![STATS_VERSION];
        let put_str = |p: &mut Vec<u8>, s: &str| {
            let b = s.as_bytes();
            let n = b.len().min(u16::MAX as usize);
            p.extend_from_slice(&(n as u16).to_le_bytes());
            p.extend_from_slice(&b[..n]);
        };
        let put_gc = |p: &mut Vec<u8>, gc: &GCounter| {
            p.extend_from_slice(&(gc.entries.len() as u32).to_le_bytes());
            for (&r, &v) in &gc.entries {
                p.extend_from_slice(&r.to_le_bytes());
                p.extend_from_slice(&v.to_le_bytes());
            }
        };
        p.extend_from_slice(&(self.counters.len() as u32).to_le_bytes());
        for (name, gc) in &self.counters {
            put_str(&mut p, name);
            put_gc(&mut p, gc);
        }
        p.extend_from_slice(&(self.calib.len() as u32).to_le_bytes());
        for (key, rd) in &self.calib {
            put_str(&mut p, key);
            p.extend_from_slice(&(rd.ranges.len() as u32).to_le_bytes());
            for (&v, ch) in &rd.ranges {
                p.extend_from_slice(&v.to_le_bytes());
                p.extend_from_slice(&(ch.len() as u32).to_le_bytes());
                for &(lo, hi) in ch {
                    p.extend_from_slice(&lo.to_le_bytes());
                    p.extend_from_slice(&hi.to_le_bytes());
                }
            }
            put_gc(&mut p, &rd.shadow_batches);
            put_gc(&mut p, &rd.shadow_images);
        }
        p
    }

    /// Total decode: any byte sequence yields a state or a typed reason —
    /// never a panic, and never an allocation beyond what the bytes present
    /// can back (every claimed count is bounds-checked against the
    /// remaining buffer before its elements are read).
    pub fn decode(p: &[u8]) -> std::result::Result<ClusterStats, &'static str> {
        let mut c = Cur { b: p, i: 0 };
        if c.u8()? != STATS_VERSION {
            return Err("unsupported stats version");
        }
        let mut out = ClusterStats::default();
        let n_counters = c.u32()? as usize;
        for _ in 0..n_counters {
            let name = c.str()?;
            let gc = c.gcounter()?;
            out.counters.insert(name, gc);
        }
        let n_slots = c.u32()? as usize;
        for _ in 0..n_slots {
            let key = c.str()?;
            let mut rd = RangeDelta::default();
            let n_values = c.u32()? as usize;
            for _ in 0..n_values {
                let v = c.u32()?;
                let n_ch = c.u32()? as usize;
                c.check(n_ch, 8)?;
                let mut ch = Vec::with_capacity(n_ch);
                for _ in 0..n_ch {
                    ch.push((c.f32()?, c.f32()?));
                }
                rd.ranges.insert(v, ch);
            }
            rd.shadow_batches = c.gcounter()?;
            rd.shadow_images = c.gcounter()?;
            out.calib.insert(key, rd);
        }
        if c.i != p.len() {
            return Err("trailing bytes after stats payload");
        }
        Ok(out)
    }

    /// Human-readable summary: merged counter totals with per-replica
    /// breakdowns, then per-slot calibration coverage.
    pub fn to_table(&self) -> String {
        let mut o = String::new();
        let ids = self.replicas();
        let _ = writeln!(
            o,
            "cluster stats: {} replicas, {} counters, {} calibrated slots",
            ids.len(),
            self.counters.len(),
            self.calib.len()
        );
        if !self.counters.is_empty() {
            let _ = writeln!(o, "\n== merged counters ==");
            let _ = writeln!(o, "  {:<44} {:>12}  per-replica", "counter", "total");
            for (name, gc) in &self.counters {
                let by: Vec<String> =
                    gc.replicas().map(|r| format!("{}={}", r.hex(), gc.entry(r))).collect();
                let _ = writeln!(o, "  {:<44} {:>12}  {}", name, gc.value(), by.join(" "));
            }
        }
        for (slot, rd) in &self.calib {
            let _ = writeln!(
                o,
                "\n== calib {slot}: {} value ids | {} shadow batches / {} images ==",
                rd.ranges.len(),
                rd.shadow_batches.value(),
                rd.shadow_images.value()
            );
            for (v, ch) in &rd.ranges {
                let lo = ch.iter().map(|p| p.0).fold(f32::INFINITY, f32::min);
                let hi = ch.iter().map(|p| p.1).fold(f32::NEG_INFINITY, f32::max);
                let _ = writeln!(
                    o,
                    "  value {v:>3}: {:>3} channels, pooled [{lo:.4}, {hi:.4}]",
                    ch.len()
                );
            }
        }
        o
    }

    /// Compact JSON rendering (counters as `{name: {replica_hex: total}}`).
    pub fn to_json(&self) -> String {
        let mut counters = HashMap::new();
        for (name, gc) in &self.counters {
            let per: HashMap<String, Value> =
                gc.replicas().map(|r| (r.hex(), Value::Num(gc.entry(r) as f64))).collect();
            counters.insert(name.clone(), Value::Obj(per));
        }
        let mut calib = HashMap::new();
        for (slot, rd) in &self.calib {
            let mut m = HashMap::new();
            m.insert("values".to_string(), Value::Num(rd.ranges.len() as f64));
            m.insert("shadow_batches".to_string(), Value::Num(rd.shadow_batches.value() as f64));
            m.insert("shadow_images".to_string(), Value::Num(rd.shadow_images.value() as f64));
            calib.insert(slot.clone(), Value::Obj(m));
        }
        let replicas = Value::Arr(self.replicas().iter().map(|r| Value::Str(r.hex())).collect());
        let mut doc = HashMap::new();
        doc.insert("replicas".to_string(), replicas);
        doc.insert("counters".to_string(), Value::Obj(counters));
        doc.insert("calib".to_string(), Value::Obj(calib));
        Value::Obj(doc).to_string_compact()
    }

    /// Prometheus text exposition ([`crate::obs::validate_prometheus`]
    /// clean): merged totals plus per-replica entries.
    pub fn to_prometheus(&self) -> String {
        let mut o = String::new();
        let _ = writeln!(o, "# HELP qft_cluster_replicas replicas in this merged snapshot");
        let _ = writeln!(o, "# TYPE qft_cluster_replicas gauge");
        let _ = writeln!(o, "qft_cluster_replicas {}", self.replicas().len());
        if !self.counters.is_empty() {
            let _ = writeln!(o, "# HELP qft_cluster_counter merged G-Counter totals");
            let _ = writeln!(o, "# TYPE qft_cluster_counter counter");
            for (name, gc) in &self.counters {
                let n = esc(name);
                let _ = writeln!(o, "qft_cluster_counter{{name=\"{n}\"}} {}", gc.value());
            }
            let _ = writeln!(o, "# HELP qft_cluster_counter_replica per-replica entries");
            let _ = writeln!(o, "# TYPE qft_cluster_counter_replica counter");
            for (name, gc) in &self.counters {
                let n = esc(name);
                for r in gc.replicas() {
                    let rh = r.hex();
                    let e = gc.entry(r);
                    let _ = writeln!(
                        o,
                        "qft_cluster_counter_replica{{name=\"{n}\",replica=\"{rh}\"}} {e}"
                    );
                }
            }
        }
        if !self.calib.is_empty() {
            let _ = writeln!(o, "# HELP qft_cluster_shadow_batches pooled shadowed batches");
            let _ = writeln!(o, "# TYPE qft_cluster_shadow_batches counter");
            for (slot, rd) in &self.calib {
                let s = esc(slot);
                let b = rd.shadow_batches.value();
                let _ = writeln!(o, "qft_cluster_shadow_batches{{slot=\"{s}\"}} {b}");
            }
            let _ = writeln!(o, "# HELP qft_cluster_calib_values calibrated value ids");
            let _ = writeln!(o, "# TYPE qft_cluster_calib_values gauge");
            for (slot, rd) in &self.calib {
                let s = esc(slot);
                let v = rd.ranges.len();
                let _ = writeln!(o, "qft_cluster_calib_values{{slot=\"{s}\"}} {v}");
            }
        }
        o
    }
}

impl obs::Exposition for ClusterStats {
    fn render(&self, fmt: obs::Format) -> String {
        match fmt {
            obs::Format::Table => self.to_table(),
            obs::Format::Json => self.to_json(),
            obs::Format::Prometheus => self.to_prometheus(),
        }
    }
}

/// Escape a Prometheus label value.
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Bounds-checked little-endian cursor backing [`ClusterStats::decode`].
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> std::result::Result<&'a [u8], &'static str> {
        let end = self.i.checked_add(n).ok_or("stats payload length overflow")?;
        if end > self.b.len() {
            return Err("stats payload truncated");
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    /// Reject a claimed element count the remaining bytes cannot back
    /// (before any allocation proportional to it).
    fn check(&self, n: usize, elem_bytes: usize) -> std::result::Result<(), &'static str> {
        let need = n.checked_mul(elem_bytes).ok_or("stats payload length overflow")?;
        if self.i.saturating_add(need) > self.b.len() {
            return Err("stats payload truncated");
        }
        Ok(())
    }

    fn u8(&mut self) -> std::result::Result<u8, &'static str> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> std::result::Result<u32, &'static str> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> std::result::Result<u64, &'static str> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn f32(&mut self) -> std::result::Result<f32, &'static str> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str(&mut self) -> std::result::Result<String, &'static str> {
        let n = {
            let s = self.take(2)?;
            u16::from_le_bytes([s[0], s[1]]) as usize
        };
        let b = self.take(n)?;
        std::str::from_utf8(b).map(str::to_string).map_err(|_| "stats name is not utf-8")
    }

    fn gcounter(&mut self) -> std::result::Result<GCounter, &'static str> {
        let n = self.u32()? as usize;
        self.check(n, 16)?;
        let mut gc = GCounter::default();
        for _ in 0..n {
            let r = self.u64()?;
            let v = self.u64()?;
            let e = gc.entries.entry(r).or_insert(0);
            *e = (*e).max(v);
        }
        Ok(gc)
    }
}

/// Snapshot a fleet's live counters and calibration ranges as this
/// replica's delta.  Counter names are stable:
///
/// * `engine/submitted`, `fleet/route_changes` — process-wide obs totals;
/// * `net/conns_accepted`, `net/shed` — wire-layer totals;
/// * `slot/{key}/route_changes` — per-slot route-word changes;
/// * `slot/{key}/v{id}/{requests,batches,errors}` — per-version traffic.
pub fn local_delta(fleet: &Fleet, replica: ReplicaId) -> ClusterStats {
    let mut s = ClusterStats::default();
    let nm = obs::net_metrics();
    s.observe("engine/submitted", replica, obs::submitted().get());
    s.observe("fleet/route_changes", replica, obs::route_changes().get());
    s.observe("net/conns_accepted", replica, nm.conns_accepted.get());
    s.observe("net/shed", replica, nm.shed.get());
    for i in 0..fleet.len() {
        let Some(slot) = fleet.slot(i) else { continue };
        let rc = format!("slot/{}/route_changes", slot.key);
        s.observe(&rc, replica, slot.route_changes.get());
        for v in slot.versions() {
            let p = format!("slot/{}/v{}", slot.key, v.id);
            s.observe(&format!("{p}/requests"), replica, v.requests.get());
            s.observe(&format!("{p}/batches"), replica, v.batches.get());
            s.observe(&format!("{p}/errors"), replica, v.errors.get());
        }
        if let Some(calib) = slot.calib() {
            let rd = s.calib.entry(slot.key.clone()).or_default();
            for (v, ch) in calib.export_ranges() {
                rd.ranges.insert(v as u32, ch);
            }
            rd.shadow_batches.observe(replica, calib.shadow_batches.get());
            rd.shadow_images.observe(replica, calib.shadow_images.get());
        }
    }
    s
}

/// One replica's CRDT cell: its identity plus everything absorbed from
/// peers.  Owned by [`crate::net::NetServer`]; the stats frames terminate
/// here.
pub struct ClusterNode {
    replica: ReplicaId,
    remote: Mutex<ClusterStats>,
}

impl ClusterNode {
    pub fn new(replica: ReplicaId) -> ClusterNode {
        ClusterNode { replica, remote: Mutex::new(ClusterStats::default()) }
    }

    pub fn replica(&self) -> ReplicaId {
        self.replica
    }

    /// Fold an incoming delta in; returns every replica id known after the
    /// merge (the `stats-ack` body).  Idempotent — at-least-once delivery
    /// and stale replays are no-ops.
    pub fn absorb(&self, delta: &ClusterStats) -> Vec<ReplicaId> {
        let mut r = self.remote.lock().unwrap();
        r.merge(delta);
        let mut ids = r.replicas();
        if !ids.contains(&self.replica) {
            ids.push(self.replica);
            ids.sort_unstable();
        }
        ids
    }

    /// This node's merged state: everything absorbed from peers joined with
    /// a fresh local delta.  What a `stats-pull` answers with.
    pub fn snapshot(&self, fleet: &Fleet) -> ClusterStats {
        let mut s = self.remote.lock().unwrap().clone();
        s.merge(&local_delta(fleet, self.replica));
        s
    }
}

/// Pull one replica's merged stats over the wire (`stats-pull` →
/// `stats-delta`).
pub fn pull_stats(addr: &str, timeout: Duration) -> Result<ClusterStats> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("cluster: cannot connect {addr}"))?;
    stream.set_read_timeout(Some(timeout)).context("cluster: set_read_timeout")?;
    stream.set_write_timeout(Some(timeout)).context("cluster: set_write_timeout")?;
    stream.set_nodelay(true).ok();
    frame::write_frame(&mut stream, &Frame::StatsPull { id: 1 })
        .with_context(|| format!("cluster: cannot send stats-pull to {addr}"))?;
    let reply = frame::read_frame(&mut stream)
        .with_context(|| format!("cluster: no stats-delta from {addr}"))?;
    match reply {
        Frame::StatsDelta { delta, .. } => Ok(delta),
        Frame::Error { code, msg, .. } => bail!("cluster: {addr} answered {}: {msg}", code.key()),
        other => bail!("cluster: {addr} answered an unexpected {other:?}"),
    }
}

/// Push a delta to one replica (`stats-delta` → `stats-ack`); returns the
/// replica ids the receiver knows after merging.
pub fn push_stats(addr: &str, delta: &ClusterStats, timeout: Duration) -> Result<Vec<ReplicaId>> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("cluster: cannot connect {addr}"))?;
    stream.set_read_timeout(Some(timeout)).context("cluster: set_read_timeout")?;
    stream.set_write_timeout(Some(timeout)).context("cluster: set_write_timeout")?;
    stream.set_nodelay(true).ok();
    frame::write_frame(&mut stream, &Frame::StatsDelta { id: 1, delta: delta.clone() })
        .with_context(|| format!("cluster: cannot send stats-delta to {addr}"))?;
    let reply = frame::read_frame(&mut stream)
        .with_context(|| format!("cluster: no stats-ack from {addr}"))?;
    match reply {
        Frame::StatsAck { replicas, .. } => Ok(replicas.into_iter().map(ReplicaId).collect()),
        Frame::Error { code, msg, .. } => bail!("cluster: {addr} answered {}: {msg}", code.key()),
        other => bail!("cluster: {addr} answered an unexpected {other:?}"),
    }
}

/// Pull every address and lattice-merge the answers (`repro stats --pull`,
/// `repro requantize --pool`).  Any unreachable replica is a hard error —
/// a silently partial merge would defeat the pooling.
pub fn pull_merged(addrs: &[&str], timeout: Duration) -> Result<ClusterStats> {
    let mut merged = ClusterStats::default();
    for addr in addrs {
        merged.merge(&pull_stats(addr, timeout)?);
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rid(n: u64) -> ReplicaId {
        ReplicaId(n)
    }

    #[test]
    fn gcounter_sums_replicas_and_replay_is_noop() {
        let mut a = GCounter::new();
        a.observe(rid(1), 10);
        a.observe(rid(2), 5);
        assert_eq!(a.value(), 15);
        // stale re-observation cannot regress
        a.observe(rid(1), 7);
        assert_eq!(a.entry(rid(1)), 10);
        let snapshot = a.clone();
        a.merge(&snapshot);
        assert_eq!(a, snapshot, "self-merge is identity");
    }

    #[test]
    fn cluster_encode_decode_round_trips() {
        let mut s = ClusterStats::new();
        s.observe("engine/submitted", rid(3), 42);
        s.observe("slot/synthetic/lw/v1/requests", rid(3), 40);
        s.observe("slot/synthetic/lw/v1/requests", rid(9), 2);
        let rd = s.calib.entry("synthetic/lw".to_string()).or_default();
        rd.ranges.insert(0, vec![(-1.0, 2.5), (0.0, 0.125)]);
        rd.shadow_batches.observe(rid(3), 4);
        rd.shadow_images.observe(rid(3), 32);
        let back = ClusterStats::decode(&s.encode()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.counter("slot/synthetic/lw/v1/requests"), 42);
        assert_eq!(back.replicas(), vec![rid(3), rid(9)]);
    }

    #[test]
    fn decode_rejects_garbage_with_typed_reasons() {
        assert!(ClusterStats::decode(&[]).is_err());
        assert!(ClusterStats::decode(&[9]).is_err(), "unknown version");
        // a lying count is rejected before allocation
        let mut p = vec![STATS_VERSION];
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(ClusterStats::decode(&p).is_err());
        // trailing bytes after a valid document are rejected
        let mut ok = ClusterStats::new();
        ok.observe("x", rid(1), 1);
        let mut bytes = ok.encode();
        bytes.push(0);
        assert_eq!(ClusterStats::decode(&bytes), Err("trailing bytes after stats payload"));
    }

    #[test]
    fn node_absorb_reports_known_replicas() {
        let node = ClusterNode::new(rid(7));
        let mut d = ClusterStats::new();
        d.observe("engine/submitted", rid(1), 3);
        let ids = node.absorb(&d);
        assert_eq!(ids, vec![rid(1), rid(7)], "ack lists peers plus self");
        assert_eq!(node.absorb(&d), vec![rid(1), rid(7)], "replay changes nothing");
    }

    #[test]
    fn replica_ids_are_distinct_in_process() {
        let a = ReplicaId::fresh();
        let b = ReplicaId::fresh();
        assert_ne!(a, b);
        assert_eq!(a.hex().len(), 16);
    }
}
