//! `CalibBackend` — a calibration-capturing shadow wrapper over any
//! [`PreparedNet`].
//!
//! QFT derives every deployment constant from *calibration ranges*: the
//! per-channel magnitudes the activations reach on representative inputs.
//! Offline PTQ guesses those ranges from a handful of calibration batches;
//! this wrapper closes the loop with production traffic instead.  It
//! decorates a primary net and
//!
//! 1. always answers from the primary — replies are bit-identical to the
//!    unwrapped net, at any thread count, shadow on or off;
//! 2. mirrors every `shadow_every`-th micro-batch into a *shadow* FP
//!    forward over the same input (the trainable map carries the full
//!    `w:`/`b:` FP weight set, so the reference graph is always
//!    reconstructible), off the reply path's critical data;
//! 3. folds the shadow pass's per-value, per-channel observed `min`/`max`
//!    into a shared [`CalibRanges`] accumulator.
//!
//! [`CalibRanges::absmax`] then renders the captured ranges in exactly the
//! shape [`crate::coordinator::state::init_trainables`] consumes, so
//! `repro requantize` (and [`crate::fleet::Slot::install_requantized`]) can
//! rebuild the deployment grid from what the model actually saw and
//! hot-swap the result in — the fleet-level realization of the paper's
//! premise that constants should be fit to real activation statistics.
//!
//! Cost model: unsampled batches pay one relaxed `fetch_add` and a branch.
//! Sampled batches run one extra FP forward on the worker thread (the
//! mirrored fraction is the knob) plus a short mutex hold to merge ranges —
//! the lock is per-slot and touched only 1-in-`shadow_every` batches, so it
//! is invisible next to the forward itself.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

use super::{BackendKind, PreparedNet, Scratch};
use crate::nn::{ArchSpec, ParamMap};
use crate::obs::Counter;
use crate::par::Pool;
use crate::tensor::Tensor;

/// Observed per-value, per-channel activation ranges, merged across every
/// shadowed batch.  Shared between the wrapper (writer) and the requantize
/// path (reader) via `Arc`.
#[derive(Default)]
pub struct CalibRanges {
    /// value id → per-channel `(min, max)` over everything shadowed so far.
    ranges: Mutex<HashMap<usize, Vec<(f32, f32)>>>,
    /// Micro-batches mirrored into the shadow forward.
    pub shadow_batches: Counter,
    /// Images those batches carried.
    pub shadow_images: Counter,
}

impl CalibRanges {
    /// Fold one shadow forward's value tensors in (channelwise min/max,
    /// channels on the last axis — the same convention as
    /// [`Tensor::abs_max_per_channel`]).
    fn record(&self, arch: &ArchSpec, values: &HashMap<usize, Tensor>, images: usize) {
        let mut r = self.ranges.lock().unwrap();
        for &v in &arch.quantized_values {
            let t = &values[&v];
            let c = *t.shape.last().unwrap();
            let e = r.entry(v).or_insert_with(|| vec![(f32::INFINITY, f32::NEG_INFINITY); c]);
            for chunk in t.data.chunks(c) {
                for ((lo, hi), &x) in e.iter_mut().zip(chunk) {
                    *lo = lo.min(x);
                    *hi = hi.max(x);
                }
            }
        }
        drop(r);
        self.shadow_batches.add(1);
        self.shadow_images.add(images as u64);
    }

    /// Whether anything has been captured yet.
    pub fn is_empty(&self) -> bool {
        self.ranges.lock().unwrap().is_empty()
    }

    /// Per-channel `max(|min|, |max|)` in the exact shape the offline PTQ
    /// init ([`crate::coordinator::state::init_trainables`]) consumes —
    /// captured live ranges become drop-in calibration statistics.
    pub fn absmax(&self) -> HashMap<usize, Vec<f32>> {
        self.ranges
            .lock()
            .unwrap()
            .iter()
            .map(|(&v, ch)| {
                (v, ch.iter().map(|&(lo, hi)| lo.abs().max(hi.abs())).collect())
            })
            .collect()
    }

    /// Clone the captured ranges out (what [`crate::cluster::local_delta`]
    /// ships over the wire as a `RangeDelta`).
    pub fn export_ranges(&self) -> HashMap<usize, Vec<(f32, f32)>> {
        self.ranges.lock().unwrap().clone()
    }

    /// Lattice-join remotely captured ranges in: pointwise min-of-mins /
    /// max-of-maxes, growing the channel vector when the remote saw more
    /// channels.  The join is commutative, associative, and idempotent, so
    /// pooled requantize is insensitive to peer order and repeated delivery.
    pub fn merge_ranges(&self, other: &HashMap<usize, Vec<(f32, f32)>>) {
        let mut r = self.ranges.lock().unwrap();
        for (&v, remote) in other {
            let e = r.entry(v).or_default();
            if e.len() < remote.len() {
                e.resize(remote.len(), (f32::INFINITY, f32::NEG_INFINITY));
            }
            for ((lo, hi), &(rlo, rhi)) in e.iter_mut().zip(remote) {
                *lo = lo.min(rlo);
                *hi = hi.max(rhi);
            }
        }
    }

    /// Human-readable range summary, one row per captured value id.
    pub fn table(&self) -> String {
        use std::fmt::Write as _;
        let mut o = String::new();
        let _ = writeln!(
            o,
            "captured ranges: {} shadow batches / {} images",
            self.shadow_batches.get(),
            self.shadow_images.get()
        );
        let r = self.ranges.lock().unwrap();
        let mut ids: Vec<_> = r.keys().copied().collect();
        ids.sort_unstable();
        for v in ids {
            let ch = &r[&v];
            let lo = ch.iter().map(|p| p.0).fold(f32::INFINITY, f32::min);
            let hi = ch.iter().map(|p| p.1).fold(f32::NEG_INFINITY, f32::max);
            let _ = writeln!(
                o,
                "  value {v:>3}: {:>3} channels, observed [{lo:.4}, {hi:.4}]",
                ch.len()
            );
        }
        o
    }
}

/// The shadow wrapper.  Construct with [`CalibBackend::wrap`]; behaves
/// exactly like the wrapped primary on every [`PreparedNet`] method.
pub struct CalibBackend {
    primary: Box<dyn PreparedNet>,
    arch: ArchSpec,
    /// The map the primary was prepared from — it always carries the FP
    /// `w:`/`b:` tensors, which is all the shadow FP forward reads.
    params: ParamMap,
    /// Mirror 1 micro-batch in `every` (0 disables the shadow entirely).
    every: u32,
    tick: AtomicU32,
    ranges: Arc<CalibRanges>,
}

impl CalibBackend {
    /// Wrap `primary`, mirroring one micro-batch in `every` as shadow
    /// traffic.  Returns the wrapped net plus the shared range accumulator
    /// handle the requantize path reads.
    pub fn wrap(
        primary: Box<dyn PreparedNet>,
        arch: &ArchSpec,
        params: &ParamMap,
        every: u32,
    ) -> (Box<dyn PreparedNet>, Arc<CalibRanges>) {
        let ranges = Arc::new(CalibRanges::default());
        let net = CalibBackend {
            primary,
            arch: arch.clone(),
            params: params.clone(),
            every,
            tick: AtomicU32::new(0),
            ranges: ranges.clone(),
        };
        (Box::new(net), ranges)
    }

    /// The shared accumulator (same handle [`CalibBackend::wrap`] returned).
    pub fn ranges(&self) -> Arc<CalibRanges> {
        self.ranges.clone()
    }

    fn maybe_shadow(&self, x: &Tensor) {
        if self.every == 0 {
            return;
        }
        let t = self.tick.fetch_add(1, Ordering::Relaxed);
        if t % self.every != 0 {
            return;
        }
        // the reply already left the primary's forward; this runs after
        let fwd = crate::nn::fp_forward(&self.arch, &self.params, x);
        self.ranges.record(&self.arch, &fwd.values, x.shape[0]);
    }
}

impl PreparedNet for CalibBackend {
    fn kind(&self) -> BackendKind {
        self.primary.kind()
    }

    fn input_hw(&self) -> usize {
        self.primary.input_hw()
    }

    fn input_ch(&self) -> usize {
        self.primary.input_ch()
    }

    fn num_classes(&self) -> usize {
        self.primary.num_classes()
    }

    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, pool: &Pool) -> Tensor {
        let y = self.primary.forward_batch(x, scratch, pool);
        self.maybe_shadow(x);
        y
    }

    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        pool: &Pool,
    ) -> (Tensor, Tensor) {
        let y = self.primary.forward_batch_feat(x, scratch, pool);
        self.maybe_shadow(x);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::deploy::Mode;

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn replies_are_bit_identical_to_the_unwrapped_primary() {
        let (arch, tm) = crate::serve::synthetic_trainables(Mode::Lw, 5);
        let kind = BackendKind::Int(Mode::Lw);
        let plain = crate::backend::prepare(kind, &arch, &tm);
        let (wrapped, ranges) =
            CalibBackend::wrap(crate::backend::prepare(kind, &arch, &tm), &arch, &tm, 1);
        let x = crate::data::Dataset::new(2).batch(crate::data::Split::Val, 0, 4).0;
        let pool = crate::par::Pool::new(2);
        let want = plain.forward_batch(&x, &mut Scratch::new(), &pool);
        let got = wrapped.forward_batch(&x, &mut Scratch::new(), &pool);
        assert_eq!(bits(&want), bits(&got), "shadow capture must not touch replies");
        assert_eq!(wrapped.kind(), kind);
        assert_eq!(ranges.shadow_batches.get(), 1);
        assert_eq!(ranges.shadow_images.get(), 4);
        assert!(!ranges.is_empty());
    }

    #[test]
    fn sampling_period_and_absmax_shape_hold() {
        let (arch, tm) = crate::serve::synthetic_trainables(Mode::Lw, 1);
        let kind = BackendKind::Int(Mode::Lw);
        let (net, ranges) =
            CalibBackend::wrap(crate::backend::prepare(kind, &arch, &tm), &arch, &tm, 3);
        let x = crate::data::Dataset::new(0).batch(crate::data::Split::Val, 0, 2).0;
        let pool = crate::par::Pool::new(1);
        let mut scratch = Scratch::new();
        for _ in 0..7 {
            net.forward_batch(&x, &mut scratch, &pool);
        }
        // ticks 0,3,6 of 0..7 are sampled
        assert_eq!(ranges.shadow_batches.get(), 3);
        let absmax = ranges.absmax();
        for &v in &arch.quantized_values {
            let ch = &absmax[&v];
            let want = arch.value_channels[&v.to_string()];
            assert_eq!(ch.len(), want, "value {v}");
            assert!(ch.iter().all(|m| m.is_finite() && *m >= 0.0));
        }
        assert!(ranges.table().contains("3 shadow batches"));
    }
}
