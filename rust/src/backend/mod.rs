//! `qft::backend` — ONE execution-backend API over every forward path (S18).
//!
//! QFT's core claim is HW-aware parameterization: the *same* network must
//! run under full precision, fake-quant simulation, and the integer
//! deployment grid, and stay comparable across them.  Historically those
//! paths were divergent free functions (`fp_forward`, `forward_fakequant`)
//! plus [`DeployedModel`], each with its own scratch and batching
//! conventions.  This module is the seam that unifies them:
//!
//! * [`BackendKind`] — the closed set of execution grids, with a stable
//!   string `key()` / [`BackendKind::from_key`] round trip (`fp`, `fq-lw`,
//!   `fq-dch`, `lw`, `dch`, `lw-i8`) used by the CLI `--backend` flag, the
//!   fleet slot wire keys, and the bench emitters.
//! * [`Backend`] — `prepare(&ArchSpec, &ParamMap) -> Box<dyn PreparedNet>`:
//!   run whatever offline subgraph the grid needs ONCE and freeze it.
//! * [`PreparedNet`] — the uniform online contract: batched
//!   `forward_batch` / `forward_batch_feat` over a caller-owned [`Scratch`]
//!   and a [`Pool`], plus the shape metadata serving needs.  Batched and
//!   single-image execution are bit-exactly equal per image, and results
//!   never depend on the pool width (each implementation either chunks the
//!   batch into per-image-independent sub-batches or runs kernels that are
//!   bit-identical to their serial twins).  The deployment grids
//!   ([`IntBackend`], [`Int8Backend`]) additionally give a *single* image
//!   intra-op (output-row) parallelism inside each conv/fc GEMM, so
//!   batch-1 latency scales with the pool width too.
//! * [`Scratch`] — one reusable buffer bundle per worker/caller, replacing
//!   the ad-hoc `DeployScratch` threading: every backend borrows the slice
//!   of it it needs, so holders (serve workers, eval loops) no longer know
//!   which grid they are driving.
//!
//! The existing paths are re-homed as [`FpBackend`], [`FakeQuantBackend`]
//! and [`IntBackend`] (a thin wrapper over [`DeployedModel`]).  Genuinely
//! new citizens: [`Int8Backend`] (`lw-i8`) — lw weight codes packed into i8
//! K-major panels ([`crate::kernel::PackedWi8`]) under a true i8×i8→i32
//! accumulate micro-kernel ([`crate::kernel::gemm_i8`]) with zero-point
//! folding (see the [`Int8Backend`] docs for the arithmetic) — and
//! [`CalibBackend`], a decorator over any prepared net that mirrors a
//! sampled fraction of live traffic into a shadow FP forward and captures
//! per-value activation ranges for requantization.
//!
//! Consumers: [`crate::fleet::Fleet`] slots store versioned
//! `Box<dyn PreparedNet>`s (one engine serves any grid, and hot-swaps
//! between them), [`crate::coordinator::eval::eval_backend`] scores any
//! grid offline, and the `repro` CLI exposes all of it behind `--backend`.

mod calib;
mod int8;

pub use calib::{CalibBackend, CalibRanges};
pub use int8::Int8Backend;

use std::sync::Arc;

use crate::nn::{ArchSpec, ParamMap};
use crate::obs::NetObs;
use crate::par::Pool;
use crate::quant::deploy::{forward_fakequant_obs, DeployScratch, DeployedModel, Mode};
use crate::tensor::Tensor;

/// The closed set of execution grids a network can run under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Full-precision reference (`fp`): the FP32 teacher graph.
    Fp,
    /// Fake-quant simulation (`fq-lw` / `fq-dch`): FP32-represented
    /// quantization, the rust mirror of the L2 student graph.
    FakeQuant(Mode),
    /// Integer deployment (`lw` / `dch`): the frozen online subgraph over
    /// f32-held codes ([`DeployedModel`]).
    Int(Mode),
    /// True-integer lw deployment (`lw-i8`): i8 weight panels, i8
    /// activations (zero-point offset), i32 accumulation.
    Int8,
}

impl BackendKind {
    /// Every kind, in CLI/doc order.
    pub const ALL: [BackendKind; 6] = [
        BackendKind::Fp,
        BackendKind::FakeQuant(Mode::Lw),
        BackendKind::FakeQuant(Mode::Dch),
        BackendKind::Int(Mode::Lw),
        BackendKind::Int(Mode::Dch),
        BackendKind::Int8,
    ];

    /// The stable string form: what `--backend` accepts, what fleet wire
    /// keys and bench rows embed.  Round-trips through [`Self::from_key`].
    pub fn key(self) -> &'static str {
        match self {
            BackendKind::Fp => "fp",
            BackendKind::FakeQuant(Mode::Lw) => "fq-lw",
            BackendKind::FakeQuant(Mode::Dch) => "fq-dch",
            BackendKind::Int(Mode::Lw) => "lw",
            BackendKind::Int(Mode::Dch) => "dch",
            BackendKind::Int8 => "lw-i8",
        }
    }

    /// Fallible inverse of [`Self::key`].  Exact-match only (built on
    /// [`Mode::from_key`]), so `"LW"`-vs-`"lw"` style drift in flags or
    /// `.qftw` filenames errors out with the full list of valid keys
    /// instead of silently resolving to something else.
    pub fn from_key(s: &str) -> anyhow::Result<BackendKind> {
        match s {
            "fp" => Ok(BackendKind::Fp),
            "lw-i8" => Ok(BackendKind::Int8),
            _ => {
                let parsed = match s.strip_prefix("fq-") {
                    Some(m) => Mode::from_key(m).map(BackendKind::FakeQuant),
                    None => Mode::from_key(s).map(BackendKind::Int),
                };
                parsed.map_err(|_| {
                    let valid: Vec<&str> = Self::ALL.iter().map(|k| k.key()).collect();
                    anyhow::anyhow!("unknown backend {s:?} (expected one of {valid:?})")
                })
            }
        }
    }

    /// The quantization mode whose trainable set this grid consumes
    /// (`None` for [`BackendKind::Fp`], which runs raw FP parameters).
    /// `lw-i8` shares the `lw` trainables — same DoF, different engine.
    pub fn mode(self) -> Option<Mode> {
        match self {
            BackendKind::Fp => None,
            BackendKind::FakeQuant(m) | BackendKind::Int(m) => Some(m),
            BackendKind::Int8 => Some(Mode::Lw),
        }
    }
}

/// Reusable per-caller buffers for any [`PreparedNet`].  One `Scratch` per
/// worker/eval loop serves every backend; each implementation borrows only
/// the fields it needs.  For the deployment grids ([`IntBackend`],
/// [`Int8Backend`]) the hot path allocates nothing once warm (beyond
/// per-reply logits rows); the [`FpBackend`] / [`FakeQuantBackend`]
/// reference grids ignore the scratch and allocate their intermediates per
/// call — they exist for correctness cross-checks, not serving throughput.
#[derive(Default)]
pub struct Scratch {
    /// [`DeployedModel`] buffers ([`IntBackend`]).
    pub(crate) deploy: DeployScratch,
    /// i8 code / i32 accumulator buffers ([`Int8Backend`]).
    pub(crate) int8: int8::Int8Scratch,
    /// Per-caller 1-in-N sampling countdown for per-layer kernel timing
    /// ([`crate::obs`]): every backend consults it once per forward, and a
    /// sampled pass threads its net's timing slots down through the conv /
    /// GEMM internals.  Unsampled passes cost one branch.
    pub timer: crate::obs::LayerTimer,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The per-layer timing slot names for an arch: one slot per op, named by
/// the op (shared by every backend so per-layer rows line up across grids).
fn obs_layer_names(arch: &ArchSpec) -> Vec<String> {
    arch.ops.iter().map(|o| o.name.clone()).collect()
}

/// One sampling decision per forward pass: consult the caller's
/// [`crate::obs::LayerTimer`]; on a sampled pass, count it (passes +
/// images) and hand the net's timing slots down the forward path.
pub(crate) fn sample_obs<'a>(
    obs: &'a NetObs,
    scratch: &mut Scratch,
    x: &Tensor,
) -> Option<&'a NetObs> {
    if !scratch.timer.tick() {
        return None;
    }
    obs.passes.add(1);
    obs.images.add(x.shape[0] as u64);
    Some(obs)
}

/// A network frozen for execution under one grid: the uniform online
/// contract every consumer (serve workers, eval loops, benches) drives.
pub trait PreparedNet: Send + Sync {
    /// Which grid this net runs under.
    fn kind(&self) -> BackendKind;

    /// Input spatial size (square).
    fn input_hw(&self) -> usize;

    /// Input channels.
    fn input_ch(&self) -> usize;

    /// Logit width.
    fn num_classes(&self) -> usize;

    /// Pixels per image (`hw*hw*ch`) — the request payload contract.
    fn image_len(&self) -> usize {
        self.input_hw() * self.input_hw() * self.input_ch()
    }

    /// Batched forward: logits `[batch, classes]`.  Bit-exactly independent
    /// of how images are grouped into batches and of `pool`'s width.
    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, pool: &Pool) -> Tensor;

    /// As [`Self::forward_batch`] but also returning the backbone feature
    /// map (the KD target tensor, decoded to FP where the grid is integer).
    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        pool: &Pool,
    ) -> (Tensor, Tensor);
}

/// An execution engine: runs a grid's offline subgraph over `(arch,
/// params)` once and freezes the result behind the uniform online contract.
pub trait Backend {
    /// The grid this engine implements.
    fn kind(&self) -> BackendKind;

    /// Run the offline subgraph and freeze.  `params` is the FP parameter
    /// map for [`BackendKind::Fp`] and the mode's trainable set otherwise
    /// (see [`BackendKind::mode`]).
    fn prepare(&self, arch: &ArchSpec, params: &ParamMap) -> Box<dyn PreparedNet>;
}

/// The engine for a kind.
pub fn backend_for(kind: BackendKind) -> Box<dyn Backend> {
    match kind {
        BackendKind::Fp => Box::new(FpBackend),
        BackendKind::FakeQuant(m) => Box::new(FakeQuantBackend(m)),
        BackendKind::Int(m) => Box::new(IntBackend(m)),
        BackendKind::Int8 => Box::new(Int8Backend::new()),
    }
}

/// One-call prepare: `backend_for(kind).prepare(arch, params)`.
pub fn prepare(kind: BackendKind, arch: &ArchSpec, params: &ParamMap) -> Box<dyn PreparedNet> {
    backend_for(kind).prepare(arch, params)
}

// ------------------------------------------------------------------ fp

/// Full-precision reference backend: the FP32 teacher graph behind the
/// uniform contract.  `prepare` freezes the `(arch, params)` pair; the
/// forward is the historical [`crate::nn::fp_forward`] (which already runs
/// on the packed [`crate::kernel`] GEMM via thread-local scratch).  The
/// batch is executed serially per call — per-image results are independent
/// by construction, so pool width cannot change anything.
pub struct FpBackend;

struct FpPrepared {
    arch: ArchSpec,
    params: ParamMap,
    obs: Arc<NetObs>,
}

impl Backend for FpBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Fp
    }

    fn prepare(&self, arch: &ArchSpec, params: &ParamMap) -> Box<dyn PreparedNet> {
        let obs = crate::obs::net_obs(
            &format!("{}/{}", arch.name, self.kind().key()),
            &obs_layer_names(arch),
        );
        Box::new(FpPrepared { arch: arch.clone(), params: params.clone(), obs })
    }
}

impl PreparedNet for FpPrepared {
    fn kind(&self) -> BackendKind {
        BackendKind::Fp
    }

    fn input_hw(&self) -> usize {
        self.arch.input_hw
    }

    fn input_ch(&self) -> usize {
        self.arch.input_ch
    }

    fn num_classes(&self) -> usize {
        self.arch.num_classes
    }

    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, _pool: &Pool) -> Tensor {
        let obs = sample_obs(&self.obs, scratch, x);
        crate::nn::fp_forward_obs(&self.arch, &self.params, x, obs).logits
    }

    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        _pool: &Pool,
    ) -> (Tensor, Tensor) {
        let obs = sample_obs(&self.obs, scratch, x);
        let f = crate::nn::fp_forward_obs(&self.arch, &self.params, x, obs);
        (f.logits, f.feat)
    }
}

// ------------------------------------------------------------- fake-quant

/// Fake-quant simulation backend: the FP32-represented student graph
/// ([`crate::quant::deploy::forward_fakequant`]) behind the uniform
/// contract — the grid the
/// analysis figures and AOT parity tests speak.
pub struct FakeQuantBackend(pub Mode);

struct FakeQuantPrepared {
    arch: ArchSpec,
    tm: ParamMap,
    mode: Mode,
    obs: Arc<NetObs>,
}

impl Backend for FakeQuantBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::FakeQuant(self.0)
    }

    fn prepare(&self, arch: &ArchSpec, tm: &ParamMap) -> Box<dyn PreparedNet> {
        let obs = crate::obs::net_obs(
            &format!("{}/{}", arch.name, self.kind().key()),
            &obs_layer_names(arch),
        );
        Box::new(FakeQuantPrepared { arch: arch.clone(), tm: tm.clone(), mode: self.0, obs })
    }
}

impl PreparedNet for FakeQuantPrepared {
    fn kind(&self) -> BackendKind {
        BackendKind::FakeQuant(self.mode)
    }

    fn input_hw(&self) -> usize {
        self.arch.input_hw
    }

    fn input_ch(&self) -> usize {
        self.arch.input_ch
    }

    fn num_classes(&self) -> usize {
        self.arch.num_classes
    }

    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, _pool: &Pool) -> Tensor {
        let obs = sample_obs(&self.obs, scratch, x);
        forward_fakequant_obs(&self.arch, &self.tm, self.mode, x, obs).0
    }

    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        _pool: &Pool,
    ) -> (Tensor, Tensor) {
        let obs = sample_obs(&self.obs, scratch, x);
        forward_fakequant_obs(&self.arch, &self.tm, self.mode, x, obs)
    }
}

// ------------------------------------------------------------------- int

/// Integer deployment backend: [`DeployedModel`] behind the uniform
/// contract.  `prepare` is exactly [`DeployedModel::prepare`] and the
/// forward is exactly `forward_batch_pooled`, so results are bit-identical
/// to driving [`DeployedModel`] directly at any thread count (the backend
/// parity suite pins this).
pub struct IntBackend(pub Mode);

struct IntPrepared {
    model: DeployedModel,
    input_hw: usize,
    input_ch: usize,
    num_classes: usize,
    obs: Arc<NetObs>,
}

impl Backend for IntBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Int(self.0)
    }

    fn prepare(&self, arch: &ArchSpec, tm: &ParamMap) -> Box<dyn PreparedNet> {
        let obs = crate::obs::net_obs(
            &format!("{}/{}", arch.name, self.kind().key()),
            &obs_layer_names(arch),
        );
        Box::new(IntPrepared {
            model: DeployedModel::prepare(arch, tm, self.0),
            input_hw: arch.input_hw,
            input_ch: arch.input_ch,
            num_classes: arch.num_classes,
            obs,
        })
    }
}

impl PreparedNet for IntPrepared {
    fn kind(&self) -> BackendKind {
        BackendKind::Int(self.model.mode)
    }

    fn input_hw(&self) -> usize {
        self.input_hw
    }

    fn input_ch(&self) -> usize {
        self.input_ch
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, pool: &Pool) -> Tensor {
        let obs = sample_obs(&self.obs, scratch, x);
        self.model.forward_batch_pooled_obs(x, &mut scratch.deploy, pool, obs)
    }

    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        pool: &Pool,
    ) -> (Tensor, Tensor) {
        let obs = sample_obs(&self.obs, scratch, x);
        self.model.forward_batch_feat_pooled_obs(x, &mut scratch.deploy, pool, obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_round_trip() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::from_key(kind.key()).unwrap(), kind);
        }
    }

    #[test]
    fn bad_keys_are_rejected_with_the_valid_list() {
        for bad in ["LW", "Lw", "fq_lw", "int8", "i8", "lw-I8", "", " lw"] {
            let err = BackendKind::from_key(bad).unwrap_err().to_string();
            assert!(err.contains("unknown backend"), "{bad:?}: {err}");
            assert!(err.contains("lw-i8"), "{bad:?}: error must list valid keys, got {err}");
        }
        assert!(Mode::from_key("LW").is_err());
        assert!(Mode::from_key("lw").is_ok());
    }

    #[test]
    fn mode_of_kind() {
        assert_eq!(BackendKind::Fp.mode(), None);
        assert_eq!(BackendKind::Int8.mode(), Some(Mode::Lw));
        assert_eq!(BackendKind::FakeQuant(Mode::Dch).mode(), Some(Mode::Dch));
        assert_eq!(BackendKind::Int(Mode::Dch).mode(), Some(Mode::Dch));
    }
}
