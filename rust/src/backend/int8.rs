//! `lw-i8` — the true-integer lw deployment backend.
//!
//! The historical `lw` path ([`crate::quant::deploy::DeployedModel`]) is
//! *semantically* integer — every activation and weight is a code — but the
//! codes are held in f32 and multiplied by the f32 GEMM.  This backend
//! closes the gap the ROADMAP left open ("i8×i8→i32 integer panels for the
//! `lw` deployment path"): weight codes are packed into i8 K-major panels
//! ([`crate::kernel::PackedWi8`], same panel geometry as the f32
//! [`crate::kernel::PackedW`], 4× denser), activations travel as i8, and
//! every conv runs the [`crate::kernel::gemm_i8`] i8×i8→i32 accumulate
//! micro-kernel — or, when the codebook fits 4 bits and a SIMD path is
//! dispatched, nibble-packed [`crate::kernel::PackedW4`] panels under
//! [`crate::kernel::gemm_w4`] at half the weight bandwidth (see
//! [`Int8Backend`] for the selection rules; the results are bit-identical
//! either way).
//!
//! ## Zero-point folding
//!
//! lw activation codes are unsigned (`[0, 255]`) on most values, which does
//! not fit i8.  Stored activations are therefore offset by a per-value
//! zero-point `zp` (128 for unsigned grids, 0 for signed):
//! `stored = q - zp ∈ [-128, 127]`.  Since
//! `Σ q·w = Σ (q - zp)·w + zp·Σ w`, the correction `zp · col_sum(w)` is a
//! per-output-channel i32 constant, computed once at prepare time from
//! [`crate::kernel::PackedWi8::col_sums`] and folded into the integer bias.
//! SAME-padding patch positions must contribute `q = 0`, so the i8 im2col
//! fills padding with `-zp` (not 0) — the fold then cancels it exactly.
//!
//! ## Relation to `lw`
//!
//! Per conv the i32 accumulator holds the *exact* integer sum; the f32 path
//! computes the same sum in f32, which is exact while the accumulator stays
//! under 2^24 (lw shapes are far inside that).  Bias, integer relu6
//! thresholds, and the multiplicative F̂ recode reuse the identical scalar
//! arithmetic, so `lw-i8` tracks `lw` to near-bit agreement on real
//! networks — the backend parity suite asserts tight logits agreement and
//! argmax equality rather than bit equality, since the guarantee decays for
//! pathological accumulator magnitudes.
//!
//! ## Parallelism
//!
//! Multi-image batches split into per-chunk sub-batches over the shared
//! [`crate::par::Pool`] (the generic batch driver the f32 deployment path
//! uses).  A *single* image instead gets **intra-op** parallelism: every
//! conv GEMM chunks its `b*oh*ow` output rows MR-aligned across the pool
//! ([`conv_gemm`]) and the fc head runs
//! [`crate::tensor::matmul_packed_rows_par`], mirroring
//! `conv2d_packed_into_par` on the f32 grids — so batch-1 latency scales
//! with `--threads`.  Integer accumulation is exact and the chunks own
//! disjoint accumulator rows, so results are bit-identical to the serial
//! walk at any thread count (`rust/tests/backend.rs` pins this at batch 1).

use std::collections::HashMap;

use std::sync::Arc;

use crate::kernel::{gemm_i8, gemm_w4, kernel_path, KernelPath, PackedW, PackedW4, PackedWi8};
use crate::nn::{ArchSpec, OpKind, ParamMap};
use crate::obs::{layer, LayerObs, NetObs, Phase};
use crate::par::{chunk_ranges_aligned, Pool, ScopedTask};
use crate::quant::deploy::{self, Mode};
use crate::tensor::conv::{im2col_rows_generic, out_dim};
use crate::tensor::{size_for_write, Tensor};

use super::{Backend, BackendKind, PreparedNet, Scratch};

/// i8 activation-code tensor (shape + offset codes).
#[derive(Default)]
struct QTensor {
    shape: Vec<usize>,
    data: Vec<i8>,
}

/// Per-value zero point: unsigned grids store `q - 128`, signed store `q`.
fn zp_of(arch: &ArchSpec, v: usize) -> i32 {
    if arch.signed_of(v) {
        0
    } else {
        128
    }
}

/// i8 im2col over one group's channel slice, padding filled with `fill`
/// (`-zp`, so padded positions decode to code 0).  Delegates to the SAME
/// element-generic geometry core the f32 conv paths run
/// ([`im2col_rows_generic`]) — one source of truth for the padding math.
#[allow(clippy::too_many_arguments)]
fn im2col_i8(
    x: &QTensor,
    k: usize,
    stride: usize,
    c0: usize,
    cg: usize,
    rows: std::ops::Range<usize>,
    fill: i8,
    cols: &mut Vec<i8>,
) {
    im2col_rows_generic(
        &x.data, x.shape[1], x.shape[2], x.shape[3], k, stride, c0, cg, rows, fill, cols,
    );
}

/// The weight panels of one conv: byte-per-code i8 panels, or — when the
/// codebook fits the two's-complement nibble range and the backend elected
/// the 4-bit path — nibble-packed [`PackedW4`] panels at half the weight
/// bandwidth.  Both run the same dispatched integer kernels and produce
/// bit-identical accumulators (the codes are identical, only the storage
/// density differs), so the choice is pure performance.
enum I8Panels {
    I8(Vec<PackedWi8>),
    W4(Vec<PackedW4>),
}

impl I8Panels {
    /// Run group `g`'s panel GEMM through whichever storage this conv uses.
    fn gemm(&self, g: usize, cols: &[i8], nrows: usize, out: &mut [i32]) {
        match self {
            I8Panels::I8(p) => gemm_i8(cols, nrows, &p[g], out),
            I8Panels::W4(p) => gemm_w4(cols, nrows, &p[g], out),
        }
    }
}

/// One conv frozen onto the i8 grid.
struct I8Conv {
    inp: usize,
    out: usize,
    stride: usize,
    k: usize,
    cin_g: usize,
    cout: usize,
    groups: usize,
    act: String,
    /// one panel pack per group (group `g` = columns `g*cg_out ..`).
    packs: I8Panels,
    /// integer bias at accumulator scale with the input zero-point
    /// correction (`zp_in · col_sum`) folded in.
    bias: Vec<i32>,
    /// per-channel integer clip(6/S_acc) thresholds for relu6.
    relu6_thr: Option<Vec<i32>>,
    /// multiplicative recode factor F̂ (Eq. 11).
    f: f32,
    qmin: f32,
    qmax: f32,
    zp_out: i32,
    /// `-zp_in` — the i8 im2col padding fill.
    fill: i8,
}

enum I8Op {
    Conv(I8Conv),
    Add {
        a: usize,
        b: usize,
        out: usize,
        act: String,
        sa: Vec<f32>,
        sb: Vec<f32>,
        sout: Vec<f32>,
        qmin: f32,
        qmax: f32,
        zp_a: i32,
        zp_b: i32,
        zp_out: i32,
    },
    Gap {
        inp: usize,
        sv: Vec<f32>,
        zp: i32,
    },
    Fc {
        w: PackedW,
        bias: Vec<f32>,
    },
}

/// Per-chunk im2col / per-group buffers for the single-image intra-op
/// parallel conv path: each output-row chunk owns its own patch matrix and
/// grouped-conv staging, so chunks never share a buffer.
#[derive(Default)]
struct I8ConvScratch {
    cols: Vec<i8>,
    gacc: Vec<i32>,
}

/// Reusable buffers for the i8 forward (the [`Scratch`] slice this backend
/// owns): i8 activation tensors per graph value, the i8 im2col matrix, i32
/// conv accumulators, and the FP decode/pool staging for the head.
#[derive(Default)]
pub(crate) struct Int8Scratch {
    vals: HashMap<usize, QTensor>,
    cols: Vec<i8>,
    /// full conv i32 accumulator (`rows * cout`).
    acc: Vec<i32>,
    /// per-group i32 accumulator (grouped convs only).
    gacc: Vec<i32>,
    /// FP decode buffer (gap / feature map).
    dec: Tensor,
    /// pooled FP features feeding the fc head.
    pooled: Tensor,
    /// sub-batch input staging for the batch-parallel path.
    input: Tensor,
    /// per-chunk child scratches for the batch-parallel path.
    par: Vec<Int8Scratch>,
    /// per-chunk child buffers for the intra-op (output-row) parallel path.
    intra: Vec<I8ConvScratch>,
}

fn take_qval(vals: &mut HashMap<usize, QTensor>, id: usize) -> QTensor {
    vals.remove(&id).unwrap_or_default()
}

/// Minimum output rows per intra-op conv chunk (`b*oh*ow` granularity) —
/// the same floor the f32 conv path uses: below it the scope submit/latch
/// overhead outweighs the row work.
const MIN_PAR_I8_ROWS: usize = 64;

/// The conv GEMM core for one contiguous output-row range: i8 im2col over
/// `r`, one [`gemm_i8`] per group, grouped results scattered into `out`
/// (the `r.len() * cout` accumulator slice for exactly those rows).  ONE
/// copy of this body serves both the serial path (`r = 0..rows` into the
/// full accumulator) and every parallel chunk (disjoint `r` into its
/// disjoint slice), so the two cannot drift.
#[allow(clippy::too_many_arguments)]
fn conv_gemm_rows(
    pc: &I8Conv,
    xin: &QTensor,
    r: std::ops::Range<usize>,
    out: &mut [i32],
    cols: &mut Vec<i8>,
    gacc: &mut Vec<i32>,
    lobs: Option<&LayerObs>,
) {
    let nrows = r.end - r.start;
    let cout = pc.cout;
    if pc.groups == 1 {
        let t0 = layer::start(lobs);
        im2col_i8(xin, pc.k, pc.stride, 0, pc.cin_g, r, pc.fill, cols);
        let t1 = layer::lap(lobs, Phase::Im2col, t0);
        pc.packs.gemm(0, cols, nrows, out);
        layer::lap(lobs, Phase::Gemm, t1);
        return;
    }
    let cg_out = cout / pc.groups;
    for g in 0..pc.groups {
        let c0 = g * pc.cin_g;
        let t0 = layer::start(lobs);
        im2col_i8(xin, pc.k, pc.stride, c0, pc.cin_g, r.clone(), pc.fill, cols);
        let t1 = layer::lap(lobs, Phase::Im2col, t0);
        size_for_write(gacc, nrows * cg_out);
        pc.packs.gemm(g, cols, nrows, gacc);
        layer::lap(lobs, Phase::Gemm, t1);
        for (row, chunk) in gacc.chunks(cg_out).enumerate() {
            let dst = row * cout + g * cg_out;
            out[dst..dst + cg_out].copy_from_slice(chunk);
        }
    }
}

/// Phase-1 conv GEMM: [`conv_gemm_rows`] into `acc`, either serially over
/// the whole row space (reusing `cols`/`gacc`) or — when a pool was handed
/// down for a single image — with the `b*oh*ow` output-row dimension split
/// into [`crate::kernel::MR`]-aligned chunks via [`chunk_ranges_aligned`],
/// mirroring [`crate::tensor::conv::conv2d_packed_into_par`].  Each chunk
/// runs the identical core over its own disjoint row block into its own
/// disjoint `acc` slice with its own child buffers; integer accumulation
/// is exact and the chunks do not even share accumulators, so results are
/// bit-identical to the serial path at any thread count.
#[allow(clippy::too_many_arguments)]
fn conv_gemm(
    pc: &I8Conv,
    xin: &QTensor,
    rows: usize,
    acc: &mut [i32],
    cols: &mut Vec<i8>,
    gacc: &mut Vec<i32>,
    intra: &mut Vec<I8ConvScratch>,
    pool: Option<&Pool>,
    lobs: Option<&LayerObs>,
) {
    let cout = pc.cout;
    let ranges = match pool {
        Some(p) => chunk_ranges_aligned(rows, p.threads(), MIN_PAR_I8_ROWS, crate::kernel::MR),
        None => Vec::new(),
    };
    let pool = match pool {
        Some(p) if ranges.len() > 1 => p,
        _ => {
            conv_gemm_rows(pc, xin, 0..rows, acc, cols, gacc, lobs);
            return;
        }
    };
    let nch = ranges.len();
    if intra.len() < nch {
        intra.resize_with(nch, I8ConvScratch::default);
    }
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(nch);
    let mut rest: &mut [i32] = acc;
    for (child, r) in intra.iter_mut().take(nch).zip(ranges) {
        let nrows = r.end - r.start;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(nrows * cout);
        rest = tail;
        tasks.push(Box::new(move || {
            conv_gemm_rows(pc, xin, r, head, &mut child.cols, &mut child.gacc, lobs);
        }));
    }
    pool.scope(tasks);
}

/// The `lw-i8` execution engine.  `prepare` consumes the *same* lw
/// trainable set as [`super::IntBackend`]`(Mode::Lw)` — same DoF, different
/// engine — so any exported `{arch}.lw.qftw` serves under either backend.
///
/// ## W4 panel selection
///
/// Per conv, weights pack as byte-per-code i8 panels or nibble-packed
/// [`PackedW4`] panels ([`I8Panels`]).  Resolution order at prepare time:
/// an explicit [`Int8Backend::with_w4`] choice, else the `QFT_W4=1|0` env
/// override, else *auto* — W4 whenever the conv's codes fit the nibble
/// range `[-8, 7]` (always true on the lw grids, `|w| ≤ 7`) **and** the
/// dispatched kernel path is SIMD ([`kernel_path`] `!= Scalar`; the scalar
/// W4 decode costs more than the bandwidth it saves).  Both storages hold
/// identical codes and accumulate exactly, so outputs are bit-identical
/// either way — the choice is pure performance.
#[derive(Default)]
pub struct Int8Backend {
    /// `Some` forces the W4 path on/off; `None` resolves env + auto probe.
    w4: Option<bool>,
}

impl Int8Backend {
    /// Auto-selecting backend (the [`super::backend_for`] construction).
    pub fn new() -> Int8Backend {
        Int8Backend::default()
    }

    /// Force the W4 panel path on or off, ignoring `QFT_W4` and the auto
    /// probe — the hook tests use to pin both storages without touching
    /// process-global env.
    pub fn with_w4(w4: bool) -> Int8Backend {
        Int8Backend { w4: Some(w4) }
    }

    /// Resolve the W4 choice (see the type docs for the order).
    fn resolve_w4(&self) -> bool {
        if let Some(forced) = self.w4 {
            return forced;
        }
        match std::env::var("QFT_W4") {
            Ok(v) if v == "1" => true,
            Ok(v) if v == "0" => false,
            Ok(v) => panic!("QFT_W4={v}: expected 1 or 0"),
            Err(_) => kernel_path() != KernelPath::Scalar,
        }
    }
}

impl Backend for Int8Backend {
    fn kind(&self) -> BackendKind {
        BackendKind::Int8
    }

    fn prepare(&self, arch: &ArchSpec, tm: &ParamMap) -> Box<dyn PreparedNet> {
        Box::new(Int8Prepared::prepare(arch, tm, self.resolve_w4()))
    }
}

/// A network lowered onto the i8 grid: i8 weight panels, i32 biases with
/// zero-point folds, recode constants — all frozen offline.
pub(crate) struct Int8Prepared {
    input_hw: usize,
    input_ch: usize,
    num_classes: usize,
    /// input encode: per-channel scales + activation grid + zero point.
    enc0: (Vec<f32>, f32, f32, i32),
    ops: Vec<I8Op>,
    /// per-layer timing slots (shared with the global [`crate::obs`]
    /// registry under `"arch/lw-i8"`), filled on sampled passes.
    obs: Arc<NetObs>,
}

impl Int8Prepared {
    fn prepare(arch: &ArchSpec, tm: &ParamMap, want_w4: bool) -> Self {
        let mode = Mode::Lw;
        let layer_names: Vec<String> = arch.ops.iter().map(|o| o.name.clone()).collect();
        let obs = crate::obs::net_obs(
            &format!("{}/{}", arch.name, BackendKind::Int8.key()),
            &layer_names,
        );
        let (qmin0, qmax0) = deploy::act_range(arch, 0);
        let enc0 = (deploy::sv_of(tm, 0), qmin0, qmax0, zp_of(arch, 0));
        let mut gap_out = None;
        let mut ops = Vec::with_capacity(arch.ops.len());
        for op in &arch.ops {
            match op.kind() {
                OpKind::Conv => {
                    let w = tm.get(&format!("w:{}", op.name));
                    let b = tm.get(&format!("b:{}", op.name));
                    let (s_l, s_r) = deploy::kernel_covectors(arch, tm, mode, op);
                    // f32 codes in [-7, 7] cast to i8.  Non-finite weights
                    // land exactly where the f32 path puts them: ±inf were
                    // already clamped to the saturated codes ±7 by
                    // `kernel_codes`, and NaN (which `clamp` passes through
                    // and the f32 kernel must mask via its zero-activation
                    // skip) casts to the zero code — so a NaN tap
                    // contributes nothing here, matching the f32 kernel's
                    // masking wherever that masking applies (zero codes)
                    let codes_f = deploy::kernel_codes(w, &s_l, &s_r);
                    let codes: Vec<i8> = codes_f.data.iter().map(|&c| c as i8).collect();
                    let (k, cin_g, cout) = (w.shape[0], w.shape[2], w.shape[3]);
                    let groups = op.groups;
                    let cg_out = cout / groups;
                    let rows = k * k * cin_g;
                    let mut csum = vec![0i32; cout];
                    // W4 needs every code in the nibble range; the lw grid
                    // guarantees it, but a forced-on backend must still
                    // fall back per conv rather than corrupt wider codes
                    let packs = if want_w4 && deploy::codes_fit_w4(&codes) {
                        let mut ps = Vec::with_capacity(groups);
                        for g in 0..groups {
                            let mut p = PackedW4::default();
                            p.pack_cols(&codes, rows, cout, g * cg_out, cg_out);
                            csum[g * cg_out..(g + 1) * cg_out].copy_from_slice(&p.col_sums());
                            ps.push(p);
                        }
                        I8Panels::W4(ps)
                    } else {
                        let mut ps = Vec::with_capacity(groups);
                        for g in 0..groups {
                            let mut p = PackedWi8::default();
                            p.pack_cols(&codes, rows, cout, g * cg_out, cg_out);
                            csum[g * cg_out..(g + 1) * cg_out].copy_from_slice(&p.col_sums());
                            ps.push(p);
                        }
                        I8Panels::I8(ps)
                    };
                    let f = deploy::pos(tm.get(&format!("f:{}", op.name)).data[0]);
                    let sv = deploy::sv_of(tm, op.out);
                    // accumulator scale per n: S_acc = S_v * F (Eq. 11)
                    let s_acc: Vec<f32> = sv.iter().map(|&s| s * f).collect();
                    let zp_in = zp_of(arch, op.inp);
                    // integer bias (Eq. 7) + the zero-point fold
                    let bias: Vec<i32> = b
                        .data
                        .iter()
                        .zip(&s_acc)
                        .zip(&csum)
                        .map(|((&bv, &s), &cs)| (bv / s).round() as i32 + zp_in * cs)
                        .collect();
                    let relu6_thr = (op.act == "relu6")
                        .then(|| s_acc.iter().map(|&s| (6.0 / s).round() as i32).collect());
                    let (qmin, qmax) = deploy::act_range(arch, op.out);
                    ops.push(I8Op::Conv(I8Conv {
                        inp: op.inp,
                        out: op.out,
                        stride: op.stride,
                        k,
                        cin_g,
                        cout,
                        groups,
                        act: op.act.clone(),
                        packs,
                        bias,
                        relu6_thr,
                        f,
                        qmin,
                        qmax,
                        zp_out: zp_of(arch, op.out),
                        fill: (-zp_in) as i8,
                    }));
                }
                OpKind::Add => {
                    let (qmin, qmax) = deploy::act_range(arch, op.out);
                    ops.push(I8Op::Add {
                        a: op.a,
                        b: op.b,
                        out: op.out,
                        act: op.act.clone(),
                        sa: deploy::sv_of(tm, op.a),
                        sb: deploy::sv_of(tm, op.b),
                        sout: deploy::sv_of(tm, op.out),
                        qmin,
                        qmax,
                        zp_a: zp_of(arch, op.a),
                        zp_b: zp_of(arch, op.b),
                        zp_out: zp_of(arch, op.out),
                    });
                }
                OpKind::Gap => {
                    gap_out = Some(op.out);
                    ops.push(I8Op::Gap {
                        inp: op.inp,
                        sv: deploy::sv_of(tm, op.inp),
                        zp: zp_of(arch, op.inp),
                    });
                }
                OpKind::Fc => {
                    assert_eq!(
                        Some(op.inp),
                        gap_out,
                        "lw-i8 expects the fc head to read the gap output"
                    );
                    let w = tm.get(&format!("w:{}", op.name));
                    assert_eq!(w.rank(), 2, "fc weight must be [k, classes]");
                    ops.push(I8Op::Fc {
                        w: PackedW::pack(&w.data, w.shape[0], w.shape[1]),
                        bias: tm.get(&format!("b:{}", op.name)).data.clone(),
                    });
                }
            }
        }
        Int8Prepared {
            input_hw: arch.input_hw,
            input_ch: arch.input_ch,
            num_classes: arch.num_classes,
            enc0,
            ops,
            obs,
        }
    }

    /// The per-op online pipeline.  `pool` is `Some` only on the
    /// single-image intra-op path: conv (and fc) GEMMs then split their
    /// output rows across the pool, bit-identically to the serial walk
    /// (see [`conv_gemm`]); everything elementwise stays serial.
    fn exec(
        &self,
        x: &Tensor,
        s: &mut Int8Scratch,
        want_feat: bool,
        pool: Option<&Pool>,
        obs: Option<&NetObs>,
    ) -> (Tensor, Option<Tensor>) {
        assert_eq!(x.rank(), 4, "input must be [b,h,w,c]");
        // encode the input to offset i8 codes
        {
            let mut v0 = take_qval(&mut s.vals, 0);
            let (sv, qmin, qmax, zp) = &self.enc0;
            let c = *x.shape.last().unwrap();
            v0.data.clear();
            v0.data.extend(x.data.iter().enumerate().map(|(i, &val)| {
                let q = (val / sv[i % c]).round().clamp(*qmin, *qmax);
                (q as i32 - zp) as i8
            }));
            v0.shape = x.shape.clone();
            s.vals.insert(0, v0);
        }

        let mut logits = None;
        let mut feat = None;
        for (i, iop) in self.ops.iter().enumerate() {
            // i8 ops are 1:1 with arch ops, so index i addresses the
            // matching per-layer timing slot on a sampled pass
            let lobs = obs.and_then(|o| o.layer(i));
            match iop {
                I8Op::Conv(pc) => {
                    let t0 = layer::start(lobs);
                    // phase 1: i8×i8→i32 GEMM into the accumulator, serial
                    // or intra-op row-chunked (see conv_gemm — identical
                    // results either way)
                    let (b, oh, ow) = {
                        let xin = &s.vals[&pc.inp];
                        let b = xin.shape[0];
                        let (oh, ow) =
                            (out_dim(xin.shape[1], pc.stride), out_dim(xin.shape[2], pc.stride));
                        let rows = b * oh * ow;
                        size_for_write(&mut s.acc, rows * pc.cout);
                        conv_gemm(
                            pc,
                            xin,
                            rows,
                            &mut s.acc,
                            &mut s.cols,
                            &mut s.gacc,
                            &mut s.intra,
                            pool,
                            lobs,
                        );
                        (b, oh, ow)
                    };
                    let tr = layer::start(lobs);
                    // phase 2: bias + integer activation + F̂ recode → i8,
                    // each as its own pass so the activation branch is
                    // resolved once per conv, not once per element (the
                    // same structure the f32 lw path uses)
                    let cout = pc.cout;
                    for (i, v) in s.acc.iter_mut().enumerate() {
                        *v += pc.bias[i % cout];
                    }
                    match pc.act.as_str() {
                        "relu" => {
                            for v in s.acc.iter_mut() {
                                *v = (*v).max(0);
                            }
                        }
                        "relu6" => {
                            let thr = pc.relu6_thr.as_ref().unwrap();
                            for (i, v) in s.acc.iter_mut().enumerate() {
                                *v = (*v).clamp(0, thr[i % cout]);
                            }
                        }
                        _ => {}
                    }
                    // recode: out_code = clip(round(acc * F̂)) — the
                    // accumulator is exact in i32 and (for lw shapes)
                    // exactly representable in f32, so this is the same
                    // scalar arithmetic the f32 lw path runs
                    let mut o = take_qval(&mut s.vals, pc.out);
                    o.data.clear();
                    o.data.extend(s.acc.iter().map(|&v| {
                        let q = (v as f32 * pc.f).round().clamp(pc.qmin, pc.qmax);
                        (q as i32 - pc.zp_out) as i8
                    }));
                    o.shape = vec![b, oh, ow, cout];
                    layer::lap(lobs, Phase::Recode, tr);
                    layer::finish(lobs, t0);
                    s.vals.insert(pc.out, o);
                }
                I8Op::Add { a, b, out, act, sa, sb, sout, qmin, qmax, zp_a, zp_b, zp_out } => {
                    // decode → FP add (App. D item 1) → re-encode, exactly
                    // the lw scalar pipeline over decoded codes
                    let mut o = take_qval(&mut s.vals, *out);
                    {
                        let ta = &s.vals[a];
                        let tb = &s.vals[b];
                        assert_eq!(ta.shape, tb.shape);
                        let c = *ta.shape.last().unwrap();
                        o.data.clear();
                        o.data.extend(ta.data.iter().zip(&tb.data).enumerate().map(
                            |(i, (&qa, &qb))| {
                                let v = (qa as i32 + zp_a) as f32 * sa[i % c]
                                    + (qb as i32 + zp_b) as f32 * sb[i % c];
                                let q = (deploy::act_scalar(act, v) / sout[i % c])
                                    .round()
                                    .clamp(*qmin, *qmax);
                                (q as i32 - zp_out) as i8
                            },
                        ));
                        o.shape = ta.shape.clone();
                    }
                    s.vals.insert(*out, o);
                }
                I8Op::Gap { inp, sv, zp } => {
                    // decode the backbone to FP for the head
                    let src = &s.vals[inp];
                    let fp = &mut s.dec;
                    let c = *src.shape.last().unwrap();
                    fp.data.clear();
                    fp.data.extend(
                        src.data
                            .iter()
                            .enumerate()
                            .map(|(i, &q)| (q as i32 + zp) as f32 * sv[i % c]),
                    );
                    fp.shape = src.shape.clone();
                    if want_feat {
                        feat = Some(fp.clone());
                    }
                    s.pooled = fp.global_avg_pool();
                }
                I8Op::Fc { w, bias } => {
                    let src = &s.pooled;
                    assert_eq!(src.rank(), 2);
                    assert_eq!(src.shape[1], w.k());
                    let m = src.shape[0];
                    let mut ydata = Vec::new();
                    let t0 = layer::start(lobs);
                    match pool {
                        Some(p) => {
                            size_for_write(&mut ydata, m * w.n());
                            crate::tensor::matmul_packed_rows_par(&src.data, m, w, &mut ydata, p);
                        }
                        None => crate::tensor::matmul_packed_slices(&src.data, m, w, &mut ydata),
                    }
                    layer::lap(lobs, Phase::Gemm, t0);
                    let mut y = Tensor::new(vec![m, w.n()], ydata);
                    for row in y.data.chunks_mut(bias.len()) {
                        for (v, &bv) in row.iter_mut().zip(bias) {
                            *v += bv;
                        }
                    }
                    layer::finish(lobs, t0);
                    logits = Some(y);
                }
            }
        }
        (logits.expect("arch has fc"), feat)
    }

    /// Dispatch between batch-level and intra-op parallelism, mirroring
    /// the f32 [`deploy::DeployedModel`] exactly: a multi-image batch is
    /// split into per-chunk sub-batches, a single image gets intra-op
    /// output-row parallelism inside each conv/fc GEMM so its latency
    /// scales with `--threads`.
    fn exec_pooled(
        &self,
        x: &Tensor,
        s: &mut Int8Scratch,
        want_feat: bool,
        pool: &Pool,
        obs: Option<&NetObs>,
    ) -> (Tensor, Option<Tensor>) {
        assert_eq!(x.rank(), 4, "input must be [b,h,w,c]");
        if pool.threads() <= 1 {
            return self.exec(x, s, want_feat, None, obs);
        }
        if x.shape[0] > 1 {
            // batch-level parallelism via the SAME chunking/staging/concat
            // driver the f32 deployment path runs — per-image execution is
            // independent, so the concatenation is bit-identical to serial
            return deploy::exec_batch_par_generic(
                x,
                self.num_classes,
                want_feat,
                pool,
                &mut s.par,
                |xin, child, wf| self.exec(xin, child, wf, None, obs),
            );
        }
        self.exec(x, s, want_feat, Some(pool), obs)
    }
}

impl deploy::ChunkScratch for Int8Scratch {
    fn input_buf(&mut self) -> &mut Tensor {
        &mut self.input
    }
}

impl PreparedNet for Int8Prepared {
    fn kind(&self) -> BackendKind {
        BackendKind::Int8
    }

    fn input_hw(&self) -> usize {
        self.input_hw
    }

    fn input_ch(&self) -> usize {
        self.input_ch
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, pool: &Pool) -> Tensor {
        let obs = super::sample_obs(&self.obs, scratch, x);
        self.exec_pooled(x, &mut scratch.int8, false, pool, obs).0
    }

    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        pool: &Pool,
    ) -> (Tensor, Tensor) {
        let obs = super::sample_obs(&self.obs, scratch, x);
        let (logits, feat) = self.exec_pooled(x, &mut scratch.int8, true, pool, obs);
        (logits, feat.expect("arch has gap"))
    }
}
