//! `qft::par` — a shared, chunk-based thread pool for the integer kernel
//! path (S16).
//!
//! Design constraints (see `DESIGN.md` and the serving docs in [`crate`]):
//!
//! * **std only** — threads + channels + condvars; the image's cargo cache
//!   has no rayon/crossbeam, and the workloads are coarse, regular chunks,
//!   so work stealing buys nothing: every primitive here pre-partitions
//!   work into contiguous chunks and hands one chunk to one task.
//! * **one process-wide pool** — the serve [`crate::serve::Engine`] workers
//!   and [`crate::coordinator::eval::eval_backend`] all submit scopes
//!   to the same [`global`] pool, so concurrent callers cooperate (their
//!   tasks interleave on the same worker set) instead of oversubscribing
//!   the machine with per-caller pools.
//! * **bit-exactness contract** — parallel callers split work so that each
//!   task owns a *disjoint output row range* and runs the *identical serial
//!   inner loop* over it.  Per-element f32 accumulation order is therefore
//!   unchanged, and every parallel kernel is bit-identical to its serial
//!   twin at any thread count (enforced by `rust/tests/par.rs`).
//!
//! The submitting thread always participates: [`Pool::scope`] drains the
//! scope's own task queue before blocking on completion, so a pool of width
//! `t` runs `t-1` background workers, width 1 means fully serial, and a
//! nested scope opened from inside a pool task cannot deadlock (its opener
//! executes the nested tasks itself if every worker is busy).

// One of the two sanctioned `unsafe` sites in the crate (see the README
// "unsafe policy"): the scoped-task lifetime erasure in `Pool::scope`,
// sound because the scope latch blocks until every erased task has run.
#![allow(unsafe_code)]

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A borrowed unit of work: runs once, on the submitting thread or a pool
/// worker, strictly before the owning [`Pool::scope`] call returns.
pub type ScopedTask<'a> = Box<dyn FnOnce() + Send + 'a>;

/// One `scope()` call in flight: its pending tasks plus a completion latch.
struct Scope {
    queue: Mutex<Vec<Box<dyn FnOnce() + Send + 'static>>>,
    remaining: Mutex<usize>,
    done: Condvar,
    /// First panic payload from any task, re-raised by the scope owner so
    /// a parallel-only failure keeps its original diagnostic message.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Scope {
    /// Pop-and-run until this scope's queue is empty.  Each finished task
    /// decrements the latch; the last one wakes the scope owner.
    fn run_pending(&self) {
        loop {
            let task = self.queue.lock().unwrap().pop();
            let Some(task) = task else { return };
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)) {
                self.panic.lock().unwrap().get_or_insert(payload);
            }
            let mut rem = self.remaining.lock().unwrap();
            *rem -= 1;
            if *rem == 0 {
                self.done.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done.wait(rem).unwrap();
        }
    }
}

/// Chunk-based scoped thread pool (see module docs for the sharing and
/// bit-exactness contracts).
pub struct Pool {
    threads: usize,
    /// Scope hand-off to workers; `None` only during drop.
    tx: Mutex<Option<mpsc::Sender<Arc<Scope>>>>,
    workers: Vec<JoinHandle<()>>,
    /// Kernel scopes currently in flight (load signal for the adaptive
    /// [`crate::serve::Batcher`] policy; nested scopes count individually).
    active: AtomicUsize,
}

/// Decrements the pool's active-scope counter even if the scope re-raises
/// a task panic.
struct ActiveGuard<'a>(&'a AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Pool {
    /// Pool of total width `threads` (the submitting thread counts as one,
    /// so this spawns `threads - 1` background workers; width <= 1 is a
    /// fully serial pool with no threads at all).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Arc<Scope>>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (1..threads)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("qft-par-{i}"))
                    .spawn(move || loop {
                        // hold the receiver lock only for the recv itself
                        let scope = match rx.lock().unwrap().recv() {
                            Ok(s) => s,
                            Err(_) => return,
                        };
                        scope.run_pending();
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { threads, tx: Mutex::new(Some(tx)), workers, active: AtomicUsize::new(0) }
    }

    /// Total parallel width (background workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Kernel scopes currently executing on this pool — a cheap, racy load
    /// signal (0 = idle).  The serve batcher uses it to trade batching
    /// latency against pool saturation; correctness never depends on it.
    pub fn active_scopes(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// Run every task to completion before returning, using the calling
    /// thread plus up to `tasks.len() - 1` pool workers.  Tasks may borrow
    /// from the caller's stack (that is the point); the first panicking
    /// task's payload is re-raised here once all tasks have finished.
    pub fn scope<'a>(&self, tasks: Vec<ScopedTask<'a>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        self.active.fetch_add(1, Ordering::Relaxed);
        let _active = ActiveGuard(&self.active);
        if self.workers.is_empty() || n == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let mut queue: Vec<Box<dyn FnOnce() + Send + 'static>> = Vec::with_capacity(n);
        for t in tasks {
            // SAFETY: `scope` blocks on the latch below until every task has
            // run (and been dropped), so borrows captured with lifetime 'a
            // strictly outlive all uses; the 'static erasure never escapes.
            queue.push(unsafe {
                std::mem::transmute::<ScopedTask<'a>, Box<dyn FnOnce() + Send + 'static>>(t)
            });
        }
        let scope = Arc::new(Scope {
            queue: Mutex::new(queue),
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        // wake just enough workers; the caller takes a share itself
        let helpers = self.workers.len().min(n - 1);
        {
            let tx = self.tx.lock().unwrap();
            if let Some(tx) = tx.as_ref() {
                for _ in 0..helpers {
                    let _ = tx.send(scope.clone());
                }
            }
        }
        scope.run_pending();
        scope.wait();
        if let Some(payload) = scope.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Scoped parallel-for over chunk indices `0..chunks`: `f(i)` runs once
    /// per index, distributed across the pool, returning when all are done.
    pub fn par_for<F: Fn(usize) + Sync>(&self, chunks: usize, f: F) {
        let f = &f;
        let tasks: Vec<ScopedTask<'_>> = (0..chunks)
            .map(|i| Box::new(move || f(i)) as ScopedTask<'_>)
            .collect();
        self.scope(tasks);
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // closing the channel ends every worker's recv loop
        self.tx.lock().unwrap().take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Split `0..n` into at most `width` contiguous near-equal ranges of at
/// least `min_per_chunk` items each.  Deterministic in its inputs only —
/// chunk boundaries never depend on runtime state, and because parallel
/// kernels give each range a disjoint output block run by the serial inner
/// loop, the boundaries cannot affect results either.
pub fn chunk_ranges(n: usize, width: usize, min_per_chunk: usize) -> Vec<Range<usize>> {
    chunk_ranges_aligned(n, width, min_per_chunk, 1)
}

/// [`chunk_ranges`] with every chunk boundary (except the final end at `n`)
/// rounded up to a multiple of `align`.  The GEMM callers — the f32 conv /
/// matmul `_par` paths and the `lw-i8` intra-op conv chunks — pass
/// [`crate::kernel::MR`] so at most ONE chunk — the last — carries a ragged
/// register-tile remainder; alignment is pure perf, results never depend on
/// chunk boundaries (see above).
pub fn chunk_ranges_aligned(
    n: usize,
    width: usize,
    min_per_chunk: usize,
    align: usize,
) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let align = align.max(1);
    let chunks = width.max(1).min(n.div_ceil(min_per_chunk.max(1))).max(1);
    let per = n.div_ceil(chunks).div_ceil(align) * align;
    (0..n).step_by(per).map(|s| s..(s + per).min(n)).collect()
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// Build the process-wide pool at width `threads` (the `--threads` CLI
/// flag).  The build happens inside the same `get_or_init` that [`global`]
/// uses, so there is no configure-then-build window: whoever initializes
/// first wins atomically.  Returns `true` iff the pool now runs at the
/// requested width (i.e. this call built it, or an earlier one built it at
/// the same width).
pub fn configure_global(threads: usize) -> bool {
    let want = threads.max(1);
    GLOBAL.get_or_init(|| Pool::new(want)).threads() == want
}

/// The process-wide shared pool.  Built on first use at the
/// [`configure_global`]-requested width, else at `available_parallelism`.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| {
        Pool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_runs_every_index_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.par_for(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn scope_tasks_mutate_disjoint_slices() {
        let pool = Pool::new(3);
        let mut data = vec![0u64; 90];
        {
            let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
            for (ci, chunk) in data.chunks_mut(30).enumerate() {
                tasks.push(Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (ci * 100 + j) as u64;
                    }
                }));
            }
            pool.scope(tasks);
        }
        for ci in 0..3 {
            for j in 0..30 {
                assert_eq!(data[ci * 30 + j], (ci * 100 + j) as u64);
            }
        }
    }

    #[test]
    fn serial_pool_still_runs_everything() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.par_for(10, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn nested_scopes_complete() {
        // a task that opens its own scope must not deadlock the pool
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        pool.par_for(4, |_| {
            pool.par_for(4, |j| {
                total.fetch_add(j + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 10);
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = Pool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.par_for(3, |i| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        // the ORIGINAL payload must reach the scope owner, not a generic one
        let payload = caught.expect_err("panic must propagate to the scope owner");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // the pool is still usable afterwards
        let sum = AtomicUsize::new(0);
        pool.par_for(8, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 28);
    }

    #[test]
    fn global_configure_is_atomic_first_wins() {
        // NOTE: the only unit test allowed to touch GLOBAL — nothing else
        // in the lib test binary calls global()/configure_global, so the
        // first configure here deterministically builds the pool.
        assert!(configure_global(3), "first configure must build the pool");
        assert_eq!(global().threads(), 3);
        // same-width reconfigure reports success, different width refuses
        assert!(configure_global(3));
        assert!(!configure_global(5));
        assert_eq!(global().threads(), 3);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, width, min) in
            [(10, 4, 1), (10, 4, 8), (1, 8, 1), (100, 3, 7), (64, 64, 1), (5, 2, 100)]
        {
            let ranges = chunk_ranges(n, width, min);
            assert!(ranges.len() <= width.max(1));
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "ranges must be contiguous");
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, n, "ranges must cover 0..{n}");
        }
        assert!(chunk_ranges(0, 4, 1).is_empty());
    }

    #[test]
    fn aligned_chunk_boundaries_are_multiples() {
        for (n, width, min, align) in
            [(100usize, 4usize, 1usize, 4usize), (37, 8, 1, 4), (64, 3, 8, 8), (5, 4, 1, 4)]
        {
            let ranges = chunk_ranges_aligned(n, width, min, align);
            let mut next = 0;
            for (i, r) in ranges.iter().enumerate() {
                assert_eq!(r.start, next, "contiguous");
                assert!(r.end > r.start);
                if i + 1 < ranges.len() {
                    assert_eq!(r.end % align, 0, "interior boundary must be aligned");
                }
                next = r.end;
            }
            assert_eq!(next, n, "cover 0..{n}");
        }
        assert!(chunk_ranges_aligned(0, 4, 1, 4).is_empty());
    }

    #[test]
    fn active_scopes_tracks_in_flight_work() {
        let pool = Pool::new(2);
        assert_eq!(pool.active_scopes(), 0);
        let min_seen = AtomicUsize::new(usize::MAX);
        pool.par_for(4, |_| {
            min_seen.fetch_min(pool.active_scopes(), Ordering::SeqCst);
        });
        assert!(min_seen.load(Ordering::SeqCst) >= 1, "counter visible inside the scope");
        assert_eq!(pool.active_scopes(), 0, "counter returns to idle");
    }
}
