//! Open-loop Poisson load harness for the TCP front-end.
//!
//! Closed-loop load generators (each client waits for its reply before
//! sending again) *hide* queueing collapse: as the server slows down the
//! offered rate falls with it, so tail latency looks flat right up to the
//! cliff.  This harness is **open-loop**: every connection pre-computes a
//! Poisson arrival schedule (exponential inter-arrival times at the
//! configured rate) and sends each request at its scheduled instant
//! whether or not earlier replies have come back — and latency is measured
//! from the *scheduled* arrival, not the actual send, so time a request
//! spends waiting behind a slow socket counts against the server
//! (coordinated-omission-free measurement).
//!
//! The hot loop is allocation-free: each connection pre-encodes a small
//! pool of infer frames from deterministic [`crate::data::Dataset`] images
//! and patches only the 8 id bytes per send.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::data::{Dataset, Rng, Split};

use super::frame::{self, Frame};

/// One open-loop sweep configuration.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Server address (usually a [`super::NetServer::local_addr`]).
    pub addr: SocketAddr,
    /// Fleet wire key to target (`"arch/backend"`).
    pub slot_key: String,
    /// Image payload length the slot expects (floats).
    pub image_len: usize,
    /// Concurrent connections, each running its own arrival process.
    pub connections: usize,
    /// *Total* offered arrival rate (requests/s across all connections).
    pub rate_rps: f64,
    /// Measurement horizon.
    pub duration: Duration,
    /// Seed for schedules and images (deterministic per connection).
    pub seed: u64,
}

/// What one open-loop run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests the schedule offered (sent or attempted).
    pub offered: u64,
    /// Successful replies.
    pub replies: u64,
    /// Typed `Busy` sheds (admission control working as designed).
    pub shed: u64,
    /// Everything else: other error frames, I/O failures.
    pub errors: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
    /// Replies per wall-clock second.
    pub throughput_rps: f64,
    pub wall_s: f64,
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "open-loop: {} offered, {} replied, {} shed, {} errors in {:.2}s \
             ({:.1} replies/s)",
            self.offered, self.replies, self.shed, self.errors, self.wall_s, self.throughput_rps
        )?;
        write!(
            f,
            "latency-under-load (us, from scheduled arrival): p50 {} | p99 {} | p99.9 {} \
             | max {} | mean {:.1}",
            self.p50_us, self.p99_us, self.p999_us, self.max_us, self.mean_us
        )
    }
}

/// Frames each connection pre-encodes and cycles through (distinct images,
/// zero allocation in the send loop).
const FRAME_POOL: usize = 8;

/// Run one open-loop sweep: `connections` threads, each an independent
/// Poisson process at `rate_rps / connections`, all started together on a
/// barrier.  Returns merged counts and latency quantiles.
pub fn open_loop(cfg: &LoadConfig) -> Result<LoadReport> {
    assert!(cfg.connections >= 1, "need at least one connection");
    assert!(cfg.rate_rps > 0.0, "offered rate must be positive");
    let per_conn_rate = cfg.rate_rps / cfg.connections as f64;
    let start_gate = Barrier::new(cfg.connections);
    let results: Vec<Result<ConnResult>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|idx| {
                let gate = &start_gate;
                s.spawn(move || run_conn(cfg, idx, per_conn_rate, gate))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("load thread panicked")).collect()
    });
    let mut merged = ConnResult::default();
    for r in results {
        let r = r?;
        merged.offered += r.offered;
        merged.replies += r.replies;
        merged.shed += r.shed;
        merged.errors += r.errors;
        merged.latencies_us.extend_from_slice(&r.latencies_us);
        merged.wall = merged.wall.max(r.wall);
    }
    merged.latencies_us.sort_unstable();
    let lat = &merged.latencies_us;
    let q = |p: f64| -> u64 {
        if lat.is_empty() {
            return 0;
        }
        let rank = ((p * lat.len() as f64).ceil() as usize).clamp(1, lat.len());
        lat[rank - 1]
    };
    let wall_s = merged.wall.as_secs_f64().max(1e-9);
    Ok(LoadReport {
        offered: merged.offered,
        replies: merged.replies,
        shed: merged.shed,
        errors: merged.errors,
        p50_us: q(0.50),
        p99_us: q(0.99),
        p999_us: q(0.999),
        max_us: lat.last().copied().unwrap_or(0),
        mean_us: if lat.is_empty() {
            0.0
        } else {
            lat.iter().map(|&v| v as f64).sum::<f64>() / lat.len() as f64
        },
        throughput_rps: merged.replies as f64 / wall_s,
        wall_s,
    })
}

#[derive(Default)]
struct ConnResult {
    offered: u64,
    replies: u64,
    shed: u64,
    errors: u64,
    latencies_us: Vec<u64>,
    wall: Duration,
}

fn run_conn(
    cfg: &LoadConfig,
    idx: usize,
    per_conn_rate: f64,
    gate: &Barrier,
) -> Result<ConnResult> {
    // setup before the barrier, but ALWAYS reach the barrier — a failed
    // connect must not strand the other connections' gate.wait()
    let setup = conn_setup(cfg, idx, per_conn_rate);
    gate.wait();
    let (mut stream, schedule, mut pool) = setup?;
    let reader = stream.try_clone().context("clone stream for reader")?;

    let mut out = ConnResult::default();
    let t0 = Instant::now();
    let (replies, shed, frame_errors, latencies) = std::thread::scope(|s| {
        // reader thread: replies come back in request order but are read
        // INDEPENDENTLY of the send schedule, so a slow server delays
        // replies, never the offered load (true open loop).  The echoed id
        // indexes the schedule, anchoring latency at the scheduled arrival
        // (coordinated-omission-free).
        let schedule = &schedule;
        let h = s.spawn(move || {
            let mut reader = reader;
            let (mut replies, mut shed, mut errors) = (0u64, 0u64, 0u64);
            let mut lats: Vec<u64> = Vec::with_capacity(schedule.len());
            loop {
                match frame::read_frame(&mut reader) {
                    Ok(Frame::Reply { id, .. }) => {
                        let at = schedule.get(id as usize).copied().unwrap_or_default();
                        lats.push(t0.elapsed().saturating_sub(at).as_micros() as u64);
                        replies += 1;
                    }
                    Ok(Frame::Error { code: super::ErrCode::Busy, .. }) => shed += 1,
                    Ok(_) => errors += 1,
                    // EOF after the server drained the pipeline is the
                    // normal end; anything lost shows up in the caller's
                    // offered-vs-answered reconciliation
                    Err(_) => break,
                }
            }
            (replies, shed, errors, lats)
        });
        // writer (this thread): fire each request at its scheduled
        // arrival, whether or not earlier replies have come back
        for (i, &at) in schedule.iter().enumerate() {
            let now = t0.elapsed();
            if at > now {
                std::thread::sleep(at - now);
            }
            out.offered += 1;
            let buf = &mut pool[i % FRAME_POOL];
            buf[8..16].copy_from_slice(&(i as u64).to_le_bytes());
            if stream.write_all(buf).is_err() {
                break;
            }
        }
        // half-close: the server drains what is pipelined, replies, sees
        // EOF, and closes — which ends the reader loop
        let _ = stream.shutdown(std::net::Shutdown::Write);
        h.join().expect("reader thread panicked")
    });
    out.replies = replies;
    out.shed = shed;
    out.latencies_us = latencies;
    // whatever was offered but never answered (send failures, lost
    // replies, malformed answers) counts as an error
    out.errors = frame_errors + out.offered.saturating_sub(replies + shed + frame_errors);
    out.wall = t0.elapsed();
    Ok(out)
}

type ConnSetup = (TcpStream, Vec<Duration>, Vec<Vec<u8>>);

/// Connect and pre-compute this connection's schedule + frame pool.
fn conn_setup(cfg: &LoadConfig, idx: usize, per_conn_rate: f64) -> Result<ConnSetup> {
    let stream = TcpStream::connect(cfg.addr)
        .with_context(|| format!("load conn {idx}: connect {}", cfg.addr))?;
    stream.set_nodelay(true).context("nodelay")?;
    stream.set_read_timeout(Some(Duration::from_secs(30))).context("read timeout")?;

    // Poisson schedule: exponential inter-arrival gaps at this
    // connection's share of the offered rate, pre-computed so the hot loop
    // does no float math
    let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B9).wrapping_add(idx as u64 + 1));
    let horizon = cfg.duration.as_secs_f64();
    let mut schedule: Vec<Duration> = Vec::new();
    let mut t = 0.0f64;
    loop {
        let u = rng.uniform() as f64;
        t += -(1.0 - u).max(1e-12).ln() / per_conn_rate;
        if t >= horizon {
            break;
        }
        schedule.push(Duration::from_secs_f64(t));
    }

    // pre-encoded frame pool: distinct deterministic images, id patched in
    // place per send
    let ds = Dataset::new(cfg.seed.wrapping_add(idx as u64));
    let pool: Vec<Vec<u8>> = (0..FRAME_POOL)
        .map(|i| {
            let (mut img, _) = ds.sample(Split::Val, i as u64);
            // the slot's contract may differ from the dataset's native
            // size; cycle or truncate to fit
            if img.len() != cfg.image_len {
                let src = img.clone();
                img = (0..cfg.image_len).map(|j| src[j % src.len()]).collect();
            }
            Frame::Infer { id: 0, slot_key: cfg.slot_key.clone(), image: img }.encode()
        })
        .collect();
    Ok((stream, schedule, pool))
}
