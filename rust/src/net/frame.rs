//! Length-prefixed binary wire protocol for the serving front-end.
//!
//! Every frame is a fixed 20-byte header followed by a bounded payload:
//!
//! ```text
//! offset  size  field
//!      0     4  magic            b"QFN1"
//!      4     1  version          0x01
//!      5     1  frame type       1 = infer, 2 = reply, 3 = error,
//!                                4 = stats-pull, 5 = stats-delta,
//!                                6 = stats-ack
//!      6     2  reserved         must be 0
//!      8     8  request id       u64 LE (echoed verbatim in the reply)
//!     16     4  payload length   u32 LE, <= MAX_PAYLOAD (1 MiB)
//!     20     n  payload          (per frame type, below)
//! ```
//!
//! Payloads (all integers little-endian):
//!
//! * **infer** — `[slot_len: u16][slot key: utf8][image: f32 × n]`; the
//!   image region must be a multiple of 4 bytes.  The slot key is the
//!   fleet wire key (`"arch/backend"`, e.g. `"synthetic/lw-i8"`).
//! * **reply** — `[top1: u16][batch: u16][latency_us: u32][logits: f32 × n]`.
//! * **error** — `[code: u16][message: utf8]`; codes mirror
//!   [`crate::serve::Reject`] plus the framing failures ([`ErrCode`]).
//! * **stats-pull** — `[ver: u8 = 1]`; asks the server for its merged
//!   cluster stats (answered with a stats-delta).  Trailing bytes are
//!   reserved and ignored.
//! * **stats-delta** — `[ver: u8 = 1][cluster stats]`; one replica's
//!   merged CRDT state, encoded by
//!   [`crate::cluster::ClusterStats::encode`] (the version byte is part of
//!   that encoding).
//! * **stats-ack** — `[ver: u8 = 1][n: u32][replica id: u64 × n]`; the
//!   replica ids the receiver knows after absorbing a stats-delta.
//!
//! Every frame type lives in the [`REGISTRY`] — a [`FrameKind`] entry
//! carrying the type code, a minimum payload length, and the decoder fn —
//! so new control frames register in one place instead of growing a
//! match-on-type-byte in three.
//!
//! Decoding is total: any byte sequence either yields a frame or a typed
//! [`FrameError`] — never a panic, never an allocation proportional to a
//! lying length prefix (lengths are validated against [`MAX_PAYLOAD`] and
//! the bytes actually present before anything is copied).

use std::io::{Read, Write};

use crate::serve::Reject;

/// First four bytes of every frame (and what the server sniffs to tell
/// binary clients from HTTP ones on the same port).
pub const MAGIC: [u8; 4] = *b"QFN1";
/// Protocol version carried in byte 4.
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 20;
/// Upper bound on a payload: large enough for any deployment image
/// (`224*224*4` floats ≈ 784 KiB), small enough that a lying length prefix
/// cannot balloon allocation.
pub const MAX_PAYLOAD: usize = 1 << 20;

pub const TY_INFER: u8 = 1;
pub const TY_REPLY: u8 = 2;
pub const TY_ERROR: u8 = 3;
pub const TY_STATS_PULL: u8 = 4;
pub const TY_STATS_DELTA: u8 = 5;
pub const TY_STATS_ACK: u8 = 6;

/// Typed error codes carried in error-frame payloads.  The first four
/// mirror [`Reject`] (engine-side admission failures); the rest are
/// framing failures the server answers before a request ever reaches the
/// engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    UnknownSlot,
    PayloadSize,
    Busy,
    Shutdown,
    BadMagic,
    BadVersion,
    Oversized,
    Truncated,
    Malformed,
    Internal,
}

impl ErrCode {
    pub fn as_u16(self) -> u16 {
        match self {
            ErrCode::UnknownSlot => 1,
            ErrCode::PayloadSize => 2,
            ErrCode::Busy => 3,
            ErrCode::Shutdown => 4,
            ErrCode::BadMagic => 5,
            ErrCode::BadVersion => 6,
            ErrCode::Oversized => 7,
            ErrCode::Truncated => 8,
            ErrCode::Malformed => 9,
            ErrCode::Internal => 10,
        }
    }

    pub fn from_u16(v: u16) -> Option<ErrCode> {
        Some(match v {
            1 => ErrCode::UnknownSlot,
            2 => ErrCode::PayloadSize,
            3 => ErrCode::Busy,
            4 => ErrCode::Shutdown,
            5 => ErrCode::BadMagic,
            6 => ErrCode::BadVersion,
            7 => ErrCode::Oversized,
            8 => ErrCode::Truncated,
            9 => ErrCode::Malformed,
            10 => ErrCode::Internal,
            _ => return None,
        })
    }

    /// Stable lowercase name (HTTP shim error bodies, logs).
    pub fn key(self) -> &'static str {
        match self {
            ErrCode::UnknownSlot => "unknown_slot",
            ErrCode::PayloadSize => "payload_size",
            ErrCode::Busy => "busy",
            ErrCode::Shutdown => "shutdown",
            ErrCode::BadMagic => "bad_magic",
            ErrCode::BadVersion => "bad_version",
            ErrCode::Oversized => "oversized",
            ErrCode::Truncated => "truncated",
            ErrCode::Malformed => "malformed",
            ErrCode::Internal => "internal",
        }
    }
}

/// Typed decode failure.  [`decode`] returns these instead of panicking on
/// any input; the server turns them into error frames via
/// [`Frame::from_frame_error`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// First four bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unsupported protocol version.
    BadVersion(u8),
    /// Unknown frame type byte.
    BadType(u8),
    /// Length prefix exceeds [`MAX_PAYLOAD`].
    Oversized { len: usize, max: usize },
    /// Fewer bytes than the header + length prefix promise.
    Truncated { want: usize, got: usize },
    /// Header fine, payload internally inconsistent.
    Malformed(&'static str),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad magic {m:02x?} (want {MAGIC:02x?})"),
            FrameError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            FrameError::BadType(t) => write!(f, "unknown frame type {t}"),
            FrameError::Oversized { len, max } => {
                write!(f, "payload length {len} exceeds maximum {max}")
            }
            FrameError::Truncated { want, got } => {
                write!(f, "truncated frame: want {want} bytes, got {got}")
            }
            FrameError::Malformed(why) => write!(f, "malformed payload: {why}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl FrameError {
    /// The wire code an error frame reporting this failure carries.
    pub fn code(&self) -> ErrCode {
        match self {
            FrameError::BadMagic(_) => ErrCode::BadMagic,
            FrameError::BadVersion(_) => ErrCode::BadVersion,
            FrameError::BadType(_) | FrameError::Malformed(_) => ErrCode::Malformed,
            FrameError::Oversized { .. } => ErrCode::Oversized,
            FrameError::Truncated { .. } => ErrCode::Truncated,
        }
    }
}

/// One decoded wire frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → server: classify `image` on fleet slot `slot_key`.
    Infer { id: u64, slot_key: String, image: Vec<f32> },
    /// Server → client: the classification result.
    Reply { id: u64, top1: u16, batch: u16, latency_us: u32, logits: Vec<f32> },
    /// Server → client: typed failure (admission or framing).
    Error { id: u64, code: ErrCode, msg: String },
    /// Client → server: "send me your merged cluster stats".
    StatsPull { id: u64 },
    /// Either direction: one replica's merged CRDT state (a full state is
    /// a valid delta).
    StatsDelta { id: u64, delta: crate::cluster::ClusterStats },
    /// Server → client: replica ids known after absorbing a stats-delta.
    StatsAck { id: u64, replicas: Vec<u64> },
}

impl Frame {
    pub fn id(&self) -> u64 {
        match self {
            Frame::Infer { id, .. }
            | Frame::Reply { id, .. }
            | Frame::Error { id, .. }
            | Frame::StatsPull { id }
            | Frame::StatsDelta { id, .. }
            | Frame::StatsAck { id, .. } => *id,
        }
    }

    /// The error frame mirroring an engine-side [`Reject`].
    pub fn from_reject(id: u64, r: &Reject) -> Frame {
        let code = match r {
            Reject::UnknownSlot { .. } => ErrCode::UnknownSlot,
            Reject::PayloadSize { .. } => ErrCode::PayloadSize,
            Reject::Busy { .. } => ErrCode::Busy,
            Reject::Shutdown => ErrCode::Shutdown,
        };
        Frame::Error { id, code, msg: r.to_string() }
    }

    /// The error frame reporting a framing failure.
    pub fn from_frame_error(id: u64, e: &FrameError) -> Frame {
        Frame::Error { id, code: e.code(), msg: e.to_string() }
    }

    /// Serialize to header + payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        let (ty, payload) = match self {
            Frame::Infer { slot_key, image, .. } => {
                let key = slot_key.as_bytes();
                let n = key.len().min(u16::MAX as usize);
                let mut p = Vec::with_capacity(2 + n + image.len() * 4);
                p.extend_from_slice(&(n as u16).to_le_bytes());
                p.extend_from_slice(&key[..n]);
                for v in image {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                (TY_INFER, p)
            }
            Frame::Reply { top1, batch, latency_us, logits, .. } => {
                let mut p = Vec::with_capacity(8 + logits.len() * 4);
                p.extend_from_slice(&top1.to_le_bytes());
                p.extend_from_slice(&batch.to_le_bytes());
                p.extend_from_slice(&latency_us.to_le_bytes());
                for v in logits {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                (TY_REPLY, p)
            }
            Frame::Error { code, msg, .. } => {
                let m = msg.as_bytes();
                let n = m.len().min(MAX_PAYLOAD - 2);
                let mut p = Vec::with_capacity(2 + n);
                p.extend_from_slice(&code.as_u16().to_le_bytes());
                p.extend_from_slice(&m[..n]);
                (TY_ERROR, p)
            }
            Frame::StatsPull { .. } => (TY_STATS_PULL, vec![crate::cluster::STATS_VERSION]),
            Frame::StatsDelta { delta, .. } => (TY_STATS_DELTA, delta.encode()),
            Frame::StatsAck { replicas, .. } => {
                let mut p = Vec::with_capacity(5 + replicas.len() * 8);
                p.push(crate::cluster::STATS_VERSION);
                p.extend_from_slice(&(replicas.len() as u32).to_le_bytes());
                for r in replicas {
                    p.extend_from_slice(&r.to_le_bytes());
                }
                (TY_STATS_ACK, p)
            }
        };
        debug_assert!(payload.len() <= MAX_PAYLOAD);
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(ty);
        out.extend_from_slice(&[0, 0]);
        out.extend_from_slice(&self.id().to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

/// Validated header fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    pub ty: u8,
    pub id: u64,
    pub len: usize,
}

/// One registered wire frame type: its code, a human name (logs, docs),
/// the minimum payload length its decoder requires (checked centrally,
/// with `short_payload` as the malformed-payload reason), and the decoder
/// itself.  New control frames add a [`REGISTRY`] entry instead of growing
/// the header validator and the payload dispatcher separately.
pub struct FrameKind {
    pub code: u8,
    pub name: &'static str,
    pub min_payload: usize,
    pub short_payload: &'static str,
    decode: fn(u64, &[u8]) -> Result<Frame, FrameError>,
}

/// Every frame type this protocol version speaks.
pub const REGISTRY: &[FrameKind] = &[
    FrameKind {
        code: TY_INFER,
        name: "infer",
        min_payload: 2,
        short_payload: "infer payload shorter than slot_len",
        decode: decode_infer,
    },
    FrameKind {
        code: TY_REPLY,
        name: "reply",
        min_payload: 8,
        short_payload: "reply payload shorter than its fixed part",
        decode: decode_reply,
    },
    FrameKind {
        code: TY_ERROR,
        name: "error",
        min_payload: 2,
        short_payload: "error payload shorter than its code",
        decode: decode_error,
    },
    FrameKind {
        code: TY_STATS_PULL,
        name: "stats-pull",
        min_payload: 1,
        short_payload: "stats payload shorter than its version byte",
        decode: decode_stats_pull,
    },
    FrameKind {
        code: TY_STATS_DELTA,
        name: "stats-delta",
        min_payload: 1,
        short_payload: "stats payload shorter than its version byte",
        decode: decode_stats_delta,
    },
    FrameKind {
        code: TY_STATS_ACK,
        name: "stats-ack",
        min_payload: 5,
        short_payload: "stats-ack payload shorter than its fixed part",
        decode: decode_stats_ack,
    },
];

/// Look a type byte up in the [`REGISTRY`].
pub fn frame_kind(ty: u8) -> Option<&'static FrameKind> {
    REGISTRY.iter().find(|k| k.code == ty)
}

/// Validate a full 20-byte header: magic, version, registered type, and
/// the length prefix against [`MAX_PAYLOAD`].
pub fn parse_header(h: &[u8; HEADER_LEN]) -> Result<Header, FrameError> {
    if h[..4] != MAGIC {
        return Err(FrameError::BadMagic([h[0], h[1], h[2], h[3]]));
    }
    if h[4] != VERSION {
        return Err(FrameError::BadVersion(h[4]));
    }
    let ty = h[5];
    if frame_kind(ty).is_none() {
        return Err(FrameError::BadType(ty));
    }
    let id = u64::from_le_bytes(h[8..16].try_into().unwrap());
    let len = u32::from_le_bytes(h[16..20].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversized { len, max: MAX_PAYLOAD });
    }
    Ok(Header { ty, id, len })
}

/// Decode a payload whose header already validated: registry lookup, the
/// central minimum-length check, then the type's decoder.
pub fn decode_payload(ty: u8, id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    let kind = frame_kind(ty).ok_or(FrameError::BadType(ty))?;
    if p.len() < kind.min_payload {
        return Err(FrameError::Malformed(kind.short_payload));
    }
    (kind.decode)(id, p)
}

fn decode_infer(id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    let n = u16::from_le_bytes([p[0], p[1]]) as usize;
    if 2 + n > p.len() {
        return Err(FrameError::Malformed("slot key runs past the payload"));
    }
    let slot_key = std::str::from_utf8(&p[2..2 + n])
        .map_err(|_| FrameError::Malformed("slot key is not utf-8"))?
        .to_string();
    let img = &p[2 + n..];
    if img.len() % 4 != 0 {
        return Err(FrameError::Malformed("image region is not a multiple of 4 bytes"));
    }
    let image = img
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Frame::Infer { id, slot_key, image })
}

fn decode_reply(id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    let rest = &p[8..];
    if rest.len() % 4 != 0 {
        return Err(FrameError::Malformed("logits region is not a multiple of 4 bytes"));
    }
    Ok(Frame::Reply {
        id,
        top1: u16::from_le_bytes([p[0], p[1]]),
        batch: u16::from_le_bytes([p[2], p[3]]),
        latency_us: u32::from_le_bytes([p[4], p[5], p[6], p[7]]),
        logits: rest
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    })
}

fn decode_error(id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    let code = ErrCode::from_u16(u16::from_le_bytes([p[0], p[1]]))
        .ok_or(FrameError::Malformed("unknown error code"))?;
    let msg = String::from_utf8_lossy(&p[2..]).into_owned();
    Ok(Frame::Error { id, code, msg })
}

fn decode_stats_pull(id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    if p[0] != crate::cluster::STATS_VERSION {
        return Err(FrameError::Malformed("unsupported stats version"));
    }
    Ok(Frame::StatsPull { id })
}

fn decode_stats_delta(id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    let delta = crate::cluster::ClusterStats::decode(p).map_err(FrameError::Malformed)?;
    Ok(Frame::StatsDelta { id, delta })
}

fn decode_stats_ack(id: u64, p: &[u8]) -> Result<Frame, FrameError> {
    if p[0] != crate::cluster::STATS_VERSION {
        return Err(FrameError::Malformed("unsupported stats version"));
    }
    let n = u32::from_le_bytes([p[1], p[2], p[3], p[4]]) as usize;
    let need = n.checked_mul(8).ok_or(FrameError::Malformed("stats-ack length overflow"))?;
    let body = &p[5..];
    if body.len() != need {
        return Err(FrameError::Malformed("stats-ack replica region length mismatch"));
    }
    let replicas = body
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Frame::StatsAck { id, replicas })
}

/// Decode one frame from the front of `buf`; on success also returns how
/// many bytes it consumed (trailing bytes are the next frame).  Total over
/// arbitrary input — every failure is a typed [`FrameError`].
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
    if buf.len() < HEADER_LEN {
        // report the most specific failure the bytes present allow, so a
        // short garbage prefix is "bad magic", not "truncated"
        if buf.len() >= 4 && buf[..4] != MAGIC {
            return Err(FrameError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
        }
        if buf.len() >= 5 && buf[4] != VERSION {
            return Err(FrameError::BadVersion(buf[4]));
        }
        return Err(FrameError::Truncated { want: HEADER_LEN, got: buf.len() });
    }
    let hdr: &[u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().unwrap();
    let h = parse_header(hdr)?;
    let total = HEADER_LEN + h.len;
    if buf.len() < total {
        return Err(FrameError::Truncated { want: total, got: buf.len() });
    }
    let frame = decode_payload(h.ty, h.id, &buf[HEADER_LEN..total])?;
    Ok((frame, total))
}

/// Blocking client-side read of one whole frame (test + load-harness
/// helper; the server has its own poll-aware read path).
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Frame> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr)?;
    let h = parse_header(&hdr)?;
    let mut payload = vec![0u8; h.len];
    r.read_exact(&mut payload)?;
    Ok(decode_payload(h.ty, h.id, &payload)?)
}

/// Write one frame and flush; returns the encoded byte count.
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> std::io::Result<usize> {
    let bytes = f.encode();
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(bytes.len())
}
