//! Minimal HTTP/1.1 shim so `curl` can reach the serving engine without a
//! binary-protocol client.
//!
//! One request per connection (`Connection: close`), three routes:
//!
//! * `GET /healthz` — `200 ok` while serving, `503 draining` once
//!   shutdown has begun (load-balancer health probe semantics);
//! * `GET /metrics` — Prometheus text exposition from [`crate::obs`];
//! * `POST /infer` — body `{"slot": "arch/backend", "image": [f32, …]}`,
//!   reply `{"id", "top1", "batch", "latency_us", "logits"}`; admission
//!   failures map onto HTTP status codes (`Busy` → 429, unknown slot →
//!   404, shutdown → 503, malformed → 400).
//!
//! This is a shim, not a web server: no keep-alive, no chunked encoding,
//! no TLS — the binary protocol ([`super::frame`]) is the production
//! path, and everything here routes through the same
//! [`super::serve_infer`] admission logic.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::obs;
use crate::util::json::Value;

use super::frame::MAX_PAYLOAD;
use super::{read_exact_poll, serve_infer, ConnCtx, ErrCode, Frame};

/// Largest request head (request line + headers) the shim will buffer.
const MAX_HEAD: usize = 16 * 1024;
/// Whole-request deadline: a client must deliver head + body within this.
const REQUEST_DEADLINE: Duration = Duration::from_secs(10);

/// Serve one HTTP request on a freshly sniffed connection.  `first` holds
/// the already-consumed sniff bytes (the start of the request line).
pub(crate) fn handle(
    mut stream: TcpStream,
    first: &[u8],
    ctx: &ConnCtx,
    shed_conn: bool,
) -> std::io::Result<()> {
    let deadline = Instant::now() + REQUEST_DEADLINE;
    let mut buf: Vec<u8> = first.to_vec();
    // read until the blank line ending the head
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return respond(&mut stream, 431, "request head too large\n", "text/plain");
        }
        if Instant::now() > deadline || ctx.stop.load(Ordering::SeqCst) {
            return respond(&mut stream, 408, "request timeout\n", "text/plain");
        }
        let mut chunk = [0u8; 1024];
        match std::io::Read::read(&mut stream, &mut chunk) {
            Ok(0) => return Ok(()), // peer gave up
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.lines();
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let content_length = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    obs::net_metrics().bytes_in.add((head_end + 4) as u64);

    match (method, path) {
        ("GET", "/healthz") => {
            if ctx.stop.load(Ordering::SeqCst) {
                respond(&mut stream, 503, "draining\n", "text/plain")
            } else {
                respond(&mut stream, 200, "ok\n", "text/plain")
            }
        }
        ("GET", "/metrics") => {
            let body = obs::render_prometheus();
            respond(&mut stream, 200, &body, "text/plain; version=0.0.4")
        }
        ("POST", "/infer") => {
            if content_length > MAX_PAYLOAD {
                return respond(&mut stream, 413, "body too large\n", "text/plain");
            }
            // part of the body may already sit in the sniff buffer
            let mut body = buf[head_end + 4..].to_vec();
            if body.len() > content_length {
                body.truncate(content_length);
            }
            let already = body.len();
            body.resize(content_length, 0);
            if content_length > already
                && !read_exact_poll(&mut stream, &mut body[already..], &ctx.stop, false)?
            {
                return Ok(());
            }
            obs::net_metrics().bytes_in.add((content_length - already) as u64);
            infer(&mut stream, &body, ctx, shed_conn)
        }
        _ => respond(&mut stream, 404, "not found\n", "text/plain"),
    }
}

/// `POST /infer` body → [`serve_infer`] → JSON response.
fn infer(
    stream: &mut TcpStream,
    body: &[u8],
    ctx: &ConnCtx,
    shed_conn: bool,
) -> std::io::Result<()> {
    let parsed = std::str::from_utf8(body)
        .ok()
        .and_then(|t| Value::parse(t).ok())
        .and_then(|v| {
            let slot = v.get("slot").ok()?.str().ok()?.to_string();
            let image: Option<Vec<f32>> = v
                .get("image")
                .ok()?
                .arr()
                .ok()?
                .iter()
                .map(|x| x.num().ok().map(|n| n as f32))
                .collect();
            Some((slot, image?))
        });
    let Some((slot, image)) = parsed else {
        return respond(
            stream,
            400,
            "body must be {\"slot\": \"arch/backend\", \"image\": [..]}\n",
            "text/plain",
        );
    };
    match serve_infer(ctx, 0, &slot, image, shed_conn) {
        Frame::Reply { id, top1, batch, latency_us, logits } => {
            let mut m = std::collections::HashMap::new();
            m.insert("id".to_string(), Value::Num(id as f64));
            m.insert("top1".to_string(), Value::Num(top1 as f64));
            m.insert("batch".to_string(), Value::Num(batch as f64));
            m.insert("latency_us".to_string(), Value::Num(latency_us as f64));
            m.insert(
                "logits".to_string(),
                Value::Arr(logits.iter().map(|&v| Value::Num(v as f64)).collect()),
            );
            let body = Value::Obj(m).to_string_compact();
            respond(stream, 200, &body, "application/json")
        }
        Frame::Error { code, msg, .. } => {
            let status = match code {
                ErrCode::UnknownSlot => 404,
                ErrCode::Busy => 429,
                ErrCode::Shutdown => 503,
                ErrCode::Internal => 500,
                _ => 400,
            };
            let mut m = std::collections::HashMap::new();
            m.insert("error".to_string(), Value::Str(code.key().to_string()));
            m.insert("message".to_string(), Value::Str(msg));
            let body = Value::Obj(m).to_string_compact();
            respond(stream, status, &body, "application/json")
        }
        Frame::Infer { .. } => {
            respond(stream, 500, "internal: unexpected frame\n", "text/plain")
        }
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one full response and count its bytes.
fn respond(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    content_type: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        status_reason(status),
        body.len(),
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    obs::net_metrics().bytes_out.add((head.len() + body.len()) as u64);
    Ok(())
}
