//! `qft::net` — TCP serving front-end over the [`crate::serve`] engine.
//!
//! The ROADMAP's serving stack ends, before this module, at in-process
//! calls into the batcher; `qft::net` puts that engine on a wire.  One
//! listener speaks two protocols, told apart by sniffing the first four
//! bytes of each connection:
//!
//! * the length-prefixed **binary protocol** ([`frame`]) — magic +
//!   version + fleet slot key + f32 payload, with typed error frames
//!   mirroring [`crate::serve::Reject`]; a connection pipelines any number
//!   of requests;
//! * a minimal **HTTP/1.1 shim** ([`http`]) so `curl` works: `POST
//!   /infer` (JSON), `GET /healthz`, and `GET /metrics` (Prometheus text
//!   from [`crate::obs`]).
//!
//! The binary side also speaks the cluster control frames: every server
//! owns a [`crate::cluster::ClusterNode`], answers `stats-pull` with its
//! merged CRDT state as a `stats-delta`, and folds incoming `stats-delta`
//! frames in (acknowledged with `stats-ack`) — see [`crate::cluster`].
//!
//! Admission control ([`crate::serve::Client::try_submit`]): a full
//! batcher queue sheds the request with an explicit `Busy` frame (HTTP
//! 429) instead of stalling the connection and letting the queue collapse;
//! a connection accepted over `max_conns` gets `Busy` for its first
//! request and is closed.  Graceful shutdown ([`NetServer::shutdown`]):
//! stop accepting, unblock per-connection reads, finish in-flight work via
//! [`crate::serve::Engine::drain`] (bounded, with a dropped-request
//! count), then close.
//!
//! Std-only by design — acceptor threads + a thread per connection over
//! blocking sockets with short read timeouts.  The engine batches across
//! connections, so concurrency is bounded by `max_conns`, not by kernel
//! threads doing work: connection threads spend their life parked in
//! `read()`.  [`load`] is the open-loop Poisson load harness behind
//! `cargo bench --bench net_load` and `repro net-bench`.

pub mod frame;
pub mod http;
pub mod load;

pub use frame::{ErrCode, Frame, FrameError};
pub use load::{open_loop, LoadConfig, LoadReport};

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::{ClusterNode, ReplicaId};
use crate::fleet::Fleet;
use crate::obs;
use crate::serve::{Client, DrainReport, Engine, Reject};

/// Front-end knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Listen address (`"127.0.0.1:0"` picks an ephemeral port).
    pub addr: String,
    /// Acceptor threads sharing the one listener.
    pub acceptors: usize,
    /// Connection cap: connections accepted beyond this answer their first
    /// request with `Busy` and are closed.
    pub max_conns: usize,
    /// Per-request engine reply deadline.
    pub infer_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            acceptors: 1,
            max_conns: 256,
            infer_timeout: Duration::from_secs(30),
        }
    }
}

/// How long a connection blocked in a read may linger after shutdown
/// begins before its read errors out.
const STOP_GRACE: Duration = Duration::from_secs(2);
/// Read-timeout quantum: how often a parked read rechecks the stop flag.
const POLL: Duration = Duration::from_millis(50);

/// Shared per-connection context.
pub(crate) struct ConnCtx {
    pub client: Client,
    pub fleet: Arc<Fleet>,
    pub cluster: Arc<ClusterNode>,
    pub stop: Arc<AtomicBool>,
    pub infer_timeout: Duration,
}

/// A listening front-end over a running [`Engine`].
pub struct NetServer {
    engine: Engine,
    local_addr: SocketAddr,
    cluster: Arc<ClusterNode>,
    stop: Arc<AtomicBool>,
    acceptors: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// What [`NetServer::shutdown`] returns: where it listened plus the
/// engine's bounded-drain outcome.
#[derive(Debug)]
pub struct NetReport {
    pub addr: SocketAddr,
    pub drain: DrainReport,
}

impl NetServer {
    /// Bind `cfg.addr` and start accepting on top of a running engine.
    pub fn start(engine: Engine, cfg: &NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("net: cannot bind {}", cfg.addr))?;
        let local_addr = listener.local_addr().context("net: local_addr")?;
        // non-blocking listener + poll: accept() cannot be woken portably,
        // so acceptors must never park in it if shutdown is to be prompt
        listener.set_nonblocking(true).context("net: set_nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::default();
        let active = Arc::new(AtomicUsize::new(0));
        let max_conns = cfg.max_conns.max(1);
        let infer_timeout = cfg.infer_timeout;
        // one CRDT cell per server: its ReplicaId keys every G-Counter
        // entry this process contributes to the cluster state
        let cluster = Arc::new(ClusterNode::new(ReplicaId::fresh()));
        obs::set_replica(&cluster.replica().hex());
        let acceptors = (0..cfg.acceptors.max(1))
            .map(|_| {
                let listener = listener.try_clone().context("net: clone listener")?;
                let stop = stop.clone();
                let conns = conns.clone();
                let active = active.clone();
                let client = engine.client();
                let fleet = engine.fleet().clone();
                let cluster = cluster.clone();
                Ok(std::thread::spawn(move || {
                    accept_loop(&listener, &stop, &conns, &active, max_conns, infer_timeout,
                        client, fleet, cluster);
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(NetServer { engine, local_addr, cluster, stop, acceptors, conns })
    }

    /// Where the listener actually bound (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Handle for in-process submissions alongside the wire.
    pub fn client(&self) -> Client {
        self.engine.client()
    }

    /// This server's CRDT cell (replica id + absorbed peer state).
    pub fn cluster(&self) -> &Arc<ClusterNode> {
        &self.cluster
    }

    /// Graceful shutdown: stop accepting, unblock connection reads, join
    /// them, then [`Engine::drain`] with `timeout` — in-flight and queued
    /// requests get up to that long to finish; the rest are answered with
    /// typed `Shutdown` rejections and counted in the report.
    pub fn shutdown(self, timeout: Duration) -> NetReport {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.acceptors {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        NetReport { addr: self.local_addr, drain: self.engine.drain(timeout) }
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: &TcpListener,
    stop: &Arc<AtomicBool>,
    conns: &Arc<Mutex<Vec<JoinHandle<()>>>>,
    active: &Arc<AtomicUsize>,
    max_conns: usize,
    infer_timeout: Duration,
    client: Client,
    fleet: Arc<Fleet>,
    cluster: Arc<ClusterNode>,
) {
    while !stop.load(Ordering::SeqCst) {
        let (stream, _peer) = match listener.accept() {
            Ok(ok) => ok,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            Err(_) => {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        obs::net_metrics().conns_accepted.add(1);
        let n = active.fetch_add(1, Ordering::SeqCst) + 1;
        obs::net_metrics().conns_active.set(n as i64);
        // over the cap: still answer — one typed Busy for the first parsed
        // request, then close — so the client learns *why*, in-protocol
        let shed_conn = n > max_conns;
        let ctx = ConnCtx {
            client: client.clone(),
            fleet: fleet.clone(),
            cluster: cluster.clone(),
            stop: stop.clone(),
            infer_timeout,
        };
        let active = active.clone();
        let handle = std::thread::spawn(move || {
            let _ = handle_conn(stream, &ctx, shed_conn);
            let n = active.fetch_sub(1, Ordering::SeqCst) - 1;
            obs::net_metrics().conns_active.set(n as i64);
        });
        let mut held = conns.lock().unwrap();
        held.retain(|h| !h.is_finished());
        held.push(handle);
    }
}

/// Serve one connection: sniff the first four bytes, then dispatch to the
/// binary loop or the HTTP shim.
fn handle_conn(stream: TcpStream, ctx: &ConnCtx, shed_conn: bool) -> std::io::Result<()> {
    // accepted sockets are blocking with a short read timeout: reads poll
    // the stop flag every POLL instead of parking forever
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(POLL))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    stream.set_nodelay(true)?;
    let mut stream = stream;
    let mut first = [0u8; 4];
    if !read_exact_poll(&mut stream, &mut first, &ctx.stop, true)? {
        return Ok(()); // closed (or shutdown) before a first byte arrived
    }
    if first == frame::MAGIC {
        handle_binary(stream, ctx, shed_conn, first)
    } else {
        http::handle(stream, &first, ctx, shed_conn)
    }
}

/// Read exactly `buf.len()` bytes off a short-timeout socket, polling the
/// stop flag between timeouts.  Returns `Ok(false)` for a clean "nothing
/// here": EOF or shutdown before the *first* byte, when `abortable` — a
/// mid-buffer EOF or a post-grace shutdown is an error either way.
pub(crate) fn read_exact_poll(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    abortable: bool,
) -> std::io::Result<bool> {
    let mut filled = 0usize;
    let mut stop_seen: Option<Instant> = None;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if abortable && filled == 0 {
                    return Ok(false);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::SeqCst) {
                    if abortable && filled == 0 {
                        return Ok(false);
                    }
                    // shutdown mid-frame: give the peer a grace period to
                    // finish the bytes, then give up
                    let seen = *stop_seen.get_or_insert_with(Instant::now);
                    if seen.elapsed() > STOP_GRACE {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "shutdown while mid-frame",
                        ));
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Binary-protocol connection loop: read frames, answer each with a reply
/// or a typed error frame.  Framing errors that poison the byte stream
/// (bad header) get one error frame and a close; payload-level errors keep
/// the connection alive.
fn handle_binary(
    mut stream: TcpStream,
    ctx: &ConnCtx,
    shed_conn: bool,
    first4: [u8; 4],
) -> std::io::Result<()> {
    let nm = obs::net_metrics();
    let mut preread: Option<[u8; 4]> = Some(first4);
    loop {
        let mut hdr = [0u8; frame::HEADER_LEN];
        let read_t0;
        match preread.take() {
            Some(four) => {
                // the sniff already consumed the magic; wire-read time for
                // this first request starts at the sniffed byte
                read_t0 = Instant::now();
                hdr[..4].copy_from_slice(&four);
                if !read_exact_poll(&mut stream, &mut hdr[4..], &ctx.stop, false)? {
                    return Ok(());
                }
            }
            None => {
                // idle-wait for the next request OUTSIDE the wire-read
                // timer: read one byte abortably, then time the rest
                if !read_exact_poll(&mut stream, &mut hdr[..1], &ctx.stop, true)? {
                    return Ok(());
                }
                read_t0 = Instant::now();
                if !read_exact_poll(&mut stream, &mut hdr[1..], &ctx.stop, false)? {
                    return Ok(());
                }
            }
        }
        let h = match frame::parse_header(&hdr) {
            Ok(h) => h,
            Err(e) => {
                // the byte stream is unframed from here on — answer once,
                // then close
                write_reply(&mut stream, &Frame::from_frame_error(0, &e))?;
                return Ok(());
            }
        };
        let mut payload = vec![0u8; h.len];
        if !read_exact_poll(&mut stream, &mut payload, &ctx.stop, false)? {
            return Ok(());
        }
        nm.bytes_in.add((frame::HEADER_LEN + h.len) as u64);
        nm.wire_read_us.record(read_t0.elapsed().as_micros() as u64);
        let reply = match frame::decode_payload(h.ty, h.id, &payload) {
            Ok(Frame::Infer { id, slot_key, image }) => {
                serve_infer(ctx, id, &slot_key, image, shed_conn)
            }
            Ok(Frame::StatsPull { id }) => {
                Frame::StatsDelta { id, delta: ctx.cluster.snapshot(&ctx.fleet) }
            }
            Ok(Frame::StatsDelta { id, delta }) => {
                let known = ctx.cluster.absorb(&delta);
                Frame::StatsAck { id, replicas: known.iter().map(|r| r.0).collect() }
            }
            Ok(_) => Frame::Error {
                id: h.id,
                code: ErrCode::Malformed,
                msg: "server accepts only infer and stats frames".to_string(),
            },
            Err(e) => Frame::from_frame_error(h.id, &e),
        };
        write_reply(&mut stream, &reply)?;
        if shed_conn {
            return Ok(()); // over the connection cap: one answer, then close
        }
    }
}

/// Run one admission-checked inference and build the reply frame.  Every
/// failure mode is a typed error frame; nothing here can panic a worker.
pub(crate) fn serve_infer(
    ctx: &ConnCtx,
    id: u64,
    slot_key: &str,
    image: Vec<f32>,
    shed: bool,
) -> Frame {
    let nm = obs::net_metrics();
    if ctx.stop.load(Ordering::SeqCst) {
        return Frame::from_reject(id, &Reject::Shutdown);
    }
    if shed {
        nm.shed.add(1);
        return Frame::Error {
            id,
            code: ErrCode::Busy,
            msg: "connection limit reached, request shed".to_string(),
        };
    }
    let Some(slot) = ctx.fleet.resolve(slot_key) else {
        let known: Vec<&str> = ctx.fleet.keys().collect();
        return Frame::Error {
            id,
            code: ErrCode::UnknownSlot,
            msg: format!("unknown slot {slot_key:?} (serving: {})", known.join(", ")),
        };
    };
    let rx = match ctx.client.try_submit(slot, image) {
        Ok(rx) => rx,
        Err(reject) => {
            if matches!(reject, Reject::Busy { .. }) {
                nm.shed.add(1);
            }
            return Frame::from_reject(id, &reject);
        }
    };
    match rx.recv_timeout(ctx.infer_timeout) {
        Ok(Ok(reply)) => Frame::Reply {
            // the wire id is the client's correlation handle — echo it, not
            // the engine-internal request id
            id,
            top1: reply.top1.min(u16::MAX as usize) as u16,
            batch: reply.batch_size.min(u16::MAX as usize) as u16,
            latency_us: reply.latency.as_micros().min(u32::MAX as u128) as u32,
            logits: reply.logits,
        },
        Ok(Err(reject)) => Frame::from_reject(id, &reject),
        Err(_) => Frame::Error {
            id,
            code: ErrCode::Internal,
            msg: format!("no reply within {:?}", ctx.infer_timeout),
        },
    }
}

/// Timed, counted frame write.
fn write_reply(stream: &mut TcpStream, f: &Frame) -> std::io::Result<()> {
    let nm = obs::net_metrics();
    let t0 = Instant::now();
    let n = frame::write_frame(stream, f)?;
    nm.bytes_out.add(n as u64);
    nm.wire_write_us.record(t0.elapsed().as_micros() as u64);
    Ok(())
}
