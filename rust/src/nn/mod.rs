//! Deployment-graph IR (S2): the rust twin of `python/compile/archs.py`.
//!
//! The manifest emitted by `aot.py` is the single source of truth; this
//! module deserializes it and provides a pure-rust FP forward pass used by
//! the heuristics (CLE, bias correction), the integer deployment simulator,
//! and the per-channel analysis figures.  The *hot* path (training/eval)
//! always goes through the AOT HLO executables instead — but even this
//! reference forward runs on the [`crate::kernel`] packed GEMM via
//! [`crate::tensor::conv::conv2d`] (thread-local scratch, per-call weight
//! packing), so heuristic loops are not scalar-bound either.

pub mod arch;

use std::collections::HashMap;

use crate::tensor::{conv::conv2d_obs, Tensor};
pub use arch::{ArchSpec, OpKind, OpSpec, ParamSpec};

/// Named parameter store (`w:conv0`, `b:conv0`, ... or trainables incl.
/// `sv:3`, `f:conv2`, `swl:conv1`, `swr:conv1`).
#[derive(Clone, Debug, Default)]
pub struct ParamMap(pub HashMap<String, Tensor>);

impl ParamMap {
    pub fn from_ordered(specs: &[ParamSpec], tensors: Vec<Tensor>) -> Self {
        assert_eq!(specs.len(), tensors.len());
        ParamMap(
            specs
                .iter()
                .zip(tensors)
                .map(|(s, t)| {
                    assert_eq!(s.shape, t.shape, "{}", s.name);
                    (s.name.clone(), t)
                })
                .collect(),
        )
    }

    pub fn to_ordered(&self, specs: &[ParamSpec]) -> Vec<Tensor> {
        specs.iter().map(|s| self.0[&s.name].clone()).collect()
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.0
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        self.0.get_mut(name).unwrap_or_else(|| panic!("missing param {name}"))
    }
}

pub fn apply_act(t: &Tensor, act: &str) -> Tensor {
    match act {
        "relu" => t.relu(),
        "relu6" => t.relu6(),
        _ => t.clone(),
    }
}

/// [`apply_act`] without the output clone — the forward passes own their
/// conv outputs, so the activation can rewrite them in place (same scalar
/// ops element-for-element, so results are bit-identical).
pub fn apply_act_inplace(t: &mut Tensor, act: &str) {
    match act {
        "relu" => t.map_inplace(|x| x.max(0.0)),
        "relu6" => t.map_inplace(|x| x.clamp(0.0, 6.0)),
        _ => {}
    }
}

/// Full-precision forward, collecting every value tensor.
pub struct Forward {
    pub values: HashMap<usize, Tensor>,
    pub logits: Tensor,
    pub feat: Tensor,
}

pub fn fp_forward(arch: &ArchSpec, params: &ParamMap, x: &Tensor) -> Forward {
    fp_forward_obs(arch, params, x, None)
}

/// [`fp_forward`] with optional per-layer timing: on a sampled pass each
/// conv/fc op `i` laps its phases into `obs.layer(i)` (`pack` = per-call
/// weight packing, then `im2col` / `gemm`; the fc matmul is all `gemm`) and
/// stamps its wall-clock total.
pub fn fp_forward_obs(
    arch: &ArchSpec,
    params: &ParamMap,
    x: &Tensor,
    obs: Option<&crate::obs::NetObs>,
) -> Forward {
    use crate::obs::layer;
    let mut values: HashMap<usize, Tensor> = HashMap::new();
    values.insert(0, x.clone());
    let mut logits = None;
    let mut feat = None;
    for (i, op) in arch.ops.iter().enumerate() {
        let lobs = obs.and_then(|o| o.layer(i));
        match op.kind() {
            OpKind::Conv => {
                let w = params.get(&format!("w:{}", op.name));
                let b = params.get(&format!("b:{}", op.name));
                let t0 = layer::start(lobs);
                let mut y =
                    conv2d_obs(&values[&op.inp], w, &b.data, op.stride, op.groups, lobs);
                apply_act_inplace(&mut y, &op.act);
                layer::finish(lobs, t0);
                values.insert(op.out, y);
            }
            OpKind::Add => {
                let mut y = values[&op.a].add(&values[&op.b]);
                apply_act_inplace(&mut y, &op.act);
                values.insert(op.out, y);
            }
            OpKind::Gap => {
                feat = Some(values[&op.inp].clone());
                values.insert(op.out, values[&op.inp].global_avg_pool());
            }
            OpKind::Fc => {
                let w = params.get(&format!("w:{}", op.name));
                let b = params.get(&format!("b:{}", op.name));
                let t0 = layer::start(lobs);
                let mut y = values[&op.inp].matmul(w);
                layer::lap(lobs, crate::obs::Phase::Gemm, t0);
                for row in y.data.chunks_mut(b.data.len()) {
                    for (v, &bv) in row.iter_mut().zip(&b.data) {
                        *v += bv;
                    }
                }
                layer::finish(lobs, t0);
                logits = Some(y.clone());
                values.insert(op.out, y);
            }
        }
    }
    Forward {
        values,
        logits: logits.expect("arch has fc"),
        feat: feat.expect("arch has gap"),
    }
}

/// Consumers of each value: conv ops reading it (used by CLE fan-out rules).
pub fn conv_consumers(arch: &ArchSpec) -> HashMap<usize, Vec<usize>> {
    let mut m: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, op) in arch.ops.iter().enumerate() {
        if op.kind() == OpKind::Conv {
            m.entry(op.inp).or_default().push(i);
        }
    }
    m
}

/// Op index producing each value (input value 0 has no producer).
pub fn producers(arch: &ArchSpec) -> HashMap<usize, usize> {
    arch.ops
        .iter()
        .enumerate()
        .map(|(i, op)| (op.out, i))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Option<Manifest> {
        Manifest::load("artifacts/manifest.json").ok()
    }

    #[test]
    fn forward_all_archs_shapes() {
        let Some(m) = manifest() else { return };
        for (name, arch) in &m.archs {
            let params = crate::coordinator::state::he_init_params(arch, 0);
            let x = Tensor::full(&[2, arch.input_hw, arch.input_hw, arch.input_ch], 0.5);
            let f = fp_forward(arch, &params, &x);
            assert_eq!(f.logits.shape, vec![2, arch.num_classes], "{name}");
            assert_eq!(f.feat.shape[3], arch.feat_channels, "{name}");
        }
    }

    #[test]
    fn consumers_and_producers_consistent() {
        let Some(m) = manifest() else { return };
        let arch = &m.archs["resnet_tiny"];
        let cons = conv_consumers(arch);
        let prod = producers(arch);
        // every conv's input value is either the net input or produced
        for op in arch.ops.iter().filter(|o| o.kind() == OpKind::Conv) {
            assert!(op.inp == 0 || prod.contains_key(&op.inp));
        }
        // residual: some value has >= 2 conv consumers
        assert!(cons.values().any(|v| v.len() >= 2));
    }
}
