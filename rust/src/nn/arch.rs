//! Model of the manifest's architecture IR (see `archs.py::Arch.to_json`),
//! parsed with the vendored JSON module (the image has no serde_json).

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::util::json::Value;

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(ParamSpec {
            name: v.get("name")?.str()?.to_string(),
            shape: v.get("shape")?.usize_vec()?,
        })
    }

    pub fn list_from_json(v: &Value) -> Result<Vec<Self>> {
        v.arr()?.iter().map(Self::from_json).collect()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Conv,
    Add,
    Gap,
    Fc,
}

#[derive(Clone, Debug)]
pub struct OpSpec {
    pub op: String,
    pub name: String,
    pub out: usize,
    pub inp: usize,
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
    pub groups: usize,
    pub act: String,
    pub a: usize,
    pub b: usize,
}

impl OpSpec {
    fn from_json(v: &Value) -> Result<Self> {
        let get_usize = |k: &str, default: usize| -> usize {
            v.opt(k).and_then(|x| x.usize().ok()).unwrap_or(default)
        };
        Ok(OpSpec {
            op: v.get("op")?.str()?.to_string(),
            name: v.get("name")?.str()?.to_string(),
            out: v.get("out")?.usize()?,
            inp: get_usize("inp", 0),
            k: get_usize("k", 0),
            stride: get_usize("stride", 1),
            cin: get_usize("cin", 0),
            cout: get_usize("cout", 0),
            groups: get_usize("groups", 1),
            act: v
                .opt("act")
                .and_then(|x| x.str().ok())
                .unwrap_or("none")
                .to_string(),
            a: get_usize("a", 0),
            b: get_usize("b", 0),
        })
    }

    pub fn kind(&self) -> OpKind {
        match self.op.as_str() {
            "conv" => OpKind::Conv,
            "add" => OpKind::Add,
            "gap" => OpKind::Gap,
            "fc" => OpKind::Fc,
            other => panic!("unknown op kind {other}"),
        }
    }

    pub fn is_depthwise(&self) -> bool {
        self.kind() == OpKind::Conv && self.groups > 1
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<ParamSpec>,
    pub outputs: Vec<ParamSpec>,
}

impl ArtifactSpec {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(ArtifactSpec {
            file: v.get("file")?.str()?.to_string(),
            inputs: ParamSpec::list_from_json(v.get("inputs")?)?,
            outputs: ParamSpec::list_from_json(v.get("outputs")?)?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: String,
    pub input_hw: usize,
    pub input_ch: usize,
    pub num_classes: usize,
    pub batch: usize,
    pub nvals: usize,
    pub backbone_value: usize,
    pub feat_channels: usize,
    pub ops: Vec<OpSpec>,
    pub params: Vec<ParamSpec>,
    pub trainables: HashMap<String, Vec<ParamSpec>>,
    pub quantized_values: Vec<usize>,
    pub value_channels: HashMap<String, usize>,
    pub value_signed: HashMap<String, bool>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl ArchSpec {
    pub fn from_json(v: &Value) -> Result<Self> {
        let ops = v
            .get("ops")?
            .arr()?
            .iter()
            .map(OpSpec::from_json)
            .collect::<Result<Vec<_>>>()
            .context("ops")?;
        let mut trainables = HashMap::new();
        for (mode, specs) in v.get("trainables")?.obj()? {
            trainables.insert(mode.clone(), ParamSpec::list_from_json(specs)?);
        }
        let mut value_channels = HashMap::new();
        for (k, n) in v.get("value_channels")?.obj()? {
            value_channels.insert(k.clone(), n.usize()?);
        }
        let mut value_signed = HashMap::new();
        for (k, b) in v.get("value_signed")?.obj()? {
            value_signed.insert(k.clone(), b.boolean()?);
        }
        let mut artifacts = HashMap::new();
        if let Some(arts) = v.opt("artifacts") {
            for (k, a) in arts.obj()? {
                artifacts.insert(k.clone(), ArtifactSpec::from_json(a)?);
            }
        }
        Ok(ArchSpec {
            name: v.get("name")?.str()?.to_string(),
            input_hw: v.get("input_hw")?.usize()?,
            input_ch: v.get("input_ch")?.usize()?,
            num_classes: v.get("num_classes")?.usize()?,
            batch: v.get("batch")?.usize()?,
            nvals: v.get("nvals")?.usize()?,
            backbone_value: v.get("backbone_value")?.usize()?,
            feat_channels: v.get("feat_channels")?.usize()?,
            ops,
            params: ParamSpec::list_from_json(v.get("params")?)?,
            trainables,
            quantized_values: v.get("quantized_values")?.usize_vec()?,
            value_channels,
            value_signed,
            artifacts,
        })
    }

    pub fn conv_ops(&self) -> Vec<&OpSpec> {
        self.ops.iter().filter(|o| o.kind() == OpKind::Conv).collect()
    }

    pub fn channels_of(&self, value: usize) -> usize {
        self.value_channels[&value.to_string()]
    }

    pub fn signed_of(&self, value: usize) -> bool {
        self.value_signed[&value.to_string()]
    }

    /// Activation grid max for a value: 255 unsigned, 127 signed.
    pub fn act_qmax(&self, value: usize) -> f32 {
        if self.signed_of(value) {
            crate::ACT_SIGNED_QMAX
        } else {
            crate::ACT_UNSIGNED_QMAX
        }
    }

    pub fn trainable_specs(&self, mode: &str) -> &[ParamSpec] {
        &self.trainables[mode]
    }

    /// Total conv weight parameter count (the "99%-4b backbone" accounting).
    pub fn conv_weight_numel(&self) -> usize {
        self.conv_ops()
            .iter()
            .map(|o| o.k * o.k * (o.cin / o.groups) * o.cout)
            .sum()
    }
}
