//! # QFT — post-training quantization via fast joint finetuning of all DoF
//!
//! Rust + JAX + Pallas reproduction of *"QFT: Post-training quantization via
//! fast joint finetuning of all degrees of freedom"* (Finkelstein et al.,
//! Hailo, 2022).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L1** — Pallas fake-quant / fused quantized-matmul kernels
//!   (`python/compile/kernels/`, AOT-lowered, never run from python at
//!   runtime).
//! * **L2** — the twin-graph QFT simulation (offline subgraph inferring all
//!   deployment constants from the DoF set, online HW-emulating subgraph)
//!   exported per-(arch × mode) as HLO text (`python/compile/`).
//! * **L3** — this crate: the deployment-compiler coordinator.  It owns the
//!   PJRT runtime ([`runtime`]), the synthetic workload ([`data`]), a pure
//!   rust quantization substrate implementing every heuristic the paper uses
//!   or compares against ([`quant`]): PPQ, APQ, MMSE at all granularities,
//!   4b-adapted CLE, bias correction, integer-deployment simulation — and the
//!   end-to-end pipeline ([`coordinator`]): pretrain → calibrate → MMSE init
//!   → (CLE) → QFT finetune → export → eval.
//!
//! ## Execution backends — `qft::backend`
//!
//! [`backend`] is the one seam every forward path now sits behind: a
//! [`backend::Backend`] runs a grid's offline subgraph once
//! (`prepare(&ArchSpec, &ParamMap) -> Box<dyn PreparedNet>`) and the frozen
//! [`backend::PreparedNet`] exposes a uniform batched online contract
//! (`forward_batch{,_feat}` over a caller-owned [`backend::Scratch`] and a
//! [`par::Pool`]).  [`backend::BackendKind`] names the grids with stable
//! string keys (`fp`, `fq-lw`, `fq-dch`, `lw`, `dch`, `lw-i8` —
//! `BackendKind::{key, from_key}` round-trip), which is what the CLI
//! `--backend` flag, the fleet wire keys and the bench emitters
//! speak.  The historical free functions (`nn::fp_forward`,
//! `quant::deploy::forward_fakequant`, the integer `DeployedModel`) are
//! re-homed as [`backend::FpBackend`], [`backend::FakeQuantBackend`] and
//! [`backend::IntBackend`]; [`backend::Int8Backend`] (`lw-i8`) is the first
//! genuinely new engine — lw weight codes in i8 K-major panels
//! ([`kernel::PackedWi8`]) under the i8×i8→i32 [`kernel::gemm_i8`]
//! micro-kernel, activations carried as zero-point-offset i8 with the
//! correction folded into the integer bias at prepare time.
//!
//! ## Serving
//!
//! The paper freezes all deployment constants offline precisely so the
//! online integer path is cheap; [`serve`] turns that online path into an
//! inference server over ANY backend.  [`backend::Backend::prepare`] runs
//! the offline subgraph once per (arch × backend); [`fleet::Fleet`] holds
//! the frozen `Box<dyn PreparedNet>`s in versioned [`fleet::Slot`]s
//! (atomic hot-swap / A/B routing / rollback while serving, plus shadow
//! range capture feeding `repro requantize`); [`serve::Engine`] runs a
//! std-thread worker pool over a bounded dynamic micro-batching queue
//! ([`serve::Batcher`], max-batch / max-wait-µs policy with blocking
//! backpressure), each worker reusing one [`backend::Scratch`] so
//! steady-state execution does not allocate.  [`serve::ServeStats`] tracks
//! p50/p95/p99 latency, throughput, and batch/queue-depth histograms.
//!
//! ```text
//! repro qft --arch resnet_tiny --mode lw        # exports weights/resnet_tiny.lw.qftw
//! repro serve --arch resnet_tiny --backend lw-i8 --workers 4 --max-batch 8
//! repro bench-serve --backend lw --workers 4 --concurrency 16 --requests 2048
//! repro eval --arch resnet_tiny --backend lw-i8 --images 512
//! ```
//!
//! Without AOT artifacts both commands fall back to a built-in
//! [`serve::synthetic_arch`], so the serving stack is exercisable in any
//! checkout (`cargo bench --bench serve_throughput` emits
//! `BENCH_serve.json`).
//!
//! ## Serving on the wire — `qft::net`
//!
//! [`net`] puts the engine on a TCP socket: one listener speaks a
//! length-prefixed binary protocol ([`net::frame`] — magic + version +
//! fleet slot key + f32 payload, typed error frames mirroring
//! [`serve::Reject`]) and a minimal HTTP/1.1 shim ([`net::http`] —
//! `POST /infer`, `GET /healthz`, `GET /metrics` Prometheus text), told
//! apart by sniffing the first four bytes.  Admission control sheds
//! over-capacity load with explicit `Busy` frames
//! ([`serve::Client::try_submit`]) instead of letting the queue collapse;
//! [`net::NetServer::shutdown`] drains gracefully through
//! [`serve::Engine::drain`] (bounded, dropped requests answered with
//! typed `Shutdown` rejections and counted).  [`net::open_loop`] is the
//! open-loop Poisson load harness behind `cargo bench --bench net_load`
//! (`BENCH_net.json`: throughput + p50/p99/p99.9-under-load, measured
//! from scheduled arrivals so coordinated omission cannot hide queueing).
//!
//! ## Cluster stats & pooled calibration — `qft::cluster`
//!
//! [`cluster`] makes per-replica serving state *mergeable* across a fleet
//! of processes with delta-state CRDTs: a [`cluster::GCounter`] per
//! request / shed / route counter (keyed by a stable
//! [`cluster::ReplicaId`], merged by pointwise max, read as the sum) and a
//! min/max-register lattice ([`cluster::RangeDelta`]) over the shadow
//! calibration ranges [`backend::CalibRanges`] captures — the lattice join
//! is the same pointwise min/max fold applied locally, so merge order,
//! duplicate delivery, and traffic partitioning cannot change the result.
//! Every [`net::NetServer`] owns a [`cluster::ClusterNode`] answering the
//! `stats-pull` / `stats-delta` / `stats-ack` frame family; `repro stats
//! --pull A,B,...` renders the merged view and `repro requantize --pool
//! A,B,...` rebuilds the deployment grid from ranges pooled over every
//! replica — bit-identical to a single process that saw all the traffic
//! (`rust/tests/cluster.rs`).
//!
//! ## Observability — `qft::obs`
//!
//! [`obs`] is the std-only, always-compiled telemetry layer over the
//! serving engine.  Lock-free primitives ([`obs::Counter`],
//! [`obs::Gauge`], the sharded log-linear [`obs::LogHistogram`] — exact
//! small samples, sub-bucket interpolation for trustworthy p99/p99.9)
//! feed a process-global registry keyed by the serving wire key.  Every
//! [`serve::InferRequest`] carries an [`obs::Trace`]; workers stamp an
//! [`obs::BatchSpan`] (batch-formed → forward-start → forward-end →
//! replied) so queue wait, batch-formation hold, compute and reply
//! latency become separate per-model histograms
//! ([`obs::StageMetrics`]).  Per-layer kernel timing ([`obs::NetObs`])
//! splits each conv/fc into pack / im2col / gemm / recode phases across
//! all six backends, sampled 1-in-N (default
//! [`obs::DEFAULT_SAMPLE_EVERY`], `--obs-sample N` / `--no-obs` to tune)
//! by an [`obs::LayerTimer`] in [`backend::Scratch`].  Every rendering —
//! Prometheus text, JSON flush files, human tables — goes through one
//! [`obs::Exposition`] trait driven by [`obs::Format`], implemented by
//! the engine [`obs::Snapshot`], the wire metrics, and the merged
//! [`cluster::ClusterStats`] alike: [`obs::render_prometheus`] /
//! [`obs::render_json`], the `repro stats` command, `--stats-json <path>`
//! periodic flushes on `serve` / `bench-serve`, and a table dump on
//! graceful shutdown.  The `repro` front-end itself parses against the
//! declarative flag table in [`cli`] (one [`cli::FlagSpec`] row per flag:
//! arity, default, help, per-command applicability), from which usage
//! text, parsing, and rejection diagnostics are all derived.
//!
//! ## The kernel engine — `qft::kernel`
//!
//! [`kernel`] owns THE inner loop every forward path bottoms out in: a
//! register-blocked ([`kernel::MR`]×[`kernel::NR`] accumulator tile,
//! 8-wide lanes) write-mode GEMM over a panel-packed weight layout
//! ([`kernel::PackedW`]), replacing the historical scalar `matmul_rows`
//! walk (kept as [`kernel::gemm_ref`], the tested-against baseline).  The
//! f32 kernel stays safe auto-vectorized Rust; the **integer kernels are
//! runtime-dispatched** ([`kernel::kernel_path`], probed once) to explicit
//! u8×i8 dot-product micro-kernels — AVX2 `maddubs`, AVX-512-VNNI
//! `vpdpbusd`, NEON `sdot` — over byte-per-code [`kernel::PackedWi8`] or
//! nibble-packed [`kernel::PackedW4`] panels (two 4-bit codes per byte,
//! half the weight bandwidth), with safe scalar twins as the
//! always-present fallback and ground truth.
//! `QFT_KERNEL=scalar|avx2|vnni|neon` forces any path.
//!
//! *Packing*: [`quant::deploy::DeployedModel::prepare`] packs every conv
//! (per group, [`tensor::conv::PackedConvW`]) and the fc head once,
//! offline, so serving workers stream K-major panels and never repack;
//! training-forward / heuristic paths repack per call into reusable
//! scratch, amortized over the `b*oh*ow` GEMM rows.
//!
//! *Cache blocking*: the packed layout is K-block major — the reduction
//! splits into [`kernel::KC`]-row blocks whose panel sub-slices sit
//! contiguously, so one sub-panel stays L1-resident across all row tiles
//! of its block once `k` outgrows a single panel; the accumulator tile
//! spills to `out` and reloads between blocks (a lossless f32 round
//! trip), and one generic walker drives the f32 and i8 kernels through
//! the identical block structure.
//!
//! *Bit-exactness contract*: per output element the f32 reduction is
//! always `kk = 0..k` ascending with one mul + one add per step and the
//! zero-activation skip preserved — including across [`kernel::KC`]
//! boundaries; vectorization runs only across the `n` output-column
//! lanes, which never interact.  The integer kernels are exact i32
//! arithmetic, so every dispatch path is bit-identical to the scalar
//! twin with no ordering discipline at all.  Packed, scalar, serial,
//! chunk-parallel, conv and batched-deploy results are therefore
//! bit-identical, at any thread count (`rust/tests/kernel.rs`, under
//! default codegen, forced `QFT_KERNEL` legs and `-Ctarget-cpu=native`
//! in CI).
//!
//! *Unsafe policy*: the crate denies `unsafe_code` globally; the per-ISA
//! kernel modules and the scoped-pool lifetime erasure in [`par`] carry
//! the only module-level allows, every block has a `SAFETY:` comment,
//! and every SIMD kernel is pinned by a scalar-twin parity test (see the
//! README's "Kernel engine" section for the full policy).
//!
//! ## Parallelism — `qft::par`
//!
//! [`par`] is a std-only (threads + channels) chunk-based scoped thread
//! pool behind every intra-op parallel kernel: the GEMM
//! [`tensor::matmul_slices_par`], the conv
//! [`tensor::conv::conv2d_into_par`], and the batch-level
//! [`quant::deploy::DeployedModel::forward_batch_pooled`].  GEMM chunks
//! are [`kernel::MR`]-aligned ([`par::chunk_ranges_aligned`]) so only the
//! last chunk carries a ragged register tile.
//!
//! *Pool sharing model*: there is ONE process-wide pool ([`par::global`]),
//! sized by the `--threads` CLI flag on `serve` / `bench-serve` / the eval
//! commands (else `available_parallelism`).  The [`serve::Engine`] workers
//! and [`coordinator::eval::eval_backend`] all submit scopes to it,
//! so concurrent callers cooperate on one worker set instead of
//! oversubscribing the machine; [`serve::ServeStats`] reports the pool
//! width alongside latency, and the batcher reads the pool's live
//! [`par::Pool::active_scopes`] load to adapt its max-wait policy
//! (idle pool → dispatch small batches immediately; saturated pool →
//! hold for full micro-batches).  Tests and benches build private
//! [`par::Pool`]s at explicit widths.
//!
//! The public API is consumed by the `repro` CLI, `examples/` and
//! `rust/benches/` (one bench per paper table/figure).

// `unsafe` is opt-in per module: only the kernel ISA modules (runtime
// feature-gated intrinsics, scalar-parity-pinned) and the par scope
// lifetime erasure may allow it — see the README "unsafe policy".
#![deny(unsafe_code)]

pub mod backend;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod fleet;
pub mod kernel;
pub mod net;
pub mod nn;
pub mod obs;
pub mod par;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

pub use tensor::Tensor;

/// 4-bit symmetric weight grid: clip(round(w/s)) in [-7, 7].
pub const WEIGHT_QMAX: f32 = 7.0;
/// Unsigned 8-bit activation grid.
pub const ACT_UNSIGNED_QMAX: f32 = 255.0;
/// Signed 8-bit activation grid.
pub const ACT_SIGNED_QMAX: f32 = 127.0;
