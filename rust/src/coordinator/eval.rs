//! Accuracy evaluation via the AOT `fp_eval` / `q_eval_{mode}` executables
//! (plus pure-rust cross-check paths used by tests and analyses).

use anyhow::Result;

use crate::backend::BackendKind;
use crate::data::{Dataset, Split};
use crate::nn::ParamMap;
use crate::quant::deploy::Mode;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Top-1 accuracy of the FP teacher on the held-out val split.
pub fn eval_fp(
    rt: &Runtime,
    arch_name: &str,
    params: &ParamMap,
    n_images: usize,
    seed: u64,
) -> Result<f32> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let ordered = params.to_ordered(&arch.params);
    let ds = Dataset::new(seed);
    let b = arch.batch;
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n_images / b {
        let (x, _, labels) = ds.batch(Split::Val, (i * b) as u64, b);
        let mut inputs = ordered.clone();
        inputs.push(x);
        let out = rt.run(arch_name, "fp_eval", &inputs)?;
        let preds = out[0].argmax_lastdim();
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        total += b;
    }
    Ok(correct as f32 / total.max(1) as f32)
}

/// Top-1 accuracy of a quantized student (trainable set `tm`).
pub fn eval_q(
    rt: &Runtime,
    arch_name: &str,
    tm: &ParamMap,
    mode: Mode,
    n_images: usize,
    seed: u64,
) -> Result<f32> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let ordered = tm.to_ordered(arch.trainable_specs(mode.key()));
    let ds = Dataset::new(seed);
    let b = arch.batch;
    let entry = format!("q_eval_{}", mode.key());
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n_images / b {
        let (x, _, labels) = ds.batch(Split::Val, (i * b) as u64, b);
        let mut inputs = ordered.clone();
        inputs.push(x);
        let out = rt.run(arch_name, &entry, &inputs)?;
        let preds = out[0].argmax_lastdim();
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        total += b;
    }
    Ok(correct as f32 / total.max(1) as f32)
}

/// Pure-rust eval under ANY execution backend: prepares the grid's frozen
/// state once ([`crate::backend::prepare`]) and drives the uniform batched
/// [`crate::backend::PreparedNet::forward_batch`] contract — literally the
/// same code the serving workers run, so offline accuracy numbers and the
/// online server cannot diverge.  `params` is the FP parameter map for
/// [`BackendKind::Fp`] and the mode's trainable set otherwise.  Batches go
/// through the process-wide [`crate::par::global`] pool (the same one the
/// serve engine submits to), and every backend's parallel path is
/// bit-identical to its serial one, so accuracies are independent of
/// `--threads`.
pub fn eval_backend(
    arch: &crate::nn::ArchSpec,
    params: &ParamMap,
    kind: BackendKind,
    n_images: usize,
    seed: u64,
) -> f32 {
    let net = crate::backend::prepare(kind, arch, params);
    eval_prepared(net.as_ref(), arch.batch, n_images, seed)
}

/// [`eval_backend`] over an already-prepared net (the fleet / CLI path).
/// Scores `eval_image_count(batch, n_images)` images: the batch size is
/// clamped so small `n_images` still run at least one batch, and the
/// trailing partial batch is dropped.
pub fn eval_prepared(
    net: &dyn crate::backend::PreparedNet,
    batch: usize,
    n_images: usize,
    seed: u64,
) -> f32 {
    let mut scratch = crate::backend::Scratch::new();
    let pool = crate::par::global();
    let ds = Dataset::new(seed);
    let b = clamped_batch(batch, n_images);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n_images / b {
        let (x, _, labels) = ds.batch(Split::Val, (i * b) as u64, b);
        let logits = net.forward_batch(&x, &mut scratch, pool);
        let preds = logits.argmax_lastdim();
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        total += b;
    }
    correct as f32 / total.max(1) as f32
}

/// Pure-rust quantized eval (fake-quant simulator) — parity cross-check.
/// Thin wrapper over [`eval_backend`] with the `fq-{mode}` grid.
pub fn eval_q_rust(
    arch: &crate::nn::ArchSpec,
    tm: &ParamMap,
    mode: Mode,
    n_images: usize,
    seed: u64,
) -> f32 {
    eval_backend(arch, tm, BackendKind::FakeQuant(mode), n_images, seed)
}

/// The batch size [`eval_prepared`] actually runs: clamped so small
/// `n_images` still fill one batch.  ONE copy, shared with
/// [`eval_image_count`], so the reported image count can never diverge
/// from the number scored.
fn clamped_batch(batch: usize, n_images: usize) -> usize {
    batch.max(1).min(n_images.max(1))
}

/// Images [`eval_prepared`] actually scores for a requested `(batch,
/// n_images)` — whole batches only, with the batch clamped to `n_images`.
/// Callers reporting "top-1 over N images" must use this N.
pub fn eval_image_count(batch: usize, n_images: usize) -> usize {
    let b = clamped_batch(batch, n_images);
    n_images / b * b
}

/// Collect calibration activation statistics through the AOT `fp_stats`.
pub fn calib_stats(
    rt: &Runtime,
    arch_name: &str,
    params: &ParamMap,
    calib_images: u64,
    seed: u64,
) -> Result<std::collections::HashMap<usize, Vec<f32>>> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let ordered = params.to_ordered(&arch.params);
    let ds = Dataset::new(seed);
    let b = arch.batch;
    let nb = (calib_images as usize).div_ceil(b).max(1);
    let mut per_batch = Vec::with_capacity(nb);
    for i in 0..nb {
        let (x, _, _) = ds.batch(Split::Calib, (i * b) as u64, b);
        let mut inputs = ordered.clone();
        inputs.push(x);
        per_batch.push(rt.run(arch_name, "fp_stats", &inputs)?);
    }
    Ok(crate::coordinator::state::absmax_from_stats(&arch, &per_batch))
}

/// Batch of calibration image tensors (for the rust-side heuristics).
pub fn calib_batches(arch_batch: usize, n: usize, seed: u64) -> Vec<Tensor> {
    let ds = Dataset::new(seed);
    (0..n)
        .map(|i| ds.batch(Split::Calib, (i * arch_batch) as u64, arch_batch).0)
        .collect()
}
