//! L3 coordinator (S14): the deployment-compiler pipeline around the AOT
//! executables — the industrial "HW-vendor quantization tool" setting the
//! paper targets (§1).
//!
//! Stages: [`pretrain`] (teacher) → [`eval::calib_stats`] (calibration) →
//! [`state::init_trainables`] / [`crate::quant::baselines`] (the sole
//! pre-QFT step: naive-max activation ranges, MMSE weight ranges, F via
//! Eq. 2 inversion, optional CLE) → [`qft::run_qft`] (the paper's single
//! joint finetune of all DoF) → [`eval`] (degradation) — with
//! [`experiments`] packaging every paper table/figure and [`metrics`]
//! tracking the PJRT duty cycle.

pub mod eval;
pub mod experiments;
pub mod metrics;
pub mod pretrain;
pub mod qft;
pub mod state;
pub mod weights_io;
