//! Parameter/trainable state management: He init, calibration statistics,
//! and the paper's pre-QFT initialization (§4: naive max-min calibration for
//! activation scales, MMSE for weights, then F via inversion of Eq. 2 — "a
//! sole pre-QFT step").

use std::collections::HashMap;

use crate::data::Rng;
use crate::nn::{fp_forward, ArchSpec, OpKind, ParamMap, ParamSpec};
use crate::quant::deploy::Mode;
use crate::quant::{mmse, ppq};
use crate::tensor::Tensor;
use crate::WEIGHT_QMAX;

/// He-normal init of the FP parameter set (the rust side owns the teacher's
/// initial weights; pretraining itself runs through the AOT `fp_train`).
pub fn he_init_params(arch: &ArchSpec, seed: u64) -> ParamMap {
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let tensors = arch
        .params
        .iter()
        .map(|spec| {
            if spec.name.starts_with("w:") {
                let fan_in: usize = if spec.shape.len() > 2 {
                    spec.shape[..3].iter().product()
                } else {
                    spec.shape[0]
                };
                let std = (2.0 / fan_in.max(1) as f32).sqrt();
                Tensor::new(
                    spec.shape.clone(),
                    (0..spec.numel()).map(|_| rng.normal() * std).collect(),
                )
            } else {
                Tensor::zeros(&spec.shape)
            }
        })
        .collect();
    ParamMap::from_ordered(&arch.params, tensors)
}

pub fn zeros_like_specs(specs: &[ParamSpec]) -> Vec<Tensor> {
    specs.iter().map(|s| Tensor::zeros(&s.shape)).collect()
}

/// Calibration statistics via the pure-rust forward (used by tests and the
/// heuristics; the pipeline normally uses the AOT `fp_stats` executable).
pub fn absmax_from_rust_forward(
    arch: &ArchSpec,
    params: &ParamMap,
    batches: &[Tensor],
) -> HashMap<usize, Vec<f32>> {
    let mut out: HashMap<usize, Vec<f32>> = HashMap::new();
    for x in batches {
        let fwd = fp_forward(arch, params, x);
        for &v in &arch.quantized_values {
            let m = fwd.values[&v].abs_max_per_channel();
            let e = out.entry(v).or_insert_with(|| vec![0.0; m.len()]);
            for (a, b) in e.iter_mut().zip(m) {
                *a = a.max(b);
            }
        }
    }
    out
}

/// Reduce a sequence of `fp_stats` outputs (one Vec<Tensor> per batch) into
/// the per-value max statistics.
pub fn absmax_from_stats(
    arch: &ArchSpec,
    per_batch: &[Vec<Tensor>],
) -> HashMap<usize, Vec<f32>> {
    let mut out: HashMap<usize, Vec<f32>> = HashMap::new();
    for outputs in per_batch {
        for (&v, t) in arch.quantized_values.iter().zip(outputs) {
            let e = out.entry(v).or_insert_with(|| vec![0.0; t.len()]);
            for (a, &b) in e.iter_mut().zip(&t.data) {
                *a = a.max(b);
            }
        }
    }
    out
}

/// Weight-scale initialization granularity for the pre-QFT step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightScaleInit {
    /// naive max(|.|)/qmax — no clipping (Table 2 "naive" comparator).
    NaiveMax,
    /// scalar (per-tensor) PPQ MMSE — the paper's §4 default init.
    Uniform,
    /// per-output-channel PPQ (standard channelwise).
    PerChannel,
    /// APQ doubly-channelwise co-vectors (Table 2 dch MMSE rows).
    DoublyChannelwise,
}

/// Build the full trainable set for `mode` (manifest order available via
/// `arch.trainable_specs`).  `cle` optionally carries per-value CLE factors
/// C_m (Eq. 18): S_a^{l-1}_m = C_m · s_a.
pub fn init_trainables(
    arch: &ArchSpec,
    params: &ParamMap,
    absmax: &HashMap<usize, Vec<f32>>,
    mode: Mode,
    winit: WeightScaleInit,
    cle: Option<&HashMap<usize, Vec<f32>>>,
) -> ParamMap {
    // base (scalar) activation scales from naive max calibration
    let mut sv_base: HashMap<usize, f32> = HashMap::new();
    for &v in &arch.quantized_values {
        let mx = absmax
            .get(&v)
            .map(|m| m.iter().fold(0.0f32, |a, &b| a.max(b)))
            .unwrap_or(1.0);
        sv_base.insert(v, (mx / arch.act_qmax(v)).max(1e-6));
    }

    let conv_by_name: HashMap<&str, &crate::nn::OpSpec> = arch
        .ops
        .iter()
        .filter(|o| o.kind() == OpKind::Conv)
        .map(|o| (o.name.as_str(), o))
        .collect();

    let scalar_wscale = |w: &Tensor| -> f32 {
        match winit {
            WeightScaleInit::NaiveMax => (w.abs_max() / WEIGHT_QMAX).max(1e-8),
            _ => ppq::mmse_scale(&w.data, WEIGHT_QMAX),
        }
    };

    let mut tensors = Vec::with_capacity(arch.trainable_specs(mode.key()).len());
    for spec in arch.trainable_specs(mode.key()) {
        let (kind, id) = spec.name.split_once(':').expect("name kind:id");
        let t = match kind {
            "w" | "b" => params.get(&spec.name).clone(),
            "sv" => {
                let v: usize = id.parse().unwrap();
                let s0 = sv_base[&v];
                let mut data = vec![s0; spec.shape[0]];
                if let Some(factors) = cle.and_then(|c| c.get(&v)) {
                    for (d, &c) in data.iter_mut().zip(factors) {
                        *d *= c;
                    }
                }
                Tensor::new(spec.shape.clone(), data)
            }
            "f" => {
                let op = conv_by_name[id];
                let w = params.get(&format!("w:{id}"));
                let s_w = scalar_wscale(w);
                // inversion of Eq. 2 with uniform scales:
                // s_w = sv·f/su  =>  f = s_w·su/sv
                let su = sv_base[&op.inp];
                let sv = sv_base[&op.out];
                Tensor::new(spec.shape.clone(), vec![s_w * su / sv])
            }
            "swl" => {
                let w = params.get(&format!("w:{id}"));
                match winit {
                    WeightScaleInit::DoublyChannelwise => {
                        let (s_l, _, _) = mmse::mmse_dch(w, WEIGHT_QMAX, 10);
                        Tensor::new(spec.shape.clone(), s_l)
                    }
                    _ => Tensor::full(&spec.shape, 1.0),
                }
            }
            "swr" => {
                let w = params.get(&format!("w:{id}"));
                let data = match winit {
                    WeightScaleInit::NaiveMax => {
                        vec![(w.abs_max() / WEIGHT_QMAX).max(1e-8); spec.shape[0]]
                    }
                    WeightScaleInit::Uniform => {
                        vec![ppq::mmse_scale(&w.data, WEIGHT_QMAX); spec.shape[0]]
                    }
                    WeightScaleInit::PerChannel => mmse::mmse_channelwise(w, WEIGHT_QMAX).0,
                    WeightScaleInit::DoublyChannelwise => {
                        let op = conv_by_name[id];
                        if op.groups == 1 {
                            mmse::mmse_dch(w, WEIGHT_QMAX, 10).1
                        } else {
                            // depthwise: single channel axis, per-channel PPQ
                            mmse::mmse_channelwise(w, WEIGHT_QMAX).0
                        }
                    }
                };
                Tensor::new(spec.shape.clone(), data)
            }
            other => panic!("unknown trainable kind {other}"),
        };
        tensors.push(t);
    }
    ParamMap::from_ordered(arch.trainable_specs(mode.key()), tensors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn init_trainables_all_modes_all_archs() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let ds = crate::data::Dataset::new(0);
        for arch in m.archs.values() {
            let params = he_init_params(arch, 7);
            let batches = vec![ds.batch(crate::data::Split::Calib, 0, 4).0];
            let absmax = absmax_from_rust_forward(arch, &params, &batches);
            for mode in [Mode::Lw, Mode::Dch] {
                for winit in [
                    WeightScaleInit::NaiveMax,
                    WeightScaleInit::Uniform,
                    WeightScaleInit::PerChannel,
                    WeightScaleInit::DoublyChannelwise,
                ] {
                    let tm = init_trainables(arch, &params, &absmax, mode, winit, None);
                    for spec in arch.trainable_specs(mode.key()) {
                        let t = tm.get(&spec.name);
                        assert_eq!(t.shape, spec.shape);
                        if !spec.name.starts_with("w:") && !spec.name.starts_with("b:") {
                            assert!(t.data.iter().all(|&v| v > 0.0 && v.is_finite()),
                                    "{} {:?} {:?}", arch.name, winit, spec.name);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn f_inversion_reconstructs_weight_scale() {
        // with uniform scales: sv*f/su == s_w exactly
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let params = he_init_params(arch, 1);
        let ds = crate::data::Dataset::new(0);
        let batches = vec![ds.batch(crate::data::Split::Calib, 0, 4).0];
        let absmax = absmax_from_rust_forward(arch, &params, &batches);
        let tm = init_trainables(arch, &params, &absmax, Mode::Lw,
                                 WeightScaleInit::Uniform, None);
        for op in arch.conv_ops() {
            let w = params.get(&format!("w:{}", op.name));
            let s_w = ppq::mmse_scale(&w.data, WEIGHT_QMAX);
            let su = tm.get(&format!("sv:{}", op.inp)).data[0];
            let sv = tm.get(&format!("sv:{}", op.out)).data[0];
            let f = tm.get(&format!("f:{}", op.name)).data[0];
            let rec = sv * f / su;
            assert!((rec - s_w).abs() < 1e-4 * s_w, "{}", op.name);
        }
    }

    #[test]
    fn he_init_is_deterministic() {
        let Ok(m) = Manifest::load("artifacts/manifest.json") else { return };
        let arch = &m.archs["convnet_tiny"];
        let a = he_init_params(arch, 5);
        let b = he_init_params(arch, 5);
        for spec in &arch.params {
            assert_eq!(a.get(&spec.name).data, b.get(&spec.name).data);
        }
        let c = he_init_params(arch, 6);
        assert_ne!(a.get("w:conv0").data, c.get("w:conv0").data);
    }
}
