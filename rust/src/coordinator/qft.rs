//! The QFT finetuning loop (§3.1/§4): the paper's single-stage, label-free,
//! small-data knowledge-distillation finetune of ALL quantization DoF.
//!
//! The rust leader owns the trainable/optimizer state and the LR schedule
//! (cosine decaying across 4 epochs, reloading at /2 — §4), streams
//! calibration batches from a prefetch thread, and drives the AOT
//! `qft_train_{mode}` Adam step through PJRT.  No labels are ever read.

use anyhow::Result;

use crate::coordinator::{eval, pretrain::batch_stream, state};
use crate::data::{Dataset, Split};
use crate::nn::ParamMap;
use crate::quant::baselines::{self, Baseline};
use crate::quant::deploy::Mode;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct QftConfig {
    pub mode: Mode,
    /// epochs of the paper's schedule (12 in §4).
    pub epochs: usize,
    /// distinct calibration images (the paper's 8K working point, scaled).
    pub calib_images: u64,
    /// images fed per epoch (== calib_images at the working point; the
    /// Fig. 5 ablation holds epochs*images_per_epoch constant).
    pub images_per_epoch: u64,
    pub base_lr: f32,
    /// CE-on-logits mixing proportion (Fig. 6; 0.0 = pure backbone L2).
    pub ce_mix: f32,
    /// train the scale DoF (false = frozen-scales ablation arm).
    pub train_scales: bool,
    /// initialize the activation vector scale with 4b-adapted CLE (App. D).
    pub cle_init: bool,
    pub winit: state::WeightScaleInit,
    pub seed: u64,
}

impl QftConfig {
    pub fn standard(mode: Mode) -> Self {
        QftConfig {
            mode,
            epochs: 12,
            calib_images: 512,
            images_per_epoch: 512,
            base_lr: 5e-4,
            ce_mix: 0.0,
            train_scales: true,
            cle_init: false,
            winit: match mode {
                Mode::Lw => state::WeightScaleInit::Uniform,
                // paper §4: dch starts from the plain uniform initialization
                Mode::Dch => state::WeightScaleInit::Uniform,
            },
            seed: 0,
        }
    }

    /// Scaled-down profile for benches.  The shorter schedule needs a
    /// gentler base LR: with Adam the scale DoF move ~lr per step regardless
    /// of gradient magnitude, and 192 steps at 5e-4 can walk a 0.02-magnitude
    /// activation scale far off before the cosine decays (the full schedule
    /// converges fine; see EXPERIMENTS.md Fig. 7/8 notes).
    pub fn fast(mode: Mode) -> Self {
        let mut c = Self::standard(mode);
        c.epochs = 6;
        c.calib_images = 256;
        c.images_per_epoch = 256;
        c.base_lr = 2e-4;
        c
    }

    pub fn total_steps(&self, batch: usize) -> usize {
        (self.epochs as u64 * self.images_per_epoch) as usize / batch
    }
}

/// §4 LR schedule: cosine decaying across 4 epochs, reloading at half the
/// base every 4 epochs (1e-4 → 5e-5 @4 → 2.5e-5 @8 in the paper).
pub fn qft_lr(base: f32, step: usize, steps_per_epoch: usize) -> f32 {
    let epoch = step / steps_per_epoch.max(1);
    let window = epoch / 4;
    let base_w = base / 2f32.powi(window as i32);
    let frac_in_window = (step as f32 - (window * 4 * steps_per_epoch) as f32)
        / (4 * steps_per_epoch) as f32;
    base_w * 0.5 * (1.0 + (std::f32::consts::PI * frac_in_window.clamp(0.0, 1.0)).cos())
}

pub struct QftResult {
    pub trainables: ParamMap,
    pub losses: Vec<f32>,
    /// initialization used (before any training) — the frozen baseline.
    pub init: ParamMap,
}

/// Initialize the trainable set per the config (the "sole pre-QFT step").
pub fn initialize(
    rt: &Runtime,
    arch_name: &str,
    teacher: &ParamMap,
    cfg: &QftConfig,
) -> Result<ParamMap> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let absmax = eval::calib_stats(rt, arch_name, teacher, cfg.calib_images.min(128), cfg.seed)?;
    let calib = eval::calib_batches(arch.batch, 2, cfg.seed);
    let baseline = if cfg.cle_init { Baseline::MmseCle } else { Baseline::Mmse };
    let mut tm = baselines::build(&arch, teacher, &absmax, cfg.mode, baseline, &calib);
    if cfg.winit != state::WeightScaleInit::Uniform && cfg.mode == Mode::Dch {
        // explicit granularity override for ablations
        let cle = None;
        tm = state::init_trainables(&arch, teacher, &absmax, cfg.mode, cfg.winit, cle);
    }
    Ok(tm)
}

/// Run QFT: returns finetuned trainables + the loss curve.
pub fn run_qft(
    rt: &Runtime,
    arch_name: &str,
    teacher: &ParamMap,
    cfg: &QftConfig,
) -> Result<QftResult> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let init = initialize(rt, arch_name, teacher, cfg)?;
    let specs = arch.trainable_specs(cfg.mode.key());
    let n = specs.len();
    let mut tr = init.to_ordered(specs);
    let mut m = state::zeros_like_specs(specs);
    let mut v = state::zeros_like_specs(specs);
    let teacher_ordered = teacher.to_ordered(&arch.params);

    let batch = arch.batch;
    let steps = cfg.total_steps(batch);
    let steps_per_epoch = ((cfg.images_per_epoch as usize) / batch).max(1);
    let ds = Dataset::new(cfg.seed);
    let rx = batch_stream(ds, Split::Calib, cfg.calib_images, batch, steps);

    let entry = format!("qft_train_{}", cfg.mode.key());
    let ce_mix = Tensor::scalar(cfg.ce_mix);
    let train_scales = Tensor::scalar(if cfg.train_scales { 1.0 } else { 0.0 });

    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let (x, _) = rx.recv().expect("batch stream ended early");
        let lr = qft_lr(cfg.base_lr, step, steps_per_epoch);
        let mut inputs = Vec::with_capacity(3 * n + 4 + teacher_ordered.len() + 1);
        inputs.extend(tr.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(Tensor::scalar(step as f32 + 1.0));
        inputs.push(Tensor::scalar(lr));
        inputs.push(ce_mix.clone());
        inputs.push(train_scales.clone());
        inputs.extend(teacher_ordered.iter().cloned());
        inputs.push(x);
        let mut out = rt.run(arch_name, &entry, &inputs)?;
        let loss = out.pop().expect("loss").data[0];
        losses.push(loss);
        v = out.split_off(2 * n);
        m = out.split_off(n);
        tr = out;
    }
    Ok(QftResult {
        trainables: ParamMap::from_ordered(specs, tr),
        losses,
        init,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let spe = 64;
        let base = 1e-4;
        // start of training: full base
        assert!((qft_lr(base, 0, spe) - base).abs() < 1e-9);
        // end of first 4-epoch window: near zero
        assert!(qft_lr(base, 4 * spe - 1, spe) < 0.01 * base);
        // reload at epoch 4: half the base
        let reload = qft_lr(base, 4 * spe, spe);
        assert!((reload - base / 2.0).abs() < 1e-3 * base, "{reload}");
        // reload at epoch 8: quarter
        let reload2 = qft_lr(base, 8 * spe, spe);
        assert!((reload2 - base / 4.0).abs() < 1e-3 * base);
        // monotone within a window
        assert!(qft_lr(base, spe, spe) > qft_lr(base, 2 * spe, spe));
    }

    #[test]
    fn config_step_accounting() {
        let cfg = QftConfig::standard(Mode::Lw);
        assert_eq!(cfg.total_steps(8), 12 * 512 / 8);
    }
}
