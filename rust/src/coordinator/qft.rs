//! The QFT finetuning loop (§3.1/§4): the paper's single-stage, label-free,
//! small-data knowledge-distillation finetune of ALL quantization DoF.
//!
//! The rust leader owns the trainable/optimizer state and the LR schedule
//! (cosine decaying across 4 epochs, reloading at /2 — §4), streams
//! calibration batches from a prefetch thread, and drives the AOT
//! `qft_train_{mode}` Adam step through PJRT.  No labels are ever read.

use anyhow::Result;

use crate::coordinator::{eval, pretrain::batch_stream, state};
use crate::data::{Dataset, Split};
use crate::nn::ParamMap;
use crate::quant::baselines::{self, Baseline};
use crate::quant::deploy::Mode;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct QftConfig {
    pub mode: Mode,
    /// epochs of the paper's schedule (12 in §4).
    pub epochs: usize,
    /// distinct calibration images (the paper's 8K working point, scaled).
    pub calib_images: u64,
    /// images fed per epoch (== calib_images at the working point; the
    /// Fig. 5 ablation holds epochs*images_per_epoch constant).
    pub images_per_epoch: u64,
    pub base_lr: f32,
    /// CE-on-logits mixing proportion (Fig. 6; 0.0 = pure backbone L2).
    pub ce_mix: f32,
    /// train the scale DoF (false = frozen-scales ablation arm).
    pub train_scales: bool,
    /// initialize the activation vector scale with 4b-adapted CLE (App. D).
    pub cle_init: bool,
    pub winit: state::WeightScaleInit,
    pub seed: u64,
}

impl QftConfig {
    pub fn standard(mode: Mode) -> Self {
        QftConfig {
            mode,
            epochs: 12,
            calib_images: 512,
            images_per_epoch: 512,
            base_lr: 5e-4,
            ce_mix: 0.0,
            train_scales: true,
            cle_init: false,
            winit: match mode {
                Mode::Lw => state::WeightScaleInit::Uniform,
                // paper §4: dch starts from the plain uniform initialization
                Mode::Dch => state::WeightScaleInit::Uniform,
            },
            seed: 0,
        }
    }

    /// Scaled-down profile for benches.  The shorter schedule needs a
    /// gentler base LR: with Adam the scale DoF move ~lr per step regardless
    /// of gradient magnitude, and 192 steps at 5e-4 can walk a 0.02-magnitude
    /// activation scale far off before the cosine decays (the full schedule
    /// converges fine; see EXPERIMENTS.md Fig. 7/8 notes).
    pub fn fast(mode: Mode) -> Self {
        let mut c = Self::standard(mode);
        c.epochs = 6;
        c.calib_images = 256;
        c.images_per_epoch = 256;
        c.base_lr = 2e-4;
        c
    }

    /// Steps per epoch at batch size `batch`, rounded UP: when `batch` does
    /// not divide `images_per_epoch` the trailing partial batch still runs
    /// (the calibration pool is cyclic, so that batch wraps to the head of
    /// the pool instead of silently dropping the tail images).  The §4 LR
    /// reload windows are exact multiples of this, so epoch boundaries and
    /// schedule boundaries always coincide.
    pub fn steps_per_epoch(&self, batch: usize) -> usize {
        (self.images_per_epoch as usize).div_ceil(batch.max(1)).max(1)
    }

    /// Exact total step count: `epochs * steps_per_epoch(batch)`.  Never
    /// truncates, so the last epoch is as long as every other and the
    /// cosine windows in [`qft_lr`] never drift from the data epochs.
    pub fn total_steps(&self, batch: usize) -> usize {
        self.epochs * self.steps_per_epoch(batch)
    }
}

/// §4 LR schedule: cosine decaying across 4 epochs, reloading at half the
/// base every 4 epochs (1e-4 → 5e-5 @4 → 2.5e-5 @8 in the paper).
/// `steps_per_epoch == 0` is clamped to 1 everywhere (including the cosine
/// denominator) so the schedule degrades to a finite value, never NaN.
pub fn qft_lr(base: f32, step: usize, steps_per_epoch: usize) -> f32 {
    let spe = steps_per_epoch.max(1);
    let epoch = step / spe;
    let window = epoch / 4;
    let base_w = base / 2f32.powi(window as i32);
    let frac_in_window = (step as f32 - (window * 4 * spe) as f32) / (4 * spe) as f32;
    base_w * 0.5 * (1.0 + (std::f32::consts::PI * frac_in_window.clamp(0.0, 1.0)).cos())
}

pub struct QftResult {
    pub trainables: ParamMap,
    pub losses: Vec<f32>,
    /// initialization used (before any training) — the frozen baseline.
    pub init: ParamMap,
}

/// Initialize the trainable set per the config (the "sole pre-QFT step").
pub fn initialize(
    rt: &Runtime,
    arch_name: &str,
    teacher: &ParamMap,
    cfg: &QftConfig,
) -> Result<ParamMap> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let absmax = eval::calib_stats(rt, arch_name, teacher, cfg.calib_images.min(128), cfg.seed)?;
    let calib = eval::calib_batches(arch.batch, 2, cfg.seed);
    let baseline = if cfg.cle_init { Baseline::MmseCle } else { Baseline::Mmse };
    let mut tm = baselines::build(&arch, teacher, &absmax, cfg.mode, baseline, &calib);
    if cfg.winit != state::WeightScaleInit::Uniform && cfg.mode == Mode::Dch {
        // explicit granularity override for ablations
        let cle = None;
        tm = state::init_trainables(&arch, teacher, &absmax, cfg.mode, cfg.winit, cle);
    }
    Ok(tm)
}

/// Run QFT: returns finetuned trainables + the loss curve.
pub fn run_qft(
    rt: &Runtime,
    arch_name: &str,
    teacher: &ParamMap,
    cfg: &QftConfig,
) -> Result<QftResult> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let init = initialize(rt, arch_name, teacher, cfg)?;
    let specs = arch.trainable_specs(cfg.mode.key());
    let n = specs.len();
    let mut tr = init.to_ordered(specs);
    let mut m = state::zeros_like_specs(specs);
    let mut v = state::zeros_like_specs(specs);
    let teacher_ordered = teacher.to_ordered(&arch.params);

    let batch = arch.batch;
    let steps = cfg.total_steps(batch);
    let steps_per_epoch = cfg.steps_per_epoch(batch);
    let ds = Dataset::new(cfg.seed);
    let rx = batch_stream(ds, Split::Calib, cfg.calib_images, batch, steps);

    let entry = format!("qft_train_{}", cfg.mode.key());
    let ce_mix = Tensor::scalar(cfg.ce_mix);
    let train_scales = Tensor::scalar(if cfg.train_scales { 1.0 } else { 0.0 });

    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        // a dead prefetch thread must surface as a coordinator error, not
        // abort the process mid-finetune
        let (x, _) = rx.recv().map_err(|_| {
            anyhow::anyhow!(
                "calibration batch stream ended early at step {step}/{steps} \
                 (prefetch thread died)"
            )
        })?;
        let lr = qft_lr(cfg.base_lr, step, steps_per_epoch);
        let mut inputs = Vec::with_capacity(3 * n + 4 + teacher_ordered.len() + 1);
        inputs.extend(tr.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(Tensor::scalar(step as f32 + 1.0));
        inputs.push(Tensor::scalar(lr));
        inputs.push(ce_mix.clone());
        inputs.push(train_scales.clone());
        inputs.extend(teacher_ordered.iter().cloned());
        inputs.push(x);
        let mut out = rt.run(arch_name, &entry, &inputs)?;
        let loss = out.pop().expect("loss").data[0];
        losses.push(loss);
        v = out.split_off(2 * n);
        m = out.split_off(n);
        tr = out;
    }
    Ok(QftResult {
        trainables: ParamMap::from_ordered(specs, tr),
        losses,
        init,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let spe = 64;
        let base = 1e-4;
        // start of training: full base
        assert!((qft_lr(base, 0, spe) - base).abs() < 1e-9);
        // end of first 4-epoch window: near zero
        assert!(qft_lr(base, 4 * spe - 1, spe) < 0.01 * base);
        // reload at epoch 4: half the base
        let reload = qft_lr(base, 4 * spe, spe);
        assert!((reload - base / 2.0).abs() < 1e-3 * base, "{reload}");
        // reload at epoch 8: quarter
        let reload2 = qft_lr(base, 8 * spe, spe);
        assert!((reload2 - base / 4.0).abs() < 1e-3 * base);
        // monotone within a window
        assert!(qft_lr(base, spe, spe) > qft_lr(base, 2 * spe, spe));
    }

    #[test]
    fn config_step_accounting() {
        let cfg = QftConfig::standard(Mode::Lw);
        assert_eq!(cfg.total_steps(8), 12 * 512 / 8);
    }

    #[test]
    fn step_accounting_is_exact_at_non_dividing_batch() {
        // standard: 12 epochs x 512 images
        let cfg = QftConfig::standard(Mode::Lw);
        // dividing batch: unchanged behaviour
        assert_eq!(cfg.steps_per_epoch(8), 64);
        assert_eq!(cfg.total_steps(8), 12 * 64);
        // non-dividing batch: rounds UP (truncation used to drop the 2
        // trailing images every epoch and shrink the schedule by 8 steps)
        assert_eq!(cfg.steps_per_epoch(5), 103); // ceil(512/5)
        assert_eq!(cfg.total_steps(5), 12 * 103);
        for b in [1usize, 3, 5, 7, 8, 100, 511, 512, 1000] {
            // LR windows are whole multiples of the epoch length...
            assert_eq!(cfg.total_steps(b), cfg.epochs * cfg.steps_per_epoch(b));
            // ...and no calibration image is ever dropped
            assert!(cfg.steps_per_epoch(b) * b >= cfg.images_per_epoch as usize, "batch {b}");
        }
        // degenerate batch stays sane instead of dividing by zero
        assert_eq!(cfg.steps_per_epoch(0), 512);
    }

    #[test]
    fn lr_is_finite_at_zero_steps_per_epoch() {
        // steps_per_epoch == 0 used to NaN the cosine fraction denominator
        let base = 1e-4f32;
        let lr0 = qft_lr(base, 0, 0);
        assert!(lr0.is_finite());
        assert!((lr0 - base).abs() < 1e-9, "{lr0}");
        for step in [1usize, 3, 4, 17] {
            let lr = qft_lr(base, step, 0);
            assert!(lr.is_finite() && lr >= 0.0 && lr <= base, "step {step}: {lr}");
        }
    }
}
