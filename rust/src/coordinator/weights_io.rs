//! Tiny on-disk tensor-bundle format for cached teacher weights.
//!
//! Layout: `QFTW` magic, u32 header length, JSON header
//! `[{"name":..,"shape":[..]}, ..]`, then raw little-endian f32 payloads in
//! header order.  Keeps pretraining a one-time cost across benches/examples.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::{ParamMap, ParamSpec};
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"QFTW";

pub fn save(path: impl AsRef<Path>, specs: &[ParamSpec], params: &ParamMap) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let header = crate::util::json::Value::Arr(
        specs
            .iter()
            .map(|s| {
                let mut m = std::collections::HashMap::new();
                m.insert("name".to_string(), crate::util::json::Value::Str(s.name.clone()));
                m.insert(
                    "shape".to_string(),
                    crate::util::json::Value::Arr(
                        s.shape.iter().map(|&d| crate::util::json::Value::Num(d as f64)).collect(),
                    ),
                );
                crate::util::json::Value::Obj(m)
            })
            .collect(),
    )
    .to_string_compact()
    .into_bytes();
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(&header)?;
    for s in specs {
        let t = params.get(&s.name);
        let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<ParamMap> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}");
    let mut len = [0u8; 4];
    f.read_exact(&mut len)?;
    let mut header = vec![0u8; u32::from_le_bytes(len) as usize];
    f.read_exact(&mut header)?;
    let header_v = crate::util::json::Value::parse(std::str::from_utf8(&header)?)?;
    let specs: Vec<ParamSpec> = ParamSpec::list_from_json(&header_v)?;
    let mut map = std::collections::HashMap::new();
    for s in &specs {
        let n = s.numel();
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        map.insert(s.name.clone(), Tensor::new(s.shape.clone(), data));
    }
    Ok(ParamMap(map))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let specs = vec![
            ParamSpec { name: "w:a".into(), shape: vec![2, 3] },
            ParamSpec { name: "b:a".into(), shape: vec![3] },
        ];
        let mut map = std::collections::HashMap::new();
        map.insert("w:a".to_string(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        map.insert("b:a".to_string(), Tensor::new(vec![3], vec![-1., 0., 1.]));
        let pm = ParamMap(map);
        let dir = std::env::temp_dir().join("qft_weights_io_test");
        let path = dir.join("t.qftw");
        save(&path, &specs, &pm).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.get("w:a"), pm.get("w:a"));
        assert_eq!(loaded.get("b:a"), pm.get("b:a"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/nonexistent/qft.bin").is_err());
    }
}
