//! FP teacher pretraining driver: the rust leader feeds synthetic batches to
//! the AOT `fp_train` Adam step (the in-repo substitute for torchvision
//! pretrained models — see DESIGN.md §Substitutions).
//!
//! Data batches are prefetched on a worker thread while PJRT executes the
//! current step, so the coordinator never starves the executor.

use std::sync::mpsc;

use anyhow::Result;

use crate::coordinator::state;
use crate::data::{Dataset, Split};
use crate::nn::ParamMap;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub steps: usize,
    pub base_lr: f32,
    pub batch: usize,
    /// number of distinct training images (cycled).
    pub train_images: u64,
    pub seed: u64,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig { steps: 6000, base_lr: 1.5e-3, batch: 8, train_images: 4096, seed: 0 }
    }
}

/// Cosine LR with a small floor.
pub fn cosine_lr(base: f32, t: usize, total: usize) -> f32 {
    let frac = t as f32 / total.max(1) as f32;
    base * (0.5 * (1.0 + (std::f32::consts::PI * frac).cos())).max(0.02)
}

/// Spawn a prefetch thread producing (images, labels_f32) batches: a cyclic
/// walk over a fixed pool of `n_images`.  Every index wraps modulo the pool
/// (not just the batch start), so when `batch` does not divide `n_images`
/// the trailing partial batch re-reads the pool head instead of sampling
/// images beyond the pool budget.
pub fn batch_stream(
    ds: Dataset,
    split: Split,
    n_images: u64,
    batch: usize,
    steps: usize,
) -> mpsc::Receiver<(Tensor, Tensor)> {
    let (tx, rx) = mpsc::sync_channel(4);
    std::thread::spawn(move || {
        let pool = n_images.max(1);
        let mut cursor = 0u64;
        for _ in 0..steps {
            let (x, yf, _) = ds.batch_wrapped(split, cursor % pool, batch, pool);
            cursor += batch as u64;
            if tx.send((x, yf)).is_err() {
                return;
            }
        }
    });
    rx
}

pub struct PretrainResult {
    pub params: ParamMap,
    pub losses: Vec<f32>,
}

pub fn pretrain(rt: &Runtime, arch_name: &str, cfg: &PretrainConfig) -> Result<PretrainResult> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let n = arch.params.len();
    let params0 = state::he_init_params(&arch, cfg.seed);
    let mut params = params0.to_ordered(&arch.params);
    let mut m = state::zeros_like_specs(&arch.params);
    let mut v = state::zeros_like_specs(&arch.params);

    let ds = Dataset::new(cfg.seed);
    let rx = batch_stream(ds, Split::Train, cfg.train_images, cfg.batch, cfg.steps);

    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let (x, yf) = rx.recv().expect("batch stream ended early");
        let lr = cosine_lr(cfg.base_lr, step, cfg.steps);
        let mut inputs = Vec::with_capacity(3 * n + 4);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(Tensor::scalar(step as f32 + 1.0));
        inputs.push(Tensor::scalar(lr));
        inputs.push(x);
        inputs.push(yf);
        let mut out = rt.run(arch_name, "fp_train", &inputs)?;
        let loss = out.pop().expect("loss").data[0];
        losses.push(loss);
        v = out.split_off(2 * n);
        m = out.split_off(n);
        params = out;
    }
    Ok(PretrainResult { params: ParamMap::from_ordered(&arch.params, params), losses })
}

/// Load a cached teacher or pretrain + cache one.
pub fn teacher(rt: &Runtime, arch_name: &str, cfg: &PretrainConfig) -> Result<ParamMap> {
    let path = rt
        .dir()
        .join("weights")
        .join(format!("{arch_name}.qftw"));
    if let Ok(p) = super::weights_io::load(&path) {
        return Ok(p);
    }
    let result = pretrain(rt, arch_name, cfg)?;
    let arch = rt.manifest.arch(arch_name)?;
    super::weights_io::save(&path, &arch.params, &result.params)?;
    Ok(result.params)
}
