//! Wall-clock + PJRT duty-cycle metrics for the §Perf pass.

use std::time::Instant;

use crate::runtime::Runtime;

pub struct Span<'a> {
    rt: &'a Runtime,
    start: Instant,
    start_exec_ns: u64,
    start_execs: u64,
    pub label: String,
}

#[derive(Debug, Clone)]
pub struct SpanReport {
    pub label: String,
    pub wall_ms: f64,
    pub exec_ms: f64,
    pub executions: u64,
    /// fraction of wall time spent inside PJRT execution — the coordinator
    /// is "not the bottleneck" when this is high.
    pub duty_cycle: f64,
}

impl<'a> Span<'a> {
    pub fn start(rt: &'a Runtime, label: impl Into<String>) -> Self {
        let s = rt.stats();
        Span {
            rt,
            start: Instant::now(),
            start_exec_ns: s.exec_ns,
            start_execs: s.executions,
            label: label.into(),
        }
    }

    pub fn finish(self) -> SpanReport {
        let wall = self.start.elapsed().as_secs_f64() * 1e3;
        let s = self.rt.stats();
        let exec_ms = (s.exec_ns - self.start_exec_ns) as f64 / 1e6;
        SpanReport {
            label: self.label,
            wall_ms: wall,
            exec_ms,
            executions: s.executions - self.start_execs,
            duty_cycle: if wall > 0.0 { exec_ms / wall } else { 0.0 },
        }
    }
}

impl std::fmt::Display for SpanReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: wall {:.1} ms, pjrt {:.1} ms over {} execs (duty {:.0}%)",
            self.label,
            self.wall_ms,
            self.exec_ms,
            self.executions,
            self.duty_cycle * 100.0
        )
    }
}
