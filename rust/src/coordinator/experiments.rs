//! Experiment runners (one per paper table/figure — DESIGN.md §3).
//!
//! Each runner returns structured rows and prints the paper-shaped output;
//! `rust/benches/*` and the `repro` CLI are thin wrappers over these.

use anyhow::Result;

use crate::coordinator::{eval, pretrain, qft};
use crate::nn::ParamMap;
use crate::quant::baselines::{self, Baseline};
use crate::quant::deploy::Mode;
use crate::quant::{cle, mmse};
use crate::runtime::Runtime;

pub const EVAL_IMAGES: usize = 512;

/// A (network × configuration) accuracy result.
#[derive(Clone, Debug)]
pub struct Row {
    pub arch: String,
    pub config: String,
    pub fp_acc: f32,
    pub acc: f32,
}

impl Row {
    pub fn degradation(&self) -> f32 {
        self.fp_acc - self.acc
    }
}

pub fn print_rows(title: &str, rows: &[Row]) {
    println!("\n=== {title} ===");
    println!("{:<16} {:<28} {:>7} {:>7} {:>8}", "arch", "config", "fp", "acc", "degr");
    for r in rows {
        println!(
            "{:<16} {:<28} {:>6.1}% {:>6.1}% {:>+7.2}%",
            r.arch,
            r.config,
            r.fp_acc * 100.0,
            r.acc * 100.0,
            -r.degradation() * 100.0
        );
    }
}

/// Shared fixture: cached teacher + FP accuracy.
pub struct TeacherCtx {
    pub params: ParamMap,
    pub fp_acc: f32,
}

pub fn teacher_ctx(rt: &Runtime, arch: &str) -> Result<TeacherCtx> {
    let params = pretrain::teacher(rt, arch, &pretrain::PretrainConfig::default())?;
    let fp_acc = eval::eval_fp(rt, arch, &params, EVAL_IMAGES, 0)?;
    Ok(TeacherCtx { params, fp_acc })
}

fn eval_tm(rt: &Runtime, arch: &str, tm: &ParamMap, mode: Mode) -> Result<f32> {
    eval::eval_q(rt, arch, tm, mode, EVAL_IMAGES, 0)
}

fn baseline_tm(
    rt: &Runtime,
    arch_name: &str,
    t: &TeacherCtx,
    mode: Mode,
    b: Baseline,
) -> Result<ParamMap> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let absmax = eval::calib_stats(rt, arch_name, &t.params, 128, 0)?;
    let calib = eval::calib_batches(arch.batch, 4, 0);
    Ok(baselines::build(&arch, &t.params, &absmax, mode, b, &calib))
}

// ---------------------------------------------------------------- Table 1

/// Table 1: QFT vs the heuristic baselines, 4/8 lw and 4/32 dch regimes.
pub fn table1(rt: &Runtime, archs: &[&str], fast: bool) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &a in archs {
        let t = teacher_ctx(rt, a)?;
        let mk = |mode| if fast { qft::QftConfig::fast(mode) } else { qft::QftConfig::standard(mode) };

        // 4/8 lw: QFT and CLE+QFT
        for (label, cle_init) in [("QFT 4/8 lw", false), ("CLE+QFT 4/8 lw", true)] {
            let mut cfg = mk(Mode::Lw);
            cfg.cle_init = cle_init;
            let r = qft::run_qft(rt, a, &t.params, &cfg)?;
            rows.push(Row {
                arch: a.into(),
                config: label.into(),
                fp_acc: t.fp_acc,
                acc: eval_tm(rt, a, &r.trainables, Mode::Lw)?,
            });
        }
        // 4/32 dch: QFT
        let cfg = mk(Mode::Dch);
        let r = qft::run_qft(rt, a, &t.params, &cfg)?;
        rows.push(Row {
            arch: a.into(),
            config: "QFT 4/32 dch".into(),
            fp_acc: t.fp_acc,
            acc: eval_tm(rt, a, &r.trainables, Mode::Dch)?,
        });
        // reference comparator (Adaround/BRECQ stand-in): strongest
        // heuristics-only pipeline on the same substrate
        let tm = baseline_tm(rt, a, &t, Mode::Lw, Baseline::MmseCleBc)?;
        rows.push(Row {
            arch: a.into(),
            config: "mmse+CLE+bc 4/8 lw (ref)".into(),
            fp_acc: t.fp_acc,
            acc: eval_tm(rt, a, &tm, Mode::Lw)?,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------- Table 2

/// Table 2: heuristic-only ablation (weights never trained).
pub fn table2(rt: &Runtime, archs: &[&str]) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &a in archs {
        let t = teacher_ctx(rt, a)?;
        for (mode, blist) in [
            (Mode::Lw, vec![Baseline::Mmse, Baseline::MmseBc, Baseline::MmseCleBc]),
            (Mode::Dch, vec![Baseline::Mmse, Baseline::MmseBc]),
        ] {
            for b in blist {
                let tm = baseline_tm(rt, a, &t, mode, b)?;
                rows.push(Row {
                    arch: a.into(),
                    config: format!("{} {}", b.label(), mode.key()),
                    fp_acc: t.fp_acc,
                    acc: eval_tm(rt, a, &tm, mode)?,
                });
            }
        }
    }
    Ok(rows)
}

// ----------------------------------------------------------------- Fig. 3

#[derive(Clone, Debug)]
pub struct GranularityRow {
    pub layer: String,
    pub e_layerwise: f32,
    pub e_channelwise: f32,
    pub e_dch: f32,
}

/// Fig. 3: kernel quantization error norm across scale-tensor granularity.
pub fn fig3(rt: &Runtime, arch_name: &str) -> Result<Vec<GranularityRow>> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let t = teacher_ctx(rt, arch_name)?;
    let mut rows = Vec::new();
    for op in arch.conv_ops() {
        let w = t.params.get(&format!("w:{}", op.name));
        let (_, e_lw) = mmse::mmse_layerwise(w, crate::WEIGHT_QMAX);
        let (_, e_ch) = mmse::mmse_channelwise(w, crate::WEIGHT_QMAX);
        let e_dch = if op.groups == 1 {
            mmse::mmse_dch(w, crate::WEIGHT_QMAX, 10).2
        } else {
            e_ch // depthwise: single channel axis, dCh degenerates to ch
        };
        rows.push(GranularityRow {
            layer: op.name.clone(),
            e_layerwise: e_lw,
            e_channelwise: e_ch,
            e_dch,
        });
    }
    Ok(rows)
}

// ----------------------------------------------------------------- Fig. 5

/// Fig. 5: dataset-size ablation, total fed images held constant.
pub fn fig5(rt: &Runtime, arch: &str, sizes: &[u64], fast: bool) -> Result<Vec<Row>> {
    let t = teacher_ctx(rt, arch)?;
    let total: u64 = if fast { 1536 } else { 6144 };
    let mut rows = Vec::new();
    for &sz in sizes {
        let mut cfg = qft::QftConfig::standard(Mode::Lw);
        cfg.calib_images = sz;
        cfg.images_per_epoch = sz;
        cfg.epochs = (total / sz).max(1) as usize;
        let r = qft::run_qft(rt, arch, &t.params, &cfg)?;
        rows.push(Row {
            arch: arch.into(),
            config: format!("{sz} images"),
            fp_acc: t.fp_acc,
            acc: eval_tm(rt, arch, &r.trainables, Mode::Lw)?,
        });
    }
    Ok(rows)
}

// ----------------------------------------------------------------- Fig. 6

/// Fig. 6: CE-on-logits mixing proportion ablation.
pub fn fig6(rt: &Runtime, arch: &str, mixes: &[f32], fast: bool) -> Result<Vec<Row>> {
    let t = teacher_ctx(rt, arch)?;
    let mut rows = Vec::new();
    for &p in mixes {
        let mut cfg = if fast { qft::QftConfig::fast(Mode::Lw) } else { qft::QftConfig::standard(Mode::Lw) };
        cfg.ce_mix = p;
        let r = qft::run_qft(rt, arch, &t.params, &cfg)?;
        rows.push(Row {
            arch: arch.into(),
            config: format!("ce_mix={p:.2}"),
            fp_acc: t.fp_acc,
            acc: eval_tm(rt, arch, &r.trainables, Mode::Lw)?,
        });
    }
    Ok(rows)
}

// ----------------------------------------------------------------- Fig. 7

/// Fig. 7: base learning-rate sweep.
pub fn fig7(rt: &Runtime, arch: &str, lrs: &[f32], fast: bool) -> Result<Vec<Row>> {
    let t = teacher_ctx(rt, arch)?;
    let mut rows = Vec::new();
    for &lr in lrs {
        let mut cfg = if fast { qft::QftConfig::fast(Mode::Lw) } else { qft::QftConfig::standard(Mode::Lw) };
        cfg.base_lr = lr;
        let r = qft::run_qft(rt, arch, &t.params, &cfg)?;
        rows.push(Row {
            arch: arch.into(),
            config: format!("lr={lr:.0e}"),
            fp_acc: t.fp_acc,
            acc: eval_tm(rt, arch, &r.trainables, Mode::Lw)?,
        });
    }
    Ok(rows)
}

// ----------------------------------------------------------------- Fig. 8

/// Fig. 8: 2×2 {CLE init?} × {train vector scales?} in the lw regime.
pub fn fig8(rt: &Runtime, archs: &[&str], fast: bool) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &a in archs {
        let t = teacher_ctx(rt, a)?;
        for (label, cle_init, train_scales) in [
            ("base (no CLE, frozen sv)", false, false),
            ("CLE init, frozen sv", true, false),
            ("trained sv", false, true),
            ("CLE + trained sv", true, true),
        ] {
            let mut cfg = if fast { qft::QftConfig::fast(Mode::Lw) } else { qft::QftConfig::standard(Mode::Lw) };
            cfg.cle_init = cle_init;
            cfg.train_scales = train_scales;
            let r = qft::run_qft(rt, a, &t.params, &cfg)?;
            rows.push(Row {
                arch: a.into(),
                config: label.into(),
                fp_acc: t.fp_acc,
                acc: eval_tm(rt, a, &r.trainables, Mode::Lw)?,
            });
        }
    }
    Ok(rows)
}

// ----------------------------------------------------------------- Fig. 9

/// Fig. 9: dch regime, frozen vs trained L/R kernel scale co-vectors.
pub fn fig9(rt: &Runtime, archs: &[&str], fast: bool) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &a in archs {
        let t = teacher_ctx(rt, a)?;
        for (label, train_scales) in [("frozen L/R scales", false), ("trained L/R scales", true)] {
            let mut cfg = if fast { qft::QftConfig::fast(Mode::Dch) } else { qft::QftConfig::standard(Mode::Dch) };
            cfg.train_scales = train_scales;
            let r = qft::run_qft(rt, a, &t.params, &cfg)?;
            rows.push(Row {
                arch: a.into(),
                config: label.into(),
                fp_acc: t.fp_acc,
                acc: eval_tm(rt, a, &r.trainables, Mode::Dch)?,
            });
        }
    }
    Ok(rows)
}

// ---------------------------------------------------------------- Fig. 12

#[derive(Clone, Debug)]
pub struct KernelErrorRow {
    pub layer: String,
    pub e_layerwise: f32,
    pub e_cle: f32,
    pub e_qft: f32,
    pub e_channelwise: f32,
}

/// Fig. 12: per-layer kernel error under lw / CLE / QFT / channelwise scale
/// optimization (QFT column uses the actually-finetuned trainables).
pub fn fig12(rt: &Runtime, arch_name: &str, fast: bool) -> Result<Vec<KernelErrorRow>> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let t = teacher_ctx(rt, arch_name)?;
    let mut cfg = if fast { qft::QftConfig::fast(Mode::Lw) } else { qft::QftConfig::standard(Mode::Lw) };
    cfg.cle_init = false;
    let r = qft::run_qft(rt, arch_name, &t.params, &cfg)?;

    let cle_f = cle::cle_factors(&arch, &t.params, &cle::BitConfig::default());
    let mut rows = Vec::new();
    for op in arch.conv_ops() {
        if op.groups != 1 {
            continue;
        }
        let w = t.params.get(&format!("w:{}", op.name));
        let (s_lw, e_lw) = mmse::mmse_layerwise(w, crate::WEIGHT_QMAX);
        let (_, e_ch) = mmse::mmse_channelwise(w, crate::WEIGHT_QMAX);
        // CLE column: outer grid with factors folded in (Eq. 18)
        let ones = vec![1.0f32; op.cin];
        let c_in = cle_f.get(&op.inp).unwrap_or(&ones);
        let s_l: Vec<f32> = c_in.iter().map(|&c| 1.0 / c).collect();
        let s_r = vec![s_lw; op.cout];
        let wq = mmse::fq_outer(w, &s_l, &s_r, crate::WEIGHT_QMAX);
        let e_cle = w.sub(&wq).norm();
        // QFT column: the trained DoF's grid applied to the trained weights
        let (ql, qr) = crate::quant::deploy::kernel_covectors(&arch, &r.trainables, Mode::Lw, op);
        let w_t = r.trainables.get(&format!("w:{}", op.name));
        let wq_t = match &ql {
            Some(l) => mmse::fq_outer(w_t, l, &qr, crate::WEIGHT_QMAX),
            None => mmse::fq_per_out_channel(w_t, &qr, crate::WEIGHT_QMAX),
        };
        let e_qft = w_t.sub(&wq_t).norm();
        rows.push(KernelErrorRow {
            layer: op.name.clone(),
            e_layerwise: e_lw,
            e_cle,
            e_qft,
            e_channelwise: e_ch,
        });
    }
    Ok(rows)
}

// ------------------------------------------------------- channel analysis

#[derive(Clone, Debug)]
pub struct ChannelPoint {
    pub layer: String,
    pub channel: usize,
    /// mmse-optimal slice range normalized by whole-kernel naive max (Fig.13)
    pub norm_opt_range: f32,
    /// per-slice error under layerwise scale (Fig. 14)
    pub err_layerwise: f32,
    /// per-slice error under channelwise scale (Fig. 15)
    pub err_channelwise: f32,
    /// per-slice error after CLE (Fig. 16)
    pub err_cle: f32,
}

/// Figs. 13–16 scatter data: per-channel optimal ranges and errors.
pub fn channel_analysis(rt: &Runtime, arch_name: &str) -> Result<Vec<ChannelPoint>> {
    let arch = rt.manifest.arch(arch_name)?.clone();
    let t = teacher_ctx(rt, arch_name)?;
    let cle_f = cle::cle_factors(&arch, &t.params, &cle::BitConfig::default());
    let qmax = crate::WEIGHT_QMAX;
    let mut pts = Vec::new();
    for op in arch.conv_ops() {
        if op.groups != 1 {
            continue;
        }
        let w = t.params.get(&format!("w:{}", op.name));
        let naive_full = w.abs_max();
        let (s_full, _) = mmse::mmse_layerwise(w, qmax);
        let ones = vec![1.0f32; op.cin];
        let c_in = cle_f.get(&op.inp).unwrap_or(&ones);
        // The CLE'd kernel (Eq. 16: rows scaled by 1/C) gets its own
        // layerwise-mmse grid; per-slice errors are mapped back to the
        // original weight domain (multiply each scaled-row error by C_i).
        let mut w_cle = w.clone();
        for (idx, v) in w_cle.data.iter_mut().enumerate() {
            let i = (idx / op.cout) % op.cin;
            *v /= c_in[i];
        }
        let (s_full_cle, _) = mmse::mmse_layerwise(&w_cle, qmax);
        for m in 0..op.cout {
            let slice = mmse::out_channel_slice(w, m);
            let s_opt = crate::quant::ppq::mmse_scale(&slice, qmax);
            let err_lw = crate::quant::ppq::quant_error(&slice, s_full, qmax);
            let err_ch = crate::quant::ppq::quant_error(&slice, s_opt, qmax);
            // CLE slice error in the original domain: quantize the scaled
            // rows on the CLE'd layerwise grid, unscale per row
            // (out_channel_slice layout is e-major: idx % cin == row i)
            let slice_cle = mmse::out_channel_slice(&w_cle, m);
            let mut e2 = 0.0f32;
            for (idx, &v) in slice_cle.iter().enumerate() {
                let i = idx % op.cin;
                let dq = (v / s_full_cle).round().clamp(-qmax, qmax) * s_full_cle;
                let e = (v - dq) * c_in[i];
                e2 += e * e;
            }
            let err_cle = e2.sqrt();
            pts.push(ChannelPoint {
                layer: op.name.clone(),
                channel: m,
                norm_opt_range: s_opt * qmax / naive_full,
                err_layerwise: err_lw,
                err_channelwise: err_ch,
                err_cle,
            });
        }
    }
    Ok(pts)
}
