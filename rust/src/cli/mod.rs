//! `qft::cli` — the declarative flag/command surface behind the `repro`
//! binary.
//!
//! The CLI used to be three hand-maintained lists in `main.rs` (`KV_KEYS`,
//! `BOOL_FLAGS`, per-command `reject_unused` calls) that had to be kept in
//! sync by hand.  This module replaces them with ONE table: [`SPEC`] rows
//! carry a flag's name, arity, informative default, one-line help, and the
//! commands it applies to, and everything else is derived —
//!
//! * [`Args::parse`] — strict parsing: unknown options, duplicate options,
//!   and a value-flag at end-of-line are all hard errors (no silent
//!   last-wins), with the exact wording the hand-rolled parser used;
//! * [`check`] — per-command applicability: a flag the command reads
//!   nothing from is a hard error, again with the legacy wording
//!   (`--K is not used by \`CMD\` (see usage)` for the serving commands,
//!   `--K applies to the serving / backend-eval commands only` for the
//!   pipeline commands);
//! * [`help`] — [`USAGE`] plus a generated per-flag reference.
//!
//! The in-module tests pin the pre-redesign surface: every legacy flag
//! keeps its name and arity, and every legacy per-command accept/reject
//! decision is asserted against the old hardcoded lists.

use std::collections::HashMap;

use anyhow::{bail, Result};

pub const USAGE: &str = "\
repro — QFT post-training quantization pipeline

USAGE: repro [--artifacts DIR] <command> [options]

COMMANDS:
  pretrain  --arch A [--steps N]          pretrain + cache the FP teacher
  eval-fp   --arch A                      evaluate the cached FP teacher
  qft       --arch A [--mode lw|dch] [--cle] [--frozen-scales]
            [--lr F] [--ce-mix F] [--fast]   run the full QFT pipeline and
                                          export weights/A.MODE.qftw for serving
  table1    [--archs A,B,..] [--fast]     Table 1: QFT vs PTQ baselines
  table2    [--archs A,B,..]              Table 2: accuracy without QFT
  fig3      [--arch A]                    kernel error vs granularity
  fig5      [--arch A] [--fast]           dataset-size ablation
  fig6      [--arch A] [--fast]           CE-mixing ablation
  fig7      [--arch A] [--fast]           base-LR sweep
  fig8      [--archs A,B] [--fast]        CLE-init x trained-scales 2x2
  fig9      [--archs A,B] [--fast]        dch frozen vs trained L/R scales
  fig12     [--arch A] [--fast]           per-layer kernel error lw/CLE/QFT/chw

SERVING / BACKEND EVAL (pure-rust execution backends; no PJRT needed):
  serve     [--arch A] [--backend K] [--workers N] [--max-batch B]
            [--max-wait-us U] [--queue-cap Q] [--requests R] [--threads T]
            [--stats-json P]              load A/K into the fleet, run a
                                          closed-loop smoke client over R val
                                          images, report accuracy + latency
            [--backend-b K2] [--ab-bp W]  install K2 as a second version and
                                          A/B-split W basis points (of 10000)
                                          of traffic to it
            [--shadow-every S]            mirror 1-in-S micro-batches into a
                                          shadow FP forward capturing live
                                          activation ranges (0 = off)
            [--swap-after N]              after N replies, install a
                                          bit-identical twin version and
                                          atomically hot-swap to it (replies
                                          must not change — swap demo/check)
            [--listen ADDR]               serve over TCP instead of the
                                          in-process smoke client: binary
                                          QFN1 protocol + HTTP shim (/infer,
                                          /healthz, /metrics) on one port
            [--serve-secs S]              with --listen: serve S seconds then
                                          drain gracefully (0 = until killed)
            [--max-conns N]               with --listen: connection cap;
                                          over-cap connections get one Busy
                                          reply and are closed
  net-bench [--arch A] [--backend K] [--workers N] [--connections C]
            [--rate R] [--secs S] [serve options]
                                          self-hosted open-loop Poisson load
                                          (R req/s over C connections against
                                          a fresh wire server); prints
                                          p50/p99/p99.9-under-load
  requantize [--arch A] [--backend K] [--requests R] [--shadow-every S]
            [serve options]               closed-loop phase 1 captures live
                                          ranges via the shadow backend, then
                                          deployment constants are rebuilt
                                          from them, hot-swapped in, and
                                          phase 2 serves the requantized
                                          grid; per-phase accuracy + the
                                          fleet status table are printed
            [--pool ADDR,..]              pooled mode: skip local serving,
                                          pull shadow-captured ranges from
                                          the listed live replicas (QFN1
                                          stats-pull), lattice-merge them,
                                          and rebuild + promote the grid
                                          from the pooled ranges
  bench-serve [--arch A] [--backend K] [--workers N] [--max-batch B]
            [--max-wait-us U] [--queue-cap Q] [--concurrency C]
            [--requests R] [--threads T] [--stats-json P]
                                          C closed-loop clients x R requests
                                          each; reports images/sec + p50/95/99
  eval      [--arch A] [--backend K] [--images N] [--threads T]
                                          offline top-1 of A under backend K
                                          (same forward code the server runs)
  stats     [--stats-json P] [--prom]     render a flushed obs snapshot
                                          (default OBS_stats.json) as the
                                          human table, or as Prometheus text
                                          with --prom
            [--pull ADDR,..]              aggregator mode: instead of a
                                          file, pull live cluster stats from
                                          every listed replica over QFN1 and
                                          render the CRDT-merged view

--backend K selects the execution grid: fp (FP32 reference), fq-lw /
fq-dch (fake-quant simulation), lw / dch (integer deployment, f32-held
codes), lw-i8 (true i8 x i8 -> i32 integer engine over the lw grid).  The
legacy --mode lw|dch flag is still accepted on these commands and maps
to the integer backends.

Every command accepts --threads T: the width of the ONE process-wide
qft::par kernel pool that serve workers and the backend evals share
(default: available parallelism).  Results never depend on T — every
backend's parallel path is bit-identical to its serial twin.

Batching is pool-aware by default: workers shrink the micro-batch hold
time while the kernel pool is idle (latency) and grow it when the pool
is saturated (throughput).  --no-adaptive pins the hold at
--max-wait-us.  Replies are bit-identical either way.

Observability (qft::obs): serve / bench-serve / eval record per-model
stage histograms (queue-wait, batch-form, compute, reply; µs) and
sampled per-layer kernel timings (pack / im2col / gemm / recode).
--obs-sample N times every Nth forward pass (default 16; 1 = every
pass, 0 = layer timing off); --no-obs disables all recording.
--stats-json P flushes the JSON snapshot to P every ~2s (atomic
tmp+rename, so readers never see a torn file) and once at shutdown;
`repro stats` renders such a file, and a human-readable stage/layer
table is printed on graceful shutdown.

Weights for serving resolve from weights/A.MODE.qftw (qft export), else
weights/A.qftw (FP teacher + offline PTQ init), else he-init smoke weights.
Without artifacts/manifest.json a built-in `synthetic` arch is served.

Model fleet (qft::fleet): every served key is a versioned slot.  New
versions install while serving; promotion is one atomic route-word swap
(in-flight batches finish on the old version, which drains and retires);
rollback is instant.  --backend-b/--ab-bp split traffic between two
versions with per-arm obs labels (\"arch/backend@v2\"); --shadow-every
feeds the CalibBackend range capture that `repro requantize` turns into
freshly fitted deployment constants.

Cluster (qft::cluster): every `--listen` replica answers QFN1 stats-pull
frames with a CRDT delta of its counters and shadow-captured ranges.
`repro stats --pull A,B,..` merges any number of replicas without double
counting; `repro requantize --pool A,B,..` rebuilds the grid from their
pooled ranges — bit-identical to one process having seen all the traffic.
";

/// Whether a flag takes a value (`--key V`) or stands alone (`--flag`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arity {
    Value,
    Bool,
}

/// The commands a flag applies to.  A flag given to a command outside its
/// set is a hard error ([`check`]) — a typed option being silently ignored
/// defeats the strict-flag contract (e.g. `repro serve --images 100`
/// almost certainly meant `--requests`).
#[derive(Clone, Copy, Debug)]
pub enum Applies {
    All,
    AllExcept(&'static [&'static str]),
    Only(&'static [&'static str]),
}

impl Applies {
    pub fn accepts(&self, cmd: &str) -> bool {
        match self {
            Applies::All => true,
            Applies::AllExcept(x) => !x.contains(&cmd),
            Applies::Only(x) => x.contains(&cmd),
        }
    }
}

/// One row of the CLI surface: everything [`Args::parse`], [`check`], and
/// [`help`] need to know about a flag.
pub struct FlagSpec {
    pub name: &'static str,
    pub arity: Arity,
    /// Informative default shown by [`help`] (`None` when the default is
    /// per-command or the flag is optional with no default).
    pub default: Option<&'static str>,
    pub help: &'static str,
    pub applies: Applies,
}

/// Every command (validated before any runtime/artifact work happens).
pub const COMMANDS: &[&str] = &[
    "pretrain", "eval-fp", "qft", "table1", "table2", "fig3", "fig5", "fig6",
    "fig7", "fig8", "fig9", "fig12", "serve", "bench-serve", "eval", "stats",
    "requantize", "net-bench",
];

/// The PJRT-backed pipeline commands — serving-only flags given to these
/// get the historical "applies to the serving / backend-eval commands
/// only" wording instead of the per-command one.
pub const PIPELINE_COMMANDS: &[&str] = &[
    "pretrain", "eval-fp", "qft", "table1", "table2", "fig3", "fig5", "fig6",
    "fig7", "fig8", "fig9", "fig12",
];

/// Commands that read `--backend` / the obs knobs.
const BACKEND_CMDS: &[&str] = &["serve", "bench-serve", "net-bench", "eval", "requantize"];
/// Commands that flush / read `--stats-json` snapshots.
const FLUSH_CMDS: &[&str] = &["serve", "bench-serve", "stats", "requantize"];
/// Commands that attach the shadow range recorder.
const SHADOW_CMDS: &[&str] = &["serve", "requantize"];
/// Commands that open a TCP front-end (and so cap connections).
const WIRE_CMDS: &[&str] = &["serve", "net-bench"];
/// Commands that reject `--concurrency` (bench-serve is the only reader;
/// the pipeline commands tolerate it, a pre-spec quirk kept for
/// compatibility).
const NO_CONCURRENCY: &[&str] = &["serve", "requantize", "net-bench", "eval", "stats"];

const fn kv(
    name: &'static str,
    default: Option<&'static str>,
    help: &'static str,
    applies: Applies,
) -> FlagSpec {
    FlagSpec { name, arity: Arity::Value, default, help, applies }
}

const fn flag(name: &'static str, help: &'static str, applies: Applies) -> FlagSpec {
    FlagSpec { name, arity: Arity::Bool, default: None, help, applies }
}

use Applies::{All, AllExcept, Only};

/// The whole CLI surface, one row per flag.  [`Args::parse`], [`check`],
/// and [`help`] are all derived from this table — add a flag here and
/// every layer picks it up.
pub const SPEC: &[FlagSpec] = &[
    kv("arch", None, "model architecture key", AllExcept(&["stats"])),
    kv("archs", None, "comma-separated arch list", AllExcept(&["stats"])),
    kv("steps", Some("6000"), "pretrain steps", AllExcept(&["stats"])),
    kv("lr", None, "base learning rate", AllExcept(&["stats"])),
    kv("mode", Some("lw"), "legacy grid selector (lw|dch)", AllExcept(&["stats"])),
    kv("backend", None, "execution grid key", Only(BACKEND_CMDS)),
    kv("images", Some("512"), "val images to score", Only(&["eval"])),
    kv("ce-mix", Some("0"), "CE mixing weight", AllExcept(&["stats"])),
    kv("workers", Some("2"), "engine worker threads", AllExcept(&["eval", "stats"])),
    kv("max-batch", Some("8"), "micro-batch size cap", AllExcept(&["eval", "stats"])),
    kv("max-wait-us", Some("200"), "micro-batch hold (us)", AllExcept(&["eval", "stats"])),
    kv("queue-cap", Some("256"), "engine queue capacity", AllExcept(&["eval", "stats"])),
    kv("requests", None, "closed-loop request count", AllExcept(&["net-bench", "eval", "stats"])),
    kv("concurrency", Some("16"), "closed-loop clients", AllExcept(NO_CONCURRENCY)),
    kv("threads", None, "kernel pool width", All),
    kv("stats-json", None, "obs snapshot flush path", Only(FLUSH_CMDS)),
    kv("obs-sample", Some("16"), "layer-timing sample period", Only(BACKEND_CMDS)),
    kv("backend-b", None, "A/B arm-B backend", Only(&["serve"])),
    kv("ab-bp", Some("5000"), "A/B basis points to arm B", Only(&["serve"])),
    kv("shadow-every", None, "shadow-capture period", Only(SHADOW_CMDS)),
    kv("swap-after", Some("0"), "hot-swap twin after N replies", Only(&["serve"])),
    kv("listen", None, "serve over TCP on ADDR", Only(&["serve"])),
    kv("serve-secs", Some("0"), "with --listen: serve S secs", Only(&["serve"])),
    kv("max-conns", Some("256"), "TCP connection cap", Only(WIRE_CMDS)),
    kv("connections", Some("4"), "open-loop connections", Only(&["net-bench"])),
    kv("rate", Some("200"), "offered load (req/s)", Only(&["net-bench"])),
    kv("secs", Some("3"), "open-loop duration (s)", Only(&["net-bench"])),
    kv("pull", None, "replica ADDRs to pull cluster stats from", Only(&["stats"])),
    kv("pool", None, "replica ADDRs to pool shadow ranges from", Only(&["requantize"])),
    flag("cle", "CLE initialization", AllExcept(&["stats"])),
    flag("frozen-scales", "freeze quant scales", AllExcept(&["stats"])),
    flag("fast", "reduced-size experiment", AllExcept(&["stats"])),
    flag("no-adaptive", "pin the micro-batch hold", AllExcept(&["eval", "stats"])),
    flag("no-obs", "disable obs recording", Only(BACKEND_CMDS)),
    flag("prom", "Prometheus text output", Only(&["stats"])),
];

/// The [`SPEC`] row for `name`, if any.
pub fn spec(name: &str) -> Option<&'static FlagSpec> {
    SPEC.iter().find(|s| s.name == name)
}

/// Parsed flags: `--key value` pairs plus boolean `--flag`s.  Duplicates
/// and unknown options are hard errors (no silent last-wins).
pub struct Args {
    pub kv: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Strict [`SPEC`]-driven parse of everything after the command word.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut kv = HashMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?}\n{USAGE}");
            };
            match spec(name).map(|s| s.arity) {
                Some(Arity::Bool) => {
                    if flags.iter().any(|f| f == name) {
                        bail!("duplicate flag --{name}");
                    }
                    flags.push(name.to_string());
                    i += 1;
                }
                Some(Arity::Value) => {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("--{name} requires a value");
                    };
                    if kv.insert(name.to_string(), v.clone()).is_some() {
                        bail!("duplicate option --{name} (each option may be given once)");
                    }
                    i += 2;
                }
                None => bail!("unknown option --{name}\n{USAGE}"),
            }
        }
        Ok(Args { kv, flags })
    }

    pub fn get(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn req(&self, key: &str) -> Result<String> {
        self.kv
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("missing required --{key}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.kv.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.kv.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

/// Reject every given flag `cmd` reads nothing from, with the historical
/// wording: pipeline commands handed a serving-only flag get the
/// "applies to the serving / backend-eval commands only" message, the
/// serving commands get the per-command one.
pub fn check(cmd: &str, args: &Args) -> Result<()> {
    for s in SPEC {
        let given = match s.arity {
            Arity::Value => args.kv.contains_key(s.name),
            Arity::Bool => args.flag(s.name),
        };
        if !given || s.applies.accepts(cmd) {
            continue;
        }
        if PIPELINE_COMMANDS.contains(&cmd) {
            bail!("--{} applies to the serving / backend-eval commands only", s.name);
        }
        bail!("--{} is not used by `{cmd}` (see usage)", s.name);
    }
    Ok(())
}

/// [`USAGE`] plus a generated per-flag reference derived from [`SPEC`].
pub fn help() -> String {
    use std::fmt::Write as _;
    let mut o = String::from(USAGE);
    o.push_str("\nOPTIONS (derived from the qft::cli spec table):\n");
    for s in SPEC {
        let head = match s.arity {
            Arity::Value => format!("--{} V", s.name),
            Arity::Bool => format!("--{}", s.name),
        };
        let _ = write!(o, "  {head:<18} {}", s.help);
        if let Some(d) = s.default {
            let _ = write!(o, " [default {d}]");
        }
        let scope = match s.applies {
            Applies::All => "all commands".to_string(),
            Applies::AllExcept(x) => format!("all but {}", x.join(", ")),
            Applies::Only(x) => x.join(", "),
        };
        let _ = writeln!(o, " ({scope})");
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact `--key value` surface before the spec table existed.
    const LEGACY_KV: &[&str] = &[
        "arch", "archs", "steps", "lr", "mode", "backend", "images", "ce-mix",
        "workers", "max-batch", "max-wait-us", "queue-cap", "requests",
        "concurrency", "threads", "stats-json", "obs-sample", "backend-b",
        "ab-bp", "shadow-every", "swap-after", "listen", "serve-secs",
        "max-conns", "connections", "rate", "secs",
    ];
    /// The exact boolean-flag surface before the spec table existed.
    const LEGACY_BOOL: &[&str] = &["cle", "frozen-scales", "fast", "no-adaptive", "no-obs", "prom"];

    /// The hand-maintained per-command reject lists the spec table
    /// replaced: (command, rejected keys, rejected bool flags).
    const LEGACY_REJECTS: &[(&str, &[&str], &[&str])] = &[
        ("serve", &["images", "concurrency", "connections", "rate", "secs"], &["prom"]),
        (
            "requantize",
            &[
                "images", "concurrency", "backend-b", "ab-bp", "swap-after",
                "listen", "serve-secs", "max-conns", "connections", "rate",
                "secs",
            ],
            &["prom"],
        ),
        (
            "bench-serve",
            &[
                "images", "backend-b", "ab-bp", "shadow-every", "swap-after",
                "listen", "serve-secs", "max-conns", "connections", "rate",
                "secs",
            ],
            &["prom"],
        ),
        (
            "net-bench",
            &[
                "images", "concurrency", "requests", "listen", "serve-secs",
                "backend-b", "ab-bp", "shadow-every", "swap-after",
                "stats-json",
            ],
            &["prom"],
        ),
        (
            "eval",
            &[
                "workers", "max-batch", "max-wait-us", "queue-cap",
                "concurrency", "requests", "stats-json", "backend-b", "ab-bp",
                "shadow-every", "swap-after", "listen", "serve-secs",
                "max-conns", "connections", "rate", "secs",
            ],
            &["no-adaptive", "prom"],
        ),
        (
            "stats",
            &[
                "arch", "archs", "steps", "lr", "mode", "backend", "images",
                "ce-mix", "workers", "max-batch", "max-wait-us", "queue-cap",
                "requests", "concurrency", "obs-sample", "backend-b", "ab-bp",
                "shadow-every", "swap-after", "listen", "serve-secs",
                "max-conns", "connections", "rate", "secs",
            ],
            &["cle", "frozen-scales", "fast", "no-adaptive", "no-obs"],
        ),
    ];

    /// The flags the pipeline commands rejected with the "serving /
    /// backend-eval commands only" wording.
    const LEGACY_PIPELINE_KV: &[&str] = &[
        "backend", "images", "stats-json", "obs-sample", "backend-b", "ab-bp",
        "shadow-every", "swap-after", "listen", "serve-secs", "max-conns",
        "connections", "rate", "secs",
    ];
    const LEGACY_PIPELINE_BOOL: &[&str] = &["prom", "no-obs"];

    fn kv_args(key: &str) -> Args {
        let mut kv = HashMap::new();
        kv.insert(key.to_string(), "1".to_string());
        Args { kv, flags: Vec::new() }
    }

    fn flag_args(name: &str) -> Args {
        Args { kv: HashMap::new(), flags: vec![name.to_string()] }
    }

    fn owned(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn every_legacy_flag_survives_with_its_arity() {
        for k in LEGACY_KV {
            let s = spec(k).unwrap_or_else(|| panic!("--{k} dropped by the spec table"));
            assert_eq!(s.arity, Arity::Value, "--{k} changed arity");
        }
        for f in LEGACY_BOOL {
            let s = spec(f).unwrap_or_else(|| panic!("--{f} dropped by the spec table"));
            assert_eq!(s.arity, Arity::Bool, "--{f} changed arity");
        }
    }

    #[test]
    fn legacy_per_command_accept_and_reject_sets_are_preserved() {
        for &(cmd, bad_keys, bad_flags) in LEGACY_REJECTS {
            for k in LEGACY_KV {
                let want_err = bad_keys.contains(k);
                let got = check(cmd, &kv_args(k));
                assert_eq!(got.is_err(), want_err, "--{k} on `{cmd}`: {got:?}");
                if want_err {
                    let msg = format!("--{k} is not used by `{cmd}` (see usage)");
                    assert_eq!(got.unwrap_err().to_string(), msg);
                }
            }
            for f in LEGACY_BOOL {
                let want_err = bad_flags.contains(f);
                let got = check(cmd, &flag_args(f));
                assert_eq!(got.is_err(), want_err, "--{f} on `{cmd}`: {got:?}");
            }
        }
    }

    #[test]
    fn pipeline_commands_keep_the_serving_only_wording() {
        for cmd in PIPELINE_COMMANDS {
            for k in LEGACY_PIPELINE_KV {
                let got = check(cmd, &kv_args(k));
                let msg = format!("--{k} applies to the serving / backend-eval commands only");
                assert_eq!(got.unwrap_err().to_string(), msg, "--{k} on `{cmd}`");
            }
            for f in LEGACY_PIPELINE_BOOL {
                assert!(check(cmd, &flag_args(f)).is_err(), "--{f} on `{cmd}`");
            }
            // the pre-spec quirk: engine knobs pass through unread
            for ok in ["arch", "workers", "requests", "concurrency", "threads"] {
                check(cmd, &kv_args(ok)).unwrap();
            }
        }
    }

    #[test]
    fn parse_keeps_the_legacy_error_wording() {
        let dup_flag = Args::parse(&owned(&["--fast", "--fast"])).unwrap_err();
        assert_eq!(dup_flag.to_string(), "duplicate flag --fast");
        let dup_kv = Args::parse(&owned(&["--arch", "a", "--arch", "b"])).unwrap_err();
        assert_eq!(dup_kv.to_string(), "duplicate option --arch (each option may be given once)");
        let no_val = Args::parse(&owned(&["--arch"])).unwrap_err();
        assert_eq!(no_val.to_string(), "--arch requires a value");
        let unknown = Args::parse(&owned(&["--nope"])).unwrap_err();
        assert!(unknown.to_string().starts_with("unknown option --nope"));
        let stray = Args::parse(&owned(&["oops"])).unwrap_err();
        assert!(stray.to_string().starts_with("unexpected argument \"oops\""));
    }

    #[test]
    fn parse_round_trips_a_mixed_command_line() {
        let a = Args::parse(&owned(&["--arch", "synthetic", "--fast", "--requests", "9"]))
            .unwrap();
        assert_eq!(a.get("arch", "x"), "synthetic");
        assert_eq!(a.usize("requests", 0).unwrap(), 9);
        assert!(a.flag("fast"));
        assert!(!a.flag("cle"));
        assert_eq!(a.req("missing").unwrap_err().to_string(), "missing required --missing");
    }

    #[test]
    fn new_cluster_flags_are_scoped_to_their_commands() {
        check("stats", &kv_args("pull")).unwrap();
        check("requantize", &kv_args("pool")).unwrap();
        assert!(check("serve", &kv_args("pull")).is_err());
        assert!(check("stats", &kv_args("pool")).is_err());
        for s in SPEC {
            assert!(COMMANDS.iter().any(|c| s.applies.accepts(c)), "--{} applies nowhere", s.name);
        }
    }

    #[test]
    fn help_mentions_every_flag() {
        let h = help();
        for s in SPEC {
            assert!(h.contains(&format!("--{}", s.name)), "--{} missing from help", s.name);
        }
    }
}
