//! Bench F7 — regenerates Fig. 7 (base learning-rate sweep).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Fig. 7: effect of base LR");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let lrs = [1e-4f32, 3e-4, 1e-3, 3e-3, 1e-2];
    let rows = util::timed("fig7(regnet_tiny)", || {
        experiments::fig7(&rt, "regnet_tiny", &lrs, true).unwrap()
    });
    experiments::print_rows("Fig. 7", &rows);
    let best = rows
        .iter()
        .min_by(|a, b| a.degradation().partial_cmp(&b.degradation()).unwrap())
        .unwrap();
    println!("robust region around {}", best.config);
}
