//! Bench F9 — regenerates Fig. 9 (doubly-channelwise 4bW: frozen vs trained
//! L/R kernel scale co-vectors).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Fig. 9: dch — effect of training S_wL, S_wR jointly");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let names = ["resnet_tiny", "mobilenet_tiny"];
    let rows = util::timed("fig9(2 archs x 2 configs)", || {
        experiments::fig9(&rt, &names, true).unwrap()
    });
    experiments::print_rows("Fig. 9", &rows);
    for arch in names {
        let frozen = rows.iter().find(|r| r.arch == arch && r.config.starts_with("frozen")).unwrap();
        let trained = rows.iter().find(|r| r.arch == arch && r.config.starts_with("trained")).unwrap();
        println!(
            "{arch}: frozen {:+.2}% -> trained {:+.2}%",
            -frozen.degradation() * 100.0,
            -trained.degradation() * 100.0
        );
    }
}
