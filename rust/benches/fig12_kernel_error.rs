//! Bench F12 — regenerates Fig. 12 (per-layer kernel error under layerwise /
//! CLE / QFT / channelwise scale optimization).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Fig. 12: kernel error by scale-optimization procedure");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let rows = util::timed("fig12(regnet_tiny)", || {
        experiments::fig12(&rt, "regnet_tiny", true).unwrap()
    });
    println!(
        "{:<10} {:>10} {:>8} {:>8} {:>12}",
        "layer", "layerwise", "CLE", "QFT", "channelwise"
    );
    for r in &rows {
        println!(
            "{:<10} {:>10.4} {:>8.4} {:>8.4} {:>12.4}",
            r.layer, r.e_layerwise, r.e_cle, r.e_qft, r.e_channelwise
        );
    }
    // paper shape: CLE and QFT partially close the lw->chw gap
    let sum = |f: &dyn Fn(&experiments::KernelErrorRow) -> f32| {
        rows.iter().map(|r| f(r) * f(r)).sum::<f32>().sqrt()
    };
    println!(
        "total: lw {:.4} | CLE {:.4} | QFT {:.4} | chw {:.4}",
        sum(&|r| r.e_layerwise),
        sum(&|r| r.e_cle),
        sum(&|r| r.e_qft),
        sum(&|r| r.e_channelwise)
    );
}
