//! Bench T2 — regenerates Table 2 (heuristics-only ablation: accuracy
//! without QFT) and times each heuristic stage.

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Table 2: accuracy without QFT (heuristics only)");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let names = ["convnet_tiny", "resnet_tiny", "mobilenet_tiny", "regnet_tiny"];
    let rows = util::timed("table2(4 archs x 5 configs)", || {
        experiments::table2(&rt, &names).unwrap()
    });
    experiments::print_rows("Table 2", &rows);

    // paper shape check: the x10-30 gap closed by weight training is visible
    // as large degradations here vs sub-1% after QFT (bench table1)
    let worst = rows
        .iter()
        .max_by(|a, b| a.degradation().partial_cmp(&b.degradation()).unwrap())
        .unwrap();
    println!(
        "\nworst heuristics-only degradation: {} / {} at {:+.2}%",
        worst.arch,
        worst.config,
        -worst.degradation() * 100.0
    );
}
