//! Bench G — the `qft::kernel` GEMM micro-kernels: scalar reference loop
//! (`gemm_ref`, the historical `matmul_rows` plus its zero-fill pass) vs
//! the panel-packed register-blocked write-mode kernel (`gemm`) vs the
//! runtime-dispatched integer kernels (`gemm_i8` over byte panels and
//! `gemm_w4` over nibble-packed panels — the `lw-i8` backend's engines),
//! GFLOP/s (GOP/s for the integer kernels) over ResNet-shaped im2col
//! GEMMs, a large-K set (`k >= 2048`, exercising the KC reduction cache
//! block), and ragged edge shapes.  Emits `BENCH_gemm.json` at the repo
//! root with per-shape f32/i8/W4 numbers, the dispatched kernel path, and
//! per-set geomeans; the `resnet` and `largek` geomeans feed the CI perf
//! gate (`make bench-gate`, `BENCH_baseline.json`).
//!
//! Every shape is parity-checked before timing (f32 packed vs scalar
//! bit-for-bit; i8 vs the f32 kernel on the same integer codes, where f32
//! accumulation is exact; W4 bit-identical to i8), so this bench doubles
//! as a coarse guard against kernel rot.  `QFT_BENCH_SMOKE=1` drops to a
//! single iteration (CI harness smoke; numbers meaningless).

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::time::Instant;

use qft::kernel::{gemm, gemm_i8, gemm_ref, gemm_w4, kernel_dispatch, PackedW, PackedW4, PackedWi8};
use qft::util::json::Value;

struct Shape {
    set: &'static str,
    name: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

const SHAPES: &[Shape] = &[
    // ResNet-shaped: im2col GEMMs of 3x3 / 1x1 stages plus the fc head
    Shape { set: "resnet", name: "rn_stage1_3x3", m: 1024, k: 576, n: 64 },
    Shape { set: "resnet", name: "rn_stage2_3x3", m: 256, k: 1152, n: 128 },
    Shape { set: "resnet", name: "rn_stage3_3x3", m: 64, k: 2304, n: 256 },
    Shape { set: "resnet", name: "rn_proj_1x1", m: 1024, k: 64, n: 128 },
    Shape { set: "resnet", name: "rn_fc_head", m: 32, k: 512, n: 1000 },
    // large-K: fc heads and deep 1x1 convs whose reduction outgrows the KC
    // cache block (KC = 256) — the set the K-blocked kernel targets; the
    // perf gate pins this set's geomean
    Shape { set: "largek", name: "lk_fc_mlp", m: 64, k: 4096, n: 256 },
    Shape { set: "largek", name: "lk_1x1_deep", m: 196, k: 2048, n: 256 },
    Shape { set: "largek", name: "lk_1x1_wide", m: 49, k: 2304, n: 512 },
    // edge-shaped: ragged lanes / tiles, single rows, skinny reductions,
    // and the depthwise-conv per-group GEMM (one output column)
    Shape { set: "edge", name: "edge_ragged", m: 33, k: 129, n: 17 },
    Shape { set: "edge", name: "edge_single_row", m: 1, k: 2048, n: 75 },
    Shape { set: "edge", name: "edge_thin_k", m: 512, k: 9, n: 40 },
    Shape { set: "edge", name: "edge_tiny", m: 7, k: 27, n: 5 },
    Shape { set: "edge", name: "edge_depthwise_g", m: 1024, k: 9, n: 1 },
    // folded from the retired benches/kernels.rs micro-bench set: the
    // square matmul and the small-channel conv im2col it timed
    Shape { set: "edge", name: "edge_square_256", m: 256, k: 256, n: 256 },
    Shape { set: "edge", name: "edge_conv_16ch", m: 2048, k: 144, n: 16 },
];

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = qft::data::Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

/// Random integer codes on the lw weight grid (`[-7, 7]`).
fn rand_codes(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = qft::data::Rng::new(seed);
    (0..n).map(|_| (rng.normal() * 4.0).round().clamp(-7.0, 7.0) as i8).collect()
}

/// Wall time per op over `iters` timed iterations (after 2 warm-up passes).
fn time_per_op(iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..2 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    util::section("qft::kernel GEMM micro-kernels (scalar vs panel-packed f32 vs i8 vs W4)");
    println!("kernel dispatch: {}", kernel_dispatch());
    let smoke = util::smoke();
    let mut rows = Vec::new();
    // per-set speedup samples for the geomean summary (resnet + largek
    // feed the perf gate)
    let mut speedups: HashMap<&'static str, Vec<f64>> = HashMap::new();
    let mut i8_speedups: HashMap<&'static str, Vec<f64>> = HashMap::new();
    let mut w4_speedups: HashMap<&'static str, Vec<f64>> = HashMap::new();

    for (si, s) in SHAPES.iter().enumerate() {
        let flops = 2.0 * (s.m * s.k * s.n) as f64;
        let iters = if smoke {
            1
        } else {
            // ~0.2 s of work per measurement, at least 4 iterations
            ((2e8 / flops.max(1.0)) as usize).clamp(4, 4000)
        };
        let x = rand_vec(s.m * s.k, 100 + si as u64);
        let w = rand_vec(s.k * s.n, 200 + si as u64);
        let pw = PackedW::pack(&w, s.k, s.n);

        // parity first: the packed kernel must be bit-identical to the
        // scalar reference on every shape it is about to be timed on
        let mut want = vec![0.0f32; s.m * s.n];
        gemm_ref(&x, s.k, &w, s.n, &mut want);
        let mut got = vec![f32::NAN; s.m * s.n];
        gemm(&x, s.m, &pw, &mut got);
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{}: packed kernel diverged from scalar reference",
            s.name
        );

        let mut out = vec![0.0f32; s.m * s.n];
        // scalar baseline pays the historical zero-fill + accumulate
        let scalar = time_per_op(iters, || {
            out.fill(0.0);
            gemm_ref(&x, s.k, &w, s.n, &mut out);
        });
        // hot path: weights prepacked at DeployedModel::prepare time
        let packed = time_per_op(iters, || {
            gemm(&x, s.m, &pw, &mut out);
        });
        // cold path: per-call repack (training forwards) included
        let mut pw_cold = PackedW::default();
        let packed_cold = time_per_op(iters, || {
            pw_cold.pack_cols(&w, s.k, s.n, 0, s.n);
            gemm(&x, s.m, &pw_cold, &mut out);
        });

        // the i8 twin on the same shape: lw weight codes as i8 panels,
        // activations as offset i8 codes, i32 accumulation.  Parity first
        // against the f32 kernel over the same integer values (both exact
        // at these magnitudes).
        let xi = rand_codes(s.m * s.k, 300 + si as u64);
        let wi = rand_codes(s.k * s.n, 400 + si as u64);
        let pwi = PackedWi8::pack(&wi, s.k, s.n);
        let mut got_i = vec![0i32; s.m * s.n];
        gemm_i8(&xi, s.m, &pwi, &mut got_i);
        {
            let xf: Vec<f32> = xi.iter().map(|&v| v as f32).collect();
            let wf: Vec<f32> = wi.iter().map(|&v| v as f32).collect();
            let pwf = PackedW::pack(&wf, s.k, s.n);
            let mut want_f = vec![0.0f32; s.m * s.n];
            gemm(&xf, s.m, &pwf, &mut want_f);
            assert!(
                got_i.iter().zip(&want_f).all(|(&a, &b)| a as f32 == b),
                "{}: i8 kernel diverged from f32 kernel on integer codes",
                s.name
            );
        }
        let i8_time = time_per_op(iters, || {
            gemm_i8(&xi, s.m, &pwi, &mut got_i);
        });

        // the nibble-packed twin: same lw codes (always in [-7, 7], so
        // always W4-packable), two codes per byte, bit-identical to the
        // i8 panel kernel by contract
        let pw4 = PackedW4::pack(&wi, s.k, s.n);
        let mut got_w4 = vec![0i32; s.m * s.n];
        gemm_w4(&xi, s.m, &pw4, &mut got_w4);
        assert_eq!(got_w4, got_i, "{}: W4 kernel diverged from i8 kernel", s.name);
        let w4_time = time_per_op(iters, || {
            gemm_w4(&xi, s.m, &pw4, &mut got_w4);
        });

        let speedup = if packed > 0.0 { scalar / packed } else { 0.0 };
        let i8_speedup = if i8_time > 0.0 { packed / i8_time } else { 0.0 };
        let w4_speedup = if w4_time > 0.0 { i8_time / w4_time } else { 0.0 };
        speedups.entry(s.set).or_default().push(speedup.max(1e-12));
        i8_speedups.entry(s.set).or_default().push(i8_speedup.max(1e-12));
        w4_speedups.entry(s.set).or_default().push(w4_speedup.max(1e-12));
        println!(
            "[{:<16}] {:>5}x{:<5}x{:<5} scalar {:>8.3} ms ({:>6.2} GF/s) | packed {:>8.3} ms \
             ({:>6.2} GF/s) | +pack {:>8.3} ms | i8 {:>8.3} ms ({:>6.2} GOP/s) | w4 {:>8.3} ms \
             ({:>6.2} GOP/s) | speedup {:.2}x | i8-vs-f32 {:.2}x | w4-vs-i8 {:.2}x",
            s.name,
            s.m,
            s.k,
            s.n,
            scalar * 1e3,
            flops / scalar / 1e9,
            packed * 1e3,
            flops / packed / 1e9,
            packed_cold * 1e3,
            i8_time * 1e3,
            flops / i8_time / 1e9,
            w4_time * 1e3,
            flops / w4_time / 1e9,
            speedup,
            i8_speedup,
            w4_speedup
        );

        let mut row = HashMap::new();
        row.insert("set".to_string(), Value::Str(s.set.to_string()));
        row.insert("shape".to_string(), Value::Str(s.name.to_string()));
        row.insert("m".to_string(), Value::Num(s.m as f64));
        row.insert("k".to_string(), Value::Num(s.k as f64));
        row.insert("n".to_string(), Value::Num(s.n as f64));
        row.insert("scalar_ms".to_string(), Value::Num(scalar * 1e3));
        row.insert("packed_ms".to_string(), Value::Num(packed * 1e3));
        row.insert("packed_cold_ms".to_string(), Value::Num(packed_cold * 1e3));
        row.insert("i8_ms".to_string(), Value::Num(i8_time * 1e3));
        row.insert("w4_ms".to_string(), Value::Num(w4_time * 1e3));
        row.insert("gflops_scalar".to_string(), Value::Num(flops / scalar / 1e9));
        row.insert("gflops_packed".to_string(), Value::Num(flops / packed / 1e9));
        row.insert("gops_i8".to_string(), Value::Num(flops / i8_time / 1e9));
        row.insert("gops_w4".to_string(), Value::Num(flops / w4_time / 1e9));
        row.insert("speedup_vs_scalar".to_string(), Value::Num(speedup));
        row.insert("i8_speedup_vs_f32".to_string(), Value::Num(i8_speedup));
        row.insert("w4_speedup_vs_i8".to_string(), Value::Num(w4_speedup));
        rows.push(Value::Obj(row));
    }

    let geomean = |vals: &[f64]| {
        (vals.iter().map(|v| v.ln()).sum::<f64>() / vals.len().max(1) as f64).exp()
    };
    let rn = geomean(speedups.get("resnet").map_or(&[][..], |v| v.as_slice()));
    let rn_i8 = geomean(i8_speedups.get("resnet").map_or(&[][..], |v| v.as_slice()));
    let rn_w4 = geomean(w4_speedups.get("resnet").map_or(&[][..], |v| v.as_slice()));
    let lk = geomean(speedups.get("largek").map_or(&[][..], |v| v.as_slice()));
    let lk_i8 = geomean(i8_speedups.get("largek").map_or(&[][..], |v| v.as_slice()));
    let lk_w4 = geomean(w4_speedups.get("largek").map_or(&[][..], |v| v.as_slice()));
    println!("resnet-set geomean speedup: {rn:.2}x (target >= 3x single-thread)");
    println!("resnet-set geomean i8-vs-f32: {rn_i8:.2}x");
    println!("resnet-set geomean w4-vs-i8: {rn_w4:.2}x");
    println!("largek-set geomean speedup: {lk:.2}x (KC-blocked, target >= 1.2x)");
    println!("largek-set geomean i8-vs-f32: {lk_i8:.2}x");
    println!("largek-set geomean w4-vs-i8: {lk_w4:.2}x (half the weight bandwidth)");
    let mut summary = HashMap::new();
    summary.insert("set".to_string(), Value::Str("summary".to_string()));
    summary.insert("kernel_dispatch".to_string(), Value::Str(kernel_dispatch().to_string()));
    summary.insert("resnet_geomean_speedup".to_string(), Value::Num(rn));
    summary.insert("resnet_geomean_i8_vs_f32".to_string(), Value::Num(rn_i8));
    summary.insert("resnet_geomean_w4_vs_i8".to_string(), Value::Num(rn_w4));
    summary.insert("largek_geomean_speedup".to_string(), Value::Num(lk));
    summary.insert("largek_geomean_i8_vs_f32".to_string(), Value::Num(lk_i8));
    summary.insert("largek_geomean_w4_vs_i8".to_string(), Value::Num(lk_w4));
    summary.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
    rows.push(Value::Obj(summary));

    let out_path = util::repo_root_path("BENCH_gemm.json");
    std::fs::write(&out_path, Value::Arr(rows).to_string_compact())
        .expect("write BENCH_gemm.json");
    println!("wrote {}", out_path.display());
}
