//! Bench-regression gate — the comparator behind `make bench-gate` and the
//! CI `bench-gate` job.
//!
//! Reads the freshly emitted `BENCH_gemm.json` + `BENCH_serve.json`,
//! extracts the gated metrics (kernel speedup geomeans over the `resnet`
//! and `largek` shape sets, the i8-vs-f32 and W4-vs-i8 geomeans, and the
//! `lw-i8` serving p50s), compares each against the committed
//! `BENCH_baseline.json`, and prints a markdown delta table (also appended
//! to `$GITHUB_STEP_SUMMARY` when CI sets it).  `BENCH_net.json` (from
//! `make bench-net`) is consumed *optionally*: when it is absent or was
//! emitted under smoke, the wire-latency metric is reported as skipped —
//! never failed, never silently passed.  A metric that regresses by
//! more than its tolerance fails the run with a non-zero exit.  Tolerance
//! precedence, per metric: `QFT_BENCH_GATE_TOL` env override > the
//! baseline entry's own `tol` field (how strict floors like the i8/W4
//! ratio gates pin 0%) > the baseline's global `tolerance` > 15%.
//!
//! The integer-ratio floors (`needs_simd` metrics) only hold where a SIMD
//! path dispatched; when the gemm bench reports `kernel_dispatch ==
//! "scalar"` they are reported as skipped instead of failed, so the gate
//! stays honest on runners without AVX2/NEON.
//!
//! `QFT_BENCH_WRITE_BASELINE=1` re-baselines instead: the current run's
//! values are written to `BENCH_baseline.json` for the operator to review
//! and commit (`make bench-baseline`), preserving any per-metric `tol`
//! pins and printing a delta table against the previous baseline.
//! Smoke-mode numbers (`QFT_BENCH_SMOKE=1`) are refused — they are not
//! comparable.

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::fmt::Write as _;
use std::io::Write as _;

use anyhow::{anyhow, bail, Context};
use qft::util::json::Value;

/// Default regression tolerance when the baseline does not pin one.
const DEFAULT_TOL: f64 = 0.15;

/// One gated metric: a stable name, the direction that counts as better,
/// whether it only holds under a dispatched SIMD kernel path, and where in
/// the bench JSONs its current value lives (see [`current_value`]).
struct Metric {
    name: &'static str,
    higher_is_better: bool,
    needs_simd: bool,
    desc: &'static str,
}

const METRICS: &[Metric] = &[
    Metric {
        name: "gemm.resnet_geomean_speedup",
        higher_is_better: true,
        needs_simd: false,
        desc: "packed-vs-scalar GFLOP/s geomean, resnet shape set",
    },
    Metric {
        name: "gemm.largek_geomean_speedup",
        higher_is_better: true,
        needs_simd: false,
        desc: "packed-vs-scalar GFLOP/s geomean, large-K (k >= 2048, KC-blocked) set",
    },
    Metric {
        name: "gemm.resnet_geomean_i8_vs_f32",
        higher_is_better: true,
        needs_simd: true,
        desc: "i8-vs-f32 kernel geomean, resnet shape set (SIMD dot-product path)",
    },
    Metric {
        name: "gemm.largek_geomean_w4_vs_i8",
        higher_is_better: true,
        needs_simd: true,
        desc: "W4-vs-i8 kernel geomean, large-K set (nibble-packed weight bandwidth win)",
    },
    Metric {
        name: "serve.single_image_lw_i8_p50_us",
        higher_is_better: false,
        needs_simd: false,
        desc: "lw-i8 batch-1 forward p50 at 4 pool threads (intra-op path)",
    },
    Metric {
        name: "serve.closed_loop_lw_i8_w4_p50_us",
        higher_is_better: false,
        needs_simd: false,
        desc: "lw-i8 closed-loop serving p50 at 4 workers",
    },
    Metric {
        name: "net.open_loop_lw_i8_p99_us",
        higher_is_better: false,
        needs_simd: false,
        desc: "lw-i8 open-loop wire p99 at 4 conns / 200 rps offered (2 workers)",
    },
    Metric {
        name: "net.open_loop_lw_i8_p999_us",
        higher_is_better: false,
        needs_simd: false,
        desc: "lw-i8 open-loop wire p99.9 at 4 conns / 200 rps offered (2 workers)",
    },
];

/// Value of `key` from the gemm bench's `set == "summary"` row.
fn find_summary(rows: &[Value], key: &str) -> anyhow::Result<f64> {
    for r in rows {
        let is_summary = r.opt("set").and_then(|v| v.str().ok()) == Some("summary");
        if is_summary {
            if let Some(v) = r.opt(key) {
                return v.num();
            }
        }
    }
    bail!("BENCH_gemm.json has no summary key {key:?} — rerun `make bench-gemm`")
}

/// String value of `key` from the gemm summary row; empty when absent
/// (bench emissions that predate the field).
fn summary_str(rows: &[Value], key: &str) -> String {
    rows.iter()
        .filter(|r| r.opt("set").and_then(|v| v.str().ok()) == Some("summary"))
        .find_map(|r| r.opt(key).and_then(|v| v.str().ok()).map(str::to_string))
        .unwrap_or_default()
}

/// `p50_us` of the serve-bench row matching `(set, backend, dim_key=dim)`.
fn find_serve_p50(
    rows: &[Value],
    set: &str,
    backend: &str,
    dim_key: &str,
    dim: f64,
) -> anyhow::Result<f64> {
    for r in rows {
        let hit = r.opt("set").and_then(|v| v.str().ok()) == Some(set)
            && r.opt("backend").and_then(|v| v.str().ok()) == Some(backend)
            && r.opt(dim_key).and_then(|v| v.num().ok()) == Some(dim);
        if hit {
            return r.get("p50_us")?.num();
        }
    }
    bail!(
        "BENCH_serve.json has no {set}/{backend} row at {dim_key}={dim} — rerun \
         `make bench-serve`"
    )
}

/// Latency quantile `field` (`"p99_us"`, `"p999_us"`, ...) of the open-loop
/// net-bench row at `(backend, connections, rate_rps)`.  Only called once
/// `BENCH_net.json` exists and is non-smoke — a present file missing the
/// pinned row is an error, not a skip.
fn find_net_quantile(
    rows: &[Value],
    backend: &str,
    connections: f64,
    rate_rps: f64,
    field: &str,
) -> anyhow::Result<f64> {
    for r in rows {
        let hit = r.opt("set").and_then(|v| v.str().ok()) == Some("open_loop")
            && r.opt("backend").and_then(|v| v.str().ok()) == Some(backend)
            && r.opt("connections").and_then(|v| v.num().ok()) == Some(connections)
            && r.opt("rate_rps").and_then(|v| v.num().ok()) == Some(rate_rps);
        if hit {
            return r.get(field)?.num();
        }
    }
    bail!(
        "BENCH_net.json has no open_loop/{backend} row at connections={connections} \
         rate_rps={rate_rps} — rerun `make bench-net`"
    )
}

/// Extract a gated metric's current value from the fresh bench JSONs.
/// `Ok(None)` means the metric's source bench was legitimately not run
/// (optional `BENCH_net.json` absent/smoke) — reported as skipped.
fn current_value(
    name: &str,
    gemm: &[Value],
    serve: &[Value],
    net: Option<&[Value]>,
) -> anyhow::Result<Option<f64>> {
    match name {
        "gemm.resnet_geomean_speedup" => find_summary(gemm, "resnet_geomean_speedup").map(Some),
        "gemm.largek_geomean_speedup" => find_summary(gemm, "largek_geomean_speedup").map(Some),
        "gemm.resnet_geomean_i8_vs_f32" => {
            find_summary(gemm, "resnet_geomean_i8_vs_f32").map(Some)
        }
        "gemm.largek_geomean_w4_vs_i8" => find_summary(gemm, "largek_geomean_w4_vs_i8").map(Some),
        "serve.single_image_lw_i8_p50_us" => {
            find_serve_p50(serve, "single_image", "lw-i8", "threads", 4.0).map(Some)
        }
        "serve.closed_loop_lw_i8_w4_p50_us" => {
            find_serve_p50(serve, "closed_loop", "lw-i8", "workers", 4.0).map(Some)
        }
        "net.open_loop_lw_i8_p99_us" => match net {
            Some(rows) => find_net_quantile(rows, "lw-i8", 4.0, 200.0, "p99_us").map(Some),
            None => Ok(None),
        },
        "net.open_loop_lw_i8_p999_us" => match net {
            Some(rows) => find_net_quantile(rows, "lw-i8", 4.0, 200.0, "p999_us").map(Some),
            None => Ok(None),
        },
        other => bail!("unknown gate metric {other:?}"),
    }
}

fn load_json(name: &str) -> anyhow::Result<Value> {
    let path = util::repo_root_path(name);
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!("read {} (run `make bench-gemm bench-serve` first)", path.display())
    })?;
    Value::parse(&text).with_context(|| format!("parse {}", path.display()))
}

fn main() -> anyhow::Result<()> {
    util::section("bench-regression gate");
    let gemm = load_json("BENCH_gemm.json")?;
    let serve = load_json("BENCH_serve.json")?;
    let gemm_rows = gemm.arr()?;
    let serve_rows = serve.arr()?;
    if find_summary(gemm_rows, "smoke")? != 0.0 {
        bail!("BENCH_gemm.json was emitted under QFT_BENCH_SMOKE — smoke numbers are not \
               comparable; rerun the real benches");
    }
    let serve_smoke = serve_rows
        .iter()
        .any(|r| r.opt("smoke").and_then(|v| v.num().ok()).unwrap_or(0.0) != 0.0);
    if serve_smoke {
        bail!("BENCH_serve.json was emitted under QFT_BENCH_SMOKE — smoke numbers are not \
               comparable; rerun the real benches");
    }
    // BENCH_net.json is optional: absent or smoke-tainted means the
    // wire-latency metric is SKIPPED (visibly), never failed or faked
    let net: Option<Value> = match std::fs::read_to_string(util::repo_root_path("BENCH_net.json"))
    {
        Err(_) => {
            println!("no BENCH_net.json — wire-latency metric skipped (run `make bench-net`)");
            None
        }
        Ok(text) => match Value::parse(&text) {
            Ok(v) => Some(v),
            Err(e) => {
                println!("BENCH_net.json unreadable ({e}) — wire-latency metric skipped");
                None
            }
        },
    };
    let net_rows: Option<&[Value]> = match net.as_ref() {
        None => None,
        Some(v) => {
            let rows = v.arr()?;
            let net_smoke = rows
                .iter()
                .any(|r| r.opt("smoke").and_then(|v| v.num().ok()).unwrap_or(0.0) != 0.0);
            if net_smoke {
                println!(
                    "BENCH_net.json was emitted under QFT_BENCH_SMOKE — wire-latency metric \
                     skipped, not faked"
                );
                None
            } else {
                Some(rows)
            }
        }
    };

    let dispatch = summary_str(gemm_rows, "kernel_dispatch");
    // an empty field means a stale BENCH_gemm.json from before the bench
    // emitted the path — treat it like scalar (skip, never fake-pass)
    let scalar_only = dispatch.is_empty() || dispatch == "scalar";
    println!(
        "kernel dispatch: {}",
        if dispatch.is_empty() { "? (stale BENCH_gemm.json)" } else { &dispatch }
    );

    let mut current: Vec<(&Metric, Option<f64>)> = Vec::with_capacity(METRICS.len());
    for m in METRICS {
        current.push((m, current_value(m.name, gemm_rows, serve_rows, net_rows)?));
    }

    let base_path = util::repo_root_path("BENCH_baseline.json");
    if std::env::var_os("QFT_BENCH_WRITE_BASELINE").is_some_and(|v| v != "0" && !v.is_empty()) {
        // preserve operator-committed knobs across re-baselines — the
        // global tolerance, the comment, and any per-metric `tol` pins;
        // only the metric values are refreshed
        let prev = std::fs::read_to_string(&base_path)
            .ok()
            .and_then(|t| Value::parse(&t).ok());
        let tol = prev
            .as_ref()
            .and_then(|p| p.opt("tolerance"))
            .and_then(|v| v.num().ok())
            .unwrap_or(DEFAULT_TOL);
        let comment = prev
            .as_ref()
            .and_then(|p| p.opt("comment"))
            .and_then(|v| v.str().ok().map(str::to_string));
        let prev_metric = |name: &str| -> Option<&Value> {
            prev.as_ref().and_then(|p| p.opt("metrics")).and_then(|ms| ms.opt(name))
        };
        if scalar_only {
            eprintln!(
                "warning: re-baselining under scalar dispatch — the i8/W4 ratio floors will \
                 reflect scalar kernels; prefer a SIMD-capable host"
            );
        }
        let mut table =
            String::from("| metric | previous | new | delta |\n|---|---:|---:|---:|\n");
        let mut metrics = HashMap::new();
        for (m, v) in &current {
            // a skipped optional bench keeps its previous baseline entry
            // verbatim instead of being overwritten with nothing
            let Some(v) = v else {
                if let Some(pm) = prev_metric(m.name) {
                    metrics.insert(m.name.to_string(), pm.clone());
                    let _ = writeln!(table, "| `{}` | (kept) | (bench not run) | - |", m.name);
                } else {
                    let _ = writeln!(table, "| `{}` | - | (bench not run) | - |", m.name);
                }
                continue;
            };
            let mut o = HashMap::new();
            o.insert("value".to_string(), Value::Num(*v));
            o.insert("higher_is_better".to_string(), Value::Bool(m.higher_is_better));
            o.insert("desc".to_string(), Value::Str(m.desc.to_string()));
            let pinned_tol =
                prev_metric(m.name).and_then(|pm| pm.opt("tol")).and_then(|t| t.num().ok());
            if let Some(t) = pinned_tol {
                o.insert("tol".to_string(), Value::Num(t));
            }
            let pval = prev_metric(m.name)
                .and_then(|pm| pm.opt("value"))
                .and_then(|t| t.num().ok());
            match pval {
                Some(p) if p != 0.0 => {
                    let _ = writeln!(
                        table,
                        "| `{}` | {:.3} | {:.3} | {:+.1}% |",
                        m.name,
                        p,
                        *v,
                        (*v / p - 1.0) * 100.0
                    );
                }
                _ => {
                    let _ = writeln!(table, "| `{}` | (new) | {:.3} | - |", m.name, *v);
                }
            }
            metrics.insert(m.name.to_string(), Value::Obj(o));
        }
        let mut top = HashMap::new();
        top.insert("tolerance".to_string(), Value::Num(tol));
        if let Some(c) = comment {
            top.insert("comment".to_string(), Value::Str(c));
        }
        top.insert("metrics".to_string(), Value::Obj(metrics));
        std::fs::write(&base_path, Value::Obj(top).to_string_compact())?;
        println!("delta vs previous baseline:\n{table}");
        println!("wrote fresh baseline {} — review and commit it", base_path.display());
        return Ok(());
    }

    let baseline = Value::parse(&std::fs::read_to_string(&base_path).map_err(|e| {
        anyhow!(
            "no committed BENCH_baseline.json ({e}); generate one with `make bench-baseline`"
        )
    })?)?;
    let env_tol: Option<f64> = match std::env::var("QFT_BENCH_GATE_TOL") {
        Ok(s) => Some(s.parse().context("QFT_BENCH_GATE_TOL must be a float like 0.15")?),
        Err(_) => None,
    };
    let global_tol: f64 = match baseline.opt("tolerance") {
        Some(v) => v.num()?,
        None => DEFAULT_TOL,
    };

    let mut table = String::from(
        "| metric | baseline | current | delta | tol | status |\n|---|---:|---:|---:|---:|---|\n",
    );
    let mut regressions = Vec::new();
    let mut skips = 0usize;
    for (m, cur) in &current {
        let Some(cur) = cur else {
            let _ = writeln!(table, "| `{}` | - | - | - | - | skipped (bench not run) |", m.name);
            skips += 1;
            continue;
        };
        let bm = baseline.get("metrics")?.get(m.name).map_err(|_| {
            anyhow!("baseline lacks metric {:?} — rerun `make bench-baseline`", m.name)
        })?;
        let base = bm.get("value")?.num()?;
        // tolerance precedence: env override > per-metric pin > global
        let tol = match env_tol {
            Some(t) => t,
            None => bm.opt("tol").and_then(|v| v.num().ok()).unwrap_or(global_tol),
        };
        // direction comes from the gate's METRICS table; a baseline edited
        // to disagree is config drift we surface instead of silently
        // ignoring the field
        if let Some(hib) = bm.opt("higher_is_better") {
            if hib.boolean()? != m.higher_is_better {
                bail!(
                    "BENCH_baseline.json says higher_is_better={} for {:?} but the gate's \
                     metric table says {} — fix the baseline (or METRICS in bench_gate.rs)",
                    hib.boolean()?,
                    m.name,
                    m.higher_is_better
                );
            }
        }
        // the integer-ratio floors only hold where a SIMD path dispatched;
        // on a scalar-only runner they are skipped, never failed or passed
        let skipped = m.needs_simd && scalar_only;
        let delta = if base != 0.0 { cur / base - 1.0 } else { 0.0 };
        let regressed = !skipped
            && if m.higher_is_better {
                *cur < base * (1.0 - tol)
            } else {
                *cur > base * (1.0 + tol)
            };
        let improved = !skipped
            && ((m.higher_is_better && delta > tol) || (!m.higher_is_better && delta < -tol));
        let status = if skipped {
            "skipped (scalar dispatch)"
        } else if regressed {
            "**REGRESSION**"
        } else if improved {
            "improved"
        } else {
            "ok"
        };
        let _ = writeln!(
            table,
            "| `{}` | {:.3} | {:.3} | {:+.1}% | {:.0}% | {} |",
            m.name,
            base,
            cur,
            delta * 100.0,
            tol * 100.0,
            status
        );
        if skipped {
            skips += 1;
        }
        if regressed {
            regressions.push(format!(
                "{}: baseline {:.3} -> current {:.3} ({:+.1}%, tol {:.0}%)",
                m.name,
                base,
                cur,
                delta * 100.0,
                tol * 100.0
            ));
        }
    }
    println!("{table}");
    if let Ok(summary_path) = std::env::var("GITHUB_STEP_SUMMARY") {
        if let Ok(mut f) =
            std::fs::OpenOptions::new().create(true).append(true).open(summary_path)
        {
            let disp = if dispatch.is_empty() { "?" } else { &dispatch };
            let _ = writeln!(f, "## bench-gate (dispatch {disp})\n\n{table}");
        }
    }
    if !regressions.is_empty() {
        let nreg = regressions.len();
        eprintln!("bench-gate FAILED: {nreg} metric(s) regressed beyond tolerance:");
        for r in &regressions {
            eprintln!("  {r}");
        }
        eprintln!("intentional? re-baseline with `make bench-baseline` and commit the result");
        std::process::exit(1);
    }
    println!(
        "bench-gate OK: {} metrics within tolerance of the committed baseline{}",
        current.len() - skips,
        if skips > 0 { format!(" ({skips} skipped)") } else { String::new() }
    );
    Ok(())
}
