//! Bench-regression gate — the comparator behind `make bench-gate` and the
//! CI `bench-gate` job.
//!
//! Reads the freshly emitted `BENCH_gemm.json` + `BENCH_serve.json`,
//! extracts the gated metrics (kernel speedup geomeans over the `resnet`
//! and `largek` shape sets, i8-vs-f32 geomean, and the `lw-i8` serving
//! p50s), compares each against the committed `BENCH_baseline.json`, and
//! prints a markdown delta table (also appended to `$GITHUB_STEP_SUMMARY`
//! when CI sets it).  A metric that regresses by more than the tolerance
//! (baseline `tolerance` field, default 15%, `QFT_BENCH_GATE_TOL`
//! override) fails the run with a non-zero exit.
//!
//! `QFT_BENCH_WRITE_BASELINE=1` re-baselines instead: the current run's
//! values are written to `BENCH_baseline.json` for the operator to review
//! and commit (`make bench-baseline`).  Smoke-mode numbers
//! (`QFT_BENCH_SMOKE=1`) are refused — they are not comparable.

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::fmt::Write as _;
use std::io::Write as _;

use anyhow::{anyhow, bail, Context};
use qft::util::json::Value;

/// Default regression tolerance when the baseline does not pin one.
const DEFAULT_TOL: f64 = 0.15;

/// One gated metric: a stable name, the direction that counts as better,
/// and where in the bench JSONs its current value lives (see
/// [`current_value`]).
struct Metric {
    name: &'static str,
    higher_is_better: bool,
    desc: &'static str,
}

const METRICS: &[Metric] = &[
    Metric {
        name: "gemm.resnet_geomean_speedup",
        higher_is_better: true,
        desc: "packed-vs-scalar GFLOP/s geomean, resnet shape set",
    },
    Metric {
        name: "gemm.largek_geomean_speedup",
        higher_is_better: true,
        desc: "packed-vs-scalar GFLOP/s geomean, large-K (k >= 2048, KC-blocked) set",
    },
    Metric {
        name: "gemm.resnet_geomean_i8_vs_f32",
        higher_is_better: true,
        desc: "i8-vs-f32 kernel geomean, resnet shape set",
    },
    Metric {
        name: "serve.single_image_lw_i8_p50_us",
        higher_is_better: false,
        desc: "lw-i8 batch-1 forward p50 at 4 pool threads (intra-op path)",
    },
    Metric {
        name: "serve.closed_loop_lw_i8_w4_p50_us",
        higher_is_better: false,
        desc: "lw-i8 closed-loop serving p50 at 4 workers",
    },
];

/// Value of `key` from the gemm bench's `set == "summary"` row.
fn find_summary(rows: &[Value], key: &str) -> anyhow::Result<f64> {
    for r in rows {
        let is_summary = r.opt("set").and_then(|v| v.str().ok()) == Some("summary");
        if is_summary {
            if let Some(v) = r.opt(key) {
                return v.num();
            }
        }
    }
    bail!("BENCH_gemm.json has no summary key {key:?} — rerun `make bench-gemm`")
}

/// `p50_us` of the serve-bench row matching `(set, backend, dim_key=dim)`.
fn find_serve_p50(
    rows: &[Value],
    set: &str,
    backend: &str,
    dim_key: &str,
    dim: f64,
) -> anyhow::Result<f64> {
    for r in rows {
        let hit = r.opt("set").and_then(|v| v.str().ok()) == Some(set)
            && r.opt("backend").and_then(|v| v.str().ok()) == Some(backend)
            && r.opt(dim_key).and_then(|v| v.num().ok()) == Some(dim);
        if hit {
            return r.get("p50_us")?.num();
        }
    }
    bail!(
        "BENCH_serve.json has no {set}/{backend} row at {dim_key}={dim} — rerun \
         `make bench-serve`"
    )
}

/// Extract a gated metric's current value from the fresh bench JSONs.
fn current_value(name: &str, gemm: &[Value], serve: &[Value]) -> anyhow::Result<f64> {
    match name {
        "gemm.resnet_geomean_speedup" => find_summary(gemm, "resnet_geomean_speedup"),
        "gemm.largek_geomean_speedup" => find_summary(gemm, "largek_geomean_speedup"),
        "gemm.resnet_geomean_i8_vs_f32" => find_summary(gemm, "resnet_geomean_i8_vs_f32"),
        "serve.single_image_lw_i8_p50_us" => {
            find_serve_p50(serve, "single_image", "lw-i8", "threads", 4.0)
        }
        "serve.closed_loop_lw_i8_w4_p50_us" => {
            find_serve_p50(serve, "closed_loop", "lw-i8", "workers", 4.0)
        }
        other => bail!("unknown gate metric {other:?}"),
    }
}

fn load_json(name: &str) -> anyhow::Result<Value> {
    let path = util::repo_root_path(name);
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!("read {} (run `make bench-gemm bench-serve` first)", path.display())
    })?;
    Value::parse(&text).with_context(|| format!("parse {}", path.display()))
}

fn main() -> anyhow::Result<()> {
    util::section("bench-regression gate");
    let gemm = load_json("BENCH_gemm.json")?;
    let serve = load_json("BENCH_serve.json")?;
    let gemm_rows = gemm.arr()?;
    let serve_rows = serve.arr()?;
    if find_summary(gemm_rows, "smoke")? != 0.0 {
        bail!("BENCH_gemm.json was emitted under QFT_BENCH_SMOKE — smoke numbers are not \
               comparable; rerun the real benches");
    }
    let serve_smoke = serve_rows
        .iter()
        .any(|r| r.opt("smoke").and_then(|v| v.num().ok()).unwrap_or(0.0) != 0.0);
    if serve_smoke {
        bail!("BENCH_serve.json was emitted under QFT_BENCH_SMOKE — smoke numbers are not \
               comparable; rerun the real benches");
    }

    let mut current: Vec<(&Metric, f64)> = Vec::with_capacity(METRICS.len());
    for m in METRICS {
        current.push((m, current_value(m.name, gemm_rows, serve_rows)?));
    }

    let base_path = util::repo_root_path("BENCH_baseline.json");
    if std::env::var_os("QFT_BENCH_WRITE_BASELINE").is_some_and(|v| v != "0" && !v.is_empty()) {
        // preserve an operator-committed tolerance / comment across
        // re-baselines: only the metric values are refreshed
        let prev = std::fs::read_to_string(&base_path)
            .ok()
            .and_then(|t| Value::parse(&t).ok());
        let tol = prev
            .as_ref()
            .and_then(|p| p.opt("tolerance"))
            .and_then(|v| v.num().ok())
            .unwrap_or(DEFAULT_TOL);
        let comment = prev
            .as_ref()
            .and_then(|p| p.opt("comment"))
            .and_then(|v| v.str().ok().map(str::to_string));
        let mut metrics = HashMap::new();
        for (m, v) in &current {
            let mut o = HashMap::new();
            o.insert("value".to_string(), Value::Num(*v));
            o.insert("higher_is_better".to_string(), Value::Bool(m.higher_is_better));
            o.insert("desc".to_string(), Value::Str(m.desc.to_string()));
            metrics.insert(m.name.to_string(), Value::Obj(o));
        }
        let mut top = HashMap::new();
        top.insert("tolerance".to_string(), Value::Num(tol));
        if let Some(c) = comment {
            top.insert("comment".to_string(), Value::Str(c));
        }
        top.insert("metrics".to_string(), Value::Obj(metrics));
        std::fs::write(&base_path, Value::Obj(top).to_string_compact())?;
        println!("wrote fresh baseline {} — review and commit it", base_path.display());
        return Ok(());
    }

    let baseline = Value::parse(&std::fs::read_to_string(&base_path).map_err(|e| {
        anyhow!(
            "no committed BENCH_baseline.json ({e}); generate one with `make bench-baseline`"
        )
    })?)?;
    let tol: f64 = match std::env::var("QFT_BENCH_GATE_TOL") {
        Ok(s) => s.parse().context("QFT_BENCH_GATE_TOL must be a float like 0.15")?,
        Err(_) => match baseline.opt("tolerance") {
            Some(v) => v.num()?,
            None => DEFAULT_TOL,
        },
    };

    let mut table = String::from(
        "| metric | baseline | current | delta | status |\n|---|---:|---:|---:|---|\n",
    );
    let mut regressions = Vec::new();
    for (m, cur) in &current {
        let bm = baseline.get("metrics")?.get(m.name).map_err(|_| {
            anyhow!("baseline lacks metric {:?} — rerun `make bench-baseline`", m.name)
        })?;
        let base = bm.get("value")?.num()?;
        // direction comes from the gate's METRICS table; a baseline edited
        // to disagree is config drift we surface instead of silently
        // ignoring the field
        if let Some(hib) = bm.opt("higher_is_better") {
            if hib.boolean()? != m.higher_is_better {
                bail!(
                    "BENCH_baseline.json says higher_is_better={} for {:?} but the gate's \
                     metric table says {} — fix the baseline (or METRICS in bench_gate.rs)",
                    hib.boolean()?,
                    m.name,
                    m.higher_is_better
                );
            }
        }
        let delta = if base != 0.0 { cur / base - 1.0 } else { 0.0 };
        let regressed = if m.higher_is_better {
            *cur < base * (1.0 - tol)
        } else {
            *cur > base * (1.0 + tol)
        };
        let improved =
            (m.higher_is_better && delta > tol) || (!m.higher_is_better && delta < -tol);
        let status = if regressed {
            "**REGRESSION**"
        } else if improved {
            "improved"
        } else {
            "ok"
        };
        let _ = writeln!(
            table,
            "| `{}` | {:.3} | {:.3} | {:+.1}% | {} |",
            m.name,
            base,
            cur,
            delta * 100.0,
            status
        );
        if regressed {
            regressions.push(format!(
                "{}: baseline {:.3} -> current {:.3} ({:+.1}%)",
                m.name,
                base,
                cur,
                delta * 100.0
            ));
        }
    }
    println!("{table}");
    if let Ok(summary_path) = std::env::var("GITHUB_STEP_SUMMARY") {
        if let Ok(mut f) =
            std::fs::OpenOptions::new().create(true).append(true).open(summary_path)
        {
            let _ = writeln!(f, "## bench-gate (tolerance {:.0}%)\n\n{table}", tol * 100.0);
        }
    }
    if !regressions.is_empty() {
        let nreg = regressions.len();
        eprintln!("bench-gate FAILED: >{:.0}% regression on {nreg} metric(s):", tol * 100.0);
        for r in &regressions {
            eprintln!("  {r}");
        }
        eprintln!("intentional? re-baseline with `make bench-baseline` and commit the result");
        std::process::exit(1);
    }
    println!(
        "bench-gate OK: {} metrics within {:.0}% of the committed baseline",
        current.len(),
        tol * 100.0
    );
    Ok(())
}
