//! Bench K — L1/L3 micro-benchmarks: the AOT Pallas kernels through PJRT,
//! and the rust substrate hot functions (conv2d, matmul, PPQ, APQ, fq).

#[path = "util/mod.rs"]
mod util;

use qft::data::Rng;
use qft::quant::{mmse, ppq};
use qft::runtime::Runtime;
use qft::tensor::{conv::conv2d, Tensor};

fn main() {
    util::section("Kernel micro-benchmarks");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let mut rng = Rng::new(0);

    // --- L1 kernels through PJRT (256x128 / 128x128, MXU-shaped tiles) ---
    let x = Tensor::new(vec![256, 128], (0..256 * 128).map(|_| rng.normal()).collect());
    let s = Tensor::full(&[128], 0.05);
    util::micro("HLO fakequant 256x128", 50, || {
        rt.run("kernel", "fakequant", &[x.clone(), s.clone()]).unwrap()
    });
    let w = Tensor::new(vec![128, 128], (0..128 * 128).map(|_| rng.normal() * 0.2).collect());
    let sl = Tensor::full(&[128], 1.0);
    let sr = Tensor::full(&[128], 0.05);
    util::micro("HLO qmatmul 256x128x128 (fused fq+dot)", 50, || {
        rt.run("kernel", "qmatmul", &[x.clone(), w.clone(), sl.clone(), sr.clone()])
            .unwrap()
    });
    // throughput estimate for the fused kernel
    {
        let t0 = std::time::Instant::now();
        let iters = 200;
        for _ in 0..iters {
            std::hint::black_box(
                rt.run("kernel", "qmatmul", &[x.clone(), w.clone(), sl.clone(), sr.clone()])
                    .unwrap(),
            );
        }
        let s_per = t0.elapsed().as_secs_f64() / iters as f64;
        let flops = 2.0 * 256.0 * 128.0 * 128.0;
        println!("[micro] qmatmul effective: {:.2} GFLOP/s (incl. PJRT marshal)", flops / s_per / 1e9);
    }

    // --- L3 substrate ---------------------------------------------------
    let img = Tensor::new(vec![8, 16, 16, 16], (0..8 * 16 * 16 * 16).map(|_| rng.normal()).collect());
    let k = Tensor::new(vec![3, 3, 16, 16], (0..3 * 3 * 16 * 16).map(|_| rng.normal() * 0.1).collect());
    let bias = vec![0.0f32; 16];
    util::micro("rust conv2d 8x16x16x16 * 3x3x16x16", 20, || {
        conv2d(&img, &k, &bias, 1, 1)
    });
    let a = Tensor::new(vec![256, 256], (0..256 * 256).map(|_| rng.normal()).collect());
    let b = Tensor::new(vec![256, 256], (0..256 * 256).map(|_| rng.normal()).collect());
    util::micro("rust matmul 256^3", 20, || a.matmul(&b));

    let wv: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
    util::micro("PPQ mmse_scale 4096", 100, || ppq::mmse_scale(&wv, 7.0));
    let kern = Tensor::new(vec![3, 3, 32, 64], (0..3 * 3 * 32 * 64).map(|_| rng.normal() * 0.1).collect());
    util::micro("APQ dch 3x3x32x64 (10 iters)", 5, || mmse::mmse_dch(&kern, 7.0, 10));
    util::micro("fq_outer 3x3x32x64", 50, || {
        mmse::fq_outer(&kern, &vec![1.0; 32], &vec![0.05; 64], 7.0)
    });
}
