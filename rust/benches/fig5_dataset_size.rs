//! Bench F5 — regenerates Fig. 5 (dataset-size ablation; total images fed
//! held constant, like the paper).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Fig. 5: effect of calibration-set size on QFT");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let sizes = [64u64, 128, 256, 512];
    let rows = util::timed("fig5(regnet_tiny)", || {
        experiments::fig5(&rt, "regnet_tiny", &sizes, true).unwrap()
    });
    experiments::print_rows("Fig. 5", &rows);
    // paper shape: graceful decay toward small sets, diminishing returns
    let degr: Vec<f32> = rows.iter().map(|r| r.degradation()).collect();
    println!("degradation by size {sizes:?}: {degr:?}");
}
