//! Shared bench harness (the image's cargo cache has no criterion; these are
//! plain `harness = false` mains with wall-clock timing and paper-shaped
//! row output, so `cargo bench` regenerates every table/figure).

use std::time::Instant;

pub fn section(title: &str) {
    println!("\n================ {title} ================");
}

/// Run and report wall time.
pub fn timed<R>(label: &str, f: impl FnOnce() -> R) -> R {
    let t0 = Instant::now();
    let r = f();
    println!("[bench] {label}: {:.2} s", t0.elapsed().as_secs_f64());
    r
}

/// Micro-benchmark: warm up, then `iters` timed iterations; prints ns/op.
#[allow(dead_code)]
pub fn micro<R>(label: &str, iters: usize, mut f: impl FnMut() -> R) {
    for _ in 0..3 {
        std::hint::black_box(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    if per > 1e6 {
        println!("[micro] {label}: {:.3} ms/op ({iters} iters)", per / 1e6);
    } else {
        println!("[micro] {label}: {:.1} ns/op ({iters} iters)", per);
    }
}

/// Smoke mode (`QFT_BENCH_SMOKE=1`): CI runs every bench harness with a
/// tiny iteration count so the harnesses cannot rot, without paying real
/// measurement time.  Numbers produced under smoke are NOT comparable.
#[allow(dead_code)]
pub fn smoke() -> bool {
    std::env::var_os("QFT_BENCH_SMOKE").is_some_and(|v| v != "0" && !v.is_empty())
}

/// Repo-root path for a bench artifact: cargo runs bench executables with
/// cwd = the `rust` package root, but the perf-trajectory JSONs
/// (`BENCH_*.json`) belong at the repository root.
#[allow(dead_code)]
pub fn repo_root_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}
