//! Shared bench harness (the image's cargo cache has no criterion; these are
//! plain `harness = false` mains with wall-clock timing and paper-shaped
//! row output, so `cargo bench` regenerates every table/figure).

use std::time::Instant;

pub fn section(title: &str) {
    println!("\n================ {title} ================");
}

/// Run and report wall time.
pub fn timed<R>(label: &str, f: impl FnOnce() -> R) -> R {
    let t0 = Instant::now();
    let r = f();
    println!("[bench] {label}: {:.2} s", t0.elapsed().as_secs_f64());
    r
}

/// Micro-benchmark: warm up, then `iters` timed iterations; prints ns/op.
#[allow(dead_code)]
pub fn micro<R>(label: &str, iters: usize, mut f: impl FnMut() -> R) {
    for _ in 0..3 {
        std::hint::black_box(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    if per > 1e6 {
        println!("[micro] {label}: {:.3} ms/op ({iters} iters)", per / 1e6);
    } else {
        println!("[micro] {label}: {:.1} ns/op ({iters} iters)", per);
    }
}
