//! Bench F8 — regenerates Fig. 8 (layerwise 2x2: {CLE init?} x {train the
//! activation vector scale?}).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Fig. 8: trained vector activation scale vs CLE (lw, W4A8)");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let names = ["resnet_tiny", "mobilenet_tiny"];
    let rows = util::timed("fig8(2 archs x 4 configs)", || {
        experiments::fig8(&rt, &names, true).unwrap()
    });
    experiments::print_rows("Fig. 8", &rows);
    // paper shape: trained sv <= CLE-init-frozen <= base, synergy possible
    for arch in names {
        let d = |cfg: &str| {
            rows.iter()
                .find(|r| r.arch == arch && r.config.starts_with(cfg))
                .map(|r| r.degradation())
                .unwrap_or(f32::NAN)
        };
        println!(
            "{arch}: base {:+.2} | CLE {:+.2} | trained {:+.2} | CLE+trained {:+.2}",
            -d("base") * 100.0,
            -d("CLE init") * 100.0,
            -d("trained") * 100.0,
            -d("CLE + trained") * 100.0
        );
    }
}
