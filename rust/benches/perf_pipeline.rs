//! Bench P — the §4.2 runtime claim: QFT is fast and the coordinator is not
//! the bottleneck (paper: 10-50 min on one GPU with high utilization; here:
//! seconds on CPU-PJRT with the duty cycle as the utilization analogue).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::{eval, experiments, metrics, qft as qft_stage};
use qft::quant::deploy::Mode;
use qft::runtime::Runtime;

fn main() {
    util::section("Pipeline performance (the paper's speed claim)");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");

    for arch in ["convnet_tiny", "resnet_tiny", "mobilenet_tiny", "resnet_wide"] {
        let t = experiments::teacher_ctx(&rt, arch).unwrap();
        let cfg = qft_stage::QftConfig::fast(Mode::Lw);
        // warm the executable cache so the span measures the steady-state
        // loop, not one-time XLA compiles
        for entry in ["fp_stats", "qft_train_lw", "q_eval_lw"] {
            rt.executable(arch, entry).unwrap();
        }
        rt.reset_stats();
        let span = metrics::Span::start(&rt, arch);
        let r = qft_stage::run_qft(&rt, arch, &t.params, &cfg).unwrap();
        let rep = span.finish();
        let steps = r.losses.len();
        println!(
            "{arch:<16} {} steps | {:6.2} s wall | {:5.2} ms/step | duty {:3.0}% | residual compile {:4.0} ms",
            steps,
            rep.wall_ms / 1e3,
            rep.wall_ms / steps as f64,
            rep.duty_cycle * 100.0,
            rt.stats().compile_ns as f64 / 1e6,
        );
    }

    // eval throughput (images/s through the AOT q_eval path)
    let arch = "resnet_tiny";
    let t = experiments::teacher_ctx(&rt, arch).unwrap();
    let cfg = qft_stage::QftConfig::fast(Mode::Lw);
    let init = qft_stage::initialize(&rt, arch, &t.params, &cfg).unwrap();
    let t0 = std::time::Instant::now();
    let n = 512;
    let _ = eval::eval_q(&rt, arch, &init, Mode::Lw, n, 0).unwrap();
    println!(
        "q_eval throughput: {:.0} images/s",
        n as f64 / t0.elapsed().as_secs_f64()
    );
}
