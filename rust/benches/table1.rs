//! Bench T1 — regenerates Table 1 (QFT vs PTQ baselines) in the fast
//! profile and times the end-to-end pipeline per network.

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Table 1: QFT vs SoTA-baseline PTQ (fast profile)");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let names = ["resnet_tiny", "mobilenet_tiny", "regnet_tiny"];
    let rows = util::timed("table1(3 archs x 4 configs)", || {
        experiments::table1(&rt, &names, true).unwrap()
    });
    experiments::print_rows("Table 1", &rows);
    let s = rt.stats();
    println!(
        "[bench] pjrt: {} execs, {:.2} s exec, {:.2} s compile",
        s.executions,
        s.exec_ns as f64 / 1e9,
        s.compile_ns as f64 / 1e9
    );
}
