//! Bench P — the `qft::par` kernel engine: single-request conv and GEMM
//! throughput at pool widths 1/2/4 against the serial baseline, plus a
//! whole-network single-image forward.  Emits `BENCH_par.json`.
//!
//! Everything here is single-request parallelism — one conv / one GEMM /
//! one image split across the pool — the exact case PR 1's worker-level
//! scaling could not touch.

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::time::Instant;

use qft::par::Pool;
use qft::quant::deploy::{DeployScratch, Mode};
use qft::serve::synthetic_model;
use qft::tensor::conv::{conv2d_into, conv2d_into_par, ConvScratch};
use qft::tensor::{matmul_slices, matmul_slices_par};
use qft::util::json::Value;
use qft::Tensor;

fn rand_tensor(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = qft::data::Rng::new(seed);
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), (0..n).map(|_| rng.normal()).collect())
}

/// Wall-time per op over `iters` timed iterations (2 warm-up passes).
fn time_per_op(iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..2 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn row(kernel: &str, threads: usize, s_per_op: f64, serial_s: f64) -> Value {
    let mut m = HashMap::new();
    m.insert("kernel".to_string(), Value::Str(kernel.to_string()));
    m.insert("threads".to_string(), Value::Num(threads as f64));
    m.insert("ms_per_op".to_string(), Value::Num(s_per_op * 1e3));
    m.insert(
        "speedup_vs_serial".to_string(),
        Value::Num(if s_per_op > 0.0 { serial_s / s_per_op } else { 0.0 }),
    );
    Value::Obj(m)
}

fn main() {
    util::section("qft::par kernel engine (single-request conv/GEMM)");
    println!(
        "host parallelism: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let widths = [1usize, 2, 4];
    let iters = if util::smoke() { 1 } else { 8 };
    let mut rows = Vec::new();

    // GEMM: one m x k @ k x n matmul, rows split across the pool
    let (m, k, n) = (1024usize, 256, 256);
    let x = rand_tensor(&[m, k], 1);
    let w = rand_tensor(&[k, n], 2);
    let mut out = Vec::new();
    let gemm_serial =
        time_per_op(iters, || matmul_slices(&x.data, m, k, &w.data, n, &mut out));
    println!("[gemm {m}x{k}x{n}] serial: {:.2} ms/op", gemm_serial * 1e3);
    rows.push(row("gemm", 0, gemm_serial, gemm_serial));
    for &t in &widths {
        let pool = Pool::new(t);
        let s = time_per_op(iters, || {
            matmul_slices_par(&x.data, m, k, &w.data, n, &mut out, &pool)
        });
        println!(
            "[gemm {m}x{k}x{n}] pool {t}: {:.2} ms/op ({:.2}x)",
            s * 1e3,
            gemm_serial / s
        );
        rows.push(row("gemm", t, s, gemm_serial));
    }

    // conv: one NHWC conv, output rows split across the pool
    let cx = rand_tensor(&[1, 32, 32, 32], 3);
    let cw = rand_tensor(&[3, 3, 32, 64], 4);
    let bias = vec![0.1f32; 64];
    let mut scratch = ConvScratch::new();
    let mut cout = Tensor::default();
    let conv_serial =
        time_per_op(iters, || conv2d_into(&cx, &cw, &bias, 1, 1, &mut scratch, &mut cout));
    println!("[conv 32x32x32->64] serial: {:.2} ms/op", conv_serial * 1e3);
    rows.push(row("conv", 0, conv_serial, conv_serial));
    for &t in &widths {
        let pool = Pool::new(t);
        let s = time_per_op(iters, || {
            conv2d_into_par(&cx, &cw, &bias, 1, 1, &mut scratch, &mut cout, &pool)
        });
        println!(
            "[conv 32x32x32->64] pool {t}: {:.2} ms/op ({:.2}x)",
            s * 1e3,
            conv_serial / s
        );
        rows.push(row("conv", t, s, conv_serial));
    }

    // whole network, one image: intra-op parallelism through every conv
    let model = synthetic_model(Mode::Lw, 0);
    let ds = qft::data::Dataset::new(0);
    let (img, _) = ds.sample(qft::data::Split::Val, 0);
    let xi = Tensor::new(vec![1, model.input_hw, model.input_hw, model.input_ch], img);
    let mut dscratch = DeployScratch::new();
    let fwd_serial = time_per_op(iters, || {
        std::hint::black_box(model.forward_batch(&xi, &mut dscratch));
    });
    println!("[forward 1 image] serial: {:.3} ms/op", fwd_serial * 1e3);
    rows.push(row("forward1", 0, fwd_serial, fwd_serial));
    for &t in &widths {
        let pool = Pool::new(t);
        let s = time_per_op(iters, || {
            std::hint::black_box(model.forward_batch_pooled(&xi, &mut dscratch, &pool));
        });
        println!(
            "[forward 1 image] pool {t}: {:.3} ms/op ({:.2}x)",
            s * 1e3,
            fwd_serial / s
        );
        rows.push(row("forward1", t, s, fwd_serial));
    }

    let out_path = util::repo_root_path("BENCH_par.json");
    std::fs::write(&out_path, Value::Arr(rows).to_string_compact())
        .expect("write BENCH_par.json");
    println!("wrote {}", out_path.display());
}
