//! Bench N — tail latency of the TCP front-end under **open-loop** load,
//! one `BENCH_net.json` (rows tagged `set == "open_loop"`).
//!
//! Each configuration starts a fresh engine + [`qft::net::NetServer`] on an
//! ephemeral loopback port and drives it with [`qft::net::open_loop`]:
//! every connection runs an independent Poisson arrival process and sends
//! at its *scheduled* instants whether or not earlier replies are back, so
//! — unlike the closed-loop `serve_throughput` bench — queueing delay shows
//! up in the percentiles instead of silently throttling the offered rate
//! (coordinated omission).  Latency is measured from the scheduled arrival;
//! `p99.9` is the headline column.
//!
//! Sweep: backend (`lw`, `lw-i8`) × connections × total offered rate, at a
//! fixed 2-worker engine.  The `lw-i8` row at 4 connections / 200 rps feeds
//! the CI perf gate (`make bench-gate`).  Smoke mode shrinks everything and
//! tags the rows so the gate skips them.

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use qft::backend::BackendKind;
use qft::net::{open_loop, LoadConfig, NetConfig, NetServer};
use qft::quant::deploy::Mode;
use qft::serve::{Engine, Fleet, ServeConfig};
use qft::util::json::Value;

/// Engine width is pinned so the sweep varies only offered load.
const WORKERS: usize = 2;

fn main() {
    util::section("qft::net open-loop wire latency (Poisson arrivals)");
    let smoke = util::smoke();
    let backends: &[BackendKind] = if smoke {
        &[BackendKind::Int8]
    } else {
        &[BackendKind::Int(Mode::Lw), BackendKind::Int8]
    };
    let conn_sweep: &[usize] = if smoke { &[2] } else { &[4, 16] };
    let rate_sweep: &[f64] = if smoke { &[100.0] } else { &[200.0, 800.0] };
    let secs = if smoke { 0.3 } else { 2.5 };
    // prefer a manifest arch when artifacts exist; otherwise the built-in
    // synthetic arch keeps the bench runnable in any checkout
    let arch = if Path::new("artifacts/manifest.json").is_file() {
        "resnet_tiny"
    } else {
        "synthetic"
    };

    let mut rows = Vec::new();
    for &kind in backends {
        let fleet = Fleet::load(Path::new("artifacts"), &[(arch.to_string(), kind)])
            .expect("load fleet");
        let slot = fleet.slot(0).expect("fleet slot 0");
        let (slot_key, image_len) = (slot.key.clone(), slot.image_len());
        for &connections in conn_sweep {
            for &rate in rate_sweep {
                let cfg = ServeConfig {
                    workers: WORKERS,
                    max_batch: 8,
                    max_wait: Duration::from_micros(200),
                    queue_cap: 256,
                    ..Default::default()
                };
                let engine = Engine::start(fleet.clone(), &cfg);
                let server = NetServer::start(engine, &NetConfig::default())
                    .expect("bind ephemeral loopback port");
                let run = LoadConfig {
                    addr: server.local_addr(),
                    slot_key: slot_key.clone(),
                    image_len,
                    connections,
                    rate_rps: rate,
                    duration: Duration::from_secs_f64(secs),
                    seed: 7,
                };
                // trickle warm-up (first-touch, listener, scratch growth),
                // then zero the obs registry so the net counters cover
                // exactly the measured window
                let warm = LoadConfig {
                    rate_rps: rate.min(50.0),
                    duration: Duration::from_secs_f64(0.2),
                    ..run.clone()
                };
                open_loop(&warm).expect("warm-up run");
                qft::obs::reset();
                let label = format!("{slot_key} conns={connections} rate={rate:.0}rps");
                let report = util::timed(&label, || open_loop(&run).expect("open-loop run"));
                println!("{report}");
                let net_report = server.shutdown(Duration::from_secs(5));
                if net_report.drain.dropped > 0 {
                    println!(
                        "  (drain shed {} queued requests at the shutdown deadline)",
                        net_report.drain.dropped
                    );
                }

                let mut m = HashMap::new();
                m.insert("set".to_string(), Value::Str("open_loop".to_string()));
                m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
                m.insert("arch".to_string(), Value::Str(slot_key.clone()));
                m.insert("backend".to_string(), Value::Str(kind.key().to_string()));
                m.insert("workers".to_string(), Value::Num(WORKERS as f64));
                m.insert("connections".to_string(), Value::Num(connections as f64));
                m.insert("rate_rps".to_string(), Value::Num(rate));
                m.insert("duration_s".to_string(), Value::Num(secs));
                m.insert("offered".to_string(), Value::Num(report.offered as f64));
                m.insert("replies".to_string(), Value::Num(report.replies as f64));
                m.insert("shed".to_string(), Value::Num(report.shed as f64));
                m.insert("errors".to_string(), Value::Num(report.errors as f64));
                m.insert("throughput_rps".to_string(), Value::Num(report.throughput_rps));
                m.insert("p50_us".to_string(), Value::Num(report.p50_us as f64));
                m.insert("p99_us".to_string(), Value::Num(report.p99_us as f64));
                m.insert("p999_us".to_string(), Value::Num(report.p999_us as f64));
                m.insert("max_us".to_string(), Value::Num(report.max_us as f64));
                m.insert("mean_us".to_string(), Value::Num(report.mean_us));
                rows.push(Value::Obj(m));
            }
        }
    }

    let out_path = util::repo_root_path("BENCH_net.json");
    std::fs::write(&out_path, Value::Arr(rows).to_string_compact())
        .expect("write BENCH_net.json");
    println!("wrote {}", out_path.display());
}
