//! Bench S — serving performance across execution backends, two sections
//! in one `BENCH_serve.json` (rows tagged by `set`):
//!
//! * `closed_loop` — images/sec and p50/p95/p99 latency at 1/2/4 workers
//!   for each of the `lw`, `dch` and `lw-i8` grids under closed-loop load.
//! * `single_image` — batch-1 forward latency straight through the
//!   backend at 1/2/4 pool threads: the intra-op (output-row) parallelism
//!   signal for the `lw` / `lw-i8` deployment grids.  The lw-i8 row at the
//!   widest pool feeds the CI perf gate (`make bench-gate`).

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use qft::backend::{self, BackendKind, Scratch};
use qft::data::{Dataset, Split};
use qft::par::Pool;
use qft::quant::deploy::Mode;
use qft::serve::{run_closed_loop, synthetic_trainables, Fleet, ServeConfig};
use qft::util::json::Value;

const BACKENDS: &[BackendKind] =
    &[BackendKind::Int(Mode::Lw), BackendKind::Int(Mode::Dch), BackendKind::Int8];

fn main() {
    util::section("qft::serve throughput (execution-backend sweep)");
    // prefer a manifest arch when artifacts exist; otherwise the built-in
    // synthetic arch keeps the bench runnable in any checkout
    let arch = if Path::new("artifacts/manifest.json").is_file() {
        "resnet_tiny"
    } else {
        "synthetic"
    };

    let smoke = util::smoke();
    let clients = if smoke { 4 } else { 16 };
    let per_client = if smoke { 4 } else { 128 };
    let mut rows = Vec::new();
    for &kind in BACKENDS {
        let fleet = Fleet::load(Path::new("artifacts"), &[(arch.to_string(), kind)])
            .expect("load fleet");
        let mut sweep = Vec::new();
        for &workers in &[1usize, 2, 4] {
            let cfg = ServeConfig {
                workers,
                max_batch: 8,
                max_wait: Duration::from_micros(200),
                queue_cap: 512,
                ..Default::default()
            };
            // warm-up so buffer growth / first-touch doesn't skew the timing
            let _ = run_closed_loop(&fleet, &cfg, clients, if smoke { 1 } else { 8 }, 0);
            // zero the obs histograms so the stage summary covers exactly
            // this (backend, workers) measured run
            qft::obs::reset();
            let report = util::timed(&format!("{arch}/{} workers={workers}", kind.key()), || {
                run_closed_loop(&fleet, &cfg, clients, per_client, 0)
            });
            println!("  {}/workers={workers}: {report}", kind.key());
            let stage = qft::obs::snapshot()
                .stage_for(&format!("{arch}/{}", kind.key()))
                .cloned();
            sweep.push((workers, report, stage));
        }
        if sweep.len() >= 2 {
            let first = sweep.first().unwrap().1.throughput_ips;
            let last = sweep.last().unwrap().1.throughput_ips;
            println!(
                "{}: scaling {}x from {} -> {} workers",
                kind.key(),
                if first > 0.0 { last / first } else { 0.0 },
                sweep.first().unwrap().0,
                sweep.last().unwrap().0
            );
        }
        for (workers, r, stage) in sweep {
            let mut m = HashMap::new();
            m.insert("set".to_string(), Value::Str("closed_loop".to_string()));
            m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
            m.insert("arch".to_string(), Value::Str(format!("{arch}/{}", kind.key())));
            m.insert("backend".to_string(), Value::Str(kind.key().to_string()));
            m.insert("workers".to_string(), Value::Num(workers as f64));
            m.insert("clients".to_string(), Value::Num(clients as f64));
            m.insert("requests".to_string(), Value::Num(r.requests as f64));
            m.insert("images_per_sec".to_string(), Value::Num(r.throughput_ips));
            m.insert("p50_us".to_string(), Value::Num(r.p50_us as f64));
            m.insert("p95_us".to_string(), Value::Num(r.p95_us as f64));
            m.insert("p99_us".to_string(), Value::Num(r.p99_us as f64));
            m.insert("reply_p50_us".to_string(), Value::Num(r.reply_p50_us as f64));
            m.insert("reply_p99_us".to_string(), Value::Num(r.reply_p99_us as f64));
            m.insert("mean_batch".to_string(), Value::Num(r.mean_batch));
            // per-stage breakdown from qft::obs (reply stage lives in the
            // obs exposition; its end-to-end variant is reply_p50_us above)
            if let Some(s) = stage {
                for name in ["queue_wait", "batch_form", "compute"] {
                    if let Some(h) = s.stage(name) {
                        m.insert(format!("{name}_p50_us"), Value::Num(h.p50 as f64));
                        m.insert(format!("{name}_p99_us"), Value::Num(h.p99 as f64));
                    }
                }
            }
            rows.push(Value::Obj(m));
        }
    }

    // ---- single-image intra-op latency sweep --------------------------
    // one image straight through the backend (no batcher, no engine) at
    // pool widths 1/2/4: batch-1 latency should DROP as threads rise now
    // that the integer grids chunk each conv's output rows across the pool
    util::section("single-image intra-op latency (batch=1, forward only)");
    let reps = if smoke { 2 } else { 64 };
    for &kind in &[BackendKind::Int(Mode::Lw), BackendKind::Int8] {
        let (arch_s, tm) = synthetic_trainables(Mode::Lw, 0);
        let net = backend::prepare(kind, &arch_s, &tm);
        let x = Dataset::new(1).batch(Split::Val, 0, 1).0;
        for &threads in &[1usize, 2, 4] {
            let pool = Pool::new(threads);
            let mut scratch = Scratch::new();
            for _ in 0..2 {
                std::hint::black_box(net.forward_batch(&x, &mut scratch, &pool));
            }
            let mut lat_us: Vec<u64> = (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    std::hint::black_box(net.forward_batch(&x, &mut scratch, &pool));
                    t0.elapsed().as_micros() as u64
                })
                .collect();
            lat_us.sort_unstable();
            let p50 = lat_us[lat_us.len() / 2];
            let mean = lat_us.iter().sum::<u64>() as f64 / lat_us.len() as f64;
            println!(
                "  {}/threads={threads}: p50 {p50} us, mean {mean:.1} us ({reps} reps)",
                kind.key()
            );
            let mut m = HashMap::new();
            m.insert("set".to_string(), Value::Str("single_image".to_string()));
            m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
            m.insert("backend".to_string(), Value::Str(kind.key().to_string()));
            m.insert("threads".to_string(), Value::Num(threads as f64));
            m.insert("reps".to_string(), Value::Num(reps as f64));
            m.insert("p50_us".to_string(), Value::Num(p50 as f64));
            m.insert("mean_us".to_string(), Value::Num(mean));
            rows.push(Value::Obj(m));
        }
    }

    let out_path = util::repo_root_path("BENCH_serve.json");
    std::fs::write(&out_path, Value::Arr(rows).to_string_compact())
        .expect("write BENCH_serve.json");
    println!("wrote {}", out_path.display());
}
