//! Bench S — serving throughput over the integer deployment path:
//! images/sec and p99 latency at 1/2/4 workers, closed-loop load.
//! Emits `BENCH_serve.json` for trend tracking.

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use qft::quant::deploy::Mode;
use qft::serve::{run_closed_loop, Registry, ServeConfig};
use qft::util::json::Value;

fn main() {
    util::section("qft::serve throughput (integer deployment path)");
    // prefer a manifest arch when artifacts exist; otherwise the built-in
    // synthetic arch keeps the bench runnable in any checkout
    let arch = if Path::new("artifacts/manifest.json").is_file() {
        "resnet_tiny"
    } else {
        "synthetic"
    };
    let registry = Registry::load(Path::new("artifacts"), &[(arch.to_string(), Mode::Lw)])
        .expect("load registry");

    let smoke = util::smoke();
    let clients = if smoke { 4 } else { 16 };
    let per_client = if smoke { 4 } else { 128 };
    let mut rows = Vec::new();
    for &workers in &[1usize, 2, 4] {
        let cfg = ServeConfig {
            workers,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 512,
            ..Default::default()
        };
        // warm-up so buffer growth / first-touch doesn't skew the timing
        let _ = run_closed_loop(&registry, &cfg, clients, if smoke { 1 } else { 8 }, 0);
        let report = util::timed(&format!("{arch}/lw workers={workers}"), || {
            run_closed_loop(&registry, &cfg, clients, per_client, 0)
        });
        println!("  workers={workers}: {report}");
        rows.push((workers, report));
    }

    if rows.len() >= 2 {
        let first = rows.first().unwrap().1.throughput_ips;
        let last = rows.last().unwrap().1.throughput_ips;
        println!(
            "scaling {}x from {} -> {} workers",
            if first > 0.0 { last / first } else { 0.0 },
            rows.first().unwrap().0,
            rows.last().unwrap().0
        );
    }

    let json = Value::Arr(
        rows.iter()
            .map(|(workers, r)| {
                let mut m = HashMap::new();
                m.insert("arch".to_string(), Value::Str(format!("{arch}/lw")));
                m.insert("workers".to_string(), Value::Num(*workers as f64));
                m.insert("clients".to_string(), Value::Num(clients as f64));
                m.insert("requests".to_string(), Value::Num(r.requests as f64));
                m.insert("images_per_sec".to_string(), Value::Num(r.throughput_ips));
                m.insert("p50_us".to_string(), Value::Num(r.p50_us as f64));
                m.insert("p95_us".to_string(), Value::Num(r.p95_us as f64));
                m.insert("p99_us".to_string(), Value::Num(r.p99_us as f64));
                m.insert("mean_batch".to_string(), Value::Num(r.mean_batch));
                Value::Obj(m)
            })
            .collect(),
    );
    let out_path = util::repo_root_path("BENCH_serve.json");
    std::fs::write(&out_path, json.to_string_compact()).expect("write BENCH_serve.json");
    println!("wrote {}", out_path.display());
}
