//! Bench O — observability overhead gate (`make obs-overhead`).
//!
//! Runs the lw-i8 closed-loop serving config with `qft::obs` recording
//! fully enabled (default 1-in-16 layer sampling) and fully disabled,
//! interleaved across rounds so machine drift hits both states equally,
//! and compares the best closed-loop p50 of each state: obs must cost at
//! most `QFT_OBS_OVERHEAD_TOL` (default 3%) plus a 25µs absolute slack
//! for timer noise at small latencies.  Also renders the enabled run's
//! Prometheus exposition, validates the text format line-by-line
//! ([`qft::obs::validate_prometheus`]), and lands it at the repo root as
//! `OBS_metrics.prom` (uploaded by CI next to the `BENCH_*.json`s).
//!
//! Under `QFT_BENCH_SMOKE=1` the harness still runs end-to-end (one tiny
//! round, artifact + validation included) but the overhead gate is
//! skipped — smoke numbers are not comparable.

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use qft::backend::BackendKind;
use qft::serve::{run_closed_loop, Fleet, ServeConfig};
use qft::util::json::Value;

fn main() {
    util::section("qft::obs overhead (lw-i8 closed loop, obs on vs off)");
    let arch = if Path::new("artifacts/manifest.json").is_file() {
        "resnet_tiny"
    } else {
        "synthetic"
    };
    let kind = BackendKind::Int8;
    let smoke = util::smoke();
    let clients = if smoke { 2 } else { 8 };
    let per_client = if smoke { 2 } else { 96 };
    let rounds = if smoke { 1 } else { 3 };
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 512,
        ..Default::default()
    };
    let fleet = Fleet::load(Path::new("artifacts"), &[(arch.to_string(), kind)])
        .expect("load fleet");
    // warm-up so buffer growth / first-touch doesn't skew either state
    let _ = run_closed_loop(&fleet, &cfg, clients, if smoke { 1 } else { 8 }, 0);

    let mut rows = Vec::new();
    let mut min_p50 = [u64::MAX; 2]; // [off, on]
    for round in 0..rounds {
        // off first, on second, every round: interleaving means slow
        // drift (thermal, noisy neighbors) cannot masquerade as overhead
        for (si, on) in [(0usize, false), (1usize, true)] {
            qft::obs::set_enabled(on);
            qft::obs::reset();
            let state = if on { "on" } else { "off" };
            let report = util::timed(&format!("obs={state} round {round}"), || {
                run_closed_loop(&fleet, &cfg, clients, per_client, 0)
            });
            println!(
                "  obs={state}: p50 {} us, p99 {} us, {:.0} img/s",
                report.p50_us, report.p99_us, report.throughput_ips
            );
            min_p50[si] = min_p50[si].min(report.p50_us);
            let mut m = HashMap::new();
            m.insert("set".to_string(), Value::Str("obs_overhead".to_string()));
            m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
            m.insert("backend".to_string(), Value::Str(kind.key().to_string()));
            m.insert("obs".to_string(), Value::Str(state.to_string()));
            m.insert("round".to_string(), Value::Num(round as f64));
            m.insert("requests".to_string(), Value::Num(report.requests as f64));
            m.insert("p50_us".to_string(), Value::Num(report.p50_us as f64));
            m.insert("p99_us".to_string(), Value::Num(report.p99_us as f64));
            m.insert("images_per_sec".to_string(), Value::Num(report.throughput_ips));
            rows.push(Value::Obj(m));
        }
    }
    // leave the process in the default-on state for anything that follows
    qft::obs::set_enabled(true);

    // exposition artifact: the last round ran with obs on, so the registry
    // holds real stage + layer samples — render, validate, upload
    let prom = qft::obs::render_prometheus();
    qft::obs::validate_prometheus(&prom).expect("prometheus exposition must validate");
    let key = format!("{arch}/{}", kind.key());
    assert!(
        prom.contains(&format!("model=\"{key}\",stage=\"compute\"")),
        "exposition is missing the {key} compute stage"
    );
    let prom_path = util::repo_root_path("OBS_metrics.prom");
    std::fs::write(&prom_path, &prom).expect("write OBS_metrics.prom");
    println!("wrote {} ({} lines, validated)", prom_path.display(), prom.lines().count());

    let tol: f64 = std::env::var("QFT_OBS_OVERHEAD_TOL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.03);
    const SLACK_US: f64 = 25.0;
    let off = min_p50[0] as f64;
    let on = min_p50[1] as f64;
    let overhead = if off > 0.0 { on / off - 1.0 } else { 0.0 };
    println!(
        "obs overhead: off p50 {off:.0} us, on p50 {on:.0} us \
         ({:+.1}%, tol {:.0}% + {SLACK_US:.0} us slack)",
        overhead * 100.0,
        tol * 100.0
    );
    let mut m = HashMap::new();
    m.insert("set".to_string(), Value::Str("obs_overhead_summary".to_string()));
    m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
    m.insert("backend".to_string(), Value::Str(kind.key().to_string()));
    m.insert("off_p50_us".to_string(), Value::Num(off));
    m.insert("on_p50_us".to_string(), Value::Num(on));
    m.insert("overhead_frac".to_string(), Value::Num(overhead));
    m.insert("tol".to_string(), Value::Num(tol));
    m.insert("slack_us".to_string(), Value::Num(SLACK_US));
    rows.push(Value::Obj(m));

    let out_path = util::repo_root_path("BENCH_obs.json");
    std::fs::write(&out_path, Value::Arr(rows).to_string_compact())
        .expect("write BENCH_obs.json");
    println!("wrote {}", out_path.display());

    if smoke {
        println!("smoke mode: overhead gate skipped (numbers not comparable)");
    } else if on > off * (1.0 + tol) + SLACK_US {
        eprintln!(
            "FAIL: obs-enabled closed-loop p50 regressed {:.1}% (> {:.0}% + {SLACK_US:.0} us): \
             {on:.0} us vs {off:.0} us",
            overhead * 100.0,
            tol * 100.0
        );
        std::process::exit(1);
    } else {
        println!("PASS: obs overhead within tolerance");
    }
}
