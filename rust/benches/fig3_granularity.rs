//! Bench F3 — regenerates Fig. 3 (kernel MMSE error across scale-tensor
//! granularity) and micro-benchmarks the three MMSE solvers.

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::data::Rng;
use qft::quant::mmse;
use qft::runtime::Runtime;
use qft::tensor::Tensor;

fn main() {
    util::section("Fig. 3: kernel quantization error vs granularity");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let rows = util::timed("fig3(mobilenet_tiny)", || {
        experiments::fig3(&rt, "mobilenet_tiny").unwrap()
    });
    println!("{:<10} {:>10} {:>12} {:>10}", "layer", "layerwise", "channelwise", "dCh");
    let (mut lw, mut ch, mut dch) = (0.0f32, 0.0f32, 0.0f32);
    for r in &rows {
        println!(
            "{:<10} {:>10.4} {:>12.4} {:>10.4}",
            r.layer, r.e_layerwise, r.e_channelwise, r.e_dch
        );
        lw += r.e_layerwise * r.e_layerwise;
        ch += r.e_channelwise * r.e_channelwise;
        dch += r.e_dch * r.e_dch;
    }
    println!(
        "total: layerwise {:.4} >= channelwise {:.4} >= dCh {:.4}",
        lw.sqrt(),
        ch.sqrt(),
        dch.sqrt()
    );

    // solver micro-benchmarks on a 3x3x32x64 kernel (paper: "around a second
    // for matrices sized 1M" for 10 APQ iters — ours is ~18k elements)
    let mut rng = Rng::new(0);
    let w = Tensor::new(
        vec![3, 3, 32, 64],
        (0..3 * 3 * 32 * 64).map(|_| rng.normal() * 0.1).collect(),
    );
    util::micro("PPQ layerwise mmse (3x3x32x64)", 20, || {
        mmse::mmse_layerwise(&w, 7.0)
    });
    util::micro("PPQ channelwise mmse", 5, || mmse::mmse_channelwise(&w, 7.0));
    util::micro("APQ doubly-channelwise (10 iters)", 5, || {
        mmse::mmse_dch(&w, 7.0, 10)
    });
}
