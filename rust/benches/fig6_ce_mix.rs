//! Bench F6 — regenerates Fig. 6 (mixing CE-on-logits into the KD loss).

#[path = "util/mod.rs"]
mod util;

use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() {
    util::section("Fig. 6: complex KD loss — CE-logits mixing proportion");
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let mixes = [0.0f32, 0.1, 0.5, 1.0];
    let rows = util::timed("fig6(mobilenet_tiny)", || {
        experiments::fig6(&rt, "mobilenet_tiny", &mixes, true).unwrap()
    });
    experiments::print_rows("Fig. 6", &rows);
    // paper shape: CE-alone (p=1.0) is clearly worse than backbone-L2 (p=0)
    let d0 = rows.first().unwrap().degradation();
    let d1 = rows.last().unwrap().degradation();
    println!("degradation p=0: {:+.2}% vs p=1: {:+.2}%", -d0 * 100.0, -d1 * 100.0);
}
