//! Bench W — hot-swap stall (`make bench-swap`): what does a route change
//! cost the request path?
//!
//! Two closed-loop regimes over the same `synthetic/lw` fleet slot, same
//! load, same engine config:
//!
//! * `steady` — one serving version, no route changes (baseline);
//! * `swapping` — an admin thread promotes back and forth between two
//!   bit-identical versions every ~500 µs for the whole run, so nearly
//!   every micro-batch crosses a swap.
//!
//! Promote is a single atomic store and workers clone the routed Arc once
//! per batch, so the p50/p99 of the two regimes should be
//! indistinguishable — `stall_ratio` (swapping p99 / steady p99) is the
//! number to watch in `BENCH_swap.json` (uploaded by CI with the other
//! bench artifacts; no hard gate, latency tails are too noisy on shared
//! runners).

#[path = "util/mod.rs"]
mod util;

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use qft::backend::{self, BackendKind};
use qft::data::{Dataset, Split};
use qft::fleet::{Fleet, Slot};
use qft::quant::deploy::Mode;
use qft::serve::{Engine, ServeConfig, ServeReport};
use qft::util::json::Value;

/// Closed-loop run; with `swap_to` set, an admin thread toggles the
/// primary between v1 and that version for the whole run.  Returns the
/// engine report and the number of promotes issued.
fn run(
    fleet: &Arc<Fleet>,
    slot: &Arc<Slot>,
    cfg: &ServeConfig,
    clients: usize,
    per_client: usize,
    swap_to: Option<u32>,
) -> (ServeReport, u64) {
    let engine = Engine::start(fleet.clone(), cfg);
    let done = AtomicBool::new(false);
    let mut swaps = 0u64;
    std::thread::scope(|s| {
        let admin = swap_to.map(|v2| {
            let slot = slot.clone();
            let done = &done;
            s.spawn(move || {
                let mut n = 0u64;
                let mut to_v2 = true;
                while !done.load(Ordering::Relaxed) {
                    slot.promote(if to_v2 { v2 } else { 1 }).expect("promote bench twin");
                    to_v2 = !to_v2;
                    n += 1;
                    std::thread::sleep(Duration::from_micros(500));
                }
                n
            })
        });
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let client = engine.client();
                s.spawn(move || {
                    let ds = Dataset::new(c as u64 + 1);
                    for i in 0..per_client {
                        let (img, _) = ds.sample(Split::Val, i as u64);
                        if client.infer(0, img).is_err() {
                            break;
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
        if let Some(a) = admin {
            swaps = a.join().unwrap();
        }
    });
    (engine.shutdown(), swaps)
}

fn main() {
    util::section("qft::fleet hot-swap stall (steady vs swap-churn closed loop)");
    let smoke = util::smoke();
    let clients = if smoke { 2 } else { 8 };
    let per_client = if smoke { 4 } else { 96 };

    let fleet = Fleet::load(
        Path::new("artifacts"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
    )
    .expect("load fleet");
    let slot = fleet.slot(0).expect("slot 0").clone();
    // a bit-identical twin: same params, same grid, fresh prepare — the
    // swap itself is the only variable between the regimes
    let v2 = {
        let v1 = slot.primary();
        let model = backend::prepare(v1.kind, &slot.arch, &v1.params);
        slot.install(v1.kind, model, v1.params.clone(), "bench twin".into())
            .expect("install twin")
    };

    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 512,
        ..Default::default()
    };
    // warm-up so buffer growth / first-touch doesn't skew either regime
    let _ = run(&fleet, &slot, &cfg, clients, if smoke { 1 } else { 8 }, None);

    let mut rows = Vec::new();
    let mut p99 = [0u64; 2]; // [steady, swapping]
    for (i, (regime, swap_to)) in
        [("steady", None), ("swapping", Some(v2))].into_iter().enumerate()
    {
        slot.promote(1).expect("reset route");
        qft::obs::reset();
        let (report, swaps) = util::timed(&format!("{regime} closed loop"), || {
            run(&fleet, &slot, &cfg, clients, per_client, swap_to)
        });
        println!(
            "  {regime}: p50 {} us, p99 {} us, {:.0} img/s, {swaps} swaps",
            report.p50_us, report.p99_us, report.throughput_ips
        );
        p99[i] = report.p99_us;
        let mut m = HashMap::new();
        m.insert("set".to_string(), Value::Str("swap_stall".to_string()));
        m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
        m.insert("regime".to_string(), Value::Str(regime.to_string()));
        m.insert("swaps".to_string(), Value::Num(swaps as f64));
        m.insert("clients".to_string(), Value::Num(clients as f64));
        m.insert("requests".to_string(), Value::Num(report.requests as f64));
        m.insert("images_per_sec".to_string(), Value::Num(report.throughput_ips));
        m.insert("p50_us".to_string(), Value::Num(report.p50_us as f64));
        m.insert("p99_us".to_string(), Value::Num(report.p99_us as f64));
        m.insert("reply_p99_us".to_string(), Value::Num(report.reply_p99_us as f64));
        rows.push(Value::Obj(m));
    }

    let stall = if p99[0] > 0 { p99[1] as f64 / p99[0] as f64 } else { 0.0 };
    println!("swap stall ratio (swapping p99 / steady p99): {stall:.3}");
    let mut m = HashMap::new();
    m.insert("set".to_string(), Value::Str("swap_stall_summary".to_string()));
    m.insert("smoke".to_string(), Value::Num(if smoke { 1.0 } else { 0.0 }));
    m.insert("steady_p99_us".to_string(), Value::Num(p99[0] as f64));
    m.insert("swapping_p99_us".to_string(), Value::Num(p99[1] as f64));
    m.insert("stall_ratio".to_string(), Value::Num(stall));
    rows.push(Value::Obj(m));

    let out_path = util::repo_root_path("BENCH_swap.json");
    std::fs::write(&out_path, Value::Arr(rows).to_string_compact())
        .expect("write BENCH_swap.json");
    println!("wrote {}", out_path.display());
}
