//! `qft::kernel` parity suite: the packed register-blocked kernel must be
//! bit-identical to an independent scalar reference on every shape —
//! ragged lanes (`n % NR != 0`), ragged tiles (`m < MR`), degenerate
//! `k = 0` / `n = 0`, single rows, reductions straddling the `KC` cache
//! block (`k >> KC`, `k % KC != 0`, `k < KC`), NaN/Inf weights masked by
//! zero activations across K-block boundaries — and through every
//! consumer: `matmul_slices(_par)`, `conv2d(_into_par)`, and the deployed
//! forwards, at 1/2/8 threads in both `lw` and `dch` modes.
//!
//! The integer kernels get the same treatment per dispatch path: every
//! path [`qft::kernel::supported_paths`] reports (scalar always; AVX2 /
//! VNNI / NEON where the host has them) must be BIT-identical to the
//! scalar twin for both the byte-panel (`gemm_i8`) and nibble-packed
//! (`gemm_w4`) kernels, on shapes covering `k >> KC`, `k % KC != 0`, odd
//! `k` (the W4 pair-packed tail), ragged lanes, and the depthwise `n = 1`
//! column, plus `PackedW4` pack/unpack round-trip and grouped-conv column
//! slicing properties.
//!
//! CI runs this file several ways: under default codegen, under
//! `RUSTFLAGS=-Ctarget-cpu=native`, and under forced `QFT_KERNEL=scalar` /
//! `QFT_KERNEL=avx2` legs, to catch any vectorization-, FMA-contraction-
//! or dispatch-dependent divergence between the kernels.

use qft::kernel::{
    gemm, gemm_i8, gemm_i8_with, gemm_ref, gemm_w4, gemm_w4_with, kernel_dispatch, kernel_path,
    supported_paths, KernelPath, PackedW, PackedW4, PackedWi8, KC, MR, NR,
};
use qft::par::{chunk_ranges_aligned, Pool};
use qft::quant::deploy::{DeployScratch, DeployedModel, Mode};
use qft::serve::synthetic_trainables;
use qft::tensor::conv::{conv2d, conv2d_packed_into, conv2d_par, ConvScratch, PackedConvW};
use qft::tensor::{matmul_slices, matmul_slices_par};
use qft::Tensor;

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = qft::data::Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn rand_tensor(shape: &[usize], seed: u64) -> Tensor {
    Tensor::new(shape.to_vec(), rand_vec(shape.iter().product(), seed))
}

/// Independent scalar reference (not the crate's): `kk` ascending, one mul
/// + one add per step, zero activations skipped.
fn naive(x: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xv = x[i * k + kk];
            if xv == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += xv * w[kk * n + j];
            }
        }
    }
    out
}

fn assert_bits_eq(want: &[f32], got: &[f32], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: element {i}: {a} vs {b}");
    }
}

#[test]
fn packed_kernel_matches_naive_on_edge_shapes() {
    // every m (ragged tiles), n (ragged lanes), k (incl. empty reduction)
    for &m in &[0usize, 1, 2, 3, MR, MR + 1, 2 * MR + 3, 17] {
        for &k in &[0usize, 1, 7, 64] {
            for &n in &[0usize, 1, 5, NR - 1, NR, NR + 1, 2 * NR + 7] {
                let seed = (m * 1000 + k * 50 + n) as u64;
                let mut x = rand_vec(m * k, seed);
                // sprinkle exact zeros so the skip path is exercised
                for (i, v) in x.iter_mut().enumerate() {
                    if i % 5 == 0 {
                        *v = 0.0;
                    }
                }
                let w = rand_vec(k * n, seed + 1);
                let pw = PackedW::pack(&w, k, n);
                assert_eq!((pw.k(), pw.n()), (k, n));
                let mut got = vec![f32::NAN; m * n];
                gemm(&x, m, &pw, &mut got);
                let want = naive(&x, m, k, &w, n);
                assert_bits_eq(&want, &got, &format!("gemm m={m} k={k} n={n}"));

                // and the crate's own scalar reference agrees too
                let mut refr = vec![0.0f32; m * n];
                gemm_ref(&x, k, &w, n, &mut refr);
                assert_bits_eq(&want, &refr, &format!("gemm_ref m={m} k={k} n={n}"));
            }
        }
    }
}

#[test]
fn zero_activations_mask_nan_inf_weights_everywhere() {
    let (m, k, n) = (2 * MR + 1, 9, NR + 5);
    let mut x = rand_vec(m * k, 11);
    let mut w = rand_vec(k * n, 12);
    // poison two whole weight rows; zero the matching activation columns
    for i in 0..m {
        x[i * k + 3] = 0.0;
        x[i * k + 8] = 0.0;
    }
    for j in 0..n {
        w[3 * n + j] = f32::NAN;
        w[8 * n + j] = f32::INFINITY;
    }
    let pw = PackedW::pack(&w, k, n);
    let mut got = vec![0.0f32; m * n];
    gemm(&x, m, &pw, &mut got);
    assert!(got.iter().all(|v| v.is_finite()), "masked poison must not leak");
    assert_bits_eq(&naive(&x, m, k, &w, n), &got, "nan/inf masking");
}

#[test]
fn kc_blocked_reduction_is_order_preserving_vs_naive() {
    // shapes straddling the KC reduction block: k >> KC, k % KC != 0,
    // k == KC exactly, k < KC, single row, and a narrow-panel (n < LANES)
    // case.  Zeros are sprinkled so the zero-activation skip crosses block
    // boundaries.  The KC-blocked kernel spills the accumulator tile to
    // `out` and reloads it between blocks — a lossless f32 round trip — so
    // every shape must stay BIT-identical to the independent naive loop,
    // serially and through the chunk-parallel entry points at 1/2/8
    // threads.
    for &(m, k, n) in &[
        (9usize, 4 * KC + 37, NR + 9),
        (MR + 3, KC + 1, 2 * NR + 1),
        (MR, KC, NR),
        (6, KC - 3, NR - 1),
        (1, 2 * KC, 7),
        (2 * MR + 1, 2 * KC + 5, 5),
    ] {
        let mut x = rand_vec(m * k, (k + n) as u64);
        for (i, v) in x.iter_mut().enumerate() {
            if i % 9 == 0 {
                *v = 0.0;
            }
        }
        let w = rand_vec(k * n, (k * 2 + n) as u64);
        let want = naive(&x, m, k, &w, n);

        let pw = PackedW::pack(&w, k, n);
        let mut got = vec![f32::NAN; m * n];
        gemm(&x, m, &pw, &mut got);
        assert_bits_eq(&want, &got, &format!("gemm m={m} k={k} n={n}"));

        let mut out = Vec::new();
        matmul_slices(&x, m, k, &w, n, &mut out);
        assert_bits_eq(&want, &out, &format!("matmul_slices k={k}"));

        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            let mut par = Vec::new();
            matmul_slices_par(&x, m, k, &w, n, &mut par, &pool);
            assert_bits_eq(&want, &par, &format!("k={k} {threads} threads"));
        }
    }
}

#[test]
fn nan_inf_zero_code_masking_survives_kc_block_boundaries() {
    // poison whole weight rows on both sides of every KC block boundary
    // (and at the very first / last kk); the matching all-zero activation
    // columns must keep masking them in EVERY k-block — a regression guard
    // for the skip path interacting with the accumulator spill/reload
    let (m, k, n) = (MR + 1, 3 * KC + 5, NR + 3);
    let mut x = rand_vec(m * k, 91);
    let mut w = rand_vec(k * n, 92);
    let poisoned = [0usize, KC - 1, KC, 2 * KC - 1, 2 * KC, 3 * KC + 4];
    for i in 0..m {
        for &kk in &poisoned {
            x[i * k + kk] = 0.0;
        }
    }
    for (pi, &kk) in poisoned.iter().enumerate() {
        for j in 0..n {
            w[kk * n + j] = match pi % 3 {
                0 => f32::NAN,
                1 => f32::INFINITY,
                _ => f32::NEG_INFINITY,
            };
        }
    }
    let pw = PackedW::pack(&w, k, n);
    let mut got = vec![0.0f32; m * n];
    gemm(&x, m, &pw, &mut got);
    assert!(got.iter().all(|v| v.is_finite()), "poison leaked across a block boundary");
    assert_bits_eq(&naive(&x, m, k, &w, n), &got, "kc masking");
}

#[test]
fn matmul_slices_matches_naive_and_scales_across_threads() {
    // deliberately MR/NR-unaligned so every chunk tail is ragged
    let (m, k, n) = (107usize, 33, NR + 9);
    let x = rand_vec(m * k, 21);
    let w = rand_vec(k * n, 22);
    let want = naive(&x, m, k, &w, n);

    let mut serial = Vec::new();
    matmul_slices(&x, m, k, &w, n, &mut serial);
    assert_bits_eq(&want, &serial, "matmul_slices");

    for threads in [1usize, 2, 8] {
        let pool = Pool::new(threads);
        let mut par = Vec::new();
        matmul_slices_par(&x, m, k, &w, n, &mut par, &pool);
        assert_bits_eq(&want, &par, &format!("matmul_slices_par {threads} threads"));
    }
}

#[test]
fn warm_buffer_reuse_never_leaks_stale_values() {
    // drive one output buffer through shrinking/growing shapes; the
    // write-mode kernel skips zero-fill, so stale-tail bugs would show here
    let mut out = Vec::new();
    // consecutive same-size shapes reuse the buffer without any zero-fill;
    // (8,2,6) -> (8,0,6) checks that an empty reduction still clears a
    // warm, non-zero buffer of the same length
    let shapes = [
        (12usize, 5usize, 9usize),
        (12, 5, 9),
        (3, 7, 33),
        (12, 5, 9),
        (1, 1, 1),
        (8, 2, 6),
        (8, 0, 6),
    ];
    for (i, (m, k, n)) in shapes.into_iter().enumerate() {
        let x = rand_vec(m * k, 31 + i as u64);
        let w = rand_vec(k * n, 41 + i as u64);
        matmul_slices(&x, m, k, &w, n, &mut out);
        assert_bits_eq(&naive(&x, m, k, &w, n), &out, &format!("reuse step {i}"));
    }
}

#[test]
fn conv_paths_agree_serial_packed_and_pooled() {
    // plain / strided / depthwise / grouped / even-kernel geometries
    let cases: &[(&[usize], &[usize], usize, usize)] = &[
        (&[2, 12, 12, 4], &[3, 3, 4, 8], 1, 1),
        (&[1, 16, 16, 3], &[3, 3, 3, 8], 2, 1),
        (&[2, 12, 12, 8], &[3, 3, 1, 8], 1, 8),
        (&[2, 12, 12, 8], &[3, 3, 4, 8], 1, 2),
        (&[1, 9, 9, 2], &[2, 2, 2, 4], 1, 1),
    ];
    for (i, (xs, ws, stride, groups)) in cases.iter().enumerate() {
        let x = rand_tensor(xs, 50 + i as u64);
        let w = rand_tensor(ws, 60 + i as u64);
        let bias: Vec<f32> = (0..ws[3]).map(|j| j as f32 * 0.1 - 0.3).collect();
        let want = conv2d(&x, &w, &bias, *stride, *groups);

        // prepacked serial
        let pw = PackedConvW::pack(&w, *groups);
        let mut out = Tensor::default();
        conv2d_packed_into(&x, &pw, &bias, *stride, &mut ConvScratch::new(), &mut out);
        assert_eq!(want.shape, out.shape, "case {i} packed shape");
        assert_bits_eq(&want.data, &out.data, &format!("case {i} packed"));

        // pooled at 1/2/8 threads
        for threads in [1usize, 2, 8] {
            let got = conv2d_par(&x, &w, &bias, *stride, *groups, &Pool::new(threads));
            assert_eq!(want.shape, got.shape, "case {i}, {threads} threads");
            assert_bits_eq(&want.data, &got.data, &format!("case {i}, {threads} threads"));
        }
    }
}

#[test]
fn deployed_forward_is_thread_and_packing_invariant_both_modes() {
    // the full acceptance matrix: serial vs pooled at 1/2/8 threads, lw +
    // dch, through the prepacked deployment path
    for mode in [Mode::Lw, Mode::Dch] {
        let (arch, tm) = synthetic_trainables(mode, 13);
        let model = DeployedModel::prepare(&arch, &tm, mode);
        let ds = qft::data::Dataset::new(2);
        let (xb, _, _) = ds.batch(qft::data::Split::Val, 0, 5);
        let want = model.forward_batch(&xb, &mut DeployScratch::new());
        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            let mut scratch = DeployScratch::new();
            let got = model.forward_batch_pooled(&xb, &mut scratch, &pool);
            assert_bits_eq(&want.data, &got.data, &format!("{mode:?} {threads} threads"));
            let again = model.forward_batch_pooled(&xb, &mut scratch, &pool);
            assert_bits_eq(&want.data, &again.data, &format!("{mode:?} {threads} warm"));
        }
    }
}

/// Random integer codes on the lw weight grid (`[-7, 7]`).
fn rand_codes(len: usize, seed: u64) -> Vec<i8> {
    let mut rng = qft::data::Rng::new(seed);
    (0..len).map(|_| (rng.normal() * 4.0).round().clamp(-7.0, 7.0) as i8).collect()
}

/// Independent integer reference: plain triple loop, exact i32 arithmetic.
fn naive_i8(x: &[i8], m: usize, k: usize, w: &[i8], n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xv = x[i * k + kk] as i32;
            for j in 0..n {
                out[i * n + j] += xv * w[kk * n + j] as i32;
            }
        }
    }
    out
}

/// Integer-shape sweep for the dispatch parity tests: KC straddles
/// (`k >> KC`, `k % KC != 0`, `k == KC`), odd `k` (the W4 pair-packed
/// tail), ragged lanes/tiles, single rows, and the depthwise `n = 1`
/// per-group GEMM column.
const INT_SHAPES: &[(usize, usize, usize)] = &[
    (9, 4 * KC + 37, NR + 9),
    (MR + 3, KC + 1, 2 * NR + 1),
    (MR, KC, NR),
    (6, KC - 3, NR - 1),
    (1, 2 * KC, 7),
    (2 * MR + 1, 129, 17),
    (7, 9, 1),
    (64, 27, 5),
    (3, 1, NR + 1),
];

#[test]
fn every_supported_path_is_bit_identical_to_naive_i8_and_w4() {
    // the tentpole acceptance matrix: every dispatch path the host supports
    // (scalar always; AVX2 / VNNI / NEON where present) must produce the
    // EXACT i32s of the independent naive loop, for both panel layouts
    let paths = supported_paths();
    assert_eq!(paths[0], KernelPath::Scalar, "scalar is the always-present fallback");
    assert!(paths.contains(&kernel_path()), "the picked path must be a supported one");
    for &(m, k, n) in INT_SHAPES {
        let x = rand_codes(m * k, (m * 7 + k * 3 + n) as u64);
        let w = rand_codes(k * n, (m + k * 5 + n * 11) as u64);
        let want = naive_i8(&x, m, k, &w, n);
        let pwi = PackedWi8::pack(&w, k, n);
        let pw4 = PackedW4::pack(&w, k, n);
        for &path in &paths {
            let mut got = vec![i32::MIN; m * n];
            gemm_i8_with(path, &x, m, &pwi, &mut got);
            assert_eq!(want, got, "i8 path {} diverged on m={m} k={k} n={n}", path.name());
            let mut got4 = vec![i32::MIN; m * n];
            gemm_w4_with(path, &x, m, &pw4, &mut got4);
            assert_eq!(want, got4, "W4 path {} diverged on m={m} k={k} n={n}", path.name());
        }
        // and the auto-dispatched entry points agree with all of the above
        let mut auto_i8 = vec![0i32; m * n];
        gemm_i8(&x, m, &pwi, &mut auto_i8);
        assert_eq!(want, auto_i8, "dispatched gemm_i8 m={m} k={k} n={n}");
        let mut auto_w4 = vec![0i32; m * n];
        gemm_w4(&x, m, &pw4, &mut auto_w4);
        assert_eq!(want, auto_w4, "dispatched gemm_w4 m={m} k={k} n={n}");
    }
}

#[test]
fn dispatch_pick_is_supported_and_honors_forcing() {
    let path = kernel_path();
    assert!(supported_paths().contains(&path));
    assert_eq!(kernel_dispatch(), path.name());
    // under the CI forced-dispatch legs this pins the env contract; when
    // QFT_KERNEL is unset it is vacuous
    if let Ok(forced) = std::env::var("QFT_KERNEL") {
        assert_eq!(path.name(), forced, "QFT_KERNEL={forced} must win the dispatch");
    }
}

#[test]
fn w4_pack_unpack_round_trips_on_odd_k_shapes() {
    // property: unpack(pack(w)) == w for every tail geometry the layout
    // has — odd k (pair tail), k % 8 (octet tail), k % KC (block tail) —
    // and the packed buffer really is ~half the i8 bytes
    for &(k, n) in &[
        (1usize, 1usize),
        (2, NR),
        (7, NR + 3),
        (8, 2 * NR + 1),
        (KC - 1, 5),
        (KC + 9, NR - 1),
        (2 * KC + 13, NR + 1),
    ] {
        let w = rand_codes(k * n, (k * 31 + n) as u64);
        let pw4 = PackedW4::pack(&w, k, n);
        assert_eq!((pw4.k(), pw4.n()), (k, n));
        assert_eq!(pw4.unpack(), w, "k={k} n={n} round trip");
        let pwi = PackedWi8::pack(&w, k, n);
        assert_eq!(pw4.col_sums(), pwi.col_sums(), "k={k} n={n} col_sums");
        // odd k rounds each panel's K-block tail up to a whole byte row,
        // so the halving bound carries one NR-row of slack per panel
        assert!(
            2 * pw4.packed_bytes() <= pwi.packed_bytes() + n.div_ceil(NR) * NR,
            "k={k} n={n}: W4 must halve the panel bytes (got {} vs {})",
            pw4.packed_bytes(),
            pwi.packed_bytes()
        );
    }
}

#[test]
fn w4_pack_cols_slices_grouped_conv_columns() {
    // grouped-conv packing slices columns `c0..c0+ncols` out of a wider
    // row-major matrix without materializing the dense sub-matrix; the
    // sliced pack must equal packing the extracted columns, odd k included
    let (k, stride) = (KC + 7, 24usize);
    let w = rand_codes(k * stride, 77);
    for &(c0, ncols) in &[(0usize, 8usize), (8, 8), (5, 7), (16, 8), (stride - 1, 1)] {
        let mut sliced = PackedW4::default();
        sliced.pack_cols(&w, k, stride, c0, ncols);
        let dense: Vec<i8> = (0..k)
            .flat_map(|kk| w[kk * stride + c0..kk * stride + c0 + ncols].iter().copied())
            .collect();
        let direct = PackedW4::pack(&dense, k, ncols);
        assert_eq!(sliced.unpack(), direct.unpack(), "c0={c0} ncols={ncols}");
        assert_eq!(sliced.col_sums(), direct.col_sums(), "c0={c0} ncols={ncols} sums");

        // and the kernel sees identical results through both packs
        let m = MR + 1;
        let x = rand_codes(m * k, (c0 * 13 + ncols) as u64);
        let want = naive_i8(&x, m, k, &dense, ncols);
        for &path in &supported_paths() {
            let mut got = vec![0i32; m * ncols];
            gemm_w4_with(path, &x, m, &sliced, &mut got);
            assert_eq!(want, got, "sliced W4 path {} c0={c0}", path.name());
        }
    }
}

#[test]
fn w4_full_nibble_range_is_exact_on_every_path() {
    // codes spanning the full two's-complement nibble range [-8, 7] —
    // including -8, which the lw grid never emits but the layout must
    // still decode exactly (sign-extension edge)
    let (m, k, n) = (5usize, 4 * 16 + 3, NR + 2);
    let w: Vec<i8> = (0..k * n).map(|i| (i % 16) as i8 - 8).collect();
    let x = rand_codes(m * k, 123);
    let pw4 = PackedW4::pack(&w, k, n);
    assert_eq!(pw4.unpack(), w);
    let want = naive_i8(&x, m, k, &w, n);
    for &path in &supported_paths() {
        let mut got = vec![0i32; m * n];
        gemm_w4_with(path, &x, m, &pw4, &mut got);
        assert_eq!(want, got, "nibble range on path {}", path.name());
    }
}

#[test]
fn mr_aligned_chunks_cover_and_align() {
    for (rows, width) in [(1usize, 8usize), (MR, 2), (10 * MR + 3, 8), (1000, 3)] {
        let ranges = chunk_ranges_aligned(rows, width, 1, MR);
        let mut next = 0;
        for (i, r) in ranges.iter().enumerate() {
            assert_eq!(r.start, next);
            if i + 1 < ranges.len() {
                assert_eq!(r.end % MR, 0, "interior boundaries sit on MR tiles");
            }
            next = r.end;
        }
        assert_eq!(next, rows);
    }
}
