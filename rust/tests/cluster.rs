//! `qft::cluster` integration tests: CRDT merge laws under randomized
//! interleavings (commutativity / associativity / idempotence, at-least-once
//! delivery, stale-replay-after-restart), codec totality over garbage and
//! bit-flipped encodings, stats frames over a live [`NetServer`], and the
//! headline end-to-end property — pooled requantize over two wire-served
//! replicas produces a deployment grid *bit-identical* to a single process
//! that saw the concatenated traffic.
//!
//! Hermetic — synthetic arch, ephemeral loopback ports, no AOT artifacts.
//! Server tests serialize on one mutex because [`qft::obs`] metrics are
//! process-global.

use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use qft::backend::BackendKind;
use qft::cluster::{self, ClusterStats, ReplicaId, STATS_VERSION};
use qft::data::{Dataset, Rng, Split};
use qft::net::frame::{self, TY_STATS_DELTA, TY_STATS_PULL};
use qft::net::{Frame, NetConfig, NetServer};
use qft::obs::{Exposition, Format};
use qft::quant::deploy::{requantize_trainables, Mode};
use qft::serve::{Engine, Fleet, FleetOptions, ServeConfig};

/// Server tests share the process-global obs registry — run one at a time.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// One-slot synthetic lw-int fleet, shadow-capturing every micro-batch.
fn load_lw_shadowed() -> Arc<Fleet> {
    Fleet::load_with(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
        FleetOptions { shadow_every: 1 },
    )
    .unwrap()
}

/// Drive val images `lo..hi` through a server over one connection, closed
/// loop, asserting every reply echoes its request id.
fn drive(addr: SocketAddr, lo: u64, hi: u64) {
    let ds = Dataset::new(0);
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    for i in lo..hi {
        let (img, _) = ds.sample(Split::Val, i);
        let req = Frame::Infer { id: i, slot_key: "synthetic/lw".to_string(), image: img };
        frame::write_frame(&mut stream, &req).unwrap();
        match frame::read_frame(&mut stream).unwrap() {
            Frame::Reply { id, .. } => assert_eq!(id, i, "reply id echo"),
            other => panic!("image {i}: expected reply, got {other:?}"),
        }
    }
}

// ------------------------------------------------------------- CRDT laws

/// A small random delta touching a handful of counters and (sometimes) a
/// calibration range lattice — the raw material for the law tests.
fn random_delta(rng: &mut Rng) -> ClusterStats {
    let mut s = ClusterStats::new();
    for _ in 0..(rng.next_u64() % 5) {
        let name = format!("ctr/{}", rng.next_u64() % 3);
        s.observe(&name, ReplicaId(1 + rng.next_u64() % 4), rng.next_u64() % 1000);
    }
    if rng.next_u64() % 2 == 0 {
        let rd = s.calib.entry(format!("slot/{}", rng.next_u64() % 2)).or_default();
        for _ in 0..(rng.next_u64() % 3) {
            let n_ch = 1 + rng.next_u64() % 3;
            let ch: Vec<(f32, f32)> = (0..n_ch)
                .map(|_| {
                    let a = rng.uniform() * 4.0 - 2.0;
                    let b = rng.uniform() * 4.0 - 2.0;
                    (a.min(b), a.max(b))
                })
                .collect();
            rd.ranges.insert((rng.next_u64() % 3) as u32, ch);
        }
        rd.shadow_batches.observe(ReplicaId(1 + rng.next_u64() % 4), rng.next_u64() % 50);
        rd.shadow_images.observe(ReplicaId(1 + rng.next_u64() % 4), rng.next_u64() % 400);
    }
    s
}

#[test]
fn merge_is_commutative_associative_and_idempotent() {
    let mut rng = Rng::new(0xC1D7);
    for case in 0..200 {
        let a = random_delta(&mut rng);
        let b = random_delta(&mut rng);
        let c = random_delta(&mut rng);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "case {case}: a∪b != b∪a");

        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "case {case}: (a∪b)∪c != a∪(b∪c)");

        let mut aa = a.clone();
        aa.merge(&a);
        assert_eq!(aa, a, "case {case}: a∪a != a");

        // absorption: re-delivering any already-merged delta is a no-op,
        // which is exactly what makes at-least-once transport safe
        let mut again = ab_c.clone();
        again.merge(&b);
        assert_eq!(again, ab_c, "case {case}: duplicate delivery changed state");
    }
}

#[test]
fn merged_totals_equal_per_replica_sums_without_double_counting() {
    let mut rng = Rng::new(0xFEED);
    for case in 0..100 {
        // three replicas each publish a growing sequence of state snapshots
        let replicas = [ReplicaId(1), ReplicaId(2), ReplicaId(3)];
        let mut truth = [0u64; 3];
        let mut deltas: Vec<ClusterStats> = Vec::new();
        for _round in 0..5 {
            for (i, &r) in replicas.iter().enumerate() {
                truth[i] += rng.next_u64() % 10;
                let mut d = ClusterStats::new();
                d.observe("requests", r, truth[i]);
                deltas.push(d);
            }
        }
        // the aggregator sees them in a random order, many more than once
        let mut merged = ClusterStats::new();
        for _ in 0..deltas.len() * 3 {
            merged.merge(&deltas[(rng.next_u64() as usize) % deltas.len()]);
        }
        for d in &deltas {
            merged.merge(d); // guarantee each final snapshot landed
        }
        assert_eq!(
            merged.counter("requests"),
            truth.iter().sum::<u64>(),
            "case {case}: merged total != sum of per-replica maxima"
        );
        for (i, &r) in replicas.iter().enumerate() {
            assert_eq!(merged.counters["requests"].entry(r), truth[i], "case {case} replica {i}");
        }
    }
}

#[test]
fn stale_delta_replayed_after_restart_is_a_noop() {
    // a replica reports 10 requests, restarts under a fresh id, reports 4;
    // the pre-restart delta arriving late must change nothing
    let old = ReplicaId(0xAA);
    let new = ReplicaId(0xBB);
    let mut pre = ClusterStats::new();
    pre.observe("requests", old, 10);
    let mut post = ClusterStats::new();
    post.observe("requests", new, 4);

    let mut merged = ClusterStats::new();
    merged.merge(&pre);
    merged.merge(&post);
    let before = merged.clone();
    merged.merge(&pre); // stale replay
    assert_eq!(merged, before, "stale replay mutated merged state");
    assert_eq!(merged.counter("requests"), 14, "restart must not erase history");
}

// ------------------------------------------------------------ stats codec

#[test]
fn stats_codec_round_trips_random_states() {
    let mut rng = Rng::new(0x50DA);
    for case in 0..200 {
        let mut s = random_delta(&mut rng);
        s.merge(&random_delta(&mut rng));
        let bytes = s.encode();
        let back = ClusterStats::decode(&bytes)
            .unwrap_or_else(|e| panic!("case {case}: decode failed: {e}"));
        assert_eq!(back, s, "case {case}: round-trip identity");
    }
}

#[test]
fn stats_decode_is_total_over_garbage_and_bit_flips() {
    let mut rng = Rng::new(0xD00F);
    for _ in 0..4000 {
        let n = (rng.next_u64() % 160) as usize;
        let buf: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = ClusterStats::decode(&buf); // must never panic
    }
    // every single-bit corruption of a valid encoding either still decodes
    // or errors — it never panics and never over-reads
    let mut s = ClusterStats::new();
    s.observe("engine/submitted", ReplicaId(1), 7);
    let rd = s.calib.entry("synthetic/lw".to_string()).or_default();
    rd.ranges.insert(0, vec![(-1.0, 1.0), (-0.5, 2.0)]);
    rd.shadow_batches.observe(ReplicaId(1), 3);
    let bytes = s.encode();
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut m = bytes.clone();
            m[i] ^= 1 << bit;
            let _ = ClusterStats::decode(&m);
        }
    }
}

#[test]
fn stats_frames_round_trip_on_the_wire_codec() {
    let mut rng = Rng::new(0xAB1E);
    let mut delta = random_delta(&mut rng);
    delta.observe("net/shed", ReplicaId(9), 2);
    for f in [
        Frame::StatsPull { id: 11 },
        Frame::StatsDelta { id: 12, delta },
        Frame::StatsAck { id: 13, replicas: vec![1, 5, 9] },
    ] {
        let bytes = f.encode();
        let (back, used) = frame::decode(&bytes).expect("stats frame decodes");
        assert_eq!(used, bytes.len(), "consumed length");
        assert_eq!(back, f, "wire round-trip identity");
    }
    // payloads shorter than the version byte are typed errors, not panics
    assert!(frame::decode_payload(TY_STATS_PULL, 0, &[]).is_err());
    assert!(frame::decode_payload(TY_STATS_DELTA, 0, &[STATS_VERSION]).is_err());
}

// ----------------------------------------------------- exposition surface

#[test]
fn cluster_stats_render_all_three_formats() {
    let mut s = ClusterStats::new();
    s.observe("net/shed", ReplicaId(2), 3);
    s.observe("slot/synthetic/lw/v1/requests", ReplicaId(2), 40);
    let rd = s.calib.entry("synthetic/lw".to_string()).or_default();
    rd.ranges.insert(4, vec![(-0.5, 0.5)]);
    rd.shadow_batches.observe(ReplicaId(2), 1);

    qft::obs::validate_prometheus(&s.render(Format::Prometheus)).expect("prometheus well-formed");
    let table = s.render(Format::Table);
    assert!(table.contains("net/shed"), "table lists counters:\n{table}");
    let json = s.render(Format::Json);
    let v = qft::util::json::Value::parse(&json).expect("json parses");
    assert!(v.get("counters").is_ok(), "json carries counters:\n{json}");
}

// --------------------------------------------------------- live transport

#[test]
fn live_server_answers_pull_and_absorbs_push() {
    let _guard = obs_lock();
    let fleet = load_lw_shadowed();
    let cfg = ServeConfig { workers: 1, ..Default::default() };
    let server = NetServer::start(Engine::start(fleet, &cfg), &NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let me = server.cluster().replica();

    drive(server.local_addr(), 0, 4);

    let stats = cluster::pull_stats(&addr, Duration::from_secs(10)).unwrap();
    assert_eq!(stats.counter("slot/synthetic/lw/v1/requests"), 4);
    assert!(stats.replicas().contains(&me), "pull reports the serving replica");
    assert!(stats.calib.contains_key("synthetic/lw"), "shadowed ranges ride along");

    // push a foreign delta: the ack names both replicas, a re-pull carries
    // the merged count, and replaying the same delta never double counts
    let peer = ReplicaId(0x5EED);
    let mut foreign = ClusterStats::new();
    foreign.observe("slot/synthetic/lw/v1/requests", peer, 10);
    let known = cluster::push_stats(&addr, &foreign, Duration::from_secs(10)).unwrap();
    assert!(known.contains(&peer) && known.contains(&me), "ack lists known replicas");
    for _replay in 0..3 {
        cluster::push_stats(&addr, &foreign, Duration::from_secs(10)).unwrap();
    }
    let again = cluster::pull_stats(&addr, Duration::from_secs(10)).unwrap();
    assert_eq!(again.counter("slot/synthetic/lw/v1/requests"), 14, "no double counting");

    server.shutdown(Duration::from_secs(10));
}

// ------------------------------------------------------- the headline e2e

/// Two wire-served replicas each shadow half the traffic; pooling their
/// CRDT range deltas and requantizing must match — bit for bit — a single
/// process that served the concatenated stream.
#[test]
fn pooled_requantize_is_bit_identical_to_single_process() {
    let _guard = obs_lock();
    const N: u64 = 24;
    let cfg = ServeConfig { workers: 1, ..Default::default() };

    // replica A serves images 0..N, replica B serves N..2N
    let fleet_a = load_lw_shadowed();
    let fleet_b = load_lw_shadowed();
    let server_a =
        NetServer::start(Engine::start(fleet_a.clone(), &cfg), &NetConfig::default()).unwrap();
    let server_b =
        NetServer::start(Engine::start(fleet_b.clone(), &cfg), &NetConfig::default()).unwrap();
    drive(server_a.local_addr(), 0, N);
    drive(server_b.local_addr(), N, 2 * N);

    // the reference: one process sees all 2N images in order
    let fleet_all = load_lw_shadowed();
    let engine_all = Engine::start(fleet_all.clone(), &cfg);
    let client = engine_all.client();
    let ds = Dataset::new(0);
    for i in 0..2 * N {
        client.infer(0, ds.sample(Split::Val, i).0).unwrap();
    }
    engine_all.shutdown();

    let addr_a = server_a.local_addr().to_string();
    let addr_b = server_b.local_addr().to_string();
    let merged =
        cluster::pull_merged(&[addr_a.as_str(), addr_b.as_str()], Duration::from_secs(10)).unwrap();
    server_a.shutdown(Duration::from_secs(10));
    server_b.shutdown(Duration::from_secs(10));

    // counters: the merged total is exactly the sum over replicas
    assert!(merged.replicas().len() >= 2, "both replicas represented");
    assert_eq!(merged.counter("slot/synthetic/lw/v1/requests"), 2 * N);

    // ranges: pooled lattice == single-process accumulator, bit for bit
    let delta = merged.calib.get("synthetic/lw").expect("both replicas shadowed");
    assert_eq!(delta.shadow_images.value(), 2 * N, "every image was shadowed");
    let pooled = delta.absmax();
    let single = fleet_all.slot(0).unwrap().calib().unwrap().absmax();
    assert_eq!(pooled.len(), single.len(), "same captured value set");
    for (v, want) in &single {
        let got = &pooled[v];
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "value {v}: pooled absmax diverged from single-process absmax"
        );
    }

    // and the deployment grids rebuilt from them are bit-identical too
    let slot = fleet_all.slot(0).unwrap();
    let v1 = slot.primary();
    let grid_pooled = requantize_trainables(&slot.arch, &v1.params, &pooled, Mode::Lw);
    let grid_single = requantize_trainables(&slot.arch, &v1.params, &single, Mode::Lw);
    assert_eq!(grid_pooled.0.len(), grid_single.0.len());
    for (name, want) in &grid_single.0 {
        let got = &grid_pooled.0[name];
        assert_eq!(got.shape, want.shape, "tensor {name}: shape");
        assert_eq!(
            got.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "tensor {name}: pooled grid != single-process grid"
        );
    }
}
