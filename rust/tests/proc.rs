//! Two-process cluster smoke: spawn two real `repro serve --listen` replicas
//! as child processes, drive each over TCP, then run `repro stats --pull`
//! against both and check the aggregator's merged counter is exactly the sum
//! of what the two processes served — the CRDT pipeline end to end, across
//! real process boundaries (no shared obs registry to lean on).
//!
//! Kept to one test so CI pays the two-child startup cost once.

use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};

use qft::data::{Dataset, Split};
use qft::net::frame;
use qft::net::Frame;

/// Images driven through each replica.
const K: u64 = 8;

/// Kills the replica when the test ends, pass or fail.
struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Spawn one serving replica on an ephemeral port and wait for it to print
/// its bound address (`serving synthetic/lw on ADDR (...)`).
fn spawn_replica() -> (KillOnDrop, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["serve", "--listen", "127.0.0.1:0", "--serve-secs", "600", "--shadow-every", "1"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn repro serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut lines = BufReader::new(stdout).lines();
    let banner = loop {
        match lines.next() {
            Some(Ok(l)) if l.starts_with("serving ") => break l,
            Some(Ok(_)) => continue,
            other => panic!("replica exited before announcing its address: {other:?}"),
        }
    };
    let addr = banner.split_whitespace().nth(3).expect("address token in banner").to_string();
    (KillOnDrop(child), addr)
}

/// Drive val images `lo..hi` through a replica, closed loop.
fn drive(addr: &str, lo: u64, hi: u64) {
    let ds = Dataset::new(0);
    let mut stream = TcpStream::connect(addr).expect("connect to replica");
    stream.set_nodelay(true).unwrap();
    for i in lo..hi {
        let (img, _) = ds.sample(Split::Val, i);
        let req = Frame::Infer { id: i, slot_key: "synthetic/lw".to_string(), image: img };
        frame::write_frame(&mut stream, &req).unwrap();
        match frame::read_frame(&mut stream).unwrap() {
            Frame::Reply { id, .. } => assert_eq!(id, i, "reply id echo"),
            other => panic!("image {i}: expected reply, got {other:?}"),
        }
    }
}

#[test]
fn stats_pull_aggregates_two_real_processes() {
    let (_guard_a, addr_a) = spawn_replica();
    let (_guard_b, addr_b) = spawn_replica();
    drive(&addr_a, 0, K);
    drive(&addr_b, K, 2 * K);

    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["stats", "--pull", &format!("{addr_a},{addr_b}")])
        .output()
        .expect("run repro stats --pull");
    assert!(
        out.status.success(),
        "stats --pull failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);

    // header counts both replicas
    let head = text.lines().next().unwrap_or_default();
    assert!(head.starts_with("cluster stats: 2 replicas"), "header: {head}");

    // merged request counter row: `  NAME  TOTAL  hex=n hex=n`
    let row = text
        .lines()
        .find(|l| l.trim_start().starts_with("slot/synthetic/lw/v1/requests"))
        .unwrap_or_else(|| panic!("no merged requests row in:\n{text}"));
    let total: u64 = row
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("unparseable total in row: {row}"));
    assert_eq!(total, 2 * K, "merged total != images served across both processes");

    // both processes shadowed every image, so pooled ranges rode along
    assert!(
        text.contains("== calib synthetic/lw:"),
        "no pooled calib section in:\n{text}"
    );
    assert!(
        text.contains(&format!("{} images", 2 * K)),
        "pooled shadow image count missing in:\n{text}"
    );
}
